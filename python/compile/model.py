"""L2 JAX model: full MCMC steps composed from the L1 Pallas kernels.

These are the computations the Rust runtime executes via PJRT after
``aot.py`` lowers them to HLO text — the *software baseline* path the
paper profiles on CPU/GPU (Fig. 5d, Fig. 14) re-expressed for this
testbed. Python never runs at request time; every entry point here is
lowered once at build time with fixed shapes recorded in the artifact
manifest.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.gumbel import gumbel_argmax
from compile.kernels.ising import ising_halfstep
from compile.kernels.pas import maxcut_delta_e


def gumbel_sample(energies, uniforms, beta):
    """Batched categorical sampling (the SU in isolation).

    Args: energies (B, N) f32; uniforms (B, N) f32; beta scalar f32.
    Returns: (B,) f32 float-encoded indices — wrapped in a 1-tuple for
    the AOT interchange.
    """
    return (gumbel_argmax(energies, uniforms, beta),)


def ising_step(spins, u_black, u_white, beta, coupling):
    """One full Block-Gibbs sweep = black half-step + white half-step.

    The chessboard decomposition is exactly the Fig. 10(b) schedule.

    Args:
      spins: (H, W) f32 ±1.
      u_black, u_white: (H, W) f32 uniforms for the two half-steps.
      beta, coupling: scalar f32.

    Returns:
      1-tuple of (H, W) f32 updated spins.
    """
    s1 = ising_halfstep(spins, u_black, beta, coupling, 0.0)
    s2 = ising_halfstep(s1, u_white, beta, coupling, 1.0)
    return (s2,)


@functools.partial(jax.jit, static_argnames=("num_steps",))
def ising_chain(spins, uniforms, beta, coupling, *, num_steps):
    """``num_steps`` full sweeps with pre-supplied noise.

    ``uniforms`` has shape (num_steps, 2, H, W). Chain iteration happens
    *inside* the compiled module (lax.scan), so one PJRT call advances
    the whole chain segment — this is what makes the measured-CPU
    baseline fair (no per-step dispatch overhead).
    """

    def body(s, u):
        (s2,) = ising_step(s, u[0], u[1], beta, coupling)
        return s2, jnp.sum(s2)

    final, mags = jax.lax.scan(body, spins, uniforms)
    return (final, mags)


def maxcut_pas_step(adj, x, uniforms, beta, *, num_flips):
    """Hardware-style PAS step (Fig. 10c): ΔE pass + Gumbel top-L flip.

    Args:
      adj: (N, N) f32.
      x: (N,) f32 {0,1}.
      uniforms: (N,) f32 in (0, 1].
      beta: scalar f32.
      num_flips: static L.

    Returns:
      1-tuple of (N,) f32 updated labels.
    """
    delta_e = maxcut_delta_e(adj, x)
    gumbel = -jnp.log(-jnp.log(uniforms))
    scores = -0.5 * beta * delta_e + gumbel
    # Top-L via an unrolled argmax + mask loop instead of lax.top_k:
    # the interchange XLA (0.5.1) HLO parser predates the `largest`
    # attribute that jax's TopK custom-call emits. L is small and
    # static, so the unroll costs L reductions.
    flip = jnp.zeros_like(x)
    for _ in range(num_flips):
        idx = jnp.argmax(scores)
        flip = flip.at[idx].set(1.0)
        scores = scores.at[idx].set(-jnp.inf)
    return (jnp.abs(x - flip),)


@functools.partial(jax.jit, static_argnames=("num_flips", "num_steps"))
def maxcut_pas_chain(adj, x, uniforms, beta, *, num_flips, num_steps):
    """``num_steps`` PAS steps inside one compiled module.

    ``uniforms``: (num_steps, N). Returns the final labels and the
    per-step cut-proxy trace (sum of ΔE magnitudes).
    """

    def body(state, u):
        (nx,) = maxcut_pas_step(adj, state, u, beta, num_flips=num_flips)
        return nx, jnp.sum(nx)

    final, trace = jax.lax.scan(body, x, uniforms)
    return (final, trace)
