"""AOT lowering: JAX/Pallas entry points → HLO text artifacts.

HLO **text** (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust
side's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.txt``
describing argument shapes, one line per artifact::

    name|in0_shape:dtype,in1_shape:dtype,...|out_count|static_params
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Fixed AOT shapes (recorded in the manifest; the Rust runtime asserts
# against them before execution).
GUMBEL_BATCH = 64
GUMBEL_DIST = 256
ISING_H = 64
ISING_W = 64
ISING_CHAIN_STEPS = 32
MAXCUT_N = 128
MAXCUT_FLIPS = 8
MAXCUT_CHAIN_STEPS = 32


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entrypoints():
    """(name, jitted fn, example args, static-param note) tuples."""
    scalar = f32()
    return [
        (
            "gumbel_sample",
            jax.jit(model.gumbel_sample),
            (f32(GUMBEL_BATCH, GUMBEL_DIST), f32(GUMBEL_BATCH, GUMBEL_DIST), scalar),
            f"B={GUMBEL_BATCH},N={GUMBEL_DIST}",
        ),
        (
            "ising_step",
            jax.jit(model.ising_step),
            (
                f32(ISING_H, ISING_W),
                f32(ISING_H, ISING_W),
                f32(ISING_H, ISING_W),
                scalar,
                scalar,
            ),
            f"H={ISING_H},W={ISING_W}",
        ),
        (
            "ising_chain",
            jax.jit(
                lambda s, u, b, c: model.ising_chain(
                    s, u, b, c, num_steps=ISING_CHAIN_STEPS
                )
            ),
            (
                f32(ISING_H, ISING_W),
                f32(ISING_CHAIN_STEPS, 2, ISING_H, ISING_W),
                scalar,
                scalar,
            ),
            f"H={ISING_H},W={ISING_W},steps={ISING_CHAIN_STEPS}",
        ),
        (
            "maxcut_pas_step",
            jax.jit(
                lambda a, x, u, b: model.maxcut_pas_step(
                    a, x, u, b, num_flips=MAXCUT_FLIPS
                )
            ),
            (f32(MAXCUT_N, MAXCUT_N), f32(MAXCUT_N), f32(MAXCUT_N), scalar),
            f"N={MAXCUT_N},L={MAXCUT_FLIPS}",
        ),
        (
            "maxcut_pas_chain",
            jax.jit(
                lambda a, x, u, b: model.maxcut_pas_chain(
                    a, x, u, b, num_flips=MAXCUT_FLIPS, num_steps=MAXCUT_CHAIN_STEPS
                )
            ),
            (
                f32(MAXCUT_N, MAXCUT_N),
                f32(MAXCUT_N),
                f32(MAXCUT_CHAIN_STEPS, MAXCUT_N),
                scalar,
            ),
            f"N={MAXCUT_N},L={MAXCUT_FLIPS},steps={MAXCUT_CHAIN_STEPS}",
        ),
    ]


def spec_str(spec):
    shape = "x".join(str(d) for d in spec.shape) if spec.shape else "scalar"
    return f"{shape}:f32"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, example_args, static in entrypoints():
        lowered = fn.lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_out = len(lowered.out_info) if hasattr(lowered, "out_info") else 1
        ins = ",".join(spec_str(s) for s in example_args)
        manifest_lines.append(f"{name}|{ins}|{n_out}|{static}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
