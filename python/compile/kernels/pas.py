"""L1 Pallas kernel: PAS ΔE gradient pass for MaxCut.

Computes the flip gradients ``ΔE_i = -s_i · (A s)_i`` (eq. 2 of the
paper specialized to MaxCut) as a row-tiled matrix-vector product —
the TPU adaptation of the paper's multi-cycle CU ``Compute`` phase
(Fig. 10c): each grid step reduces one (block_rows × N) tile, which is
the MXU-friendly layout for the dense adjacency.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(adj_ref, s_ref, sblk_ref, o_ref):
    adj = adj_ref[...]
    s = s_ref[...]
    field = adj @ s  # (block_rows,)
    o_ref[...] = -sblk_ref[...] * field


@functools.partial(jax.jit, static_argnames=("block_rows",))
def maxcut_delta_e(adj, x, *, block_rows=16):
    """ΔE of flipping each vertex of a MaxCut instance.

    Args:
      adj: (N, N) f32 symmetric weighted adjacency, zero diagonal,
        N divisible by ``block_rows``.
      x: (N,) f32 of {0, 1} side labels.
      block_rows: tile height (static).

    Returns:
      (N,) f32 flip gradients.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n)
    assert n % block_rows == 0, f"N={n} not divisible by block {block_rows}"
    s = 2.0 * x - 1.0
    grid = (n // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(adj, s, s)
