"""Pure-jnp oracles for the Pallas kernels.

Every L1 kernel in this package has a reference implementation here;
pytest (``python/tests/``) sweeps shapes and dtypes with hypothesis and
asserts allclose between the kernel (interpret mode) and these
references. This is the core correctness signal for the compile path.
"""

import jax.lax as lax
import jax.numpy as jnp


def gumbel_argmax_ref(energies, uniforms, beta):
    """Gumbel-max categorical sampling from unnormalized energies.

    Args:
      energies: (B, N) f32 — unnormalized energies (lower = likelier).
      uniforms: (B, N) f32 in (0, 1] — the hardware URNG stream.
      beta: scalar f32 — inverse temperature.

    Returns:
      (B,) f32 — sampled state index per row (float-encoded for the
      AOT interchange; values are exact small integers).
    """
    gumbel = -jnp.log(-jnp.log(uniforms))
    scores = -beta * energies + gumbel
    return jnp.argmax(scores, axis=-1).astype(jnp.float32)


def ising_local_field_ref(spins, coupling):
    """Per-site neighbor field of a 2D Ising grid.

    Zero-padded 4-neighborhood sum of the ±1 spin lattice:
    ``field[r, c] = coupling * Σ_{nbr} spins[nbr]``; the local energies
    of site (r, c) are then ``E(s) = -s * field`` for s ∈ {-1, +1}.

    Args:
      spins: (H, W) f32 of ±1 values.
      coupling: scalar f32.

    Returns:
      (H, W) f32 — coupling-scaled neighbor field.
    """
    up = jnp.pad(spins, ((1, 0), (0, 0)))[:-1, :]
    down = jnp.pad(spins, ((0, 1), (0, 0)))[1:, :]
    left = jnp.pad(spins, ((0, 0), (1, 0)))[:, :-1]
    right = jnp.pad(spins, ((0, 0), (0, 1)))[:, 1:]
    return coupling * (up + down + left + right)


def maxcut_delta_e_ref(adj, x):
    """MaxCut flip gradients: ΔE_i of flipping vertex i.

    With spins ``s = 2x - 1`` and energy ``E = -cut_weight``:
    ``ΔE_i = -s_i * Σ_j adj[i, j] * s_j``.

    Args:
      adj: (N, N) f32 symmetric weighted adjacency (zero diagonal).
      x: (N,) f32 of {0, 1} side labels.

    Returns:
      (N,) f32 — energy change of flipping each vertex.
    """
    s = 2.0 * x - 1.0
    return -s * (adj @ s)


def ising_gibbs_halfstep_ref(spins, uniforms, beta, coupling, parity):
    """One chessboard half-sweep of Gibbs on a ±1 Ising grid.

    Sites with ``(r + c) % 2 == parity`` are resampled from their full
    conditional via the logistic (two-state Gumbel) form; other sites
    pass through.

    Args:
      spins: (H, W) f32 ±1.
      uniforms: (H, W) f32 in (0, 1).
      beta, coupling: scalars.
      parity: 0 or 1 (python int — static).

    Returns:
      (H, W) f32 — updated spins.
    """
    h, w = spins.shape
    field = ising_local_field_ref(spins, coupling)
    # P(s = +1 | field) = sigmoid(2 β field)
    p_up = 1.0 / (1.0 + jnp.exp(-2.0 * beta * field))
    proposed = jnp.where(uniforms < p_up, 1.0, -1.0)
    rr = jnp.arange(h)[:, None]
    cc = jnp.arange(w)[None, :]
    mask = ((rr + cc) % 2) == parity
    return jnp.where(mask, proposed, spins)


def pas_flip_step_ref(adj, x, uniforms, beta, num_flips):
    """Hardware-style PAS step for MaxCut: ΔE pass + Gumbel top-L flip.

    The indices of the ``num_flips`` most "dynamic" vertices are drawn
    by perturbing the proposal logits ``-β/2·ΔE`` with Gumbel noise and
    taking the top-L (the Gumbel-top-k trick = sampling L indices
    without replacement from the softmax), then those vertices flip.
    This is the accelerator's schedule of Fig. 10(c); the full MH
    correction lives on the Rust side.

    Args:
      adj: (N, N) f32 adjacency.
      x: (N,) f32 {0,1}.
      uniforms: (N,) f32 in (0, 1].
      beta: scalar.
      num_flips: static int L.

    Returns:
      (N,) f32 — updated labels.
    """
    delta_e = maxcut_delta_e_ref(adj, x)
    gumbel = -jnp.log(-jnp.log(uniforms))
    scores = -0.5 * beta * delta_e + gumbel
    _, idx = lax.top_k(scores, num_flips)
    flip = jnp.zeros_like(x).at[idx].set(1.0)
    return jnp.abs(x - flip)
