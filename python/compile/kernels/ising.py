"""L1 Pallas kernel: fused Ising chessboard Gibbs half-step.

The kernel is the tightly-coupled CU+SU pipeline of Fig. 2(b) in vector
form: per site it accumulates the neighbor field (the CU's reduced-sum),
converts to the two-state conditional via the logistic closed form of
the Gumbel compare (the SU), and commits only the active chessboard
parity. The four shifted spin planes are prepared by the L2 model
(cheap XLA data movement); the kernel fuses the arithmetic hot-spot and
is tiled in row blocks sized for VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, up_ref, dn_ref, lf_ref, rt_ref, u_ref, scal_ref, o_ref):
    beta = scal_ref[0]
    coupling = scal_ref[1]
    parity = scal_ref[2]
    block_rows = o_ref.shape[0]
    base_row = pl.program_id(0) * block_rows

    spins = c_ref[...]
    field = coupling * (up_ref[...] + dn_ref[...] + lf_ref[...] + rt_ref[...])
    # Two-state Gumbel compare == logistic rule:
    # P(s=+1) = sigmoid(2 β field).
    p_up = 1.0 / (1.0 + jnp.exp(-2.0 * beta * field))
    proposed = jnp.where(u_ref[...] < p_up, 1.0, -1.0)

    rows = base_row + jax.lax.broadcasted_iota(jnp.float32, spins.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.float32, spins.shape, 1)
    site_parity = jnp.mod(rows + cols, 2.0)
    active = site_parity == parity
    o_ref[...] = jnp.where(active, proposed, spins)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ising_halfstep(spins, uniforms, beta, coupling, parity, *, block_rows=16):
    """One chessboard half-sweep over a ±1 spin grid.

    Args:
      spins: (H, W) f32 of ±1, H divisible by ``block_rows``.
      uniforms: (H, W) f32 in (0, 1).
      beta, coupling: scalar f32.
      parity: scalar f32 (0.0 or 1.0) — which chessboard color updates.
      block_rows: VMEM tile height (static).

    Returns:
      (H, W) f32 updated spins.
    """
    h, w = spins.shape
    assert h % block_rows == 0, f"H={h} not divisible by block {block_rows}"
    up = jnp.pad(spins, ((1, 0), (0, 0)))[:-1, :]
    down = jnp.pad(spins, ((0, 1), (0, 0)))[1:, :]
    left = jnp.pad(spins, ((0, 0), (1, 0)))[:, :-1]
    right = jnp.pad(spins, ((0, 0), (0, 1)))[:, 1:]
    scal = jnp.stack(
        [
            jnp.asarray(beta, jnp.float32),
            jnp.asarray(coupling, jnp.float32),
            jnp.asarray(parity, jnp.float32),
        ]
    )
    grid = (h // block_rows,)
    plane = pl.BlockSpec((block_rows, w), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[plane, plane, plane, plane, plane, plane, pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=plane,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(spins, up, down, left, right, uniforms, scal)
