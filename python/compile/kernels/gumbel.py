"""L1 Pallas kernel: batched Gumbel-max categorical sampling.

This is the paper's Gumbel Sampler Unit (§V-D) expressed for a vector
machine: each row of unnormalized energies is perturbed with Gumbel
noise (derived from a supplied uniform stream, mirroring the hardware
URNG→LUT path) and reduced with argmax. Rows are tiled over the grid so
each block fits comfortably in VMEM (TPU adaptation: the SE comparator
chain becomes a lane-parallel argmax reduction).

Pallas runs in ``interpret=True`` throughout: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain
HLO that the Rust runtime loads (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(e_ref, u_ref, scal_ref, o_ref):
    """One block: scores = -beta * E + Gumbel(u); out = argmax."""
    beta = scal_ref[0]
    e = e_ref[...]
    u = u_ref[...]
    gumbel = -jnp.log(-jnp.log(u))
    scores = -beta * e + gumbel
    o_ref[...] = jnp.argmax(scores, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gumbel_argmax(energies, uniforms, beta, *, block_rows=16):
    """Sample one index per row of ``energies``.

    Args:
      energies: (B, N) f32, B divisible by ``block_rows``.
      uniforms: (B, N) f32 in (0, 1].
      beta: scalar f32 inverse temperature.
      block_rows: VMEM tile height (static).

    Returns:
      (B,) f32 — float-encoded sampled indices.
    """
    b, n = energies.shape
    assert b % block_rows == 0, f"B={b} not divisible by block {block_rows}"
    scal = jnp.reshape(jnp.asarray(beta, jnp.float32), (1,))
    grid = (b // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(energies, uniforms, scal)
