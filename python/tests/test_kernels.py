"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; every kernel must match its
``ref.py`` oracle to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gumbel import gumbel_argmax
from compile.kernels.ising import ising_halfstep
from compile.kernels.pas import maxcut_delta_e

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- gumbel


@settings(max_examples=20, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    n=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
    beta=st.floats(0.1, 4.0),
)
def test_gumbel_argmax_matches_ref(b_blocks, n, seed, beta):
    block = 8
    b = b_blocks * block
    r = rng(seed)
    e = r.normal(size=(b, n)).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=(b, n)).astype(np.float32)
    got = gumbel_argmax(jnp.asarray(e), jnp.asarray(u), beta, block_rows=block)
    want = ref.gumbel_argmax_ref(jnp.asarray(e), jnp.asarray(u), beta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gumbel_argmax_respects_energy_ordering():
    # With huge beta the minimum-energy state must always win.
    b, n = 16, 8
    r = rng(0)
    e = r.normal(size=(b, n)).astype(np.float32)
    u = r.uniform(0.3, 0.7, size=(b, n)).astype(np.float32)
    got = np.asarray(gumbel_argmax(jnp.asarray(e), jnp.asarray(u), 1e4, block_rows=8))
    np.testing.assert_array_equal(got, e.argmin(axis=1).astype(np.float32))


def test_gumbel_argmax_statistics():
    # Empirical distribution ≈ softmax(-beta * e).
    b, n = 64, 4
    e = np.tile(np.array([0.0, 0.5, 1.0, 2.0], np.float32), (b, 1))
    r = rng(1)
    counts = np.zeros(n)
    draws = 200
    for t in range(draws):
        u = r.uniform(1e-6, 1.0, size=(b, n)).astype(np.float32)
        idx = np.asarray(gumbel_argmax(jnp.asarray(e), jnp.asarray(u), 1.0))
        for i in idx.astype(int):
            counts[i] += 1
    p = np.exp(-e[0]) / np.exp(-e[0]).sum()
    emp = counts / counts.sum()
    np.testing.assert_allclose(emp, p, atol=0.03)


# ----------------------------------------------------------------- ising


@settings(max_examples=15, deadline=None)
@given(
    h_blocks=st.integers(1, 3),
    w=st.integers(4, 32),
    seed=st.integers(0, 2**31 - 1),
    parity=st.integers(0, 1),
    beta=st.floats(0.05, 3.0),
    coupling=st.floats(0.1, 2.0),
)
def test_ising_halfstep_matches_ref(h_blocks, w, seed, parity, beta, coupling):
    block = 8
    h = h_blocks * block
    r = rng(seed)
    spins = (2.0 * r.integers(0, 2, size=(h, w)) - 1.0).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32)
    got = ising_halfstep(
        jnp.asarray(spins), jnp.asarray(u), beta, coupling, float(parity), block_rows=block
    )
    want = ref.ising_gibbs_halfstep_ref(
        jnp.asarray(spins), jnp.asarray(u), beta, coupling, parity
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_ising_halfstep_only_touches_active_parity():
    h = w = 16
    r = rng(3)
    spins = (2.0 * r.integers(0, 2, size=(h, w)) - 1.0).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32)
    out = np.asarray(ising_halfstep(jnp.asarray(spins), jnp.asarray(u), 1.0, 1.0, 0.0))
    rr, cc = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    frozen = (rr + cc) % 2 == 1
    np.testing.assert_array_equal(out[frozen], spins[frozen])
    assert np.all(np.abs(out) == 1.0)


def test_ising_phase_behavior():
    # Ordered-phase stability: starting all-up at β = 2 (deep in the
    # ordered phase) the chain must stay magnetized; at β = 0 the same
    # start must decorrelate to ~zero magnetization. (A coarsening test
    # from a hot start is flaky: chessboard Gibbs gets stuck in stripe
    # domains, which is physics, not a kernel bug.)
    h = w = 16
    r = rng(7)

    def run(beta, sweeps):
        s = jnp.ones((h, w), jnp.float32)
        for _ in range(sweeps):
            u0 = jnp.asarray(r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32))
            u1 = jnp.asarray(r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32))
            s = ising_halfstep(s, u0, beta, 1.0, 0.0)
            s = ising_halfstep(s, u1, beta, 1.0, 1.0)
        return float(jnp.mean(s))

    assert run(2.0, 50) > 0.9
    assert abs(run(0.0, 50)) < 0.2


# ------------------------------------------------------------------- pas


@settings(max_examples=15, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxcut_delta_e_matches_ref(n_blocks, seed):
    block = 8
    n = n_blocks * block
    r = rng(seed)
    a = r.uniform(0, 1, size=(n, n)).astype(np.float32)
    adj = np.triu(a, 1)
    adj = adj + adj.T
    x = r.integers(0, 2, size=n).astype(np.float32)
    got = maxcut_delta_e(jnp.asarray(adj), jnp.asarray(x), block_rows=block)
    want = ref.maxcut_delta_e_ref(jnp.asarray(adj), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_maxcut_delta_e_semantics():
    # Path graph 0-1-2, x = [0, 1, 0]: both edges cut (cut = 2).
    adj = np.zeros((8, 8), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0
    adj[1, 2] = adj[2, 1] = 1.0
    x = np.zeros(8, np.float32)
    x[1] = 1.0
    d = np.asarray(maxcut_delta_e(jnp.asarray(adj), jnp.asarray(x), block_rows=8))
    # Flipping vertex 1 un-cuts both edges: ΔE = +2.
    assert d[1] == pytest.approx(2.0)
    # Flipping vertex 0 un-cuts edge (0,1): ΔE = +1.
    assert d[0] == pytest.approx(1.0)
    # Isolated vertices: ΔE = 0.
    assert d[4] == pytest.approx(0.0)
