"""L2 model tests: full-step semantics + AOT lowering round-trips."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed=0):
    return np.random.default_rng(seed)


def test_ising_step_composes_two_halfsteps():
    h = w = 16
    r = rng(5)
    spins = (2.0 * r.integers(0, 2, size=(h, w)) - 1.0).astype(np.float32)
    u0 = r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32)
    u1 = r.uniform(1e-6, 1.0, size=(h, w)).astype(np.float32)
    (got,) = model.ising_step(
        jnp.asarray(spins), jnp.asarray(u0), jnp.asarray(u1), 1.0, 1.0
    )
    want = ref.ising_gibbs_halfstep_ref(jnp.asarray(spins), jnp.asarray(u0), 1.0, 1.0, 0)
    want = ref.ising_gibbs_halfstep_ref(want, jnp.asarray(u1), 1.0, 1.0, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_ising_chain_equals_repeated_steps():
    h = w = 16
    steps = 4
    r = rng(9)
    spins = (2.0 * r.integers(0, 2, size=(h, w)) - 1.0).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=(steps, 2, h, w)).astype(np.float32)
    final, mags = model.ising_chain(
        jnp.asarray(spins), jnp.asarray(u), 0.7, 1.0, num_steps=steps
    )
    s = jnp.asarray(spins)
    for t in range(steps):
        (s,) = model.ising_step(s, jnp.asarray(u[t, 0]), jnp.asarray(u[t, 1]), 0.7, 1.0)
    np.testing.assert_allclose(np.asarray(final), np.asarray(s))
    assert mags.shape == (steps,)
    assert float(mags[-1]) == pytest.approx(float(jnp.sum(s)))


def test_pas_step_flips_exactly_l():
    n, l = 32, 4
    r = rng(11)
    a = r.uniform(0, 1, size=(n, n)).astype(np.float32)
    adj = np.triu(a, 1)
    adj = adj + adj.T
    x = r.integers(0, 2, size=n).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=n).astype(np.float32)
    (nx,) = model.maxcut_pas_step(
        jnp.asarray(adj), jnp.asarray(x), jnp.asarray(u), 1.0, num_flips=l
    )
    assert int(np.sum(np.asarray(nx) != x)) == l


def test_pas_step_matches_ref():
    n, l = 16, 2
    r = rng(13)
    a = r.uniform(0, 1, size=(n, n)).astype(np.float32)
    adj = np.triu(a, 1)
    adj = adj + adj.T
    x = r.integers(0, 2, size=n).astype(np.float32)
    u = r.uniform(1e-6, 1.0, size=n).astype(np.float32)
    (got,) = model.maxcut_pas_step(
        jnp.asarray(adj), jnp.asarray(x), jnp.asarray(u), 2.0, num_flips=l
    )
    want = ref.pas_flip_step_ref(jnp.asarray(adj), jnp.asarray(x), jnp.asarray(u), 2.0, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_pas_chain_improves_cut():
    n = 64
    r = rng(17)
    a = (r.uniform(0, 1, size=(n, n)) < 0.2).astype(np.float32)
    adj = np.triu(a, 1)
    adj = adj + adj.T
    x0 = r.integers(0, 2, size=n).astype(np.float32)
    steps = 64
    u = r.uniform(1e-6, 1.0, size=(steps, n)).astype(np.float32)

    def cut(x):
        s = 2 * x - 1
        return 0.25 * float(np.sum(adj)) - 0.25 * float(s @ adj @ s)

    final, _ = model.maxcut_pas_chain(
        jnp.asarray(adj), jnp.asarray(x0), jnp.asarray(u), 2.0, num_flips=4,
        num_steps=steps,
    )
    assert cut(np.asarray(final)) > cut(x0)


# ------------------------------------------------------------------- AOT


def test_all_entrypoints_lower_to_hlo_text():
    for name, fn, args, _static in aot.entrypoints():
        text = aot.to_hlo_text(fn.lower(*args))
        assert text.startswith("HloModule"), f"{name}: bad HLO header"
        assert "ENTRY" in text, f"{name}: no entry computation"


def test_manifest_spec_strings():
    assert aot.spec_str(aot.f32(4, 8)) == "4x8:f32"
    assert aot.spec_str(aot.f32()) == "scalar:f32"
