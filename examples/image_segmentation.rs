//! Image segmentation with a Potts MRF (the Table I "Image Seg." row).
//!
//! Generates a synthetic two-class image (smooth shape + heavy pixel
//! noise), builds the 8-connected Potts MRF with unary data terms, and
//! denoises it with Block Gibbs — as a batch of annealed chains on the
//! batched software backend (keeping the best restart), and once on
//! the MC²A accelerator simulator, both through the [`Engine`] API —
//! reporting pixel accuracy against the clean ground truth and the
//! accelerator's throughput.
//!
//! Run with: `cargo run --release --example image_segmentation`

use mc2a::energy::PottsGrid;
use mc2a::engine::Engine;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{AlgoKind, BetaSchedule};
use mc2a::rng::Rng;

/// Ground truth: a disc on background.
fn ground_truth(h: usize, w: usize) -> Vec<u32> {
    let (cy, cx, r2) = (h as f32 / 2.0, w as f32 / 2.0, (h.min(w) as f32 / 3.2).powi(2));
    (0..h * w)
        .map(|i| {
            let (y, x) = ((i / w) as f32, (i % w) as f32);
            (((y - cy).powi(2) + (x - cx).powi(2)) < r2) as u32
        })
        .collect()
}

fn accuracy(a: &[u32], b: &[u32]) -> f64 {
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

fn main() -> mc2a::Result<()> {
    let (h, w) = (64usize, 64usize);
    let truth = ground_truth(h, w);
    let mut rng = Rng::new(0x5E6);

    // Noisy observation: 25% of pixels flipped.
    let noisy: Vec<u32> = truth
        .iter()
        .map(|&t| if rng.uniform_f32() < 0.25 { 1 - t } else { t })
        .collect();

    // Unary energies from the noisy observation: -log P(obs | label).
    let p_correct = 0.75f32;
    let labels = 2usize;
    let mut unary = vec![0.0f32; h * w * labels];
    for (i, &obs) in noisy.iter().enumerate() {
        for s in 0..labels as u32 {
            let p = if s == obs { p_correct } else { 1.0 - p_correct };
            unary[i * labels + s as usize] = -p.ln();
        }
    }
    let mut model = PottsGrid::with_connectivity(h, w, labels, 0.9, true);
    model.set_unary(unary);

    println!("noisy accuracy (before MRF): {:.3}", accuracy(&noisy, &truth));

    // Software Block Gibbs with annealing: 8 independent restarts,
    // batched SoA execution over the work-stealing pool, best restart
    // kept. The batch rides one thread pool no matter the chain count.
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Linear { from: 0.5, to: 3.0, steps: 60 })
        .steps(80)
        .chains(8)
        .batch(4)
        .seed(7)
        .build()?
        .run()?;
    let sw = metrics
        .chains
        .iter()
        .max_by(|a, b| a.best_objective.total_cmp(&b.best_objective))
        .expect("chains");
    println!(
        "software BG segmentation accuracy (best of {} batched restarts): {:.3}",
        metrics.chains.len(),
        accuracy(&sw.best_x, &truth)
    );
    println!(
        "  batched throughput: {:.3e} updates/s over {} chains",
        metrics.updates_per_sec(),
        metrics.chains.len()
    );

    // MC²A accelerator — the same annealing schedule, stepped per
    // HWLOOP iteration by the accelerator backend.
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Linear { from: 0.5, to: 3.0, steps: 60 })
        .steps(80)
        .seed(7)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    println!(
        "MC2A segmentation accuracy: {:.3} ({} cycles, {:.3} GS/s, CU util {:.2})",
        accuracy(&acc.best_x, &truth),
        rep.cycles,
        rep.gsps(&hw),
        rep.cu_utilization()
    );
    Ok(())
}
