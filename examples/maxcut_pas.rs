//! Combinatorial optimization with PAS: the Optsicom-style MaxCut
//! workload (Table I) solved by MH, Block Gibbs and PAS — the Fig. 5
//! story (gradient-based samplers need fewer steps but more ops) plus
//! the accelerator run.
//!
//! Run with: `cargo run --release --example maxcut_pas`

use mc2a::compiler::compile;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{build_algo, run_to_accuracy, AlgoKind, BetaSchedule, SamplerKind};
use mc2a::sim::Simulator;
use mc2a::workloads::wl_maxcut_optsicom;

fn main() {
    let wl = wl_maxcut_optsicom();
    let model = wl.model.as_ref();
    println!(
        "MaxCut: {} nodes, {} edges (weights 1..10)\n",
        wl.nodes(),
        wl.edges()
    );

    let schedule = BetaSchedule::Linear {
        from: 0.2,
        to: 3.0,
        steps: 500,
    };

    // Calibrate "best known" with a long PAS run.
    let algo = build_algo(AlgoKind::Pas, SamplerKind::Gumbel, model, 8);
    let cal = run_to_accuracy(model, algo, schedule, f64::INFINITY, 2_000, 50, 0xCA1);
    let best = cal.points.last().unwrap().best_objective;
    println!("calibrated best cut: {best:.0}\n");
    println!(
        "{:<6} {:>8} {:>14} {:>10}",
        "algo", "steps", "ops to 94%", "cut found"
    );
    for algo_kind in [AlgoKind::Mh, AlgoKind::BlockGibbs, AlgoKind::Pas] {
        let a = build_algo(algo_kind, SamplerKind::Gumbel, model, 8);
        let tr = run_to_accuracy(model, a, schedule, f64::INFINITY, 1_000, 10, 0x5eed);
        let goal = 0.94 * best;
        let hit = tr.points.iter().find(|p| p.best_objective >= goal);
        match hit {
            Some(p) => println!(
                "{:<6} {:>8} {:>14} {:>10.0}",
                tr.algo, p.steps, p.ops, p.best_objective
            ),
            None => println!(
                "{:<6} {:>8} {:>14} {:>10.0}",
                tr.algo,
                "-",
                "-",
                tr.points.last().unwrap().best_objective
            ),
        }
    }

    // Accelerator run with the spatial-mode SU (Fig. 10c schedule).
    let hw = HwConfig::paper_default();
    let program = compile(model, AlgoKind::Pas, &hw, 8);
    let mut sim = Simulator::new(hw, model, 8, 0xACC);
    sim.set_beta(2.0);
    let rep = sim.run(&program, 500);
    println!(
        "\nMC2A PAS: cut {:.0} after 500 iters; {} cycles, {:.3e} flips/s, SU util {:.2}",
        model.objective(&sim.x),
        rep.cycles,
        rep.updates_per_sec(&hw),
        rep.su_utilization()
    );
}
