//! Combinatorial optimization with PAS: the Optsicom-style MaxCut
//! workload (Table I) solved by MH, Block Gibbs and PAS — the Fig. 5
//! story (gradient-based samplers need fewer steps but more ops) plus
//! the accelerator run through the [`Engine`] API, with streaming
//! convergence diagnostics from a multi-chain software run.
//!
//! Run with: `cargo run --release --example maxcut_pas`

use mc2a::engine::Engine;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{build_algo, run_to_accuracy, AlgoKind, BetaSchedule, SamplerKind};

fn main() -> mc2a::Result<()> {
    let schedule = BetaSchedule::Linear {
        from: 0.2,
        to: 3.0,
        steps: 500,
    };
    let mut engine = Engine::for_workload("optsicom")?
        .schedule(schedule)
        .steps(1_000)
        .chains(4)
        .seed(0x5eed)
        .build()?;
    let model_nodes = engine.model().num_vars();
    let model_edges = engine.model().interaction().num_edges();
    println!("MaxCut: {model_nodes} nodes, {model_edges} edges (weights 1..10)\n");

    // Calibrate "best known" with a long PAS run.
    let model = engine.model();
    let algo = build_algo(AlgoKind::Pas, SamplerKind::Gumbel, model, 8);
    let cal = run_to_accuracy(model, algo, schedule, f64::INFINITY, 2_000, 50, 0xCA1);
    let best = cal.points.last().unwrap().best_objective;
    println!("calibrated best cut: {best:.0}\n");
    println!(
        "{:<6} {:>8} {:>14} {:>10}",
        "algo", "steps", "ops to 94%", "cut found"
    );
    for algo_kind in [AlgoKind::Mh, AlgoKind::BlockGibbs, AlgoKind::Pas] {
        let a = build_algo(algo_kind, SamplerKind::Gumbel, model, 8);
        let tr = run_to_accuracy(model, a, schedule, f64::INFINITY, 1_000, 10, 0x5eed);
        let goal = 0.94 * best;
        let hit = tr.points.iter().find(|p| p.best_objective >= goal);
        match hit {
            Some(p) => println!(
                "{:<6} {:>8} {:>14} {:>10.0}",
                tr.algo, p.steps, p.ops, p.best_objective
            ),
            None => println!(
                "{:<6} {:>8} {:>14} {:>10.0}",
                tr.algo,
                "-",
                "-",
                tr.points.last().unwrap().best_objective
            ),
        }
    }

    // Multi-chain PAS run with cross-chain diagnostics.
    let metrics = engine.run()?;
    println!(
        "\n4-chain PAS: best cut {:.0}, split R-hat {}, min ESS {:.1}",
        metrics.best_objective(),
        metrics
            .split_r_hat()
            .map_or("n/a".to_string(), |r| format!("{r:.3}")),
        metrics.min_ess()
    );

    // Accelerator run with the spatial-mode SU (Fig. 10c schedule).
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_workload("optsicom")?
        .schedule(BetaSchedule::Constant(2.0))
        .steps(500)
        .seed(0xACC)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    println!(
        "\nMC2A PAS: cut {:.0} after {} iters; {} cycles, {:.3e} flips/s, SU util {:.2}",
        acc.best_objective,
        acc.steps,
        rep.cycles,
        rep.updates_per_sec(&hw),
        rep.su_utilization()
    );
    Ok(())
}
