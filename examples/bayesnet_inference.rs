//! Posterior inference on the Earthquake Bayes net (Fig. 10a workload):
//! clamp JohnCalls = MaryCalls = true, estimate P(Burglary | calls)
//! with Gibbs sampling — software chain, MC²A accelerator, and exact
//! enumeration side by side.
//!
//! Run with: `cargo run --release --example bayesnet_inference`

use mc2a::compiler::compile;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind};
use mc2a::sim::Simulator;
use mc2a::workloads::earthquake;

fn main() {
    let mut net = earthquake();
    // Evidence: both neighbors called.
    net.set_evidence(3, 1);
    net.set_evidence(4, 1);

    let exact = net.exact_marginal(0);
    println!("exact          P(B=1 | john, mary) = {:.4}", exact[1]);

    // Software Block Gibbs.
    let algo = build_algo(AlgoKind::BlockGibbs, SamplerKind::Gumbel, &net, 1);
    let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 99);
    // Start consistent with the evidence.
    chain.x[3] = 1;
    chain.x[4] = 1;
    chain.run(200_000);
    let emp = chain.marginal(0);
    println!("software Gibbs P(B=1 | john, mary) = {:.4}  ({} sweeps)", emp[1], chain.step_count);

    // MC²A accelerator (hardware Gumbel-LUT sampler, 16×8-bit).
    let hw = HwConfig::paper_default();
    let program = compile(&net, AlgoKind::BlockGibbs, &hw, 1);
    let mut sim = Simulator::new(hw, &net, 1, 99);
    sim.x[3] = 1;
    sim.x[4] = 1;
    let rep = sim.run(&program, 200_000);
    let emp_hw = sim.marginal(0);
    println!(
        "MC2A (LUT16x8) P(B=1 | john, mary) = {:.4}  ({} cycles, {:.1} Msamples/s)",
        emp_hw[1],
        rep.cycles,
        rep.gsps(&hw) * 1e3
    );

    let err_sw = (emp[1] - exact[1]).abs();
    let err_hw = (emp_hw[1] - exact[1]).abs();
    println!("\nabs error: software {err_sw:.4}, accelerator {err_hw:.4}");
    assert!(err_sw < 0.02 && err_hw < 0.03, "posterior estimates diverged");
    println!("both estimators agree with exact inference ✓");
}
