//! Posterior inference on the Earthquake Bayes net (Fig. 10a workload):
//! clamp JohnCalls = MaryCalls = true, estimate P(Burglary | calls)
//! with Gibbs sampling — software chain, MC²A accelerator, and exact
//! enumeration side by side, all through the [`Engine`] API.
//!
//! Run with: `cargo run --release --example bayesnet_inference`

use mc2a::engine::Engine;
use mc2a::isa::HwConfig;
use mc2a::mcmc::AlgoKind;
use mc2a::workloads::earthquake;

fn main() -> mc2a::Result<()> {
    let mut net = earthquake();
    // Evidence: both neighbors called.
    net.set_evidence(3, 1);
    net.set_evidence(4, 1);

    let exact = net.exact_marginal(0);
    println!("exact          P(B=1 | john, mary) = {:.4}", exact[1]);

    // Start consistent with the evidence.
    let mut x0 = vec![0u32; 5];
    x0[3] = 1;
    x0[4] = 1;

    // Software Block Gibbs.
    let metrics = Engine::for_model(&net)
        .algo(AlgoKind::BlockGibbs)
        .steps(200_000)
        .seed(99)
        .init_state(x0.clone())
        .build()?
        .run()?;
    let sw = &metrics.chains[0];
    println!(
        "software Gibbs P(B=1 | john, mary) = {:.4}  ({} sweeps)",
        sw.marginal0[1], sw.steps
    );

    // MC²A accelerator (hardware Gumbel-LUT sampler, 16×8-bit).
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_model(&net)
        .algo(AlgoKind::BlockGibbs)
        .steps(200_000)
        .seed(99)
        .init_state(x0)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    println!(
        "MC2A (LUT16x8) P(B=1 | john, mary) = {:.4}  ({} cycles, {:.1} Msamples/s)",
        acc.marginal0[1],
        rep.cycles,
        rep.gsps(&hw) * 1e3
    );

    let err_sw = (sw.marginal0[1] - exact[1]).abs();
    let err_hw = (acc.marginal0[1] - exact[1]).abs();
    println!("\nabs error: software {err_sw:.4}, accelerator {err_hw:.4}");
    assert!(err_sw < 0.02 && err_hw < 0.03, "posterior estimates diverged");
    println!("both estimators agree with exact inference ✓");
    Ok(())
}
