//! Quickstart: sample a small Ising model three ways.
//!
//! 1. Software Block Gibbs (the reference algorithm library),
//! 2. the MC²A accelerator (compile → cycle-accurate simulation),
//! 3. the 3D roofline prediction for the same workload.
//!
//! Run with: `cargo run --release --example quickstart`

use mc2a::compiler::compile;
use mc2a::energy::PottsGrid;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind};
use mc2a::roofline::{self, WorkloadProfile};
use mc2a::sim::Simulator;

fn main() {
    // A 16×16 ferromagnetic Ising grid at moderate temperature.
    let model = PottsGrid::new(16, 16, 2, 1.0);
    let beta = 0.35;

    // --- 1. software chain -------------------------------------------------
    let algo = build_algo(AlgoKind::BlockGibbs, SamplerKind::Gumbel, &model, 1);
    let mut chain = Chain::new(&model, algo, BetaSchedule::Constant(beta), 42);
    chain.run(2_000);
    println!("software Block Gibbs ({} steps):", chain.step_count);
    println!("  updates          = {}", chain.stats.updates);
    println!("  P(spin[0] = 1)   = {:.3}", chain.marginal(0)[1]);
    println!("  best objective   = {:.1}", chain.best_objective);

    // --- 2. MC²A accelerator ----------------------------------------------
    let hw = HwConfig::paper_default();
    let program = compile(&model, AlgoKind::BlockGibbs, &hw, 1);
    let mut sim = Simulator::new(hw, &model, 1, 42);
    sim.set_beta(beta);
    let rep = sim.run(&program, 2_000);
    println!("\nMC2A accelerator (T={} K={} S={} B={}):", hw.t, hw.k, hw.s, hw.bw_words);
    println!("  program          = {} instrs/iter", program.body.len());
    println!("  cycles           = {}", rep.cycles);
    println!("  throughput       = {:.3} GS/s", rep.gsps(&hw));
    println!("  CU / SU util     = {:.2} / {:.2}", rep.cu_utilization(), rep.su_utilization());
    println!("  power (modeled)  = {:.3} W", rep.watts(&hw));
    println!("  P(spin[0] = 1)   = {:.3}  (must match software)", sim.marginal(0)[1]);

    // --- 3. roofline prediction --------------------------------------------
    let prof = WorkloadProfile::from_model(&model, AlgoKind::BlockGibbs);
    let point = roofline::evaluate(&hw, &prof);
    println!("\n3D roofline @ (CI={:.4}, MI={:.4}):", prof.ci, prof.mi);
    println!("  predicted TP     = {:.3} GS/s", point.tp_gsps);
    println!("  bottleneck       = {:?}", point.bottleneck);
    println!(
        "  sim/prediction   = {:.2}",
        rep.gsps(&hw) / point.tp_gsps
    );
}
