//! Quickstart: sample a small Ising model four ways through the
//! unified [`Engine`] API.
//!
//! 1. Software Block Gibbs (the reference algorithm library),
//! 2. 32 chains on the batched SoA backend (work-stealing pool) —
//!    bit-identical chains, many-chain throughput,
//! 3. the MC²A accelerator (compile → cycle-accurate simulation),
//! 4. the 3D roofline prediction for the same workload.
//!
//! Run with: `cargo run --release --example quickstart`

use mc2a::energy::PottsGrid;
use mc2a::engine::Engine;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{AlgoKind, BetaSchedule};
use mc2a::roofline::{self, WorkloadProfile};

fn main() -> mc2a::Result<()> {
    // A 16×16 ferromagnetic Ising grid at moderate temperature.
    let model = PottsGrid::new(16, 16, 2, 1.0);
    let beta = 0.35;

    // --- 1. software chain -------------------------------------------------
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Constant(beta))
        .steps(2_000)
        .seed(42)
        .build()?
        .run()?;
    let sw = &metrics.chains[0];
    println!("software Block Gibbs ({} steps):", sw.steps);
    println!("  updates          = {}", sw.stats.updates);
    println!("  P(spin[0] = 1)   = {:.3}", sw.marginal0[1]);
    println!("  best objective   = {:.1}", sw.best_objective);

    // --- 2. many chains, batched ------------------------------------------
    // 32 chains as structure-of-arrays batches over a fixed thread
    // pool: chain 0 reproduces the single-chain run above bit-for-bit
    // (same `Rng::fork(seed, chain_id)` stream on every backend).
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Constant(beta))
        .steps(2_000)
        .chains(32)
        .batch(16)
        .seed(42)
        .build()?
        .run()?;
    println!("\nbatched backend (32 chains, batch 16):");
    println!("  updates          = {}", metrics.total_updates());
    println!("  updates/s        = {:.3e}", metrics.updates_per_sec());
    println!(
        "  mean P(spin = 1) = {:.3}  (across chains)",
        metrics.mean_marginal0()[1]
    );
    println!(
        "  chain 0 matches single-chain run: {}",
        metrics.chains[0].marginal0 == sw.marginal0
    );
    if let Some(r) = metrics.split_r_hat() {
        println!("  split R-hat      = {r:.4}");
    }

    // --- 3. MC²A accelerator ----------------------------------------------
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Constant(beta))
        .steps(2_000)
        .seed(42)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    println!("\nMC2A accelerator (T={} K={} S={} B={}):", hw.t, hw.k, hw.s, hw.bw_words);
    println!("  cycles           = {}", rep.cycles);
    println!("  throughput       = {:.3} GS/s", rep.gsps(&hw));
    println!("  CU / SU util     = {:.2} / {:.2}", rep.cu_utilization(), rep.su_utilization());
    println!("  power (modeled)  = {:.3} W", rep.watts(&hw));
    println!("  P(spin[0] = 1)   = {:.3}  (must match software)", acc.marginal0[1]);

    // --- 4. roofline prediction --------------------------------------------
    let prof = WorkloadProfile::from_model(&model, AlgoKind::BlockGibbs);
    let point = roofline::evaluate(&hw, &prof);
    println!("\n3D roofline @ (CI={:.4}, MI={:.4}):", prof.ci, prof.mi);
    println!("  predicted TP     = {:.3} GS/s", point.tp_gsps);
    println!("  bottleneck       = {:?}", point.bottleneck);
    println!(
        "  sim/prediction   = {:.2}",
        rep.gsps(&hw) / point.tp_gsps
    );
    Ok(())
}
