//! §Perf ablation driver (EXPERIMENTS.md §Perf): runs each workload
//! twice through the [`Engine`] — once on a naive
//! one-phase-per-instruction accelerator backend, once on the
//! optimized VLIW schedule (load/compute fusion; the row-wide RF port
//! modeling applies to both) — and reports cycles / throughput /
//! utilization side by side. This is the custom-backend path:
//! [`AcceleratorBackend::with_optimization`] plugs in through
//! `EngineBuilder::backend` like any third-party backend would.
//!
//! Run with: `cargo run --release --example perf_ablation`
use mc2a::energy::PottsGrid;
use mc2a::engine::{AcceleratorBackend, Engine};
use mc2a::isa::HwConfig;
use mc2a::mcmc::AlgoKind;
use mc2a::workloads;

fn main() -> mc2a::Result<()> {
    let hw = HwConfig::paper_default();
    let cases: Vec<(&str, Box<dyn mc2a::energy::EnergyModel>, AlgoKind, usize, usize)> = vec![
        ("ising64-BG", Box::new(PottsGrid::new(64, 64, 2, 1.0)), AlgoKind::BlockGibbs, 1, 50),
        ("imageseg64-BG", workloads::wl_image_seg(false).model, AlgoKind::BlockGibbs, 1, 20),
        ("optsicom-PAS", workloads::wl_maxcut_optsicom().model, AlgoKind::Pas, 8, 100),
        ("er1347-PAS", workloads::wl_mis_er().model, AlgoKind::Pas, 8, 10),
        ("alarm-BG", Box::new(workloads::alarm()), AlgoKind::BlockGibbs, 1, 500),
    ];
    for (name, model, algo, flips, iters) in cases {
        let mut res = Vec::new();
        for opt in [false, true] {
            let backend = AcceleratorBackend::new(hw).with_optimization(opt);
            let metrics = Engine::for_model(model.as_ref())
                .algo(algo)
                .pas_flips(flips)
                .steps(iters)
                .seed(1)
                .backend(Box::new(backend))
                .build()?
                .run()?;
            let rep = metrics.chains[0].sim.as_ref().expect("accelerator report");
            res.push((rep.cycles, rep.gsps(&hw), rep.cu_utilization()));
        }
        println!(
            "{name:<14} naive: {:>9} cyc {:>7.3} GS/s util {:.2} | fused: {:>9} cyc {:>7.3} GS/s util {:.2} | speedup {:.2}x",
            res[0].0, res[0].1, res[0].2,
            res[1].0, res[1].1, res[1].2,
            res[0].0 as f64 / res[1].0 as f64
        );
    }
    Ok(())
}
