//! §Perf ablation driver (EXPERIMENTS.md §Perf): compiles each
//! workload twice — naive one-phase-per-instruction schedule vs the
//! optimized VLIW schedule (load/compute fusion; the row-wide RF port
//! modeling applies to both) — and reports cycles / throughput /
//! utilization side by side.
//!
//! Run with: `cargo run --release --example perf_ablation`
use mc2a::compiler::compile_opt;
use mc2a::energy::PottsGrid;
use mc2a::isa::HwConfig;
use mc2a::mcmc::AlgoKind;
use mc2a::sim::Simulator;
use mc2a::workloads;

fn main() {
    let hw = HwConfig::paper_default();
    let cases: Vec<(&str, Box<dyn mc2a::energy::EnergyModel>, AlgoKind, usize, usize)> = vec![
        ("ising64-BG", Box::new(PottsGrid::new(64, 64, 2, 1.0)), AlgoKind::BlockGibbs, 1, 50),
        ("imageseg64-BG", workloads::wl_image_seg(false).model, AlgoKind::BlockGibbs, 1, 20),
        ("optsicom-PAS", workloads::wl_maxcut_optsicom().model, AlgoKind::Pas, 8, 100),
        ("er1347-PAS", workloads::wl_mis_er().model, AlgoKind::Pas, 8, 10),
        ("alarm-BG", Box::new(workloads::alarm()), AlgoKind::BlockGibbs, 1, 500),
    ];
    for (name, model, algo, flips, iters) in cases {
        let mut res = Vec::new();
        for opt in [false, true] {
            let p = compile_opt(model.as_ref(), algo, &hw, flips, opt);
            let mut sim = Simulator::new(hw, model.as_ref(), flips, 1);
            let rep = sim.run(&p, iters);
            res.push((p.body.len(), rep.cycles, rep.gsps(&hw), rep.cu_utilization()));
        }
        println!(
            "{name:<14} naive: {:>6} instr {:>9} cyc {:>7.3} GS/s util {:.2} | fused: {:>6} instr {:>9} cyc {:>7.3} GS/s util {:.2} | speedup {:.2}x",
            res[0].0, res[0].1, res[0].2, res[0].3,
            res[1].0, res[1].1, res[1].2, res[1].3,
            res[0].1 as f64 / res[1].1 as f64
        );
    }
}
