//! End-to-end driver: proves all three layers compose on a real
//! workload, and reproduces the paper's headline comparison on this
//! testbed. The run is recorded in EXPERIMENTS.md §E2E.
//!
//! Pipeline exercised:
//!   L1/L2 (build time): Pallas kernels → JAX model → HLO text
//!     (`make artifacts` — must have been run already),
//!   runtime: Rust loads the artifacts via PJRT and *measures* the
//!     software-CPU baseline on a 64×64 Ising Block-Gibbs chain and a
//!     128-node MaxCut PAS chain,
//!   L3: the same workloads run on the cycle-accurate accelerator
//!     simulator through the [`Engine`] accelerator backend,
//!   validation: the two paths must agree statistically (mean |magnet-
//!     ization| trajectory, cut improvement), and the speedup is
//!     compared against the paper's §VI-D claims.
//!
//! Requires a build with `--features xla-runtime`; without it the
//! example reports why and exits cleanly.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_full_stack`

use mc2a::bench::bench_fn;
use mc2a::energy::{MaxCutModel, PottsGrid};
use mc2a::engine::Engine;
use mc2a::graph::erdos_renyi_with_edges;
use mc2a::isa::HwConfig;
use mc2a::mcmc::{AlgoKind, BetaSchedule};
use mc2a::rng::Rng;
use mc2a::runtime::Runtime;

fn main() -> mc2a::Result<()> {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts ({e:#}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("PJRT platform: {} | artifacts: {:?}\n", rt.platform(), rt.names());

    // ================= Ising 64×64, Block Gibbs =================
    println!("== workload 1: Ising 64x64, chessboard Block Gibbs ==");
    let h = 64usize;
    let n = h * h;
    let steps_per_call = 32usize; // fixed at AOT time
    let calls = 8usize;
    let beta = [0.6f32];
    let coupling = [1.0f32];
    let mut rng = Rng::new(0xE2E);

    // --- measured CPU path (L1/L2 artifacts through PJRT) ---
    let mut spins: Vec<f32> = (0..n).map(|_| if rng.below(2) == 1 { 1.0 } else { -1.0 }).collect();
    let mut mags = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..calls {
        let uniforms: Vec<f32> =
            (0..steps_per_call * 2 * n).map(|_| rng.uniform_open_f32()).collect();
        let out = rt.execute_f32("ising_chain", &[&spins, &uniforms, &beta, &coupling])?;
        spins = out[0].clone();
        mags.push(out[1].last().copied().unwrap_or(0.0) / n as f32);
    }
    let cpu_wall = t0.elapsed();
    let cpu_updates = (calls * steps_per_call * n) as f64;
    let cpu_gsps = cpu_updates / cpu_wall.as_secs_f64() / 1e9;
    let cpu_mag = mags.last().copied().unwrap_or(0.0).abs();
    println!("measured CPU (PJRT): {} sweeps in {:?} → {:.4} GS/s, |m|={:.3}",
        calls * steps_per_call, cpu_wall, cpu_gsps, cpu_mag);

    // --- MC²A accelerator path (L3 compiler + cycle-accurate sim) ---
    let model = PottsGrid::new(h, h, 2, 1.0);
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_model(&model)
        .algo(AlgoKind::BlockGibbs)
        .schedule(BetaSchedule::Constant(0.6))
        .steps(calls * steps_per_call)
        .seed(0xE2E)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    let sim_gsps = rep.gsps(&hw);
    // magnetization from the sim's final state (±1 encoding ↔ 0/1 labels)
    let m_sim: f64 = acc.best_x.iter().map(|&v| if v == 1 { 1.0 } else { -1.0 }).sum::<f64>()
        / n as f64;
    println!(
        "MC2A sim: {} cycles → {:.4} GS/s @ {:.2} W, |m|={:.3}",
        rep.cycles,
        sim_gsps,
        rep.watts(&hw),
        m_sim.abs()
    );
    let speedup = sim_gsps / cpu_gsps;
    println!("speedup vs measured CPU: {speedup:.1}x   (paper §VI-D: 307.6x vs Xeon)");
    // Statistical agreement: both chains are in the same phase.
    let agree = (cpu_mag as f64 - m_sim.abs()).abs() < 0.35;
    println!("statistical agreement (|m| within 0.35): {}", if agree { "OK" } else { "MISMATCH" });

    // ================= MaxCut 128, PAS =================
    println!("\n== workload 2: MaxCut N=128, PAS (L=8) ==");
    let nn = 128usize;
    let g = erdos_renyi_with_edges(nn, 640, 0x14c);
    let mc = MaxCutModel::new(g.clone(), None);
    let mut adj = vec![0.0f32; nn * nn];
    for i in 0..nn {
        for &j in g.neighbors(i) {
            adj[i * nn + j as usize] = 1.0;
        }
    }
    let x0: Vec<f32> = (0..nn).map(|_| rng.below(2) as f32).collect();
    let cut0 = mc.cut_weight(&x0.iter().map(|&v| v as u32).collect::<Vec<_>>());

    // measured CPU path
    let x = x0.clone();
    let stat = bench_fn(2, 8, || {
        let u: Vec<f32> = {
            let mut r = Rng::new(7);
            (0..32 * nn).map(|_| r.uniform_open_f32()).collect()
        };
        let out = rt
            .execute_f32("maxcut_pas_chain", &[&adj, &x, &u, &[2.0f32]])
            .expect("maxcut_pas_chain");
        out[0].clone()
    });
    // one more call, keeping the state, to report the cut improvement
    let u: Vec<f32> = (0..32 * nn).map(|_| rng.uniform_open_f32()).collect();
    let out = rt.execute_f32("maxcut_pas_chain", &[&adj, &x, &u, &[2.0f32]])?;
    let x1 = out[0].clone();
    let cut1 = mc.cut_weight(&x1.iter().map(|&v| v as u32).collect::<Vec<_>>());
    let flips_per_call = 32.0 * 8.0;
    let cpu_pas_sps = flips_per_call / (stat.mean_ms() / 1e3);
    println!(
        "measured CPU (PJRT): {:.3} ms / 32-step call → {:.3e} flips/s; cut {} → {}",
        stat.mean_ms(),
        cpu_pas_sps,
        cut0,
        cut1
    );

    // MC²A path through the engine.
    let metrics = Engine::for_model(&mc)
        .algo(AlgoKind::Pas)
        .pas_flips(8)
        .schedule(BetaSchedule::Constant(2.0))
        .steps(64)
        .seed(0xE2E)
        .accelerator(hw)
        .build()?
        .run()?;
    let acc = &metrics.chains[0];
    let rep = acc.sim.as_ref().expect("accelerator report");
    let cut_sim = mc.cut_weight(&acc.best_x);
    let sim_pas_sps = rep.updates_per_sec(&hw);
    println!(
        "MC2A sim: {} cycles for 64 iters → {:.3e} flips/s; final cut {}",
        rep.cycles, sim_pas_sps, cut_sim
    );
    println!(
        "speedup vs measured CPU: {:.0}x   (paper: avg 60x latency vs CPU on COP)",
        sim_pas_sps / cpu_pas_sps
    );
    let improved = cut1 > cut0 && cut_sim > cut0;
    println!("both paths improve the cut: {}", if improved { "OK" } else { "MISMATCH" });

    println!("\nE2E complete: L1/L2 artifacts executed from Rust, L3 compiled & simulated, outputs consistent.");
    Ok(())
}
