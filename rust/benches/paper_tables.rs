//! Bench: regenerate every paper table/figure in quick mode, timing
//! each generator. `cargo bench --bench paper_tables` is the one-shot
//! "reproduce the evaluation section" entry point (full-scale variants
//! via the `mc2a bench --full` CLI).

use mc2a::bench;
use std::time::Instant;

fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed();
    println!("=== {name} ({dt:?}) ===\n{out}");
}

fn main() {
    timed("Table I", || bench::table1(false));
    timed("Fig 5", || bench::fig5(true, 0.94));
    timed("Fig 6", bench::fig6);
    timed("Fig 11", bench::fig11);
    timed("Fig 12", || bench::fig12(true));
    timed("Fig 13", bench::fig13);
    timed("Fig 14", || bench::fig14(true));
    timed("Fig 15", || bench::fig15(true));
    timed("Headline", || bench::headline(true));
}
