//! Bench: many-chain throughput — thread-per-chain `SoftwareBackend`
//! vs the batched work-stealing `BatchedSoftwareBackend` on a
//! 1024-variable Ising Gibbs sweep at 64 chains. Prints the same CSV
//! as `mc2a bench chains` (samples/sec and chains/sec per backend).

fn main() {
    match mc2a::bench::many_chains(false) {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("many_chain bench failed: {e}");
            std::process::exit(1);
        }
    }
}
