//! Bench: multi-core scaling — the sharded `MultiCoreAcceleratorBackend`
//! on the imageseg Potts MRF at C ∈ {1, 2, 4, 8, 16}. Prints the same
//! CSV as `mc2a bench cores` (aggregate GS/s, speedup, parallel
//! efficiency, sync overhead per core count).

fn main() {
    match mc2a::bench::core_scaling(false) {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("multi_core bench failed: {e}");
            std::process::exit(1);
        }
    }
}
