//! Bench: software categorical samplers (CDF vs Gumbel vs Gumbel-LUT)
//! — the software twin of Fig. 13, plus the hardware SU models.

use mc2a::bench::bench_fn;
use mc2a::isa::HwConfig;
use mc2a::mcmc::sampler::{CategoricalSampler, CdfSampler, GumbelLutSampler, GumbelSampler};
use mc2a::rng::Rng;
use mc2a::sim::su::fig13_sweep;

fn bench_sampler(name: &str, s: &mut dyn CategoricalSampler, n: usize) {
    let mut rng = Rng::new(7);
    let e: Vec<f32> = (0..n).map(|_| rng.uniform_f32() * 4.0).collect();
    let draws = 10_000;
    let stat = bench_fn(1, 7, || {
        let mut acc = 0usize;
        for _ in 0..draws {
            acc += s.sample(&e, 1.0, &mut rng);
        }
        acc
    });
    println!(
        "{name:<14} N={n:<4} {:>9.1} ns/sample  ({:.3} ms / {draws} draws)",
        stat.median_ms() * 1e6 / draws as f64,
        stat.median_ms()
    );
}

fn main() {
    println!("# samplers — software sampling kernels");
    for n in [8usize, 64, 256] {
        bench_sampler("cdf", &mut CdfSampler, n);
        bench_sampler("gumbel", &mut GumbelSampler::default(), n);
        bench_sampler("gumbel-lut16", &mut GumbelLutSampler::new(16, 8), n);
    }
    println!("\n# hardware SU models (Fig. 13 sweep @ paper config)");
    for row in fig13_sweep(&HwConfig::paper_default(), &[8, 64, 256]) {
        println!(
            "N={:<4} cdf={:.3e} sps (util {:.2})  gumbel={:.3e} sps (util {:.2})",
            row.n, row.cdf_sps, row.cdf_util, row.gumbel_sps, row.gumbel_util
        );
    }
}
