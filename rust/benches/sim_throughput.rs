//! Bench: cycle-accurate simulator throughput (simulated cycles/s and
//! simulated samples/s) across workload classes, driven through the
//! [`Engine`] accelerator backend (compile + simulate per run). The
//! simulator must be fast enough that the Fig. 14 sweeps are not
//! bottlenecked by the host (DESIGN.md §6 target: ≥ 10 M simulated
//! cycles/s).

use mc2a::bench::bench_fn;
use mc2a::engine::Engine;
use mc2a::energy::PottsGrid;
use mc2a::isa::HwConfig;
use mc2a::mcmc::AlgoKind;
use mc2a::workloads;

fn bench_workload(
    name: &str,
    model: &dyn mc2a::energy::EnergyModel,
    algo: AlgoKind,
    flips: usize,
    iters: usize,
) {
    let hw = HwConfig::paper_default();
    let mut engine = Engine::for_model(model)
        .algo(algo)
        .pas_flips(flips)
        .steps(iters)
        .seed(42)
        .accelerator(hw)
        .build()
        .expect("engine");
    let stat = bench_fn(1, 5, || engine.run().expect("run"));
    // one extra run for the cycle count
    let metrics = engine.run().expect("run");
    let rep = metrics.chains[0].sim.as_ref().expect("sim report");
    let cyc_per_sec = rep.cycles as f64 / (stat.median_ms() / 1e3);
    println!(
        "{name:<24} {:>10} cycles/run  {:>8.3} ms/run  {:>10.2e} sim-cycles/s  {:>10.2e} sim-samples/s",
        rep.cycles,
        stat.median_ms(),
        cyc_per_sec,
        rep.samples as f64 / (stat.median_ms() / 1e3),
    );
}

fn main() {
    println!("# sim_throughput — cycle-accurate simulator speed");
    let ising = PottsGrid::new(64, 64, 2, 1.0);
    bench_workload("ising64 block-gibbs", &ising, AlgoKind::BlockGibbs, 1, 20);
    bench_workload("ising64 seq-gibbs", &ising, AlgoKind::Gibbs, 1, 2);
    let net = workloads::alarm();
    bench_workload("alarm block-gibbs", &net, AlgoKind::BlockGibbs, 1, 200);
    let mc = workloads::wl_maxcut_optsicom();
    bench_workload("optsicom pas", mc.model.as_ref(), AlgoKind::Pas, 8, 50);
    let mis = workloads::wl_mis_er();
    bench_workload("er1347 pas", mis.model.as_ref(), AlgoKind::Pas, 8, 10);
}
