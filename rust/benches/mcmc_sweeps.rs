//! Bench: software MCMC sweep throughput (RV updates/s) per algorithm —
//! the L3 hot path that the perf pass optimizes (EXPERIMENTS.md §Perf).
//! All runs are constructed through the [`Engine`] builder.

use mc2a::bench::bench_fn;
use mc2a::energy::PottsGrid;
use mc2a::engine::Engine;
use mc2a::mcmc::{AlgoKind, SamplerKind};
use mc2a::workloads;

fn bench_chain(
    name: &str,
    model: &dyn mc2a::energy::EnergyModel,
    algo: AlgoKind,
    sampler: SamplerKind,
    flips: usize,
    steps: usize,
) {
    let mut engine = Engine::for_model(model)
        .algo(algo)
        .sampler(sampler)
        .pas_flips(flips)
        .steps(steps)
        .build()
        .expect("engine");
    let stat = bench_fn(1, 5, || engine.run().expect("run"));
    let metrics = engine.run().expect("run");
    let updates = metrics.total_updates() as f64;
    let samples: u64 = metrics.chains.iter().map(|c| c.stats.cost.samples).sum();
    println!(
        "{name:<28} {:>8.3} ms/run  {:>10.3e} updates/s  {:>10.3e} samples/s",
        stat.median_ms(),
        updates / (stat.median_ms() / 1e3),
        samples as f64 / (stat.median_ms() / 1e3)
    );
}

fn main() {
    println!("# mcmc_sweeps — software chain throughput");
    let ising = PottsGrid::new(64, 64, 2, 1.0);
    bench_chain("ising64 gibbs+gumbel", &ising, AlgoKind::Gibbs, SamplerKind::Gumbel, 1, 50);
    bench_chain("ising64 gibbs+cdf", &ising, AlgoKind::Gibbs, SamplerKind::Cdf, 1, 50);
    bench_chain("ising64 block-gibbs", &ising, AlgoKind::BlockGibbs, SamplerKind::Gumbel, 1, 50);
    bench_chain("ising64 mh", &ising, AlgoKind::Mh, SamplerKind::Gumbel, 1, 50);
    let mc = workloads::wl_maxcut_optsicom();
    bench_chain("optsicom pas L=8", mc.model.as_ref(), AlgoKind::Pas, SamplerKind::Gumbel, 8, 100);
    let rbm = workloads::wl_rbm();
    bench_chain("rbm784 block-gibbs", rbm.model.as_ref(), AlgoKind::BlockGibbs, SamplerKind::Gumbel, 1, 3);

    // Many-chain backend comparison (thread-per-chain vs batched pool).
    println!();
    print!("{}", mc2a::bench::many_chains(true).expect("many_chains"));
}
