//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction is seeded and deterministic: every workload
//! generator, MCMC chain and hardware-simulator URNG derives from a
//! [`Rng`] (xoshiro256**) seeded through SplitMix64, mirroring the
//! paper's hardware URNG (a free-running LFSR) closely enough for
//! statistical purposes while staying reproducible across runs.

/// SplitMix64 step — used to expand a single `u64` seed into the four
/// words of xoshiro256** state (and as a cheap standalone mixer).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create an RNG from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Deterministically derive the RNG for stream `stream` (chain id)
    /// from a base seed. This is *the* per-chain seeding rule used by
    /// every backend: it depends only on `(seed, stream)`, so chains
    /// are bit-identical regardless of thread count, batch size, or
    /// backend.
    pub fn fork(seed: u64, stream: u64) -> Rng {
        Rng::new(Self::fork_seed(seed, stream))
    }

    /// The 64-bit seed `fork` expands — for components (e.g. the
    /// hardware simulator's URNG) that take a raw seed rather than an
    /// [`Rng`].
    pub fn fork_seed(seed: u64, stream: u64) -> u64 {
        let mut sm = seed ^ stream.wrapping_mul(0xA24BAED4963EE407);
        splitmix64(&mut sm)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `(0, 1]` — safe as a `log()` argument.
    #[inline]
    pub fn uniform_open_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) + 1) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift, unbiased
    /// enough for MCMC use; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// A standard Gumbel(0,1) variate: `-ln(-ln(u))`.
    #[inline]
    pub fn gumbel_f32(&mut self) -> f32 {
        let u = self.uniform_open_f32();
        -(-(u.ln())).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical_weights(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        let mut u = self.uniform_f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            u -= wi;
            if u <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

/// Lane width of the batched kernels: chains are processed in groups
/// of `LANES` columns. 8 × f32 fills one AVX2 register (and two NEON
/// registers); the portable kernels are written over `[_; LANES]`
/// arrays so the compiler can keep whole chunks in vector registers.
pub const LANES: usize = 8;

/// `LANES` xoshiro256** generators stepped in lockstep, stored
/// structure-of-arrays (`s[word][lane]`).
///
/// Each lane reproduces exactly the draw sequence of the scalar [`Rng`]
/// it was loaded from — the recurrence is elementwise, so advancing the
/// lane generator N times and then [`store`](LaneRng::store)-ing back
/// leaves every scalar generator exactly N draws ahead. This is what
/// lets the vectorized batched kernels keep the per-chain bit-identity
/// pins: chain `c` still consumes the stream of `Rng::fork(seed, c)`
/// in the same order, just `LANES` chains at a time.
#[derive(Clone, Debug)]
pub struct LaneRng {
    s: [[u64; LANES]; 4],
}

impl LaneRng {
    /// Gather `LANES` scalar generators into lane order.
    pub fn load(rngs: &[Rng]) -> Self {
        assert_eq!(rngs.len(), LANES);
        let mut s = [[0u64; LANES]; 4];
        for (l, r) in rngs.iter().enumerate() {
            for w in 0..4 {
                s[w][l] = r.s[w];
            }
        }
        LaneRng { s }
    }

    /// Scatter the advanced lane states back to the scalar generators.
    pub fn store(&self, rngs: &mut [Rng]) {
        assert_eq!(rngs.len(), LANES);
        for (l, r) in rngs.iter_mut().enumerate() {
            for w in 0..4 {
                r.s[w] = self.s[w][l];
            }
        }
    }

    /// One xoshiro256** step on every lane.
    #[inline]
    pub fn next_u64(&mut self) -> [u64; LANES] {
        #[cfg(all(feature = "simd", target_arch = "x86_64", target_feature = "avx2"))]
        {
            unsafe { self.next_u64_avx2() }
        }
        #[cfg(not(all(feature = "simd", target_arch = "x86_64", target_feature = "avx2")))]
        {
            self.next_u64_portable()
        }
    }

    /// Portable elementwise step — identical recurrence to
    /// [`Rng::next_u64`], applied per lane. Written as straight-line
    /// per-word loops so it autovectorizes on stable Rust.
    #[inline]
    fn next_u64_portable(&mut self) -> [u64; LANES] {
        let [s0, s1, s2, s3] = &mut self.s;
        let mut out = [0u64; LANES];
        for (o, &v) in out.iter_mut().zip(s1.iter()) {
            *o = v.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        }
        let mut t = [0u64; LANES];
        for (tl, &v) in t.iter_mut().zip(s1.iter()) {
            *tl = v << 17;
        }
        for (a, &b) in s2.iter_mut().zip(s0.iter()) {
            *a ^= b;
        }
        for (a, &b) in s3.iter_mut().zip(s1.iter()) {
            *a ^= b;
        }
        for (a, &b) in s1.iter_mut().zip(s2.iter()) {
            *a ^= b;
        }
        for (a, &b) in s0.iter_mut().zip(s3.iter()) {
            *a ^= b;
        }
        for (a, &b) in s2.iter_mut().zip(t.iter()) {
            *a ^= b;
        }
        for v in s3.iter_mut() {
            *v = v.rotate_left(45);
        }
        out
    }

    /// AVX2 step: the 8 × u64 state words live in two `__m256i`
    /// registers per word. Multiplies by the small odd constants are
    /// shift-adds (5x = x + 4x, 9x = x + 8x), rotates are
    /// shift-or pairs — all exact u64 arithmetic, so the lane outputs
    /// are bit-identical to the portable step.
    #[cfg(all(feature = "simd", target_arch = "x86_64", target_feature = "avx2"))]
    #[inline]
    unsafe fn next_u64_avx2(&mut self) -> [u64; LANES] {
        use std::arch::x86_64::*;
        #[inline]
        unsafe fn rotl(x: __m256i, k: i32) -> __m256i {
            _mm256_or_si256(_mm256_slli_epi64(x, k), _mm256_srli_epi64(x, 64 - k))
        }
        let mut out = [0u64; LANES];
        for half in 0..2 {
            let base = half * 4;
            let s0 = _mm256_loadu_si256(self.s[0][base..].as_ptr() as *const __m256i);
            let s1 = _mm256_loadu_si256(self.s[1][base..].as_ptr() as *const __m256i);
            let s2 = _mm256_loadu_si256(self.s[2][base..].as_ptr() as *const __m256i);
            let s3 = _mm256_loadu_si256(self.s[3][base..].as_ptr() as *const __m256i);
            // result = rotl(s1 * 5, 7) * 9
            let x5 = _mm256_add_epi64(s1, _mm256_slli_epi64(s1, 2));
            let r7 = rotl(x5, 7);
            let res = _mm256_add_epi64(r7, _mm256_slli_epi64(r7, 3));
            _mm256_storeu_si256(out[base..].as_mut_ptr() as *mut __m256i, res);
            let t = _mm256_slli_epi64(s1, 17);
            let s2 = _mm256_xor_si256(s2, s0);
            let s3 = _mm256_xor_si256(s3, s1);
            let s1 = _mm256_xor_si256(s1, s2);
            let s0 = _mm256_xor_si256(s0, s3);
            let s2 = _mm256_xor_si256(s2, t);
            let s3 = rotl(s3, 45);
            _mm256_storeu_si256(self.s[0][base..].as_mut_ptr() as *mut __m256i, s0);
            _mm256_storeu_si256(self.s[1][base..].as_mut_ptr() as *mut __m256i, s1);
            _mm256_storeu_si256(self.s[2][base..].as_mut_ptr() as *mut __m256i, s2);
            _mm256_storeu_si256(self.s[3][base..].as_mut_ptr() as *mut __m256i, s3);
        }
        out
    }

    /// Uniform in `(0, 1]` per lane — same bit recipe as
    /// [`Rng::uniform_open_f32`].
    #[inline]
    pub fn uniform_open_f32(&mut self) -> [f32; LANES] {
        let raw = self.next_u64();
        let mut out = [0.0f32; LANES];
        for (o, &r) in out.iter_mut().zip(raw.iter()) {
            *o = ((r >> 40) + 1) as f32 * (1.0 / (1u64 << 24) as f32);
        }
        out
    }

    /// Standard Gumbel(0,1) per lane — same formula as
    /// [`Rng::gumbel_f32`] (`ln` is evaluated per lane; the surrounding
    /// arithmetic still vectorizes).
    #[inline]
    pub fn gumbel_f32(&mut self) -> [f32; LANES] {
        let u = self.uniform_open_f32();
        let mut out = [0.0f32; LANES];
        for (o, &v) in out.iter_mut().zip(u.iter()) {
            *o = -(-(v.ln())).ln();
        }
        out
    }

    /// Uniform integer in `[0, n)` per lane — same Lemire multiply-shift
    /// as [`Rng::below`].
    #[inline]
    pub fn below(&mut self, n: usize) -> [usize; LANES] {
        debug_assert!(n > 0);
        let raw = self.next_u64();
        let mut out = [0usize; LANES];
        for (o, &r) in out.iter_mut().zip(raw.iter()) {
            *o = ((r as u128 * n as u128) >> 64) as usize;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform_f32();
            assert!((0.0..1.0).contains(&u));
            let v = r.uniform_open_f32();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        // E[Gumbel(0,1)] = γ ≈ 0.5772
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.gumbel_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn categorical_weights_proportional() {
        let mut r = Rng::new(9);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[r.categorical_weights(&w)] += 1;
        }
        let f2 = counts[2] as f64 / 50_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "f2={f2}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::fork(1234, 0);
        let mut b = Rng::fork(1234, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_is_a_pure_function_of_seed_and_stream() {
        let mut a = Rng::fork(7, 3);
        let mut b = Rng::fork(7, 3);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Streams differ from the base stream and from `seed + i`.
        assert_ne!(Rng::fork(7, 0).next_u64(), Rng::new(7).next_u64());
        assert_ne!(Rng::fork(7, 1).next_u64(), Rng::new(8).next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    fn forked_lanes(seed: u64) -> Vec<Rng> {
        (0..LANES as u64).map(|c| Rng::fork(seed, c)).collect()
    }

    #[test]
    fn lane_rng_matches_scalar_streams_bitwise() {
        let mut scalars = forked_lanes(0xC0FFEE);
        let mut lanes = LaneRng::load(&scalars);
        for _ in 0..256 {
            let got = lanes.next_u64();
            for (l, s) in scalars.iter_mut().enumerate() {
                assert_eq!(got[l], s.next_u64());
            }
        }
    }

    #[test]
    fn lane_rng_store_leaves_scalars_advanced() {
        let mut scalars = forked_lanes(42);
        let mut reference = scalars.clone();
        let mut lanes = LaneRng::load(&scalars);
        for _ in 0..17 {
            lanes.next_u64();
        }
        lanes.store(&mut scalars);
        // Advancing the reference generators 17 times by hand must land
        // on the same state: the next draws agree.
        for r in reference.iter_mut() {
            for _ in 0..17 {
                r.next_u64();
            }
        }
        for (a, b) in scalars.iter_mut().zip(reference.iter_mut()) {
            for _ in 0..8 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn lane_uniform_gumbel_below_match_scalar_bitwise() {
        let mut scalars = forked_lanes(7);
        let mut lanes = LaneRng::load(&scalars);
        for round in 0..64 {
            match round % 3 {
                0 => {
                    let got = lanes.uniform_open_f32();
                    for (l, s) in scalars.iter_mut().enumerate() {
                        assert_eq!(got[l].to_bits(), s.uniform_open_f32().to_bits());
                    }
                }
                1 => {
                    let got = lanes.gumbel_f32();
                    for (l, s) in scalars.iter_mut().enumerate() {
                        assert_eq!(got[l].to_bits(), s.gumbel_f32().to_bits());
                    }
                }
                _ => {
                    let got = lanes.below(13);
                    for (l, s) in scalars.iter_mut().enumerate() {
                        assert_eq!(got[l], s.below(13));
                    }
                }
            }
        }
    }
}
