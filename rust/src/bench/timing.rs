//! Minimal measured-benchmark harness (criterion is unavailable in the
//! offline vendor set): warmup + N timed iterations, mean / median /
//! min reporting. Used by the `cargo bench` targets and the measured
//! CPU rows of Fig. 14.

use std::time::Instant;

/// Timing statistics over the measured iterations.
#[derive(Clone, Debug)]
pub struct BenchStat {
    /// Per-iteration durations in nanoseconds, sorted ascending.
    pub samples_ns: Vec<u128>,
}

impl BenchStat {
    /// Mean milliseconds per iteration.
    pub fn mean_ms(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u128>() as f64 / self.samples_ns.len() as f64 / 1e6
    }

    /// Median milliseconds per iteration.
    pub fn median_ms(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns[self.samples_ns.len() / 2] as f64 / 1e6
    }

    /// Fastest iteration in milliseconds.
    pub fn min_ms(&self) -> f64 {
        self.samples_ns.first().map_or(0.0, |&n| n as f64 / 1e6)
    }

    /// One-line summary.
    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: mean {:.3} ms, median {:.3} ms, min {:.3} ms ({} iters)",
            self.mean_ms(),
            self.median_ms(),
            self.min_ms(),
            self.samples_ns.len()
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `iters` times measured.
pub fn bench_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStat {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos());
    }
    samples.sort_unstable();
    BenchStat {
        samples_ns: samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench_fn(1, 9, || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        assert_eq!(s.samples_ns.len(), 9);
        assert!(s.min_ms() <= s.median_ms());
        assert!(s.mean_ms() > 0.05);
        assert!(s.summary("x").contains("mean"));
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = BenchStat {
            samples_ns: Vec::new(),
        };
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.median_ms(), 0.0);
        assert_eq!(s.min_ms(), 0.0);
    }
}
