//! Benchmark harness: regenerates every table and figure of the
//! paper's evaluation (§III Fig. 5, §IV Fig. 6, §VI Fig. 11–15,
//! Table I). Each generator returns the formatted report as a `String`
//! (and the CLI prints it), so integration tests can assert on the
//! content. Absolute numbers reflect this testbed; the paper's
//! reported values are printed alongside where the comparison is the
//! point (see EXPERIMENTS.md).

mod timing;

pub use timing::{bench_fn, BenchStat};

use std::fmt::Write as _;

use crate::baselines::{self, BaselineWorkload};

use crate::energy::{EnergyModel, MaxCutModel, PottsGrid};
use crate::engine::{Engine, Mc2aError};
use crate::graph::erdos_renyi_with_edges;
use crate::isa::HwConfig;
use crate::mcmc::sampler::{sampler_tv_distance, GumbelLutSampler, GumbelSampler};
use crate::mcmc::{
    build_algo, build_batch_algo, run_to_accuracy, AlgoKind, AnnealPolicy, BetaSchedule, Chain,
    ChainBatch, Ladder, SamplerKind,
};
use crate::rng::{Rng, LANES};
use crate::roofline::{self, dse_sweep, WorkloadProfile};
use crate::runtime::Runtime;
use crate::sim::su::fig13_sweep;
use crate::workloads::{self, Workload};

/// Every bench name `mc2a bench` accepts, in the order `all` runs
/// them (the `all` meta-name itself excluded).
pub const BENCH_NAMES: &[&str] = &[
    "fig5", "fig6", "fig11", "fig12", "fig13", "fig14", "fig15", "chains", "serve", "cores",
    "anneal", "temper", "headline",
];

/// Drop a machine-readable benchmark artifact (`BENCH_<name>.json`) at
/// the repo root, so CI and successive PRs have a throughput trajectory
/// to diff. The root is found by probing for `ROADMAP.md` in `.` then
/// `..` (the crate lives one level below it); a missing root or a
/// failed write degrades to a warning line — benches must not fail
/// over artifact plumbing. Also used by `mc2a profile` for
/// `PROFILE_roofline.json`.
pub fn write_bench_artifact(file: &str, json: &str) -> String {
    let root = if std::path::Path::new("ROADMAP.md").exists() {
        std::path::Path::new(".")
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        std::path::Path::new("..")
    } else {
        return format!("(skipped {file}: repo root not found from {:?})", std::env::current_dir());
    };
    let path = root.join(file);
    match std::fs::write(&path, json) {
        Ok(()) => format!("wrote {}", path.display()),
        Err(e) => format!("(failed to write {}: {e})", path.display()),
    }
}

/// Table I: the workload suite, regenerated from the actual generators.
pub fn table1(full: bool) -> String {
    let suite = if full {
        workloads::suite_full()
    } else {
        workloads::suite_small()
    };
    let mut out = String::new();
    writeln!(out, "# Table I — workloads ({})", if full { "full scale" } else { "small scale" }).unwrap();
    writeln!(out, "{:<12} {:<10} {:>8} {:>9} {:>5}  application", "name", "model", "nodes", "edges", "alg").unwrap();
    for wl in &suite {
        writeln!(
            out,
            "{:<12} {:<10} {:>8} {:>9} {:>5}  {}",
            wl.name,
            wl.model_kind,
            wl.nodes(),
            wl.edges(),
            wl.algorithm.name(),
            wl.application
        )
        .unwrap();
    }
    out
}

/// The three COP instances of Fig. 5 (scaled-down in quick mode so the
/// sweep completes in seconds).
fn fig5_workloads(quick: bool) -> Vec<Workload> {
    if quick {
        vec![
            Workload {
                name: "MaxClique",
                model_kind: "Max clique",
                application: "fig5 quick",
                algorithm: AlgoKind::Pas,
                pas_flips: 4,
                model: Box::new(crate::energy::MaxCliqueModel::new(
                    crate::graph::power_law_graph(60, 700, 0x7717),
                    1.5,
                    None,
                )),
            },
            Workload {
                name: "MaxCut",
                model_kind: "MaxCut",
                application: "fig5 quick",
                algorithm: AlgoKind::Pas,
                pas_flips: 4,
                model: Box::new(MaxCutModel::new(
                    erdos_renyi_with_edges(125, 375, 0x097),
                    None,
                )),
            },
            Workload {
                name: "MIS",
                model_kind: "MIS",
                application: "fig5 quick",
                algorithm: AlgoKind::Pas,
                pas_flips: 4,
                model: Box::new(crate::energy::MisModel::new(
                    erdos_renyi_with_edges(120, 530, 0xe7),
                    1.5,
                    None,
                )),
            },
        ]
    } else {
        vec![
            workloads::wl_maxclique_twitter(),
            workloads::wl_maxcut_optsicom(),
            workloads::wl_mis_er(),
        ]
    }
}

/// Fig. 5(a,b): operations and algorithmic steps to reach the target
/// accuracy for MH / BG / PAS on the three COP workloads, plus (c) the
/// compute/sample/memory breakdown and (d) the modeled CPU-vs-GPU
/// latency gap.
pub fn fig5(quick: bool, target: f64) -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 5 — MCMC hardware challenges (target accuracy {target})").unwrap();
    writeln!(
        out,
        "{:<10} {:<5} {:>12} {:>14} {:>12} {:>12}",
        "workload", "alg", "steps", "ops", "bytes", "best/target"
    )
    .unwrap();

    // Accuracy = best-so-far / best-found-overall (best_known unset on
    // synthetic instances, so calibrate per workload with a long PAS run).
    for wl in fig5_workloads(quick) {
        let max_steps = if quick { 400 } else { 2000 };
        let schedule = BetaSchedule::Linear {
            from: 0.2,
            to: 3.0,
            steps: max_steps / 2,
        };
        // Calibration: the best objective any algorithm reaches here.
        let mut best = f64::NEG_INFINITY;
        let mut traces = Vec::new();
        for algo in [AlgoKind::Mh, AlgoKind::BlockGibbs, AlgoKind::Pas] {
            let a = build_algo(algo, SamplerKind::Gumbel, wl.model.as_ref(), wl.pas_flips);
            let tr = run_to_accuracy(wl.model.as_ref(), a, schedule, f64::INFINITY, max_steps, 10, 0xF16);
            best = best.max(tr.points.last().unwrap().best_objective);
            traces.push((algo, tr));
        }
        for (algo, tr) in traces {
            // Find the first trace point reaching target × best.
            let goal = target * best;
            let hit = tr.points.iter().find(|p| p.best_objective >= goal);
            match hit {
                Some(p) => writeln!(
                    out,
                    "{:<10} {:<5} {:>12} {:>14} {:>12} {:>12.3}",
                    wl.name,
                    algo.name(),
                    p.steps,
                    p.ops,
                    p.bytes,
                    p.best_objective / best
                )
                .unwrap(),
                None => writeln!(
                    out,
                    "{:<10} {:<5} {:>12} {:>14} {:>12} {:>12}",
                    wl.name,
                    algo.name(),
                    "-",
                    "-",
                    "-",
                    "miss"
                )
                .unwrap(),
            }
        }
    }

    // (c) compute/sampling ratio + memory per step for MaxClique.
    writeln!(out, "\n## Fig. 5c — per-step cost split (MaxClique)").unwrap();
    let wl = &fig5_workloads(quick)[0];
    for algo in [AlgoKind::Mh, AlgoKind::BlockGibbs, AlgoKind::Pas] {
        let a = build_algo(algo, SamplerKind::Gumbel, wl.model.as_ref(), wl.pas_flips);
        let mut chain = crate::mcmc::Chain::new(
            wl.model.as_ref(),
            a,
            BetaSchedule::Constant(1.0),
            3,
        );
        chain.run(10);
        let c = chain.stats.cost;
        writeln!(
            out,
            "{:<5} ops/step={:<10} samples/step={:<8} bytes/step={:<10} sample-share≈{:.1}%",
            algo.name(),
            c.ops / 10,
            c.samples / 10,
            c.bytes / 10,
            // sampler ops ≈ samples × mean dist (2) vs total
            100.0 * (c.samples as f64 * 2.0) / c.ops.max(1) as f64,
        )
        .unwrap();
    }

    // (d) CPU vs GPU latency (modeled; the measured CPU path is in fig14).
    writeln!(out, "\n## Fig. 5d — modeled CPU vs GPU step latency").unwrap();
    for wl in fig5_workloads(quick) {
        let w = BaselineWorkload::from_model(wl.model.as_ref(), wl.algorithm, true);
        let cpu = baselines::cpu_xeon().throughput_gsps(&w);
        let gpu = baselines::gpu_rtx().throughput_gsps(&w);
        writeln!(
            out,
            "{:<10} cpu={:.4} GS/s gpu={:.4} GS/s cpu/gpu={:.1}x",
            wl.name,
            cpu,
            gpu,
            cpu / gpu.max(1e-12)
        )
        .unwrap();
    }
    out
}

/// Fig. 6: the 3D roofline on the Ising example, with the paper's four
/// hardware configurations and the golden apex.
pub fn fig6() -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 6 — 3D MCMC roofline (Ising example: CI=0.1 S/OP, MI=0.05 S/B)").unwrap();
    let w = WorkloadProfile::fig6_ising_example();
    let configs: Vec<(&str, HwConfig)> = vec![
        (
            "balanced (golden)",
            HwConfig {
                t: 1,
                k: 3,
                s: 2,
                m: 1,
                bw_words: 5,
                clock_ghz: 0.5,
                rf_banks: 4,
                rf_regs_per_bank: 16,
                lut_size: 16,
                lut_bits: 8,
                max_dist_size: 256,
            },
        ),
        ("CU-starved", {
            let mut h = HwConfig::paper_default();
            h.t = 1;
            h.k = 0;
            h
        }),
        ("BW-starved", {
            let mut h = HwConfig::paper_default();
            h.bw_words = 1;
            h
        }),
        ("SU-starved", {
            let mut h = HwConfig::paper_default();
            h.s = 1;
            h.m = 0;
            h
        }),
    ];
    writeln!(
        out,
        "{:<18} {:>10} {:>10} {:>10} {:>10}  bottleneck",
        "config", "TP GS/s", "SU roof", "CU roof", "MEM roof"
    )
    .unwrap();
    for (name, hw) in configs {
        let p = roofline::evaluate(&hw, &w);
        writeln!(
            out,
            "{:<18} {:>10.3} {:>10.3} {:>10.3} {:>10.3}  {:?}",
            name, p.tp_gsps, p.su_roof, p.cu_roof, p.mem_roof, p.bottleneck
        )
        .unwrap();
    }
    let (ci, mi) = roofline::apex(&HwConfig::paper_default(), 2.0, false);
    writeln!(out, "\npaper-default apex: CI*={ci:.4} S/OP, MI*={mi:.4} S/B").unwrap();
    out
}

/// Fig. 11: the DSE that selects T=64, K=3, S=64, M=6, B=320.
pub fn fig11() -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 11 — roofline-guided design-space exploration").unwrap();
    let suite = workloads::suite_small();
    let profiles: Vec<WorkloadProfile> = suite
        .iter()
        .map(|wl| WorkloadProfile::from_model(wl.model.as_ref(), wl.algorithm))
        .collect();
    writeln!(out, "\n## workload positions").unwrap();
    for (wl, p) in suite.iter().zip(&profiles) {
        writeln!(
            out,
            "{:<14} CI={:.4} MI={:.4} dist={:<7.0} mode={}",
            wl.name,
            p.ci,
            p.mi,
            p.dist_size,
            if p.spatial { "spatial" } else { "temporal" }
        )
        .unwrap();
    }
    let budget = roofline::area_units(&HwConfig::paper_default()) * 1.01;
    let res = dse_sweep(&profiles, budget);
    let c = &res.candidates[res.chosen];
    writeln!(
        out,
        "\nchosen: T={} K={} S={} M={} B={}  (paper: T=64 K=3 S=64 M=6 B=320)",
        c.hw.t, c.hw.k, c.hw.s, c.hw.m, c.hw.bw_words
    )
    .unwrap();
    writeln!(out, "geomean TP = {:.3} GS/s over {} candidates", c.geomean_tp, res.candidates.len()).unwrap();
    out
}

/// Fig. 12: Gumbel-LUT size/precision ablation — TV distance on random
/// distributions and MaxCut solution quality.
pub fn fig12(quick: bool) -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 12 — Gumbel LUT size / precision ablation").unwrap();
    let sizes = [4usize, 8, 16, 32, 64];
    let bits = [4u32, 6, 8, 16];
    let draws = if quick { 20_000 } else { 200_000 };

    // (b) random distributions: mean TV distance to exact softmax.
    writeln!(out, "\n## (b) mean TV distance, {} random size-8 distributions × {} draws", 20, draws).unwrap();
    write!(out, "{:<8}", "size\\bits").unwrap();
    for b in bits {
        write!(out, "{:>9}", b).unwrap();
    }
    writeln!(out).unwrap();
    let mut rng = Rng::new(0xF12);
    let dists: Vec<Vec<f32>> = (0..20)
        .map(|_| (0..8).map(|_| rng.uniform_f32() * 4.0).collect())
        .collect();
    for size in sizes {
        write!(out, "{:<8}", size).unwrap();
        for b in bits {
            let mut s = GumbelLutSampler::new(size, b);
            let tv: f64 = dists
                .iter()
                .map(|e| sampler_tv_distance(&mut s, e, 1.0, draws / 20, &mut rng))
                .sum::<f64>()
                / dists.len() as f64;
            write!(out, "{:>9.4}", tv).unwrap();
        }
        writeln!(out).unwrap();
    }
    // exact-sampler floor for reference
    let mut exact = GumbelSampler::default();
    let tv0: f64 = dists
        .iter()
        .map(|e| sampler_tv_distance(&mut exact, e, 1.0, draws / 20, &mut rng))
        .sum::<f64>()
        / dists.len() as f64;
    writeln!(out, "{:<8}{:>9.4}  (exact Gumbel floor)", "exact", tv0).unwrap();

    // (a) MaxCut quality vs LUT config.
    writeln!(out, "\n## (a) MaxCut best-cut ratio vs exact sampler").unwrap();
    let g = erdos_renyi_with_edges(125, 375, 0x097);
    let m = MaxCutModel::new(g, None);
    let steps = if quick { 150 } else { 600 };
    let schedule = BetaSchedule::Linear {
        from: 0.3,
        to: 3.0,
        steps: steps / 2,
    };
    let run = |kind: SamplerKind| {
        let a = build_algo(AlgoKind::Gibbs, kind, &m, 1);
        let mut chain = crate::mcmc::Chain::new(&m, a, schedule, 0xAB);
        chain.run(steps);
        chain.best_objective
    };
    let exact_cut = run(SamplerKind::Gumbel);
    for size in sizes {
        let cut = run(SamplerKind::GumbelLut { size, bits: 8 });
        writeln!(out, "size={:<3} bits=8: cut={:.0} ratio={:.3}", size, cut, cut / exact_cut).unwrap();
    }
    writeln!(out, "exact: cut={exact_cut:.0}").unwrap();
    writeln!(out, "\npaper conclusion check: size-16 / 8-bit is within a few % of exact").unwrap();
    out
}

/// Fig. 13: Gumbel vs CDF sampler-unit throughput over distribution size.
pub fn fig13() -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 13 — Gumbel vs CDF sampler unit").unwrap();
    let hw = HwConfig::paper_default();
    writeln!(
        out,
        "{:>5} {:>14} {:>10} {:>14} {:>12}",
        "N", "CDF sps", "CDF util", "Gumbel sps", "Gumbel util"
    )
    .unwrap();
    for row in fig13_sweep(&hw, &[8, 16, 32, 64, 128, 256]) {
        writeln!(
            out,
            "{:>5} {:>14.3e} {:>10.3} {:>14.3e} {:>12.3}",
            row.n, row.cdf_sps, row.cdf_util, row.gumbel_sps, row.gumbel_util
        )
        .unwrap();
    }
    writeln!(out, "\n(CDF fails at N=256: CDT register file exhausted — paper Fig. 13)").unwrap();
    out
}

/// One Fig. 14/15 evaluation row.
pub struct PlatformRow {
    /// Platform name.
    pub name: String,
    /// Throughput in GS/s (0 = unsupported).
    pub gsps: f64,
    /// Energy efficiency in GS/s/W.
    pub gsps_per_watt: f64,
}

/// Evaluate one workload on MC²A (cycle-accurate sim, via the engine's
/// accelerator backend) and all baselines.
pub fn evaluate_platforms(
    wl: &Workload,
    iters: usize,
    irregular: bool,
) -> Result<Vec<PlatformRow>, Mc2aError> {
    let mut rows = Vec::new();
    // MC²A: compile + simulate through the engine.
    let hw = HwConfig::paper_default();
    let metrics = Engine::for_model(wl.model.as_ref())
        .algo(wl.algorithm)
        .pas_flips(wl.pas_flips)
        .steps(iters)
        .seed(0x14)
        .accelerator(hw)
        .build()?
        .run()?;
    let rep = metrics.chains[0].sim.as_ref().ok_or_else(|| {
        Mc2aError::InvalidConfig("accelerator backend returned no sim report".into())
    })?;
    rows.push(PlatformRow {
        name: "MC2A".into(),
        gsps: rep.gsps(&hw),
        gsps_per_watt: rep.gsps_per_watt(&hw),
    });
    let w = BaselineWorkload::from_model(wl.model.as_ref(), wl.algorithm, irregular);
    for b in [
        baselines::cpu_xeon(),
        baselines::gpu_rtx(),
        baselines::gpu_v100(),
        baselines::tpu_v3(),
    ]
    .into_iter()
    .chain(baselines::all_accelerators())
    {
        rows.push(PlatformRow {
            name: b.name.into(),
            gsps: b.throughput_gsps(&w),
            gsps_per_watt: b.gsps_per_watt(&w),
        });
    }
    Ok(rows)
}

/// Fig. 14: throughput/latency comparison across the workload suite.
pub fn fig14(quick: bool) -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 14 — throughput comparison (GS/s)").unwrap();
    let suite = if quick {
        workloads::suite_small()
    } else {
        workloads::suite_full()
    };
    let iters = if quick { 20 } else { 50 };
    for wl in &suite {
        let irregular = matches!(wl.model_kind, "Bayes Net" | "MIS" | "Max clique" | "MaxCut" | "EBM");
        writeln!(out, "\n## {} ({}, {})", wl.name, wl.model_kind, wl.algorithm.name()).unwrap();
        let rows = match evaluate_platforms(wl, iters, irregular) {
            Ok(rows) => rows,
            Err(e) => {
                writeln!(out, "evaluation failed: {e}").unwrap();
                continue;
            }
        };
        let mc2a = rows[0].gsps;
        for r in &rows {
            if r.gsps == 0.0 {
                writeln!(out, "{:<12} {:>12}  (unsupported)", r.name, "-").unwrap();
            } else {
                writeln!(
                    out,
                    "{:<12} {:>12.4e}  MC2A speedup {:>8.1}x",
                    r.name,
                    r.gsps,
                    mc2a / r.gsps
                )
                .unwrap();
            }
        }
    }
    // Measured CPU via the AOT/PJRT path, when artifacts exist.
    writeln!(out, "\n## measured CPU (JAX→HLO→PJRT, this host)").unwrap();
    match Runtime::load("artifacts") {
        Ok(rt) => {
            out.push_str(&measured_cpu_rows(&rt));
        }
        Err(e) => {
            writeln!(out, "artifacts unavailable ({e}); run `make artifacts`").unwrap();
        }
    }
    out
}

/// Measured CPU throughput through the PJRT runtime (the honest-CPU
/// column of Fig. 14): Ising-64² Block Gibbs and MaxCut-128 PAS.
pub fn measured_cpu_rows(rt: &Runtime) -> String {
    let mut out = String::new();
    let mut rng = Rng::new(0xC19);

    // Ising 64×64, 32 sweeps per call.
    let n = 64 * 64;
    let steps = 32;
    let spins: Vec<f32> = (0..n)
        .map(|_| if rng.below(2) == 1 { 1.0 } else { -1.0 })
        .collect();
    let uniforms: Vec<f32> = (0..steps * 2 * n).map(|_| rng.uniform_open_f32()).collect();
    let beta = [0.7f32];
    let coupling = [1.0f32];
    let stat = bench_fn(3, 10, || {
        rt.execute_f32(
            "ising_chain",
            &[&spins, &uniforms, &beta, &coupling],
        )
        .expect("ising_chain")
    });
    let updates = (steps * n) as f64;
    writeln!(
        out,
        "ising_chain   (64x64, {steps} sweeps/call): {:.3} ms/call → {:.4} GS/s",
        stat.mean_ms(),
        updates / (stat.mean_ms() / 1e3) / 1e9
    )
    .unwrap();

    // MaxCut 128, PAS chain.
    let nn = 128;
    let g = erdos_renyi_with_edges(nn, 640, 0x14c);
    let mut adj = vec![0.0f32; nn * nn];
    for i in 0..nn {
        for &j in g.neighbors(i) {
            adj[i * nn + j as usize] = 1.0;
        }
    }
    let x: Vec<f32> = (0..nn).map(|_| rng.below(2) as f32).collect();
    let u: Vec<f32> = (0..32 * nn).map(|_| rng.uniform_open_f32()).collect();
    let stat = bench_fn(3, 10, || {
        rt.execute_f32("maxcut_pas_chain", &[&adj, &x, &u, &[1.0f32]])
            .expect("maxcut_pas_chain")
    });
    let flips = (32 * 8) as f64;
    writeln!(
        out,
        "maxcut_chain  (N=128, 32 steps/call):      {:.3} ms/call → {:.4} GS/s",
        stat.mean_ms(),
        flips / (stat.mean_ms() / 1e3) / 1e9
    )
    .unwrap();
    out
}

/// Fig. 15: energy efficiency (GS/s/W) on structured graphs.
pub fn fig15(quick: bool) -> String {
    let mut out = String::new();
    writeln!(out, "# Fig. 15 — energy efficiency on structured graphs (GS/s/W)").unwrap();
    let wl = workloads::wl_image_seg(!quick);
    let rows = match evaluate_platforms(&wl, if quick { 10 } else { 30 }, false) {
        Ok(rows) => rows,
        Err(e) => {
            writeln!(out, "evaluation failed: {e}").unwrap();
            return out;
        }
    };
    let mc2a = rows[0].gsps_per_watt;
    for r in &rows {
        if r.gsps_per_watt > 0.0 {
            writeln!(
                out,
                "{:<12} {:>12.4e} GS/s/W   MC2A gain {:>10.1}x",
                r.name,
                r.gsps_per_watt,
                mc2a / r.gsps_per_watt
            )
            .unwrap();
        } else {
            writeln!(out, "{:<12} {:>12}  (unsupported)", r.name, "-").unwrap();
        }
    }
    writeln!(out, "\npaper: avg 10000x / 355x / 197.5x vs CPU / GPU / TPU").unwrap();
    out
}

/// One row of the per-kernel grid: kernel label plus measured scalar
/// and batched samples/sec.
struct KernelRate {
    kernel: String,
    scalar_sps: f64,
    batched_sps: f64,
}

/// Raw single-threaded kernel throughput: `k` scalar [`Chain`]s stepped
/// one after another versus one SoA [`ChainBatch`] driving the
/// lane-parallel batched kernels, over a (workload × algorithm ×
/// sampler) grid. Neither side uses a thread pool, so the ratio
/// isolates the SIMD + SoA kernel speedup itself rather than
/// scheduling effects.
fn kernel_rates(quick: bool) -> Vec<KernelRate> {
    use std::time::Instant;
    let k = 32usize;
    let sweeps = if quick { 4 } else { 16 };
    let schedule = BetaSchedule::Constant(0.8);
    let seed = 0x51AD;
    let ising = PottsGrid::new(32, 32, 2, 0.6);
    let cut = MaxCutModel::new(erdos_renyi_with_edges(256, 1024, 11), None);
    let lut = SamplerKind::GumbelLut { size: 16, bits: 8 };
    let combos: [(&str, &dyn EnergyModel, AlgoKind, SamplerKind, usize); 5] = [
        ("ising32/gibbs/gumbel", &ising, AlgoKind::Gibbs, SamplerKind::Gumbel, 1),
        ("ising32/gibbs/lut:16:8", &ising, AlgoKind::Gibbs, lut, 1),
        ("maxcut256/gibbs/gumbel", &cut, AlgoKind::Gibbs, SamplerKind::Gumbel, 1),
        ("maxcut256/ag/gumbel", &cut, AlgoKind::AsyncGibbs, SamplerKind::Gumbel, 1),
        ("maxcut256/pas/gumbel", &cut, AlgoKind::Pas, SamplerKind::Gumbel, 4),
    ];
    let mut rows = Vec::new();
    for (kernel, model, algo_kind, sampler, flips) in combos {
        let scalar_sps = {
            let mut chains: Vec<Chain<'_>> = (0..k)
                .map(|c| {
                    Chain::with_rng(
                        model,
                        build_algo(algo_kind, sampler, model, flips),
                        schedule,
                        Rng::fork(seed, c as u64),
                    )
                })
                .collect();
            for c in &mut chains {
                c.run(1); // warmup: page-in + allocator
            }
            let before: u64 = chains.iter().map(|c| c.stats.cost.samples).sum();
            let t0 = Instant::now();
            for c in &mut chains {
                c.run(sweeps);
            }
            let wall = t0.elapsed().as_secs_f64().max(1e-12);
            let after: u64 = chains.iter().map(|c| c.stats.cost.samples).sum();
            (after - before) as f64 / wall
        };
        let batched_sps = {
            let mut algo =
                build_batch_algo(algo_kind, sampler, model, flips).expect("batched kernel");
            let mut batch = ChainBatch::new(model, schedule, seed, 0, k, None);
            batch.run(algo.as_mut(), 1); // warmup
            let before: u64 = batch.stats.iter().map(|s| s.cost.samples).sum();
            let t0 = Instant::now();
            batch.run(algo.as_mut(), sweeps);
            let wall = t0.elapsed().as_secs_f64().max(1e-12);
            let after: u64 = batch.stats.iter().map(|s| s.cost.samples).sum();
            (after - before) as f64 / wall
        };
        rows.push(KernelRate { kernel: kernel.to_string(), scalar_sps, batched_sps });
    }
    rows
}

/// Many-chain throughput: the thread-per-chain [`SoftwareBackend`]
/// versus the batched work-stealing backend on a 1024-variable Ising
/// Gibbs sweep, 64 chains — the acceptance benchmark for the batched
/// execution path, reproducible with `mc2a bench chains` (or
/// `cargo bench --bench many_chain`).
///
/// Emits a CSV block with **samples/sec** and **chains/sec** per
/// backend (not just wall time), so successive PRs have a throughput
/// trajectory to track.
///
/// [`SoftwareBackend`]: crate::engine::SoftwareBackend
pub fn many_chains(quick: bool) -> Result<String, Mc2aError> {
    let mut out = String::new();
    let chains = 64usize;
    let steps = if quick { 10 } else { 50 };
    let model = PottsGrid::new(32, 32, 2, 0.6); // 1024 RVs, 4-neighborhood
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    writeln!(
        out,
        "# many-chain throughput — {chains} chains × {steps} Gibbs sweeps, 32×32 Ising (1024 RVs)"
    )
    .unwrap();
    writeln!(
        out,
        "backend,chains,batch,threads,steps,wall_ms,samples_per_sec,chains_per_sec"
    )
    .unwrap();
    // Batch so the pool's work items cover every core: `chains/batch`
    // items ≈ `threads`, and the CSV reports the configuration that
    // actually runs.
    let pool_batch = chains.div_ceil(threads).max(1);
    let mut rates = Vec::new();
    for (label, batch) in [("software", 0usize), ("batched", pool_batch)] {
        // One expression feeds both the engine and the CSV, so the
        // reported thread count is the one that actually ran.
        let pool_threads = if batch == 0 {
            chains // one OS thread per chain
        } else {
            threads.min(chains.div_ceil(batch))
        };
        let mut builder = Engine::for_model(&model)
            .algo(AlgoKind::Gibbs)
            .sampler(SamplerKind::Gumbel)
            .schedule(BetaSchedule::Constant(0.6))
            .steps(steps)
            .chains(chains)
            .seed(0xC4A1);
        if batch > 0 {
            builder = builder.batch(batch).threads(pool_threads);
        }
        let mut engine = builder.build()?;
        engine.run()?; // warmup (page-in, allocator, thread spawn)
        let metrics = engine.run()?;
        let wall = metrics.wall.as_secs_f64().max(1e-12);
        let samples: u64 = metrics.chains.iter().map(|c| c.stats.cost.samples).sum();
        let samples_per_sec = samples as f64 / wall;
        let chains_per_sec = chains as f64 / wall;
        writeln!(
            out,
            "{label},{chains},{},{pool_threads},{steps},{:.3},{:.4e},{:.2}",
            if batch == 0 { 1 } else { batch },
            wall * 1e3,
            samples_per_sec,
            chains_per_sec,
        )
        .unwrap();
        rates.push(samples_per_sec);
    }
    // Re-run the batched row with the metrics registry hot, so the
    // recorded cost of `engine::telemetry` (acceptance target: < 2%
    // throughput overhead) tracks across PRs.
    let telemetry_sps = {
        let reg = crate::engine::telemetry::metrics();
        reg.set_enabled(true);
        let mut engine = Engine::for_model(&model)
            .algo(AlgoKind::Gibbs)
            .sampler(SamplerKind::Gumbel)
            .schedule(BetaSchedule::Constant(0.6))
            .steps(steps)
            .chains(chains)
            .seed(0xC4A1)
            .batch(pool_batch)
            .threads(threads.min(chains.div_ceil(pool_batch)))
            .build()?;
        engine.run()?; // warmup with telemetry already on
        let metrics = engine.run()?;
        reg.set_enabled(false);
        reg.reset();
        let wall = metrics.wall.as_secs_f64().max(1e-12);
        let samples: u64 = metrics.chains.iter().map(|c| c.stats.cost.samples).sum();
        samples as f64 / wall
    };
    // Per-kernel grid: single-threaded scalar loop vs SoA batch, so
    // the reported ratio is the SIMD + layout speedup itself.
    let kernels = kernel_rates(quick);
    writeln!(
        out,
        "\n# per-kernel single-thread samples/sec — 32 chains, scalar loop vs SoA batch \
         (LANES = {LANES}, simd feature {})",
        if cfg!(feature = "simd") { "on" } else { "off" }
    )
    .unwrap();
    writeln!(out, "kernel,scalar_samples_per_sec,batched_samples_per_sec,kernel_speedup").unwrap();
    for r in &kernels {
        writeln!(
            out,
            "{},{:.4e},{:.4e},{:.2}",
            r.kernel,
            r.scalar_sps,
            r.batched_sps,
            r.batched_sps / r.scalar_sps.max(1e-12)
        )
        .unwrap();
    }
    if let [scalar, batched] = rates[..] {
        writeln!(
            out,
            "\nbatched/software samples-per-sec speedup: {:.2}x",
            batched / scalar.max(1e-12)
        )
        .unwrap();
        let overhead_pct = 100.0 * (batched / telemetry_sps.max(1e-12) - 1.0);
        writeln!(
            out,
            "telemetry-enabled batched run: {telemetry_sps:.4e} samples/sec \
             ({overhead_pct:+.2}% overhead vs telemetry off)"
        )
        .unwrap();
        let kernel_json: Vec<String> = kernels
            .iter()
            .map(|r| {
                format!(
                    "{{\"kernel\":\"{}\",\"scalar_samples_per_sec\":{},\
                     \"batched_samples_per_sec\":{},\"speedup\":{:.4}}}",
                    r.kernel,
                    r.scalar_sps,
                    r.batched_sps,
                    r.batched_sps / r.scalar_sps.max(1e-12)
                )
            })
            .collect();
        let json = format!(
            "{{\"bench\":\"chains\",\"quick\":{quick},\"chains\":{chains},\"steps\":{steps},\
             \"threads\":{threads},\"lanes\":{LANES},\"simd_feature\":{},\
             \"software_samples_per_sec\":{scalar},\"batched_samples_per_sec\":{batched},\
             \"batched_speedup\":{:.4},\"telemetry_samples_per_sec\":{telemetry_sps},\
             \"telemetry_overhead_pct\":{overhead_pct:.4},\"kernels\":[{}]}}\n",
            cfg!(feature = "simd"),
            batched / scalar.max(1e-12),
            kernel_json.join(",")
        );
        writeln!(out, "{}", write_bench_artifact("BENCH_chains.json", &json)).unwrap();
    }
    Ok(out)
}

/// Nearest-rank percentile of an ascending-sorted sample (`q` in
/// `[0, 1]`); 0.0 on an empty slice.
fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Job-server throughput: a mixed queue of three heterogeneous
/// registry workloads (COP / Potts-MRF / Bayesian-network) at three
/// priority classes, submitted up front and drained through one shared
/// [`JobServer`] pool — reproducible with `mc2a bench serve`. Reports
/// jobs/sec and chains/sec for the whole queue and emits
/// `BENCH_serve.json`.
///
/// [`JobServer`]: crate::engine::JobServer
pub fn serve_throughput(quick: bool) -> Result<String, Mc2aError> {
    use crate::engine::{JobServer, JobSpec, Priority};
    use std::time::{Duration, Instant};
    let mut out = String::new();
    let rounds = if quick { 3 } else { 8 };
    // (workload, steps, chains): one COP, one Potts grid, one Bayesian
    // network, sized so a quick run stays in seconds.
    let mix: &[(&str, usize, usize)] =
        &[("optsicom", 60, 2), ("imageseg", 6, 2), ("earthquake", 150, 2)];
    let priorities = [Priority::Low, Priority::Normal, Priority::High];
    let server = JobServer::in_memory(0);
    let started = Instant::now();
    let mut ids = Vec::new();
    for round in 0..rounds {
        for (k, &(workload, steps, chains)) in mix.iter().enumerate() {
            let mut spec = JobSpec::new(workload);
            spec.steps = steps;
            spec.chains = chains;
            spec.seed = 0x5E17 + (round * mix.len() + k) as u64;
            spec.priority = priorities[(round + k) % priorities.len()];
            let priority = spec.priority;
            ids.push((priority, server.submit(spec)?, Instant::now()));
        }
    }
    // One waiter thread per job, so each job's submit→result latency
    // is stamped at its own completion instead of after every
    // earlier-submitted job has drained through a sequential wait.
    let waiters: Vec<_> = ids
        .iter()
        .map(|&(priority, id, submitted)| {
            let server = server.clone();
            std::thread::spawn(move || {
                server
                    .wait(id, Duration::from_secs(600))
                    .map(|r| (priority, submitted.elapsed(), r.chains.len()))
            })
        })
        .collect();
    let mut total_chains = 0usize;
    let mut latencies: Vec<(Priority, f64)> = Vec::new();
    for waiter in waiters {
        let (priority, latency, chains) = waiter.join().expect("waiter thread panicked")?;
        total_chains += chains;
        latencies.push((priority, latency.as_secs_f64() * 1e3));
    }
    let wall = started.elapsed().as_secs_f64().max(1e-12);
    let jobs = ids.len();
    let jobs_per_sec = jobs as f64 / wall;
    let chains_per_sec = total_chains as f64 / wall;
    writeln!(
        out,
        "# job-server throughput — {jobs} mixed jobs ({} workloads × {rounds} rounds, \
         3 priority classes) over {} pool threads",
        mix.len(),
        server.threads()
    )
    .unwrap();
    writeln!(out, "jobs,chains,threads,wall_ms,jobs_per_sec,chains_per_sec").unwrap();
    writeln!(
        out,
        "{jobs},{total_chains},{},{:.3},{jobs_per_sec:.2},{chains_per_sec:.2}",
        server.threads(),
        wall * 1e3,
    )
    .unwrap();
    // Submit→result latency distribution per priority class: the whole
    // queue is submitted up front, so class separation (High draining
    // before Low) shows up directly in the spread between classes.
    writeln!(out, "\n# submit→result latency per priority class (ms)").unwrap();
    writeln!(out, "priority,jobs,p50_ms,p95_ms,p99_ms").unwrap();
    let mut latency_json = Vec::new();
    for p in priorities {
        let mut ms: Vec<f64> =
            latencies.iter().filter(|&&(lp, _)| lp == p).map(|&(_, l)| l).collect();
        ms.sort_by(f64::total_cmp);
        let (p50, p95, p99) = (pctl(&ms, 0.50), pctl(&ms, 0.95), pctl(&ms, 0.99));
        writeln!(out, "{},{},{p50:.3},{p95:.3},{p99:.3}", p.name(), ms.len()).unwrap();
        latency_json.push(format!(
            "\"{}\":{{\"jobs\":{},\"p50_ms\":{p50:.3},\"p95_ms\":{p95:.3},\"p99_ms\":{p99:.3}}}",
            p.name(),
            ms.len()
        ));
    }
    server.shutdown();
    let json = format!(
        "{{\"bench\":\"serve\",\"quick\":{quick},\"jobs\":{jobs},\"chains\":{total_chains},\
         \"threads\":{},\"wall_ms\":{:.3},\
         \"jobs_per_sec\":{jobs_per_sec},\"chains_per_sec\":{chains_per_sec},\
         \"latency_ms\":{{{}}}}}\n",
        server.threads(),
        wall * 1e3,
        latency_json.join(",")
    );
    writeln!(out, "{}", write_bench_artifact("BENCH_serve.json", &json)).unwrap();
    Ok(out)
}

/// Multi-core scaling sweep (§II-D): the Potts/MRF registry workload
/// (`imageseg`, 4096 RVs, Block Gibbs) on the sharded multi-core
/// accelerator backend at C ∈ {1, 2, 4, 8, 16}, as a CSV of aggregate
/// GS/s, speedup over one core, parallel efficiency, and sync
/// overhead — reproducible with `mc2a bench cores` (or
/// `cargo bench --bench multi_core`).
pub fn core_scaling(quick: bool) -> Result<String, Mc2aError> {
    let mut out = String::new();
    let hw = HwConfig::paper_default();
    let steps = if quick { 6 } else { 25 };
    writeln!(
        out,
        "# multi-core scaling — imageseg MRF (64×64, Block Gibbs), {steps} iterations/core-count"
    )
    .unwrap();
    writeln!(
        out,
        "cores,cycles,aggregate_gsps,speedup,parallel_efficiency,sync_overhead,xfer_words,cut_edges"
    )
    .unwrap();
    let mut base_gsps: Option<f64> = None;
    for cores in [1usize, 2, 4, 8, 16] {
        let metrics = Engine::for_workload("imageseg")?
            .steps(steps)
            .seed(0x3C0)
            .multicore(hw)
            .cores(cores)
            .build()?
            .run()?;
        let mc = metrics.chains[0].multicore.as_ref().ok_or_else(|| {
            Mc2aError::InvalidConfig("multi-core backend returned no multicore report".into())
        })?;
        let gsps = mc.aggregate_gsps(&hw);
        let base = *base_gsps.get_or_insert(gsps);
        let speedup = gsps / base.max(1e-18);
        writeln!(
            out,
            "{cores},{},{:.6},{:.3},{:.3},{:.4},{},{}",
            mc.cycles,
            gsps,
            speedup,
            speedup / cores as f64,
            mc.sync_overhead_fraction(),
            mc.xfer_words,
            mc.cut_edges,
        )
        .unwrap();
    }
    writeln!(
        out,
        "\n(aggregate GS/s = all cores' samples / synchronized makespan at {} GHz)",
        hw.clock_ghz
    )
    .unwrap();
    Ok(out)
}

/// Fixed vs adaptive annealing on the registry COP workloads — CSV of
/// best objective, steps-to-match and controller decisions,
/// reproducible with `mc2a bench anneal`.
///
/// The fixed baseline is a deliberately aggressive geometric quench
/// (β ×1.1 per step, capped at 6): it freezes the chains into local
/// optima within ~45 steps, which is exactly the regime the
/// observer-driven controllers are built for — `reheat` rewinds the
/// ramp when the best objective stalls, `plateau` freezes it.
/// `steps_to_fixed_best` is the first observation step at which a
/// mode's running best (over the boundary-sampled traces) matched the
/// fixed baseline's best boundary-sampled objective ("-" if never).
pub fn anneal_compare(quick: bool) -> Result<String, Mc2aError> {
    let steps = if quick { 240 } else { 2400 };
    let chains = 4usize;
    let every = (steps / 12).max(1);
    let seed = 0xC0A7u64;
    let schedule = BetaSchedule::Geometric { from: 0.1, to: 6.0, rate: 1.1 };
    let mut out = String::new();
    writeln!(
        out,
        "# annealing control — fixed geometric quench vs adaptive β \
         ({steps} steps, {chains} chains, observe every {every})"
    )
    .unwrap();
    writeln!(out, "workload,mode,best_objective,steps_to_fixed_best,controller").unwrap();
    // Best objective visible in the boundary-sampled traces — the
    // comparison target. (`best_objective()` tracks per-step maxima
    // the traces never see, so using it as the target could report
    // "-" even for the fixed run against itself.)
    let trace_best = |metrics: &crate::coordinator::RunMetrics| -> f64 {
        metrics
            .chains
            .iter()
            .flat_map(|c| c.objective_trace.iter().copied())
            .fold(f64::NEG_INFINITY, f64::max)
    };
    // Steps until the cross-chain running best reaches `target`.
    let steps_to = |metrics: &crate::coordinator::RunMetrics, target: f64| -> String {
        let rounds = metrics
            .chains
            .iter()
            .map(|c| c.objective_trace.len())
            .max()
            .unwrap_or(0);
        let mut best = f64::NEG_INFINITY;
        for r in 0..rounds {
            for c in &metrics.chains {
                if let Some(&obj) = c.objective_trace.get(r) {
                    best = best.max(obj);
                }
            }
            if best >= target {
                return ((r + 1) * every).to_string();
            }
        }
        "-".into()
    };
    for wname in ["maxcut", "maxclique"] {
        let build = |policy: Option<AnnealPolicy>| -> Result<Engine<'static>, Mc2aError> {
            let mut b = Engine::for_workload(wname)?
                .algo(AlgoKind::Mh)
                .schedule(schedule)
                .steps(steps)
                .chains(chains)
                .seed(seed)
                .observe_every(every);
            if let Some(p) = policy {
                b = b.adaptive(p);
            }
            b.build()
        };
        let fixed = build(None)?.run()?;
        let target = trace_best(&fixed);
        writeln!(
            out,
            "{wname},fixed,{:.3},{},-",
            fixed.best_objective(),
            steps_to(&fixed, target)
        )
        .unwrap();
        for policy in [AnnealPolicy::Reheat, AnnealPolicy::Plateau] {
            let mut engine = build(Some(policy))?;
            let metrics = engine.run()?;
            writeln!(
                out,
                "{wname},adaptive-{},{:.3},{},{}",
                policy.name(),
                metrics.best_objective(),
                steps_to(&metrics, target),
                engine.anneal_describe().unwrap_or_default(),
            )
            .unwrap();
        }
    }
    Ok(out)
}

/// `mc2a bench temper`: single-β quench vs replica exchange
/// (parallel tempering) time-to-target on COP workloads.
///
/// The single-β baseline runs every chain at the ladder's coldest β —
/// the greedy regime that freezes into local optima. The tempered run
/// spends the *same* step budget across a K-rung geometric ladder:
/// hot replicas keep crossing barriers and accepted swaps carry their
/// basins down to the cold rung. `steps_to_single_beta_best` is the
/// first observation step at which a mode's running best (over the
/// boundary-sampled traces) matched the single-β run's best
/// boundary-sampled objective ("-" if never); the tempered row also
/// reports the mean per-pair swap rate and total ladder round trips.
pub fn temper_compare(quick: bool) -> Result<String, Mc2aError> {
    let steps = if quick { 300 } else { 3000 };
    let chains = 4usize;
    let swap_every = (steps / 30).max(1);
    let seed = 0x7E4Au64;
    let (beta_cold, beta_hot, k) = (4.0f32, 0.2f32, 4usize);
    let mut out = String::new();
    writeln!(
        out,
        "# parallel tempering — single-β quench vs {k}-rung replica exchange \
         ({steps} steps, {chains} chains, swap every {swap_every})"
    )
    .unwrap();
    writeln!(
        out,
        "workload,mode,best_objective,steps_to_single_beta_best,mean_swap_rate,round_trips"
    )
    .unwrap();
    let trace_best = |metrics: &crate::coordinator::RunMetrics| -> f64 {
        metrics
            .chains
            .iter()
            .flat_map(|c| c.objective_trace.iter().copied())
            .fold(f64::NEG_INFINITY, f64::max)
    };
    // Steps until the cross-chain running best reaches `target`. Both
    // modes observe at the swap cadence, so rounds align.
    let steps_to = |metrics: &crate::coordinator::RunMetrics, target: f64| -> String {
        let rounds = metrics
            .chains
            .iter()
            .map(|c| c.objective_trace.len())
            .max()
            .unwrap_or(0);
        let mut best = f64::NEG_INFINITY;
        for r in 0..rounds {
            for c in &metrics.chains {
                if let Some(&obj) = c.objective_trace.get(r) {
                    best = best.max(obj);
                }
            }
            if best >= target {
                return ((r + 1) * swap_every).to_string();
            }
        }
        "-".into()
    };
    for wname in ["maxcut", "maxclique"] {
        let single = Engine::for_workload(wname)?
            .algo(AlgoKind::Mh)
            .schedule(BetaSchedule::Constant(beta_cold))
            .steps(steps)
            .chains(chains)
            .seed(seed)
            .observe_every(swap_every)
            .build()?
            .run()?;
        let target = trace_best(&single);
        writeln!(
            out,
            "{wname},single-beta,{:.3},{},-,-",
            single.best_objective(),
            steps_to(&single, target)
        )
        .unwrap();
        let tempered = Engine::for_workload(wname)?
            .algo(AlgoKind::Mh)
            .tempering(Ladder::geometric(beta_hot, beta_cold, k))
            .swap_every(swap_every)
            .steps(steps)
            .chains(chains)
            .seed(seed)
            .build()?
            .run()?;
        let report = tempered
            .chains
            .first()
            .and_then(|c| c.tempering.clone())
            .ok_or_else(|| {
                Mc2aError::InvalidConfig("tempered run reported no swap diagnostics".into())
            })?;
        writeln!(
            out,
            "{wname},tempered,{:.3},{},{:.3},{}",
            tempered.best_objective(),
            steps_to(&tempered, target),
            report.mean_swap_rate(),
            report.total_round_trips()
        )
        .unwrap();
    }
    Ok(out)
}

/// §VI-D headline: speedup ratios vs the paper's claims.
///
/// Always uses the paper-scale 150 k-node MRF — the analytical GPU/TPU
/// models only amortize their dispatch overhead at that scale, exactly
/// as in the paper (`quick` only trims the simulated iteration count).
pub fn headline(quick: bool) -> String {
    let mut out = String::new();
    writeln!(out, "# §VI-D headline speedups (MRF workload, 150k nodes)").unwrap();
    let wl = workloads::wl_image_seg(true);
    let rows = match evaluate_platforms(&wl, if quick { 3 } else { 30 }, false) {
        Ok(rows) => rows,
        Err(e) => {
            writeln!(out, "evaluation failed: {e}").unwrap();
            return out;
        }
    };
    let mc2a = rows[0].gsps;
    let paper: &[(&str, f64)] = &[
        ("CPU (Xeon)", 307.6),
        ("GPU (V100)", 1.4),
        ("TPU-v3", 2.0),
        ("PGMA", 84.2),
        ("SPU", 4.8),
        ("CoopMC", 32.0),
        ("PROCA", 80.0),
    ];
    writeln!(out, "{:<12} {:>12} {:>12}", "platform", "ours", "paper").unwrap();
    for (name, claimed) in paper {
        let ours = rows
            .iter()
            .find(|r| r.name == *name)
            .map(|r| if r.gsps > 0.0 { mc2a / r.gsps } else { f64::INFINITY })
            .unwrap_or(f64::NAN);
        writeln!(out, "{:<12} {:>11.1}x {:>11.1}x", name, ours, claimed).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_rows() {
        let t = table1(false);
        for name in ["Earthquake", "Survey", "Image Seg.", "Optsicom", "RBM"] {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
    }

    #[test]
    fn fig6_reports_golden_config() {
        let t = fig6();
        assert!(t.contains("Balanced"), "{t}");
        assert!(t.contains("ComputeBound"), "{t}");
        assert!(t.contains("MemoryBound"), "{t}");
    }

    #[test]
    fn fig13_has_cdf_failure() {
        let t = fig13();
        assert!(t.contains("256"));
        assert!(t.contains("0.000e0") || t.contains("0e0") || t.contains("NaN") == false);
    }

    #[test]
    fn fig12_quick_runs() {
        let t = fig12(true);
        assert!(t.contains("size=16"));
        assert!(t.contains("exact"));
    }

    #[test]
    fn core_scaling_csv_hits_the_acceptance_ratio() {
        let t = core_scaling(true).unwrap();
        assert!(t.contains("aggregate_gsps"), "{t}");
        assert!(t.contains("parallel_efficiency"), "{t}");
        // Acceptance: aggregate GS/s at C=8 ≥ 4× the C=1 figure.
        let speedup_of = |cores: &str| -> f64 {
            t.lines()
                .find(|l| l.starts_with(&format!("{cores},")))
                .unwrap_or_else(|| panic!("no row for C={cores} in:\n{t}"))
                .split(',')
                .nth(3)
                .unwrap()
                .parse()
                .unwrap()
        };
        assert!((speedup_of("1") - 1.0).abs() < 1e-9);
        let s8 = speedup_of("8");
        assert!(s8 >= 4.0, "C=8 speedup {s8} < 4x:\n{t}");
    }

    #[test]
    fn pctl_uses_nearest_rank_and_tolerates_empty_input() {
        assert_eq!(pctl(&[], 0.5), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pctl(&v, 0.0), 1.0);
        assert_eq!(pctl(&v, 0.5), 3.0);
        assert_eq!(pctl(&v, 0.95), 4.0);
        assert_eq!(pctl(&v, 1.0), 4.0);
    }

    #[test]
    fn many_chains_csv_has_throughput_columns() {
        // many_chains flips the process-wide metrics registry for its
        // overhead row; hold the telemetry test lock for the duration.
        let _g = crate::engine::telemetry::test_guard();
        let t = many_chains(true).unwrap();
        assert!(t.contains("samples_per_sec"), "{t}");
        assert!(t.contains("chains_per_sec"), "{t}");
        assert!(t.contains("telemetry-enabled batched run"), "{t}");
        assert!(t.contains("software,64"), "{t}");
        assert!(t.contains("batched,64,"), "{t}");
        assert!(t.contains("speedup"), "{t}");
        // Per-kernel grid: every (workload × algo × sampler) row is
        // present with its own scalar-vs-batched rate.
        assert!(t.contains("kernel_speedup"), "{t}");
        for kernel in [
            "ising32/gibbs/gumbel",
            "ising32/gibbs/lut:16:8",
            "maxcut256/gibbs/gumbel",
            "maxcut256/ag/gumbel",
            "maxcut256/pas/gumbel",
        ] {
            assert!(t.contains(kernel), "missing kernel row {kernel}:\n{t}");
        }
    }
}
