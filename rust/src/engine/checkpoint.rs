//! Checkpoint / resume for chain runs.
//!
//! A [`Checkpoint`] captures the minimum needed to continue a search:
//! the base seed, how many steps ran, and the best assignment (plus
//! its objective, for sanity display). The CLI writes one with
//! `--save-state <path>` after a run and feeds one back through the
//! builder's `init_state` with `--init-from <path>`.
//!
//! The format is a single flat JSON object, hand-rolled both ways
//! because the offline vendor set carries no serde:
//!
//! ```json
//! {"seed":1,"steps":500,"best_objective":-42.5,"best_x":[0,1,2]}
//! ```
//!
//! Adaptive-annealing runs append the controller's serialized memory
//! (`"anneal":[...]`, see [`crate::mcmc::anneal::BetaController::state`]),
//! so a resumed run continues both the β ramp — the engine evaluates
//! the schedule at `steps + t` via
//! [`crate::engine::EngineBuilder::schedule_offset`] — and the
//! controller's plateau/rate memory.

use std::fmt::Write as _;
use std::path::Path;

use crate::engine::error::Mc2aError;

/// Resumable snapshot of a chain run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Base RNG seed the run used.
    pub seed: u64,
    /// Steps completed when the snapshot was taken.
    pub steps: usize,
    /// Objective of `best_x`.
    pub best_objective: f64,
    /// Best assignment found (the resume state).
    pub best_x: Vec<u32>,
    /// Serialized adaptive-annealing controller memory
    /// ([`crate::engine::Engine::anneal_state`]); `None` on fixed-ramp
    /// runs.
    pub anneal: Option<Vec<f64>>,
    /// Serialized replica-exchange memory
    /// ([`crate::engine::Engine::temper_state`]): the (possibly
    /// re-spaced) β ladder, chain→rung assignment and swap history;
    /// `None` on untempered runs.
    pub temper: Option<Vec<f64>>,
}

impl Checkpoint {
    /// Serialize to the flat JSON object.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.best_x.len() * 4);
        write!(
            out,
            "{{\"seed\":{},\"steps\":{},\"best_objective\":{},\"best_x\":[",
            self.seed,
            self.steps,
            self.best_objective
        )
        .unwrap();
        for (i, v) in self.best_x.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "{v}").unwrap();
        }
        out.push(']');
        for (key, values) in [("anneal", &self.anneal), ("temper", &self.temper)] {
            if let Some(values) = values {
                out.push_str(",\"");
                out.push_str(key);
                out.push_str("\":[");
                for (i, v) in values.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write!(out, "{v}").unwrap();
                }
                out.push(']');
            }
        }
        out.push('}');
        out
    }

    /// Parse the flat JSON object produced by [`Checkpoint::to_json`]
    /// (whitespace-tolerant; key order free).
    pub fn from_json(s: &str) -> Result<Checkpoint, Mc2aError> {
        let seed = scalar_field(s, "seed")?
            .parse::<u64>()
            .map_err(|e| bad("seed", &e.to_string()))?;
        let steps = scalar_field(s, "steps")?
            .parse::<usize>()
            .map_err(|e| bad("steps", &e.to_string()))?;
        let best_objective = scalar_field(s, "best_objective")?
            .parse::<f64>()
            .map_err(|e| bad("best_objective", &e.to_string()))?;
        let body = array_field(s, "best_x")?;
        let mut best_x = Vec::new();
        for tok in body.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            best_x.push(tok.parse::<u32>().map_err(|e| bad("best_x", &e.to_string()))?);
        }
        // Optional fields: absent on checkpoints written before the
        // respective controller existed (or on plain fixed-ramp runs).
        let anneal = optional_f64_array(s, "anneal")?;
        let temper = optional_f64_array(s, "temper")?;
        Ok(Checkpoint {
            seed,
            steps,
            best_objective,
            best_x,
            anneal,
            temper,
        })
    }

    /// Write the checkpoint to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), Mc2aError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json())
            .map_err(|e| Mc2aError::Checkpoint(format!("writing {}: {e}", path.display())))
    }

    /// Read a checkpoint from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, Mc2aError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Mc2aError::Checkpoint(format!("reading {}: {e}", path.display())))?;
        Checkpoint::from_json(&text)
    }
}

fn bad(key: &str, why: &str) -> Mc2aError {
    Mc2aError::Checkpoint(format!("field `{key}`: {why}"))
}

/// Parse an optional `"key":[f64,…]` field (None when absent).
fn optional_f64_array(s: &str, key: &str) -> Result<Option<Vec<f64>>, Mc2aError> {
    if !s.contains(&format!("\"{key}\"")) {
        return Ok(None);
    }
    let body = array_field(s, key)?;
    let mut values = Vec::new();
    for tok in body.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        values.push(tok.parse::<f64>().map_err(|e| bad(key, &e.to_string()))?);
    }
    Ok(Some(values))
}

/// Locate `"key":` and return the byte offset just past the colon.
fn value_start(s: &str, key: &str) -> Result<usize, Mc2aError> {
    let pat = format!("\"{key}\"");
    let k = s.find(&pat).ok_or_else(|| bad(key, "missing"))?;
    let rest = &s[k + pat.len()..];
    let colon = rest.find(':').ok_or_else(|| bad(key, "missing `:`"))?;
    Ok(k + pat.len() + colon + 1)
}

/// Extract a numeric scalar field as a trimmed token.
fn scalar_field<'a>(s: &'a str, key: &str) -> Result<&'a str, Mc2aError> {
    let start = value_start(s, key)?;
    let rest = &s[start..];
    let end = rest.find(|c| c == ',' || c == '}').ok_or_else(|| bad(key, "unterminated value"))?;
    Ok(rest[..end].trim())
}

/// Extract the inside of a `[...]` array field.
fn array_field<'a>(s: &'a str, key: &str) -> Result<&'a str, Mc2aError> {
    let start = value_start(s, key)?;
    let rest = &s[start..];
    let open = rest.find('[').ok_or_else(|| bad(key, "missing `[`"))?;
    let close = rest[open..].find(']').ok_or_else(|| bad(key, "missing `]`"))?;
    Ok(&rest[open + 1..open + close])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let ck = Checkpoint {
            seed: 0xDEADBEEF,
            steps: 12_345,
            best_objective: -87.25,
            best_x: vec![0, 3, 1, 2, 0, 1],
            anneal: None,
            temper: None,
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
    }

    #[test]
    fn anneal_state_round_trips() {
        let ck = Checkpoint {
            seed: 7,
            steps: 400,
            best_objective: 12.5,
            best_x: vec![1, 0, 2],
            anneal: Some(vec![180.0, 400.0, 2.0, 1.0, 12.5, 3.0, 5.0, 0.0]),
            temper: None,
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
        // Negative and fractional entries survive (best_seen may be
        // -inf on a run that never observed a round).
        let ck2 = Checkpoint {
            anneal: Some(vec![0.5, -3.25, f64::NEG_INFINITY]),
            ..ck
        };
        assert_eq!(Checkpoint::from_json(&ck2.to_json()).unwrap(), ck2);
    }

    #[test]
    fn temper_state_round_trips() {
        let ck = Checkpoint {
            seed: 11,
            steps: 250,
            best_objective: 40.0,
            best_x: vec![0, 1, 1],
            anneal: None,
            temper: Some(vec![1.0, 4.0, 25.0, 0.0, 0.25, 0.5, 1.0, 2.0]),
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
        // Both optional blocks coexist.
        let both = Checkpoint {
            anneal: Some(vec![1.5, -2.0]),
            ..ck
        };
        assert_eq!(Checkpoint::from_json(&both.to_json()).unwrap(), both);
    }

    #[test]
    fn empty_state_round_trips() {
        let ck = Checkpoint {
            seed: 1,
            steps: 0,
            best_objective: 0.0,
            best_x: Vec::new(),
            anneal: None,
            temper: None,
        };
        assert_eq!(Checkpoint::from_json(&ck.to_json()).unwrap(), ck);
    }

    #[test]
    fn tolerates_whitespace_and_reordering() {
        let text = r#"{ "best_x": [ 2, 0 , 1 ],
                        "best_objective": 3.5,
                        "steps": 7, "seed": 42 }"#;
        let ck = Checkpoint::from_json(text).unwrap();
        assert_eq!(ck.seed, 42);
        assert_eq!(ck.steps, 7);
        assert_eq!(ck.best_objective, 3.5);
        assert_eq!(ck.best_x, vec![2, 0, 1]);
    }

    #[test]
    fn malformed_inputs_are_typed_errors() {
        for text in [
            "",
            "{}",
            "{\"seed\":1}",
            "{\"seed\":\"x\",\"steps\":1,\"best_objective\":0,\"best_x\":[]}",
            "{\"seed\":1,\"steps\":1,\"best_objective\":0,\"best_x\":[1,-2]}",
        ] {
            assert!(
                matches!(Checkpoint::from_json(text), Err(Mc2aError::Checkpoint(_))),
                "accepted: {text}"
            );
        }
    }

    #[test]
    fn file_round_trip() {
        let ck = Checkpoint {
            seed: 9,
            steps: 100,
            best_objective: 1.5,
            best_x: vec![1, 1, 0],
            anneal: None,
            temper: None,
        };
        let path = std::env::temp_dir().join("mc2a_checkpoint_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded, ck);
        assert!(matches!(
            Checkpoint::load("/nonexistent/mc2a.json"),
            Err(Mc2aError::Checkpoint(_))
        ));
    }
}
