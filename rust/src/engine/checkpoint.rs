//! Checkpoint / resume for chain runs.
//!
//! A [`Checkpoint`] captures the minimum needed to continue a search:
//! the base seed, how many steps ran, and the best assignment (plus
//! its objective, for sanity display). The CLI writes one with
//! `--save-state <path>` after a run and feeds one back through the
//! builder's `init_state` with `--init-from <path>`.
//!
//! The format is a single flat JSON object, hand-rolled both ways
//! because the offline vendor set carries no serde:
//!
//! ```json
//! {"seed":1,"steps":500,"best_objective":-42.5,"best_x":[0,1,2]}
//! ```
//!
//! Adaptive-annealing runs append the controller's serialized memory
//! (`"anneal":[...]`, see [`crate::mcmc::anneal::BetaController::state`]),
//! so a resumed run continues both the β ramp — the engine evaluates
//! the schedule at `steps + t` via
//! [`crate::engine::EngineBuilder::schedule_offset`] — and the
//! controller's plateau/rate memory.

use std::fmt::Write as _;
use std::path::Path;

use crate::engine::error::Mc2aError;
use crate::engine::telemetry;

/// Resumable snapshot of a chain run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Base RNG seed the run used.
    pub seed: u64,
    /// Steps completed when the snapshot was taken.
    pub steps: usize,
    /// Objective of `best_x`.
    pub best_objective: f64,
    /// Best assignment found (the resume state).
    pub best_x: Vec<u32>,
    /// Serialized adaptive-annealing controller memory
    /// ([`crate::engine::Engine::anneal_state`]); `None` on fixed-ramp
    /// runs.
    pub anneal: Option<Vec<f64>>,
    /// Serialized replica-exchange memory
    /// ([`crate::engine::Engine::temper_state`]): the (possibly
    /// re-spaced) β ladder, chain→rung assignment and swap history;
    /// `None` on untempered runs.
    pub temper: Option<Vec<f64>>,
    /// Canonical workload name the checkpoint was saved from. `None`
    /// on checkpoints written before the metadata existed; when
    /// present, [`crate::engine::EngineBuilder::init_from_checkpoint`]
    /// rejects resuming under a different workload with a typed
    /// [`Mc2aError::CheckpointMismatch`].
    pub workload: Option<String>,
    /// Canonical sampler spec ("cdf" / "gumbel" / "lut:SIZE:BITS" —
    /// [`crate::mcmc::SamplerKind::spec`]) the run used; checked on
    /// resume like [`Checkpoint::workload`]. Checkpoints written
    /// before the LUT shape was serialized hold the bare family name
    /// ("lut"), which resume still accepts.
    pub sampler: Option<String>,
    /// Chain count of the saving run; checked on resume like
    /// [`Checkpoint::workload`].
    pub chains: Option<usize>,
}

impl Checkpoint {
    /// Serialize to the flat JSON object.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.best_x.len() * 4);
        write!(
            out,
            "{{\"seed\":{},\"steps\":{},\"best_objective\":{},\"best_x\":[",
            self.seed,
            self.steps,
            self.best_objective
        )
        .unwrap();
        for (i, v) in self.best_x.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "{v}").unwrap();
        }
        out.push(']');
        for (key, values) in [("anneal", &self.anneal), ("temper", &self.temper)] {
            if let Some(values) = values {
                out.push_str(",\"");
                out.push_str(key);
                out.push_str("\":[");
                for (i, v) in values.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write!(out, "{v}").unwrap();
                }
                out.push(']');
            }
        }
        for (key, value) in [("workload", &self.workload), ("sampler", &self.sampler)] {
            if let Some(value) = value {
                write!(out, ",\"{key}\":\"{}\"", escape_json(value)).unwrap();
            }
        }
        if let Some(chains) = self.chains {
            write!(out, ",\"chains\":{chains}").unwrap();
        }
        out.push('}');
        out
    }

    /// Parse the flat JSON object produced by [`Checkpoint::to_json`]
    /// (whitespace-tolerant; key order free).
    pub fn from_json(s: &str) -> Result<Checkpoint, Mc2aError> {
        let seed = scalar_field(s, "seed")?
            .parse::<u64>()
            .map_err(|e| bad("seed", &e.to_string()))?;
        let steps = scalar_field(s, "steps")?
            .parse::<usize>()
            .map_err(|e| bad("steps", &e.to_string()))?;
        let best_objective = scalar_field(s, "best_objective")?
            .parse::<f64>()
            .map_err(|e| bad("best_objective", &e.to_string()))?;
        let body = array_field(s, "best_x")?;
        let mut best_x = Vec::new();
        for tok in body.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            best_x.push(tok.parse::<u32>().map_err(|e| bad("best_x", &e.to_string()))?);
        }
        // Optional fields: absent on checkpoints written before the
        // respective controller existed (or on plain fixed-ramp runs).
        let anneal = optional_f64_array(s, "anneal")?;
        let temper = optional_f64_array(s, "temper")?;
        let workload = optional_string_field(s, "workload")?;
        let sampler = optional_string_field(s, "sampler")?;
        let chains = match optional_scalar_field(s, "chains")? {
            None => None,
            Some(tok) => {
                Some(tok.parse::<usize>().map_err(|e| bad("chains", &e.to_string()))?)
            }
        };
        Ok(Checkpoint {
            seed,
            steps,
            best_objective,
            best_x,
            anneal,
            temper,
            workload,
            sampler,
            chains,
        })
    }

    /// Write the checkpoint to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), Mc2aError> {
        let path = path.as_ref();
        let t0 = telemetry::enabled().then(std::time::Instant::now);
        let out = std::fs::write(path, self.to_json())
            .map_err(|e| Mc2aError::Checkpoint(format!("writing {}: {e}", path.display())));
        if let Some(t0) = t0 {
            telemetry::metrics().observe(
                "checkpoint_write_seconds",
                &[("kind", "checkpoint")],
                t0.elapsed().as_secs_f64(),
            );
        }
        out
    }

    /// Read a checkpoint from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, Mc2aError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Mc2aError::Checkpoint(format!("reading {}: {e}", path.display())))?;
        Checkpoint::from_json(&text)
    }
}

pub(crate) fn bad(key: &str, why: &str) -> Mc2aError {
    Mc2aError::Checkpoint(format!("field `{key}`: {why}"))
}

/// Parse an optional `"key":[f64,…]` field (None when absent).
pub(crate) fn optional_f64_array(s: &str, key: &str) -> Result<Option<Vec<f64>>, Mc2aError> {
    if !s.contains(&format!("\"{key}\"")) {
        return Ok(None);
    }
    let body = array_field(s, key)?;
    let mut values = Vec::new();
    for tok in body.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        values.push(tok.parse::<f64>().map_err(|e| bad(key, &e.to_string()))?);
    }
    Ok(Some(values))
}

/// Locate `"key":` and return the byte offset just past the colon.
pub(crate) fn value_start(s: &str, key: &str) -> Result<usize, Mc2aError> {
    let pat = format!("\"{key}\"");
    let k = s.find(&pat).ok_or_else(|| bad(key, "missing"))?;
    let rest = &s[k + pat.len()..];
    let colon = rest.find(':').ok_or_else(|| bad(key, "missing `:`"))?;
    Ok(k + pat.len() + colon + 1)
}

/// Extract a numeric scalar field as a trimmed token.
pub(crate) fn scalar_field<'a>(s: &'a str, key: &str) -> Result<&'a str, Mc2aError> {
    let start = value_start(s, key)?;
    let rest = &s[start..];
    let end = rest.find(|c| c == ',' || c == '}').ok_or_else(|| bad(key, "unterminated value"))?;
    Ok(rest[..end].trim())
}

/// [`scalar_field`] that distinguishes "absent" (Ok(None)) from
/// "present but malformed" (Err).
pub(crate) fn optional_scalar_field<'a>(
    s: &'a str,
    key: &str,
) -> Result<Option<&'a str>, Mc2aError> {
    if !s.contains(&format!("\"{key}\"")) {
        return Ok(None);
    }
    scalar_field(s, key).map(Some)
}

/// Extract the inside of a `[...]` array field.
pub(crate) fn array_field<'a>(s: &'a str, key: &str) -> Result<&'a str, Mc2aError> {
    let start = value_start(s, key)?;
    let rest = &s[start..];
    let open = rest.find('[').ok_or_else(|| bad(key, "missing `[`"))?;
    let close = rest[open..].find(']').ok_or_else(|| bad(key, "missing `]`"))?;
    Ok(&rest[open + 1..open + close])
}

/// Extract a `"key":"…"` string field, undoing [`escape_json`].
pub(crate) fn string_field(s: &str, key: &str) -> Result<String, Mc2aError> {
    let start = value_start(s, key)?;
    let rest = s[start..].trim_start();
    if !rest.starts_with('"') {
        return Err(bad(key, "expected a string value"));
    }
    let mut out = String::new();
    let mut escaped = false;
    for c in rest[1..].chars() {
        if escaped {
            out.push(match c {
                'n' => '\n',
                't' => '\t',
                'r' => '\r',
                other => other, // covers \" \\ \/
            });
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            return Ok(out);
        } else {
            out.push(c);
        }
    }
    Err(bad(key, "unterminated string"))
}

/// [`string_field`] that distinguishes "absent" from "malformed".
pub(crate) fn optional_string_field(s: &str, key: &str) -> Result<Option<String>, Mc2aError> {
    if !s.contains(&format!("\"{key}\"")) {
        return Ok(None);
    }
    string_field(s, key).map(Some)
}

/// Escape a string for embedding in the flat JSON (the inverse of
/// [`string_field`]'s unescaping; control characters beyond \n \t \r
/// do not occur in the names we serialize).
pub(crate) fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            other => out.push(other),
        }
    }
    out
}

/// Extract the byte range of a `"key":{…}` object value (brace-depth
/// matched; the values we nest contain no braces inside strings).
pub(crate) fn object_field_range(s: &str, key: &str) -> Result<(usize, usize), Mc2aError> {
    let start = value_start(s, key)?;
    let open_rel = s[start..].find('{').ok_or_else(|| bad(key, "missing `{`"))?;
    let open = start + open_rel;
    let mut depth = 0usize;
    for (i, c) in s[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((open, open + i + 1));
                }
            }
            _ => {}
        }
    }
    Err(bad(key, "unterminated object"))
}

/// Durable record of one job-server job: everything
/// [`crate::engine::server::JobServer::recover`] needs to rebuild the
/// job — the spec that shaped its [`crate::engine::ChainSpec`], the
/// scheduling metadata (priority, backend, state), and a nested
/// [`Checkpoint`] holding the best assignment seen so far.
///
/// Serialized as one more flat-ish JSON object: every envelope field
/// first, the checkpoint object last. Written atomically by the
/// server's persistence layer on submit, per-chain completion, and
/// finalization.
#[derive(Clone, Debug, PartialEq)]
pub struct JobEnvelope {
    /// Server-assigned job id (also the file name: `job-<id>.json`).
    pub job_id: u64,
    /// Canonical registry workload name.
    pub workload: String,
    /// Algorithm name, lowercase ("mh", "gibbs", "bg", "ag", "pas").
    pub algo: String,
    /// Canonical sampler spec ("cdf", "gumbel", "lut:SIZE:BITS").
    pub sampler: String,
    /// Backend name ("sw" or "sim").
    pub backend: String,
    /// Priority class name ("low", "normal", "high").
    pub priority: String,
    /// Job state name at save time ("queued", "running", "done",
    /// "cancelled", "failed"). Non-terminal states are re-run on
    /// recovery; terminal ones are reloaded as finished.
    pub state: String,
    /// Per-chain step budget.
    pub steps: usize,
    /// Number of chains in the job.
    pub chains: usize,
    /// Observer cadence (steps between progress events).
    pub observe_every: usize,
    /// PAS proposal flips per step.
    pub pas_flips: usize,
    /// Chains that had fully completed when this envelope was saved.
    pub chains_done: usize,
    /// Base RNG seed (chain `i` forks stream `i`).
    pub seed: u64,
    /// Inverse temperature of the run's constant schedule.
    pub beta: f64,
    /// Best-so-far snapshot (seed/steps/best_x plus the run-shape
    /// metadata fields used by resume-mismatch checking).
    pub checkpoint: Checkpoint,
}

impl JobEnvelope {
    /// Serialize: envelope fields first, nested checkpoint last.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.checkpoint.best_x.len() * 4);
        write!(out, "{{\"job_id\":{}", self.job_id).unwrap();
        for (key, value) in [
            ("workload", &self.workload),
            ("algo", &self.algo),
            ("sampler", &self.sampler),
            ("backend", &self.backend),
            ("priority", &self.priority),
            ("state", &self.state),
        ] {
            write!(out, ",\"{key}\":\"{}\"", escape_json(value)).unwrap();
        }
        for (key, value) in [
            ("steps", self.steps),
            ("chains", self.chains),
            ("observe_every", self.observe_every),
            ("pas_flips", self.pas_flips),
            ("chains_done", self.chains_done),
        ] {
            write!(out, ",\"{key}\":{value}").unwrap();
        }
        write!(out, ",\"seed\":{},\"beta\":{}", self.seed, self.beta).unwrap();
        out.push_str(",\"checkpoint\":");
        out.push_str(&self.checkpoint.to_json());
        out.push('}');
        out
    }

    /// Parse the object produced by [`JobEnvelope::to_json`]. The
    /// nested checkpoint shares key names with the envelope ("seed",
    /// "steps", "chains", …), so the checkpoint object is carved out
    /// first and the envelope scalars are parsed from what remains.
    pub fn from_json(s: &str) -> Result<JobEnvelope, Mc2aError> {
        let (open, end) = object_field_range(s, "checkpoint")?;
        let checkpoint = Checkpoint::from_json(&s[open..end])?;
        let head = format!("{}{}", &s[..open], &s[end..]);
        let h = head.as_str();
        let envelope = JobEnvelope {
            job_id: scalar_field(h, "job_id")?
                .parse::<u64>()
                .map_err(|e| bad("job_id", &e.to_string()))?,
            workload: string_field(h, "workload")?,
            algo: string_field(h, "algo")?,
            sampler: string_field(h, "sampler")?,
            backend: string_field(h, "backend")?,
            priority: string_field(h, "priority")?,
            state: string_field(h, "state")?,
            steps: scalar_field(h, "steps")?
                .parse::<usize>()
                .map_err(|e| bad("steps", &e.to_string()))?,
            chains: scalar_field(h, "chains")?
                .parse::<usize>()
                .map_err(|e| bad("chains", &e.to_string()))?,
            observe_every: scalar_field(h, "observe_every")?
                .parse::<usize>()
                .map_err(|e| bad("observe_every", &e.to_string()))?,
            pas_flips: scalar_field(h, "pas_flips")?
                .parse::<usize>()
                .map_err(|e| bad("pas_flips", &e.to_string()))?,
            chains_done: scalar_field(h, "chains_done")?
                .parse::<usize>()
                .map_err(|e| bad("chains_done", &e.to_string()))?,
            seed: scalar_field(h, "seed")?
                .parse::<u64>()
                .map_err(|e| bad("seed", &e.to_string()))?,
            beta: scalar_field(h, "beta")?
                .parse::<f64>()
                .map_err(|e| bad("beta", &e.to_string()))?,
            checkpoint,
        };
        Ok(envelope)
    }

    /// Write the envelope to `path` (atomic: tmp file + rename, so a
    /// crash mid-write never leaves a truncated envelope for
    /// recovery to choke on).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), Mc2aError> {
        let path = path.as_ref();
        let t0 = telemetry::enabled().then(std::time::Instant::now);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json())
            .map_err(|e| Mc2aError::Checkpoint(format!("writing {}: {e}", tmp.display())))?;
        let out = std::fs::rename(&tmp, path)
            .map_err(|e| Mc2aError::Checkpoint(format!("renaming to {}: {e}", path.display())));
        if let Some(t0) = t0 {
            telemetry::metrics().observe(
                "checkpoint_write_seconds",
                &[("kind", "envelope")],
                t0.elapsed().as_secs_f64(),
            );
        }
        out
    }

    /// Read an envelope from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<JobEnvelope, Mc2aError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Mc2aError::Checkpoint(format!("reading {}: {e}", path.display())))?;
        JobEnvelope::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let ck = Checkpoint {
            seed: 0xDEADBEEF,
            steps: 12_345,
            best_objective: -87.25,
            best_x: vec![0, 3, 1, 2, 0, 1],
            anneal: None,
            temper: None,
            workload: None,
            sampler: None,
            chains: None,
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
    }

    #[test]
    fn anneal_state_round_trips() {
        let ck = Checkpoint {
            seed: 7,
            steps: 400,
            best_objective: 12.5,
            best_x: vec![1, 0, 2],
            anneal: Some(vec![180.0, 400.0, 2.0, 1.0, 12.5, 3.0, 5.0, 0.0]),
            temper: None,
            workload: None,
            sampler: None,
            chains: None,
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
        // Negative and fractional entries survive (best_seen may be
        // -inf on a run that never observed a round).
        let ck2 = Checkpoint {
            anneal: Some(vec![0.5, -3.25, f64::NEG_INFINITY]),
            ..ck
        };
        assert_eq!(Checkpoint::from_json(&ck2.to_json()).unwrap(), ck2);
    }

    #[test]
    fn temper_state_round_trips() {
        let ck = Checkpoint {
            seed: 11,
            steps: 250,
            best_objective: 40.0,
            best_x: vec![0, 1, 1],
            anneal: None,
            temper: Some(vec![1.0, 4.0, 25.0, 0.0, 0.25, 0.5, 1.0, 2.0]),
            workload: None,
            sampler: None,
            chains: None,
        };
        let parsed = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(parsed, ck);
        // Both optional blocks coexist.
        let both = Checkpoint {
            anneal: Some(vec![1.5, -2.0]),
            ..ck
        };
        assert_eq!(Checkpoint::from_json(&both.to_json()).unwrap(), both);
    }

    #[test]
    fn empty_state_round_trips() {
        let ck = Checkpoint {
            seed: 1,
            steps: 0,
            best_objective: 0.0,
            best_x: Vec::new(),
            anneal: None,
            temper: None,
            workload: None,
            sampler: None,
            chains: None,
        };
        assert_eq!(Checkpoint::from_json(&ck.to_json()).unwrap(), ck);
    }

    #[test]
    fn run_shape_metadata_round_trips() {
        let ck = Checkpoint {
            seed: 3,
            steps: 600,
            best_objective: -4.5,
            best_x: vec![1, 0],
            anneal: None,
            temper: None,
            workload: Some("optsicom".into()),
            sampler: Some("gumbel".into()),
            chains: Some(4),
        };
        assert_eq!(Checkpoint::from_json(&ck.to_json()).unwrap(), ck);
    }

    #[test]
    fn tolerates_whitespace_and_reordering() {
        let text = r#"{ "best_x": [ 2, 0 , 1 ],
                        "best_objective": 3.5,
                        "steps": 7, "seed": 42 }"#;
        let ck = Checkpoint::from_json(text).unwrap();
        assert_eq!(ck.seed, 42);
        assert_eq!(ck.steps, 7);
        assert_eq!(ck.best_objective, 3.5);
        assert_eq!(ck.best_x, vec![2, 0, 1]);
        // Pre-metadata checkpoints still load; the run-shape fields
        // just come back empty.
        assert_eq!(ck.workload, None);
        assert_eq!(ck.sampler, None);
        assert_eq!(ck.chains, None);
    }

    #[test]
    fn malformed_inputs_are_typed_errors() {
        for text in [
            "",
            "{}",
            "{\"seed\":1}",
            "{\"seed\":\"x\",\"steps\":1,\"best_objective\":0,\"best_x\":[]}",
            "{\"seed\":1,\"steps\":1,\"best_objective\":0,\"best_x\":[1,-2]}",
        ] {
            assert!(
                matches!(Checkpoint::from_json(text), Err(Mc2aError::Checkpoint(_))),
                "accepted: {text}"
            );
        }
    }

    #[test]
    fn file_round_trip() {
        let ck = Checkpoint {
            seed: 9,
            steps: 100,
            best_objective: 1.5,
            best_x: vec![1, 1, 0],
            anneal: None,
            temper: None,
            workload: None,
            sampler: None,
            chains: None,
        };
        let path = std::env::temp_dir().join("mc2a_checkpoint_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded, ck);
        assert!(matches!(
            Checkpoint::load("/nonexistent/mc2a.json"),
            Err(Mc2aError::Checkpoint(_))
        ));
    }

    fn sample_envelope() -> JobEnvelope {
        JobEnvelope {
            job_id: 17,
            workload: "optsicom".into(),
            algo: "pas".into(),
            sampler: "gumbel".into(),
            backend: "sw".into(),
            priority: "high".into(),
            state: "running".into(),
            steps: 500,
            chains: 4,
            observe_every: 25,
            pas_flips: 4,
            chains_done: 2,
            seed: 99,
            beta: 2.5,
            checkpoint: Checkpoint {
                seed: 99,
                steps: 500,
                best_objective: -12.75,
                best_x: vec![0, 1, 1, 0],
                anneal: None,
                temper: None,
                workload: Some("optsicom".into()),
                sampler: Some("gumbel".into()),
                chains: Some(4),
            },
        }
    }

    #[test]
    fn job_envelope_round_trips() {
        // The nested checkpoint reuses the envelope's key names
        // ("seed", "steps", "chains") — the parse must keep the two
        // scopes separate.
        let env = sample_envelope();
        let parsed = JobEnvelope::from_json(&env.to_json()).unwrap();
        assert_eq!(parsed, env);
        assert_eq!(parsed.checkpoint.chains, Some(4));
        assert_eq!(parsed.steps, 500);
    }

    #[test]
    fn job_envelope_file_round_trip_is_atomic_rename() {
        let env = sample_envelope();
        let path = std::env::temp_dir().join("mc2a_envelope_test.json");
        env.save(&path).unwrap();
        // The tmp file must be gone after a successful save.
        assert!(!path.with_extension("json.tmp").exists());
        let loaded = JobEnvelope::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(loaded, env);
    }

    #[test]
    fn string_escapes_round_trip() {
        let mut env = sample_envelope();
        env.workload = "odd \"name\"\\with\nnoise".into();
        let parsed = JobEnvelope::from_json(&env.to_json()).unwrap();
        assert_eq!(parsed.workload, env.workload);
    }
}
