//! The lockstep replica-exchange (parallel tempering) driver.
//!
//! Swap decisions are *cross-chain*: a round compares the cached
//! energies of neighboring replicas, so — exactly like the adaptive-
//! annealing driver ([`crate::engine::adaptive`]) — tempered fan-outs
//! run in **lockstep**: every chain advances to the next swap boundary
//! (`swap_every` steps on the global clock), the driver gathers each
//! chain's energy synchronously in deterministic chain order, and each
//! ensemble's [`ReplicaExchange`] controller proposes its even/odd
//! neighbor swaps before the next segment's per-chain β values are
//! planned. Swaps exchange *temperatures*, never states, so chains
//! stay bit-identical across backends whose chains are bit-identical
//! (scalar vs batched software) — and the β-label migration is O(1)
//! on every backend, including the cycle-accurate simulators.
//!
//! The driver reuses the adaptive driver's [`ExecUnit`] machinery via
//! [`ExecUnit::advance_per_chain`]: a scalar [`crate::mcmc::Chain`]
//! runs `run_betas` at its constant segment β, an SoA
//! [`crate::mcmc::ChainBatch`] finally exercises true per-chain β
//! through [`crate::mcmc::ChainBatch::run_betas_per_chain`], and the
//! single-/multi-core simulators advance through their segmented
//! `begin_run` / `advance_run` / `finish_run` APIs.

use crate::coordinator::ChainResult;
use crate::energy::EnergyModel;
use crate::engine::adaptive::{ChainSignal, ExecUnit};
use crate::engine::backend::{ChainCtx, ChainSpec};
use crate::engine::error::Mc2aError;
use crate::engine::observer::ProgressEvent;
use crate::engine::telemetry;
use crate::mcmc::tempering::ReplicaExchange;

/// Run `units` to completion (or early stop) under the per-ensemble
/// replica-exchange controllers, in lockstep swap rounds. Returns
/// per-chain results ordered by chain id, each carrying its
/// ensemble's [`crate::mcmc::tempering::TemperingReport`].
pub(crate) fn run_tempered<'m>(
    model: &'m dyn EnergyModel,
    spec: &ChainSpec,
    chains: usize,
    ctx: &ChainCtx<'_>,
    exchanges: &mut [ReplicaExchange],
    mut units: Vec<ExecUnit<'m>>,
) -> Result<Vec<ChainResult>, Mc2aError> {
    // The builder guarantees this; guard anyway because the trait
    // entry point is public: ensembles must tile 0..chains contiguously
    // (overlaps would leave chains at the never-written β 0.0, gaps
    // would panic on the energy slices below).
    let mut covered = 0usize;
    for ex in exchanges.iter() {
        if ex.first_chain() != covered {
            return Err(Mc2aError::InvalidConfig(format!(
                "replica-exchange ensemble starts at chain {}, expected {covered} \
                 (ensembles must tile the chain range contiguously)",
                ex.first_chain()
            )));
        }
        covered += ex.k();
    }
    if covered != chains {
        return Err(Mc2aError::InvalidConfig(format!(
            "replica-exchange ensembles cover {covered} chains, run has {chains}"
        )));
    }
    let swap_every = exchanges
        .first()
        .map(|ex| ex.swap_every())
        .unwrap_or(1)
        .max(1);
    let mut traces: Vec<Vec<f64>> = vec![Vec::new(); chains];
    let mut betas_by_chain: Vec<f32> = vec![0.0; chains];
    let mut energies: Vec<f64> = vec![0.0; chains];
    let mut signals: Vec<ChainSignal> = Vec::new();
    let mut done = 0usize;
    let mut round = 0usize;
    while done < spec.steps {
        if ctx.stop_requested() {
            break;
        }
        let _round_span = telemetry::span_with("lockstep", || format!("swap round {round}"));
        telemetry::metrics().counter_add("lockstep_rounds_total", &[("driver", "tempered")], 1);
        round += 1;
        // Segment ends at the next swap boundary of the *global* step
        // clock, so a resumed run keeps the uninterrupted run's swap
        // schedule (the final segment may be shorter; it ends the run
        // without a swap).
        let global = spec.beta_offset + done;
        let to_boundary = swap_every - (global % swap_every);
        let n = to_boundary.min(spec.steps - done);
        // Plan each chain's β from its replica's current rung.
        for ex in exchanges.iter() {
            for slot in 0..ex.k() {
                betas_by_chain[ex.chain_id(slot)] = ex.beta_of_slot(slot);
            }
        }
        if units.len() > 1 {
            let betas_by_chain = &betas_by_chain;
            std::thread::scope(|scope| {
                for unit in units.iter_mut() {
                    scope.spawn(move || unit.advance_per_chain(done, n, betas_by_chain));
                }
            });
        } else if let Some(unit) = units.first_mut() {
            unit.advance_per_chain(done, n, &betas_by_chain);
        }
        done += n;
        // Segment boundary: gather the chains' cached energies in
        // deterministic order and stream progress events.
        signals.clear();
        for unit in units.iter_mut() {
            unit.signals(model, &mut signals);
        }
        for s in &signals {
            // The swap rule works on energies; the engine tracks the
            // objective (−E for every shipped model).
            energies[s.chain_id] = -s.objective;
            traces[s.chain_id].push(s.objective);
            ctx.emit(ProgressEvent {
                chain_id: s.chain_id,
                step: done,
                beta: betas_by_chain[s.chain_id],
                objective: s.objective,
                best_objective: s.best,
                updates: s.updates,
                steps_per_sec: None,
                eta_seconds: None,
            });
        }
        // Swap only at true boundaries (a truncated final segment
        // ends the run without one).
        if (spec.beta_offset + done) % swap_every == 0 {
            for ex in exchanges.iter_mut() {
                let first = ex.first_chain();
                let k = ex.k();
                ex.swap_round(&energies[first..first + k]);
            }
        }
    }
    let mut results = Vec::with_capacity(chains);
    for unit in units {
        unit.finish(model, &traces, &mut results);
    }
    results.sort_by_key(|r| r.chain_id);
    // Attach each ensemble's diagnostics to its chains' results
    // (after the sort, chain ids 0..chains index the vector directly).
    for ex in exchanges.iter() {
        let report = ex.report();
        for slot in 0..ex.k() {
            results[ex.chain_id(slot)].tempering = Some(report.clone());
        }
    }
    Ok(results)
}
