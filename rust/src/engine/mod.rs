//! The unified engine API — *the* public entry point of the crate.
//!
//! One fluent builder subsumes the CLI flag soup, the bench harness
//! wiring and the per-example setups:
//!
//! ```no_run
//! use mc2a::energy::PottsGrid;
//! use mc2a::engine::Engine;
//! use mc2a::mcmc::{AlgoKind, BetaSchedule};
//!
//! let model = PottsGrid::new(16, 16, 2, 1.0);
//! let metrics = Engine::for_model(&model)
//!     .algo(AlgoKind::BlockGibbs)
//!     .schedule(BetaSchedule::Constant(0.5))
//!     .steps(2_000)
//!     .chains(4)
//!     .build()?
//!     .run()?;
//! println!("best objective: {}", metrics.best_objective());
//! # Ok::<(), mc2a::engine::Mc2aError>(())
//! ```
//!
//! The moving parts:
//!
//! * [`ExecutionBackend`] — pluggable chain executors
//!   ([`SoftwareBackend`], [`AcceleratorBackend`], [`RuntimeBackend`],
//!   or any user type via [`EngineBuilder::backend`]),
//! * [`EngineBuilder`] — validates the configuration up front and
//!   returns typed [`Mc2aError`]s instead of panicking,
//! * [`ChainObserver`] — streaming progress + convergence diagnostics
//!   (split R-hat / ESS) with cooperative early stopping,
//! * [`registry`] — the named-workload table the CLI and tests share.

pub mod backend;
pub mod error;
pub mod observer;
pub mod registry;

pub use backend::{
    AcceleratorBackend, ChainCtx, ChainSpec, ExecutionBackend, RuntimeBackend, SoftwareBackend,
};
pub use error::Mc2aError;
pub use observer::{
    ChainObserver, ConvergenceStop, DiagnosticsReport, NullObserver, ObserverAction,
    PrintObserver, ProgressEvent,
};
pub use registry::{WorkloadEntry, REGISTRY};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::{ChainResult, RunMetrics};
use crate::energy::EnergyModel;
use crate::isa::HwConfig;
use crate::mcmc::{AlgoKind, BetaSchedule, SamplerKind};
use observer::DiagnosticsTracker;

/// A model the engine can borrow (library callers) or own (registry
/// workloads).
enum ModelHandle<'m> {
    Borrowed(&'m dyn EnergyModel),
    Owned(Box<dyn EnergyModel>),
}

impl ModelHandle<'_> {
    fn get(&self) -> &dyn EnergyModel {
        match self {
            ModelHandle::Borrowed(m) => *m,
            ModelHandle::Owned(b) => b.as_ref(),
        }
    }
}

/// Backend selection held by the builder until `build()` validates it.
enum BackendChoice {
    Software,
    Accelerator(AcceleratorBackend),
    Runtime(PathBuf),
    Custom(Box<dyn ExecutionBackend>),
}

/// Fluent configuration for an [`Engine`] run.
///
/// Obtained from [`Engine::for_model`] or [`Engine::for_workload`];
/// every setter consumes and returns the builder, and [`build`]
/// (`EngineBuilder::build`) performs all validation.
pub struct EngineBuilder<'m> {
    model: ModelHandle<'m>,
    workload: Option<&'static str>,
    algo: AlgoKind,
    sampler: SamplerKind,
    schedule: BetaSchedule,
    steps: usize,
    chains: usize,
    seed: u64,
    pas_flips: usize,
    observe_every: usize,
    init_state: Option<Vec<u32>>,
    backend: BackendChoice,
    observer: Option<Box<dyn ChainObserver>>,
}

impl<'m> EngineBuilder<'m> {
    fn with_model(model: ModelHandle<'m>) -> EngineBuilder<'m> {
        EngineBuilder {
            model,
            workload: None,
            algo: AlgoKind::BlockGibbs,
            sampler: SamplerKind::Gumbel,
            schedule: BetaSchedule::Constant(1.0),
            steps: 100,
            chains: 1,
            seed: 1,
            pas_flips: 8,
            observe_every: 0,
            init_state: None,
            backend: BackendChoice::Software,
            observer: None,
        }
    }

    /// MCMC algorithm (default: the workload's pairing, else Block Gibbs).
    pub fn algo(mut self, algo: AlgoKind) -> Self {
        self.algo = algo;
        self
    }

    /// Categorical sampler for the software algorithms (default Gumbel).
    pub fn sampler(mut self, sampler: SamplerKind) -> Self {
        self.sampler = sampler;
        self
    }

    /// β (inverse-temperature) schedule, stepped every MCMC step on
    /// every backend (default: constant 1.0).
    pub fn schedule(mut self, schedule: BetaSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Steps per chain (default 100).
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Number of independent chains fanned out over OS threads
    /// (default 1; chain `i` is seeded with `seed + i`).
    pub fn chains(mut self, chains: usize) -> Self {
        self.chains = chains;
        self
    }

    /// Base RNG seed (default 1).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// PAS path length `L` (default 8; ignored by other algorithms).
    pub fn pas_flips(mut self, pas_flips: usize) -> Self {
        self.pas_flips = pas_flips;
        self
    }

    /// Observation cadence in steps for progress events, diagnostics
    /// and early-stop checks (default: `steps / 20`, at least 1).
    pub fn observe_every(mut self, every: usize) -> Self {
        self.observe_every = every;
        self
    }

    /// Shared initial assignment for every chain (default: random per
    /// chain). Length and per-RV ranges are validated by `build()`.
    pub fn init_state(mut self, x0: Vec<u32>) -> Self {
        self.init_state = Some(x0);
        self
    }

    /// Streaming observer receiving progress and diagnostics callbacks.
    pub fn observer(mut self, observer: Box<dyn ChainObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Run on the pure-Rust software chains (the default).
    pub fn software(mut self) -> Self {
        self.backend = BackendChoice::Software;
        self
    }

    /// Run on the cycle-accurate MC²A accelerator simulator with `hw`.
    pub fn accelerator(mut self, hw: HwConfig) -> Self {
        self.backend = BackendChoice::Accelerator(AcceleratorBackend::new(hw));
        self
    }

    /// Run on the PJRT/XLA runtime path, loading artifacts from `dir`
    /// (requires the `xla-runtime` feature and `make artifacts`).
    pub fn runtime(mut self, dir: impl Into<PathBuf>) -> Self {
        self.backend = BackendChoice::Runtime(dir.into());
        self
    }

    /// Run on a custom [`ExecutionBackend`] implementation.
    pub fn backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = BackendChoice::Custom(backend);
        self
    }

    /// Validate the configuration and construct the engine.
    pub fn build(self) -> Result<Engine<'m>, Mc2aError> {
        if self.chains == 0 {
            return Err(Mc2aError::InvalidConfig("chains must be ≥ 1".into()));
        }
        if self.steps == 0 {
            return Err(Mc2aError::InvalidConfig("steps must be ≥ 1".into()));
        }
        let model_vars = self.model.get().num_vars();
        if let Some(x0) = &self.init_state {
            if x0.len() != model_vars {
                return Err(Mc2aError::InvalidConfig(format!(
                    "initial state has {} entries, model has {model_vars} RVs",
                    x0.len()
                )));
            }
            for (i, &v) in x0.iter().enumerate() {
                let k = self.model.get().num_states(i);
                if v as usize >= k {
                    return Err(Mc2aError::InvalidConfig(format!(
                        "initial state[{i}] = {v} out of range (RV has {k} states)"
                    )));
                }
            }
        }
        let backend: Box<dyn ExecutionBackend> = match self.backend {
            BackendChoice::Software => Box::new(SoftwareBackend),
            BackendChoice::Accelerator(ab) => {
                ab.hw().validate().map_err(Mc2aError::InvalidHardware)?;
                Box::new(ab)
            }
            BackendChoice::Runtime(dir) => Box::new(RuntimeBackend::new(dir)?),
            BackendChoice::Custom(b) => b,
        };
        let observe_every = if self.observe_every == 0 {
            (self.steps / 20).max(1)
        } else {
            self.observe_every
        };
        Ok(Engine {
            model: self.model,
            spec: ChainSpec {
                algo: self.algo,
                sampler: self.sampler,
                schedule: self.schedule,
                steps: self.steps,
                seed: self.seed,
                pas_flips: self.pas_flips,
                observe_every,
                init_state: self.init_state,
            },
            chains: self.chains,
            backend,
            observer: self.observer,
            workload: self.workload,
        })
    }
}

/// A fully-validated multi-chain run: one model, one backend, `chains`
/// seed streams, and an optional streaming observer.
pub struct Engine<'m> {
    model: ModelHandle<'m>,
    spec: ChainSpec,
    chains: usize,
    backend: Box<dyn ExecutionBackend>,
    observer: Option<Box<dyn ChainObserver>>,
    workload: Option<&'static str>,
}

impl<'m> Engine<'m> {
    /// Start configuring a run over a caller-owned model.
    pub fn for_model(model: &'m dyn EnergyModel) -> EngineBuilder<'m> {
        EngineBuilder::with_model(ModelHandle::Borrowed(model))
    }

    /// Start configuring a run over a registry workload; the workload's
    /// Table I algorithm pairing and PAS path length become defaults.
    pub fn for_workload(name: &str) -> Result<EngineBuilder<'static>, Mc2aError> {
        let wl = registry::lookup(name)?;
        let mut b = EngineBuilder::with_model(ModelHandle::Owned(wl.model));
        b.workload = Some(wl.name);
        b.algo = wl.algorithm;
        b.pas_flips = wl.pas_flips;
        Ok(b)
    }

    /// The model this engine runs.
    pub fn model(&self) -> &dyn EnergyModel {
        self.model.get()
    }

    /// The validated chain specification.
    pub fn spec(&self) -> &ChainSpec {
        &self.spec
    }

    /// Number of chains per run.
    pub fn chains(&self) -> usize {
        self.chains
    }

    /// The backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Registry name when built via [`Engine::for_workload`].
    pub fn workload_name(&self) -> Option<&'static str> {
        self.workload
    }

    /// Fan the chains out over OS threads, stream events to the
    /// observer, and gather per-chain results. Re-running the same
    /// engine reproduces the same seeds and therefore the same chains.
    pub fn run(&mut self) -> Result<RunMetrics, Mc2aError> {
        let t0 = Instant::now();
        let model = self.model.get();
        let spec = &self.spec;
        let backend = self.backend.as_ref();
        let observer = &mut self.observer;
        let n = self.chains;
        let stop = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<ProgressEvent>();

        let joined: Vec<Result<ChainResult, Mc2aError>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for chain_id in 0..n {
                let tx = tx.clone();
                let stop = &stop;
                handles.push(scope.spawn(move || {
                    let ctx = ChainCtx {
                        stop,
                        events: Some(tx),
                    };
                    backend.run_chain(model, spec, chain_id, &ctx)
                }));
            }
            drop(tx);

            // Event loop on the coordinating thread: diagnostics are
            // computed here, so observers can hold plain mutable state.
            let mut tracker = DiagnosticsTracker::new(n);
            while let Ok(event) = rx.recv() {
                let diag = tracker.record(&event);
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.on_progress(&event) == ObserverAction::Stop {
                        stop.store(true, Ordering::Relaxed);
                    }
                    if let Some(d) = diag {
                        if obs.on_diagnostics(&d) == ObserverAction::Stop {
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                }
            }

            handles
                .into_iter()
                .enumerate()
                .map(|(chain_id, h)| {
                    h.join()
                        .unwrap_or_else(|_| Err(Mc2aError::ChainPanicked { chain_id }))
                })
                .collect()
        });

        let mut chains = Vec::with_capacity(n);
        for result in joined {
            let chain = result?;
            if let Some(obs) = self.observer.as_deref_mut() {
                obs.on_chain_done(&chain);
            }
            chains.push(chain);
        }
        Ok(RunMetrics {
            chains,
            wall: t0.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;

    #[test]
    fn software_chains_run_in_parallel_and_agree() {
        let m = PottsGrid::new(6, 6, 2, 0.3);
        let metrics = Engine::for_model(&m)
            .steps(2000)
            .chains(4)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(metrics.chains.len(), 4);
        // Symmetric Ising at moderate β: marginals near 0.5 for every chain.
        for c in &metrics.chains {
            assert!((c.marginal0[0] - 0.5).abs() < 0.1, "{:?}", c.marginal0);
        }
        assert!(metrics.total_updates() >= 4 * 2000 * 36);
        assert!(metrics.updates_per_sec() > 0.0);
    }

    #[test]
    fn accelerator_backend_reports_cycles() {
        let m = PottsGrid::new(4, 4, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(50)
            .chains(2)
            .accelerator(HwConfig::fig10_toy())
            .build()
            .unwrap()
            .run()
            .unwrap();
        for c in &metrics.chains {
            let rep = c.sim.as_ref().expect("sim report");
            assert!(rep.cycles > 0);
            assert_eq!(rep.updates, 50 * 16);
        }
    }

    #[test]
    fn chains_use_distinct_seeds() {
        let m = PottsGrid::new(5, 5, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(50)
            .chains(2)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_ne!(metrics.chains[0].marginal0, metrics.chains[1].marginal0);
    }

    struct StopImmediately;
    impl ChainObserver for StopImmediately {
        fn on_progress(&mut self, _e: &ProgressEvent) -> ObserverAction {
            ObserverAction::Stop
        }
    }

    #[test]
    fn observer_early_stop_halts_all_chains() {
        let m = PottsGrid::new(8, 8, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(100_000)
            .chains(2)
            .observe_every(5)
            .observer(Box::new(StopImmediately))
            .build()
            .unwrap()
            .run()
            .unwrap();
        // At least one chain must have observed the stop request early;
        // a chain that raced ahead of the flag may have run longer, but
        // none can exceed the full budget only if the stop was ignored.
        assert!(
            metrics.chains.iter().any(|c| c.steps < 100_000),
            "no chain stopped early: {:?}",
            metrics.chains.iter().map(|c| c.steps).collect::<Vec<_>>()
        );
    }

    #[test]
    fn builder_rejects_zero_chains_and_steps() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        assert!(matches!(
            Engine::for_model(&m).chains(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).steps(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
    }

    #[test]
    fn builder_validates_init_state() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        assert!(matches!(
            Engine::for_model(&m).init_state(vec![0; 4]).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).init_state(vec![7; 9]).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(Engine::for_model(&m).init_state(vec![1; 9]).build().is_ok());
    }

    #[test]
    fn invalid_hardware_is_a_typed_error() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        let mut hw = HwConfig::paper_default();
        hw.s = 48; // not a power of two
        assert!(matches!(
            Engine::for_model(&m).accelerator(hw).build(),
            Err(Mc2aError::InvalidHardware(_))
        ));
    }
}
