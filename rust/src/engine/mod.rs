//! The unified engine API — *the* public entry point of the crate.
//!
//! One fluent builder subsumes the CLI flag soup, the bench harness
//! wiring and the per-example setups:
//!
//! ```no_run
//! use mc2a::energy::PottsGrid;
//! use mc2a::engine::Engine;
//! use mc2a::mcmc::{AlgoKind, BetaSchedule};
//!
//! let model = PottsGrid::new(16, 16, 2, 1.0);
//! let metrics = Engine::for_model(&model)
//!     .algo(AlgoKind::BlockGibbs)
//!     .schedule(BetaSchedule::Constant(0.5))
//!     .steps(2_000)
//!     .chains(4)
//!     .build()?
//!     .run()?;
//! println!("best objective: {}", metrics.best_objective());
//! # Ok::<(), mc2a::engine::Mc2aError>(())
//! ```
//!
//! The moving parts:
//!
//! * [`ExecutionBackend`] — pluggable chain executors
//!   ([`SoftwareBackend`], [`BatchedSoftwareBackend`],
//!   [`AcceleratorBackend`], [`MultiCoreAcceleratorBackend`],
//!   [`RuntimeBackend`], or any user type via
//!   [`EngineBuilder::backend`]); a backend runs single chains and may
//!   override the whole-run fan-out,
//! * [`scheduler`] — the work-stealing thread pool the batched backend
//!   multiplexes `chains / batch` work items over,
//! * [`EngineBuilder`] — validates the configuration up front and
//!   returns typed [`Mc2aError`]s instead of panicking,
//! * [`ChainObserver`] — streaming progress + convergence diagnostics
//!   (split R-hat / ESS) with cooperative early stopping,
//! * [`registry`] — the named-workload table the CLI and tests share,
//! * [`server`] — sampling-as-a-service: the persistent multi-tenant
//!   [`server::JobServer`] that multiplexes many jobs over one shared
//!   priority-aware pool, with checkpoint-backed crash recovery and a
//!   std-only TCP front-end (`mc2a serve` / `mc2a client`),
//! * [`telemetry`] — process-wide metrics (Prometheus text exposition)
//!   and Chrome-trace span collection, disabled by default and
//!   bit-identity-safe when enabled.

pub(crate) mod adaptive;
pub mod backend;
pub mod batched;
pub mod checkpoint;
pub mod error;
pub mod observer;
pub mod profile;
pub mod registry;
pub mod scheduler;
pub mod server;
pub mod telemetry;
pub(crate) mod tempering;

pub use backend::{
    AcceleratorBackend, ChainCtx, ChainSpec, ExecutionBackend, MultiCoreAcceleratorBackend,
    RestartSignal, RuntimeBackend, SoftwareBackend,
};
pub use batched::BatchedSoftwareBackend;
pub use checkpoint::{Checkpoint, JobEnvelope};
pub use error::Mc2aError;
pub use observer::{
    event_stream, ChainObserver, ChannelObserver, ConvergenceStop, DiagnosticsReport,
    EventStream, NullObserver, ObserverAction, PrintObserver, ProgressEvent, StreamEvent,
};
pub use registry::{WorkloadEntry, REGISTRY};
pub use server::{
    JobId, JobResult, JobServer, JobServerConfig, JobSpec, JobState, JobStatus, Priority,
    ServeBackend,
};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::{ChainResult, RunMetrics};
use crate::energy::EnergyModel;
use crate::isa::{HwConfig, MultiHwConfig};
use crate::mcmc::anneal::{AdaptiveSchedule, AnnealConfig, AnnealPolicy, BetaController};
use crate::mcmc::tempering::{AdaptSpacing, Ladder, ReplicaExchange, TemperConfig};
use crate::mcmc::{AlgoKind, BetaSchedule, SamplerKind};
use crate::roofline::RooflineObservation;
use observer::{DiagnosticsTracker, RateTracker};

/// A model the engine can borrow (library callers) or own (registry
/// workloads).
enum ModelHandle<'m> {
    Borrowed(&'m dyn EnergyModel),
    Owned(Box<dyn EnergyModel>),
}

impl ModelHandle<'_> {
    fn get(&self) -> &dyn EnergyModel {
        match self {
            ModelHandle::Borrowed(m) => *m,
            ModelHandle::Owned(b) => b.as_ref(),
        }
    }
}

/// Backend selection held by the builder until `build()` validates it.
enum BackendChoice {
    Software,
    Batched,
    Accelerator(AcceleratorBackend),
    MultiCore(HwConfig),
    Runtime(PathBuf),
    Custom(Box<dyn ExecutionBackend>),
}

/// Cold-chain restart policy (see
/// [`EngineBuilder::restart_on_stagnation`]).
#[derive(Clone, Copy, Debug)]
pub struct RestartConfig {
    /// Trigger while split R-hat stays above this value.
    pub r_hat_threshold: f64,
    /// Consecutive stagnant observation rounds required to trigger.
    pub rounds: usize,
}

/// Fluent configuration for an [`Engine`] run.
///
/// Obtained from [`Engine::for_model`] or [`Engine::for_workload`];
/// every setter consumes and returns the builder, and [`build`]
/// (`EngineBuilder::build`) performs all validation.
pub struct EngineBuilder<'m> {
    model: ModelHandle<'m>,
    workload: Option<&'static str>,
    algo: AlgoKind,
    sampler: SamplerKind,
    schedule: BetaSchedule,
    schedule_offset: usize,
    adaptive: Option<AnnealConfig>,
    anneal_state: Option<Vec<f64>>,
    temper_ladder: Option<Ladder>,
    temper_swap_every: Option<usize>,
    temper_adapt: Option<AdaptSpacing>,
    temper_state: Option<Vec<f64>>,
    steps: usize,
    chains: usize,
    seed: u64,
    pas_flips: usize,
    observe_every: usize,
    init_state: Option<Vec<u32>>,
    backend: BackendChoice,
    batch: Option<usize>,
    threads: Option<usize>,
    cores: Option<usize>,
    restart: Option<RestartConfig>,
    observer: Option<Box<dyn ChainObserver>>,
}

impl<'m> EngineBuilder<'m> {
    fn with_model(model: ModelHandle<'m>) -> EngineBuilder<'m> {
        EngineBuilder {
            model,
            workload: None,
            algo: AlgoKind::BlockGibbs,
            sampler: SamplerKind::Gumbel,
            schedule: BetaSchedule::Constant(1.0),
            schedule_offset: 0,
            adaptive: None,
            anneal_state: None,
            temper_ladder: None,
            temper_swap_every: None,
            temper_adapt: None,
            temper_state: None,
            steps: 100,
            chains: 1,
            seed: 1,
            pas_flips: 8,
            observe_every: 0,
            init_state: None,
            backend: BackendChoice::Software,
            batch: None,
            threads: None,
            cores: None,
            restart: None,
            observer: None,
        }
    }

    /// MCMC algorithm (default: the workload's pairing, else Block Gibbs).
    pub fn algo(mut self, algo: AlgoKind) -> Self {
        self.algo = algo;
        self
    }

    /// Categorical sampler for the software algorithms (default Gumbel).
    pub fn sampler(mut self, sampler: SamplerKind) -> Self {
        self.sampler = sampler;
        self
    }

    /// β (inverse-temperature) schedule, stepped every MCMC step on
    /// every backend (default: constant 1.0).
    pub fn schedule(mut self, schedule: BetaSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Global-step offset of the schedule clock (default 0). A resumed
    /// run passes the checkpoint's cumulative step count here so β is
    /// evaluated at `offset + t` — the ramp continues where the
    /// previous run stopped instead of restarting at t = 0. With
    /// [`EngineBuilder::adaptive`], the controller's virtual clock
    /// starts at the same offset.
    pub fn schedule_offset(mut self, steps: usize) -> Self {
        self.schedule_offset = steps;
        self
    }

    /// Enable observer-driven adaptive annealing with the default
    /// configuration for `policy` ([`AnnealConfig::new`]): the fixed
    /// schedule becomes the *base ramp* of an
    /// [`AdaptiveSchedule`] controller that consumes each observation
    /// round's cross-chain diagnostics — reheat (or hold, per
    /// `policy`) when the best objective stagnates, accelerate cooling
    /// while split R-hat says the chains mix. Chains run in lockstep
    /// observation rounds; supported on the software, batched and
    /// accelerator-simulator backends.
    pub fn adaptive(mut self, policy: AnnealPolicy) -> Self {
        self.adaptive = Some(AnnealConfig::new(policy));
        self
    }

    /// Adaptive annealing with explicit tuning knobs (see
    /// [`EngineBuilder::adaptive`]).
    pub fn adaptive_config(mut self, cfg: AnnealConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// Restore adaptive-controller memory serialized by a previous
    /// run ([`Engine::anneal_state`], stored in
    /// [`Checkpoint::anneal`]). Requires [`EngineBuilder::adaptive`].
    pub fn anneal_state(mut self, state: Vec<f64>) -> Self {
        self.anneal_state = Some(state);
        self
    }

    /// Enable replica exchange (parallel tempering,
    /// [`crate::mcmc::tempering`]): the chains split into
    /// `chains / K` independent ensembles of `K = ladder.k()`
    /// replicas, each replica pinned to one ladder rung, with
    /// Metropolis temperature swaps between neighboring rungs every
    /// [`EngineBuilder::swap_every`] steps. Chains run in lockstep
    /// swap rounds (the swap cadence is also the observation
    /// cadence); supported on the software, batched and
    /// accelerator-simulator backends. `build()` rejects ladders with
    /// fewer than 2 rungs, non-monotone rungs, `K > chains`, chain
    /// counts that are not a multiple of `K`, and combinations with
    /// [`EngineBuilder::adaptive`] or a non-constant schedule.
    pub fn tempering(mut self, ladder: Ladder) -> Self {
        self.temper_ladder = Some(ladder);
        self
    }

    /// Steps between replica-exchange swap rounds (default 10).
    /// Requires [`EngineBuilder::tempering`].
    pub fn swap_every(mut self, every: usize) -> Self {
        self.temper_swap_every = Some(every);
        self
    }

    /// Enable adaptive ladder re-spacing: every few swap rounds the
    /// β gaps are retuned toward `target_rate` per-pair swap
    /// acceptance ([`AdaptSpacing::new`]). `build()` rejects rates
    /// outside (0, 1). Requires [`EngineBuilder::tempering`].
    pub fn temper_adapt(mut self, target_rate: f64) -> Self {
        self.temper_adapt = Some(AdaptSpacing::new(target_rate));
        self
    }

    /// Restore replica-exchange memory serialized by a previous run
    /// ([`Engine::temper_state`], stored in [`Checkpoint::temper`]):
    /// the (possibly re-spaced) ladder, the chain→rung assignment,
    /// swap statistics and the swap-RNG position. Requires
    /// [`EngineBuilder::tempering`] with a same-K ladder.
    pub fn temper_state(mut self, state: Vec<f64>) -> Self {
        self.temper_state = Some(state);
        self
    }

    /// Steps per chain (default 100).
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Number of independent chains (default 1). Chain `i` draws from
    /// the RNG stream `Rng::fork(seed, i)` on every backend, so its
    /// trajectory is bit-identical regardless of thread count, batch
    /// size, or backend.
    pub fn chains(mut self, chains: usize) -> Self {
        self.chains = chains;
        self
    }

    /// Base RNG seed (default 1).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// PAS path length `L` (default 8; ignored by other algorithms).
    pub fn pas_flips(mut self, pas_flips: usize) -> Self {
        self.pas_flips = pas_flips;
        self
    }

    /// Observation cadence in steps for progress events, diagnostics
    /// and early-stop checks (default: `steps / 20`, at least 1).
    pub fn observe_every(mut self, every: usize) -> Self {
        self.observe_every = every;
        self
    }

    /// Shared initial assignment for every chain (default: random per
    /// chain). Length and per-RV ranges are validated by `build()`.
    pub fn init_state(mut self, x0: Vec<u32>) -> Self {
        self.init_state = Some(x0);
        self
    }

    /// Resume from a saved [`Checkpoint`]: seed every chain with the
    /// checkpoint's best assignment and continue the β-schedule clock
    /// at its cumulative step count.
    ///
    /// The checkpoint's run-shape metadata (workload, sampler, chain
    /// count — recorded by `--save-state` since the fields were added;
    /// absent fields are not checked) must match this builder, and the
    /// saved assignment must match the model's RV count; a mismatch is
    /// a typed [`Mc2aError::CheckpointMismatch`] naming both sides
    /// instead of a silent resume of the wrong run. Call after setting
    /// the workload/model, sampler and chain count.
    pub fn init_from_checkpoint(self, ck: &Checkpoint) -> Result<Self, Mc2aError> {
        let mismatch = |what: &str, run: String, checkpoint: String| {
            Err(Mc2aError::CheckpointMismatch { what: what.to_string(), run, checkpoint })
        };
        if let (Some(run), Some(saved)) = (self.workload, ck.workload.as_deref()) {
            if !run.eq_ignore_ascii_case(saved) {
                return mismatch("workload", run.to_string(), saved.to_string());
            }
        }
        if let Some(saved) = ck.sampler.as_deref() {
            // Accept the canonical spec (`lut:16:8`), an equivalent
            // parseable spelling, or (pre-spec checkpoints) the bare
            // family name (`lut`).
            let run = self.sampler.spec();
            let equivalent = SamplerKind::parse(saved).map(|k| k == self.sampler);
            if !run.eq_ignore_ascii_case(saved)
                && equivalent != Ok(true)
                && !self.sampler.name().eq_ignore_ascii_case(saved)
            {
                return mismatch("sampler", run, saved.to_string());
            }
        }
        if let Some(saved) = ck.chains {
            if self.chains != saved {
                return mismatch("chains", self.chains.to_string(), saved.to_string());
            }
        }
        let num_vars = self.model.get().num_vars();
        if ck.best_x.len() != num_vars {
            return mismatch("model RVs", num_vars.to_string(), ck.best_x.len().to_string());
        }
        Ok(self.init_state(ck.best_x.clone()).schedule_offset(ck.steps))
    }

    /// Streaming observer receiving progress and diagnostics callbacks.
    pub fn observer(mut self, observer: Box<dyn ChainObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Run on the pure-Rust software chains (the default),
    /// thread-per-chain.
    pub fn software(mut self) -> Self {
        self.backend = BackendChoice::Software;
        self
    }

    /// Run on the batched software backend: structure-of-arrays chain
    /// batches multiplexed over a work-stealing thread pool. Batch
    /// size defaults to `min(chains, 32)`; tune with
    /// [`EngineBuilder::batch`] / [`EngineBuilder::threads`].
    pub fn batched(mut self) -> Self {
        self.backend = BackendChoice::Batched;
        self
    }

    /// Chains per batched work item (implies the batched backend).
    /// `build()` rejects 0 and values above the chain count.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = Some(batch);
        if matches!(self.backend, BackendChoice::Software) {
            self.backend = BackendChoice::Batched;
        }
        self
    }

    /// Worker-pool size for the batched backend (implies the batched
    /// backend; default: the machine's available parallelism).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        if matches!(self.backend, BackendChoice::Software) {
            self.backend = BackendChoice::Batched;
        }
        self
    }

    /// Run on the cycle-accurate MC²A accelerator simulator with `hw`.
    pub fn accelerator(mut self, hw: HwConfig) -> Self {
        self.backend = BackendChoice::Accelerator(AcceleratorBackend::new(hw));
        self
    }

    /// Run on the sharded multi-core MC²A simulator (§II-D) with `hw`
    /// per core; choose the core count with [`EngineBuilder::cores`]
    /// (default 1, which is bit-identical to the single-core
    /// accelerator backend).
    pub fn multicore(mut self, hw: HwConfig) -> Self {
        self.backend = BackendChoice::MultiCore(hw);
        self
    }

    /// Number of parallel MC²A cores (implies the multi-core
    /// accelerator backend with the paper-default hardware when no
    /// backend was chosen). `build()` rejects 0, more cores than the
    /// model has RVs, and — at cores > 1 — algorithms that cannot be
    /// sharded (only Block Gibbs and Async Gibbs can).
    pub fn cores(mut self, cores: usize) -> Self {
        self.cores = Some(cores);
        if matches!(self.backend, BackendChoice::Software) {
            self.backend = BackendChoice::MultiCore(HwConfig::paper_default());
        }
        self
    }

    /// Enable observer-driven cold-chain restarts (off by default):
    /// when split R-hat stays above `r_hat_threshold` for `rounds`
    /// consecutive observation rounds, every software chain re-forks
    /// its RNG stream and restarts from its best state so far.
    /// Honored by the scalar software chain runner (the thread-per-
    /// chain backend and the batched backend's scalar fallback);
    /// accelerator backends ignore it.
    pub fn restart_on_stagnation(mut self, r_hat_threshold: f64, rounds: usize) -> Self {
        self.restart = Some(RestartConfig { r_hat_threshold, rounds: rounds.max(1) });
        self
    }

    /// Run on the PJRT/XLA runtime path, loading artifacts from `dir`
    /// (requires the `xla-runtime` feature and `make artifacts`).
    pub fn runtime(mut self, dir: impl Into<PathBuf>) -> Self {
        self.backend = BackendChoice::Runtime(dir.into());
        self
    }

    /// Run on a custom [`ExecutionBackend`] implementation.
    pub fn backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = BackendChoice::Custom(backend);
        self
    }

    /// Validate the configuration and construct the engine.
    pub fn build(self) -> Result<Engine<'m>, Mc2aError> {
        if self.chains == 0 {
            return Err(Mc2aError::InvalidConfig("chains must be ≥ 1".into()));
        }
        if self.steps == 0 {
            return Err(Mc2aError::InvalidConfig("steps must be ≥ 1".into()));
        }
        self.schedule.validate().map_err(Mc2aError::InvalidConfig)?;
        if self.anneal_state.is_some() && self.adaptive.is_none() {
            return Err(Mc2aError::InvalidConfig(
                "anneal_state restores adaptive-controller memory; enable adaptive(...) first"
                    .into(),
            ));
        }
        if self.adaptive.is_some() {
            // Both features respond to the same stagnation signal;
            // combining them would fight over the escape strategy.
            if self.restart.is_some() {
                return Err(Mc2aError::InvalidConfig(
                    "adaptive annealing and restart_on_stagnation are mutually exclusive"
                        .into(),
                ));
            }
            if matches!(self.backend, BackendChoice::Runtime(_)) {
                return Err(Mc2aError::InvalidConfig(
                    "adaptive annealing is supported on the software, batched and \
                     accelerator-simulator backends only"
                        .into(),
                ));
            }
        }
        if self.temper_ladder.is_none()
            && (self.temper_swap_every.is_some()
                || self.temper_adapt.is_some()
                || self.temper_state.is_some())
        {
            return Err(Mc2aError::InvalidConfig(
                "swap_every/temper_adapt/temper_state configure replica exchange; \
                 enable tempering(ladder) first"
                    .into(),
            ));
        }
        if let Some(ladder) = &self.temper_ladder {
            ladder.validate().map_err(Mc2aError::InvalidConfig)?;
            let k = ladder.k();
            // Both controllers want to own β; a tempered replica's
            // temperature is fixed by its rung, not a schedule.
            if self.adaptive.is_some() {
                return Err(Mc2aError::InvalidConfig(
                    "adaptive annealing and replica exchange are mutually exclusive \
                     (the ladder already fixes each replica's β)"
                        .into(),
                ));
            }
            if self.restart.is_some() {
                return Err(Mc2aError::InvalidConfig(
                    "replica exchange and restart_on_stagnation are mutually exclusive"
                        .into(),
                ));
            }
            if matches!(self.backend, BackendChoice::Runtime(_)) {
                return Err(Mc2aError::InvalidConfig(
                    "replica exchange is supported on the software, batched and \
                     accelerator-simulator backends only"
                        .into(),
                ));
            }
            if !matches!(self.schedule, BetaSchedule::Constant(_)) {
                return Err(Mc2aError::InvalidConfig(
                    "tempering pins each replica to a ladder rung; drop the β \
                     schedule (the ladder replaces it)"
                        .into(),
                ));
            }
            if k > self.chains {
                return Err(Mc2aError::InvalidConfig(format!(
                    "tempering ladder has {k} rungs but only {} chains; \
                     need chains ≥ K",
                    self.chains
                )));
            }
            if self.chains % k != 0 {
                return Err(Mc2aError::InvalidConfig(format!(
                    "chains ({}) must be a multiple of the ladder size ({k}) — \
                     each ensemble holds one replica per rung",
                    self.chains
                )));
            }
            if self.temper_swap_every == Some(0) {
                return Err(Mc2aError::InvalidConfig("swap_every must be ≥ 1".into()));
            }
            if let Some(adapt) = &self.temper_adapt {
                let rate = adapt.target_rate;
                if !rate.is_finite() || rate <= 0.0 || rate >= 1.0 {
                    return Err(Mc2aError::InvalidConfig(format!(
                        "tempering target swap rate must be in (0, 1) (got {rate})"
                    )));
                }
            }
        }
        let model_vars = self.model.get().num_vars();
        if let Some(x0) = &self.init_state {
            if x0.len() != model_vars {
                return Err(Mc2aError::InvalidConfig(format!(
                    "initial state has {} entries, model has {model_vars} RVs",
                    x0.len()
                )));
            }
            for (i, &v) in x0.iter().enumerate() {
                let k = self.model.get().num_states(i);
                if v as usize >= k {
                    return Err(Mc2aError::InvalidConfig(format!(
                        "initial state[{i}] = {v} out of range (RV has {k} states)"
                    )));
                }
            }
        }
        if let Some(batch) = self.batch {
            if batch == 0 {
                return Err(Mc2aError::InvalidConfig("batch must be ≥ 1".into()));
            }
            if batch > self.chains {
                return Err(Mc2aError::InvalidConfig(format!(
                    "batch ({batch}) must not exceed chains ({})",
                    self.chains
                )));
            }
        }
        if self.threads == Some(0) {
            return Err(Mc2aError::InvalidConfig("threads must be ≥ 1".into()));
        }
        // `batch`/`threads` configure the batched software backend
        // only; silently ignoring them on another backend would let
        // `--backend sim --batch 8` run unbatched without a word.
        if (self.batch.is_some() || self.threads.is_some())
            && !matches!(self.backend, BackendChoice::Batched)
        {
            return Err(Mc2aError::InvalidConfig(
                "batch/threads apply to the batched software backend only".into(),
            ));
        }
        // Same rule for `cores` and the multi-core backend; the shard
        // constraints themselves live in one place
        // ([`crate::sim::multicore::validate_shard_config`]).
        if let Some(cores) = self.cores {
            if !matches!(self.backend, BackendChoice::MultiCore(_)) {
                return Err(Mc2aError::InvalidConfig(
                    "cores applies to the multi-core accelerator backend only".into(),
                ));
            }
            crate::sim::multicore::validate_shard_config(model_vars, self.algo, cores)
                .map_err(Mc2aError::InvalidConfig)?;
        }
        // Split R-hat — the restart trigger — is undefined for a single
        // chain, and only the software chain runners poll the signal;
        // accepting other configs would make the feature a silent no-op.
        if self.restart.is_some() {
            if self.chains < 2 {
                return Err(Mc2aError::InvalidConfig(
                    "restart_on_stagnation needs at least 2 chains (split R-hat is \
                     undefined for one chain)"
                        .into(),
                ));
            }
            if !matches!(
                self.backend,
                BackendChoice::Software | BackendChoice::Batched | BackendChoice::Custom(_)
            ) {
                return Err(Mc2aError::InvalidConfig(
                    "restart_on_stagnation is honored by the software chain runners only \
                     (software/batched backends)"
                        .into(),
                ));
            }
        }
        let backend: Box<dyn ExecutionBackend> = match self.backend {
            BackendChoice::Software => Box::new(SoftwareBackend),
            BackendChoice::Batched => {
                let batch = self
                    .batch
                    .unwrap_or_else(|| batched::DEFAULT_BATCH.min(self.chains));
                Box::new(
                    BatchedSoftwareBackend::new(batch)
                        .with_threads(self.threads.unwrap_or(0)),
                )
            }
            BackendChoice::Accelerator(ab) => {
                ab.hw().validate().map_err(Mc2aError::InvalidHardware)?;
                Box::new(ab)
            }
            BackendChoice::MultiCore(hw) => {
                let mb = MultiCoreAcceleratorBackend::new(hw, self.cores.unwrap_or(1));
                mb.hw().validate().map_err(Mc2aError::InvalidHardware)?;
                Box::new(mb)
            }
            BackendChoice::Runtime(dir) => Box::new(RuntimeBackend::new(dir)?),
            BackendChoice::Custom(b) => b,
        };
        let observe_every = if self.observe_every == 0 {
            (self.steps / 20).max(1)
        } else {
            self.observe_every
        };
        let controller: Option<Box<dyn BetaController>> = match self.adaptive {
            Some(cfg) => {
                let mut c =
                    AdaptiveSchedule::new(self.schedule, cfg).with_offset(self.schedule_offset);
                if let Some(state) = &self.anneal_state {
                    c.restore(state).map_err(Mc2aError::InvalidConfig)?;
                }
                Some(Box::new(c))
            }
            None => None,
        };
        let temper: Option<Vec<ReplicaExchange>> = match &self.temper_ladder {
            Some(ladder) => {
                let k = ladder.k();
                let cfg = TemperConfig {
                    swap_every: self.temper_swap_every.unwrap_or(10),
                    adapt: self.temper_adapt,
                };
                let mut exchanges: Vec<ReplicaExchange> = (0..self.chains / k)
                    .map(|e| ReplicaExchange::new(ladder.clone(), cfg, self.seed, e * k, e as u64))
                    .collect();
                if let Some(state) = &self.temper_state {
                    restore_temper_state(&mut exchanges, state)?;
                }
                Some(exchanges)
            }
            None => None,
        };
        Ok(Engine {
            model: self.model,
            spec: ChainSpec {
                algo: self.algo,
                sampler: self.sampler,
                schedule: self.schedule,
                beta_offset: self.schedule_offset,
                steps: self.steps,
                seed: self.seed,
                pas_flips: self.pas_flips,
                observe_every,
                init_state: self.init_state,
            },
            chains: self.chains,
            backend,
            restart: self.restart,
            observer: self.observer,
            controller,
            temper,
            workload: self.workload,
            last_observation: None,
        })
    }
}

/// Restore the per-ensemble replica-exchange states from the flat
/// blob serialized by [`Engine::temper_state`] (`[ensembles,
/// block…]`, one fixed-size block per ensemble).
fn restore_temper_state(
    exchanges: &mut [ReplicaExchange],
    state: &[f64],
) -> Result<(), Mc2aError> {
    let declared = state.first().map(|&e| e as usize);
    if declared != Some(exchanges.len()) {
        return Err(Mc2aError::InvalidConfig(format!(
            "tempering state holds {} ensemble(s), this run has {}",
            declared.unwrap_or(0),
            exchanges.len()
        )));
    }
    let mut at = 1usize;
    for ex in exchanges.iter_mut() {
        let len = ReplicaExchange::state_len(ex.k());
        let end = at + len;
        if end > state.len() {
            return Err(Mc2aError::InvalidConfig(
                "tempering state is truncated".into(),
            ));
        }
        ex.restore(&state[at..end]).map_err(Mc2aError::InvalidConfig)?;
        at = end;
    }
    if at != state.len() {
        return Err(Mc2aError::InvalidConfig(
            "tempering state has trailing entries".into(),
        ));
    }
    Ok(())
}

/// A fully-validated multi-chain run: one model, one backend, `chains`
/// seed streams, and an optional streaming observer.
pub struct Engine<'m> {
    model: ModelHandle<'m>,
    spec: ChainSpec,
    chains: usize,
    backend: Box<dyn ExecutionBackend>,
    restart: Option<RestartConfig>,
    observer: Option<Box<dyn ChainObserver>>,
    controller: Option<Box<dyn BetaController>>,
    temper: Option<Vec<ReplicaExchange>>,
    workload: Option<&'static str>,
    last_observation: Option<RooflineObservation>,
}

impl<'m> Engine<'m> {
    /// Start configuring a run over a caller-owned model.
    pub fn for_model(model: &'m dyn EnergyModel) -> EngineBuilder<'m> {
        EngineBuilder::with_model(ModelHandle::Borrowed(model))
    }

    /// Start configuring a run over a registry workload; the workload's
    /// Table I algorithm pairing and PAS path length become defaults.
    pub fn for_workload(name: &str) -> Result<EngineBuilder<'static>, Mc2aError> {
        let wl = registry::lookup(name)?;
        let mut b = EngineBuilder::with_model(ModelHandle::Owned(wl.model));
        b.workload = Some(wl.name);
        b.algo = wl.algorithm;
        b.pas_flips = wl.pas_flips;
        Ok(b)
    }

    /// The model this engine runs.
    pub fn model(&self) -> &dyn EnergyModel {
        self.model.get()
    }

    /// The validated chain specification.
    pub fn spec(&self) -> &ChainSpec {
        &self.spec
    }

    /// Number of chains per run.
    pub fn chains(&self) -> usize {
        self.chains
    }

    /// The backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Registry name when built via [`Engine::for_workload`].
    pub fn workload_name(&self) -> Option<&'static str> {
        self.workload
    }

    /// The hardware point the backend simulates, when it is a
    /// cycle-accurate simulator (see [`ExecutionBackend::sim_hw`]).
    pub fn backend_sim_hw(&self) -> Option<MultiHwConfig> {
        self.backend.sim_hw()
    }

    /// The measured-roofline observation of the last [`Engine::run`],
    /// when [`profile`] was enabled at the time the run finished.
    pub fn observation(&self) -> Option<&RooflineObservation> {
        self.last_observation.as_ref()
    }

    /// Serialized adaptive-controller memory (None unless the engine
    /// was built with [`EngineBuilder::adaptive`]). After [`Engine::run`]
    /// this is the controller's final state — store it in a
    /// [`Checkpoint`] so a resumed run continues both the β ramp and
    /// the controller's memory.
    pub fn anneal_state(&self) -> Option<Vec<f64>> {
        self.controller.as_ref().map(|c| c.state())
    }

    /// One-line adaptive-controller summary (decisions taken), when
    /// adaptive annealing is enabled.
    pub fn anneal_describe(&self) -> Option<String> {
        self.controller.as_ref().map(|c| c.describe())
    }

    /// Serialized replica-exchange memory (None unless the engine was
    /// built with [`EngineBuilder::tempering`]): `[ensembles]`
    /// followed by one fixed-size block per ensemble. After
    /// [`Engine::run`] this is the controllers' final state — store it
    /// in a [`Checkpoint`] so a resumed run continues the ladder, the
    /// chain→rung assignment and the swap schedule.
    pub fn temper_state(&self) -> Option<Vec<f64>> {
        self.temper.as_ref().map(|exs| {
            let mut out = vec![exs.len() as f64];
            for ex in exs {
                out.extend(ex.state());
            }
            out
        })
    }

    /// Per-ensemble replica-exchange summaries, when tempering is
    /// enabled.
    pub fn temper_describe(&self) -> Option<String> {
        self.temper.as_ref().map(|exs| {
            exs.iter()
                .map(|ex| ex.describe())
                .collect::<Vec<_>>()
                .join("; ")
        })
    }

    /// Hand the fan-out to the backend ([`ExecutionBackend::run_chains`]
    /// — OS thread per chain by default, a work-stealing batch pool on
    /// the batched backend), stream events to the observer, and gather
    /// per-chain results. Re-running the same engine reproduces the
    /// same seeds and therefore the same chains.
    pub fn run(&mut self) -> Result<RunMetrics, Mc2aError> {
        let t0 = Instant::now();
        let workload = self.workload.unwrap_or("model");
        let n_chains = self.chains;
        let _run_span = telemetry::span_with("engine", || {
            format!("engine.run {workload} ({n_chains} chains)")
        });
        let model = self.model.get();
        let spec = &self.spec;
        let backend = self.backend.as_ref();
        let observer = &mut self.observer;
        let controller = self.controller.as_deref_mut();
        let temper = self.temper.as_mut().map(|v| v.as_mut_slice());
        let n = self.chains;
        let restart_cfg = self.restart;
        let stop = AtomicBool::new(false);
        let restart_signal = restart_cfg.map(|_| RestartSignal::default());
        let (tx, rx) = mpsc::channel::<ProgressEvent>();

        let result: Result<Vec<ChainResult>, Mc2aError> = std::thread::scope(|scope| {
            let ctx = ChainCtx {
                stop: &stop,
                events: Some(tx),
                restart: restart_signal.as_ref(),
            };
            // The backend owns its scheduling; the coordinating thread
            // runs the event loop until every sender is gone (the
            // backend thread drops `ctx` when `run_chains` returns).
            // With adaptive annealing or replica exchange the backend
            // instead drives its chains in lockstep under the
            // respective controller.
            let handle = scope.spawn(move || {
                if let Some(exchanges) = temper {
                    backend.run_chains_tempered(model, spec, n, &ctx, exchanges)
                } else if let Some(c) = controller {
                    backend.run_chains_adaptive(model, spec, n, &ctx, c)
                } else {
                    backend.run_chains(model, spec, n, &ctx)
                }
            });

            // Diagnostics are computed here, so observers can hold
            // plain mutable state.
            let mut tracker = DiagnosticsTracker::new(n);
            let mut rate = RateTracker::new(spec.steps);
            let mut stagnant_rounds = 0usize;
            while let Ok(event) = rx.recv() {
                let mut event = event;
                rate.stamp(&mut event);
                let diag = tracker.record(&event);
                // Cold-chain restarts: after `rounds` consecutive
                // stagnant diagnostics rounds, bump the restart epoch
                // — chains re-fork at their next observation boundary.
                if let (Some(cfg), Some(d)) = (restart_cfg, &diag) {
                    let stagnating = d.r_hat.is_some_and(|r| r > cfg.r_hat_threshold);
                    stagnant_rounds = if stagnating { stagnant_rounds + 1 } else { 0 };
                    if stagnant_rounds >= cfg.rounds {
                        if let Some(signal) = restart_signal.as_ref() {
                            signal.trigger();
                        }
                        stagnant_rounds = 0;
                    }
                }
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.on_progress(&event) == ObserverAction::Stop {
                        stop.store(true, Ordering::Relaxed);
                    }
                    if let Some(d) = diag {
                        if obs.on_diagnostics(&d) == ObserverAction::Stop {
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                }
            }

            // Per-chain panics are already mapped to `ChainPanicked`
            // inside `run_chains`; a join failure here means the
            // backend's coordinator itself died.
            handle.join().unwrap_or(Err(Mc2aError::BackendPanicked))
        });

        let chains = result?;
        if telemetry::enabled() {
            let kernel = self.spec.algo.name();
            let sampler = self.spec.sampler.name();
            let backend_name = self.backend.name();
            for chain in &chains {
                telemetry::record_chain_result(kernel, sampler, backend_name, chain);
            }
        }
        for chain in &chains {
            if let Some(obs) = self.observer.as_deref_mut() {
                obs.on_chain_done(chain);
            }
        }
        // Measured-roofline profiling: a pure post-run projection of
        // the finished chains (results are bit-identical on vs. off).
        self.last_observation = if profile::enabled() {
            let observation = profile::observe_run(
                workload,
                self.model.get(),
                self.spec.algo,
                self.spec.sampler,
                self.spec.pas_flips,
                self.backend.name(),
                self.backend.sim_hw(),
                &chains,
                self.spec.steps,
                t0.elapsed(),
            );
            Some(observation)
        } else {
            None
        };
        Ok(RunMetrics {
            chains,
            wall: t0.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;

    #[test]
    fn software_chains_run_in_parallel_and_agree() {
        let m = PottsGrid::new(6, 6, 2, 0.3);
        let metrics = Engine::for_model(&m)
            .steps(2000)
            .chains(4)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(metrics.chains.len(), 4);
        // Symmetric Ising at moderate β: marginals near 0.5 for every chain.
        for c in &metrics.chains {
            assert!((c.marginal0[0] - 0.5).abs() < 0.1, "{:?}", c.marginal0);
        }
        assert!(metrics.total_updates() >= 4 * 2000 * 36);
        assert!(metrics.updates_per_sec() > 0.0);
    }

    #[test]
    fn accelerator_backend_reports_cycles() {
        let m = PottsGrid::new(4, 4, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(50)
            .chains(2)
            .accelerator(HwConfig::fig10_toy())
            .build()
            .unwrap()
            .run()
            .unwrap();
        for c in &metrics.chains {
            let rep = c.sim.as_ref().expect("sim report");
            assert!(rep.cycles > 0);
            assert_eq!(rep.updates, 50 * 16);
        }
    }

    #[test]
    fn batched_backend_matches_software_backend() {
        let m = PottsGrid::new(6, 6, 2, 0.4);
        let run = |b: EngineBuilder| b.steps(60).chains(6).seed(9).build().unwrap().run().unwrap();
        let a = run(Engine::for_model(&m));
        let b = run(Engine::for_model(&m).batch(4).threads(2));
        for (x, y) in a.chains.iter().zip(&b.chains) {
            assert_eq!(x.best_x, y.best_x);
            assert_eq!(x.best_objective, y.best_objective);
            assert_eq!(x.objective_trace, y.objective_trace);
            assert_eq!(x.marginal0, y.marginal0);
        }
    }

    #[test]
    fn builder_validates_batch_and_threads() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        assert!(matches!(
            Engine::for_model(&m).chains(2).batch(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).chains(2).batch(4).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).chains(2).threads(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        let e = Engine::for_model(&m)
            .chains(4)
            .batch(4)
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(e.backend_name(), "batched");
        // `.batched()` alone clamps the default batch to the chain count.
        assert!(Engine::for_model(&m).chains(2).batched().build().is_ok());
    }

    #[test]
    fn builder_validates_cores() {
        let m = PottsGrid::new(3, 3, 2, 0.5); // 9 RVs
        assert!(matches!(
            Engine::for_model(&m).cores(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).cores(16).build(), // > 9 RVs
            Err(Mc2aError::InvalidConfig(_))
        ));
        // `--cores` on a non-multicore backend is a contradiction.
        assert!(matches!(
            Engine::for_model(&m).accelerator(HwConfig::paper_default()).cores(2).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        // PAS cannot be sharded across cores.
        assert!(matches!(
            Engine::for_model(&m).algo(crate::mcmc::AlgoKind::Pas).cores(2).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        let e = Engine::for_model(&m).cores(2).build().unwrap();
        assert_eq!(e.backend_name(), "multicore");
        // `.multicore()` alone defaults to one core.
        assert!(Engine::for_model(&m).multicore(HwConfig::fig10_toy()).build().is_ok());
    }

    #[test]
    fn restart_signal_reforks_software_chain() {
        use crate::engine::backend::run_software_chain;
        let m = PottsGrid::new(5, 5, 2, 0.6);
        let spec = ChainSpec {
            algo: crate::mcmc::AlgoKind::Gibbs,
            sampler: SamplerKind::Gumbel,
            schedule: BetaSchedule::Constant(0.7),
            beta_offset: 0,
            steps: 40,
            seed: 11,
            pas_flips: 1,
            observe_every: 5,
            init_state: None,
        };
        let stop = AtomicBool::new(false);
        let baseline = {
            let ctx = ChainCtx {
                stop: &stop,
                events: None,
                restart: None,
            };
            run_software_chain(&m, &spec, 0, &ctx).unwrap()
        };
        let signal = RestartSignal::default();
        signal.trigger();
        let restarted = {
            let ctx = ChainCtx {
                stop: &stop,
                events: None,
                restart: Some(&signal),
            };
            run_software_chain(&m, &spec, 0, &ctx).unwrap()
        };
        assert_eq!(signal.epoch(), 1);
        assert_ne!(
            baseline.objective_trace,
            restarted.objective_trace,
            "restart did not change the trajectory"
        );
    }

    #[test]
    fn stagnation_restart_run_completes() {
        let m = PottsGrid::new(6, 6, 2, 0.5);
        // One chain has no split R-hat: the builder rejects the config
        // instead of letting the feature silently never fire.
        assert!(matches!(
            Engine::for_model(&m).restart_on_stagnation(1.1, 3).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        // Threshold 0 ⇒ every diagnostics round looks stagnant ⇒ the
        // signal fires repeatedly; the run must still complete cleanly.
        let metrics = Engine::for_model(&m)
            .steps(200)
            .chains(2)
            .observe_every(10)
            .restart_on_stagnation(0.0, 1)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(metrics.chains.len(), 2);
        assert!(metrics.chains.iter().all(|c| c.steps == 200));
    }

    #[test]
    fn chains_use_distinct_seeds() {
        let m = PottsGrid::new(5, 5, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(50)
            .chains(2)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_ne!(metrics.chains[0].marginal0, metrics.chains[1].marginal0);
    }

    struct StopImmediately;
    impl ChainObserver for StopImmediately {
        fn on_progress(&mut self, _e: &ProgressEvent) -> ObserverAction {
            ObserverAction::Stop
        }
    }

    #[test]
    fn observer_early_stop_halts_all_chains() {
        let m = PottsGrid::new(8, 8, 2, 0.5);
        let metrics = Engine::for_model(&m)
            .steps(100_000)
            .chains(2)
            .observe_every(5)
            .observer(Box::new(StopImmediately))
            .build()
            .unwrap()
            .run()
            .unwrap();
        // At least one chain must have observed the stop request early;
        // a chain that raced ahead of the flag may have run longer, but
        // none can exceed the full budget only if the stop was ignored.
        assert!(
            metrics.chains.iter().any(|c| c.steps < 100_000),
            "no chain stopped early: {:?}",
            metrics.chains.iter().map(|c| c.steps).collect::<Vec<_>>()
        );
    }

    #[test]
    fn builder_rejects_zero_chains_and_steps() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        assert!(matches!(
            Engine::for_model(&m).chains(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).steps(0).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
    }

    #[test]
    fn builder_validates_init_state() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        assert!(matches!(
            Engine::for_model(&m).init_state(vec![0; 4]).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(matches!(
            Engine::for_model(&m).init_state(vec![7; 9]).build(),
            Err(Mc2aError::InvalidConfig(_))
        ));
        assert!(Engine::for_model(&m).init_state(vec![1; 9]).build().is_ok());
    }

    #[test]
    fn invalid_hardware_is_a_typed_error() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        let mut hw = HwConfig::paper_default();
        hw.s = 48; // not a power of two
        assert!(matches!(
            Engine::for_model(&m).accelerator(hw).build(),
            Err(Mc2aError::InvalidHardware(_))
        ));
    }
}
