//! Process-wide metrics and span tracing — the observability layer.
//!
//! Two global, std-only sinks, both **disabled by default** and
//! designed to be zero-cost when off:
//!
//! * [`MetricsRegistry`] (via [`metrics`]) — named counters, gauges
//!   and fixed-bucket histograms behind one mutex. Hot paths record at
//!   *segment* granularity (per observation segment, per pool task,
//!   per swap round), never per RV update, so the enabled overhead
//!   stays well under the 2% budget and the disabled path is a single
//!   relaxed atomic load. Recording never touches an RNG stream or a
//!   floating-point reduction, so results are bit-identical with
//!   telemetry on or off (pinned by `tests/integration_telemetry.rs`).
//! * [`Tracer`] (via [`tracer`]) — span events rendered as Chrome
//!   trace-event JSON (`[{"name":…,"ph":"X","ts":…,"dur":…},…]`),
//!   loadable in Perfetto or `chrome://tracing`. Wired up by
//!   `mc2a run --trace out.json`, `mc2a serve --trace out.json` and
//!   the job-server's per-job opt-in ([`crate::engine::JobSpec`]).
//!
//! Metric names are exposed in Prometheus text format (prefixed
//! `mc2a_`) by [`MetricsRegistry::render_prometheus`], served over
//! HTTP by `mc2a serve --metrics-addr HOST:PORT` and over the job
//! protocol by the `metrics` verb.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::ChainResult;
use crate::engine::checkpoint::escape_json;

/// Histogram bucket upper bounds (seconds): micro-benches to long
/// jobs. Rendered as cumulative Prometheus `le` buckets plus `+Inf`.
pub const HISTOGRAM_BOUNDS: [f64; 8] = [0.0001, 0.001, 0.01, 0.1, 1.0, 10.0, 60.0, 600.0];

/// One fixed-bucket histogram: count, sum, cumulative bucket counts.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    /// Observations recorded.
    pub count: u64,
    /// Sum of observed values.
    pub sum: f64,
    /// Cumulative counts per [`HISTOGRAM_BOUNDS`] bound (`le` semantics).
    pub buckets: [u64; HISTOGRAM_BOUNDS.len()],
}

impl Histogram {
    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        for (slot, bound) in self.buckets.iter_mut().zip(HISTOGRAM_BOUNDS) {
            if v <= bound {
                *slot += 1;
            }
        }
    }
}

/// (metric name, rendered label pairs) — the registry key.
type Key = (&'static str, String);

#[derive(Default)]
struct MetricsInner {
    counters: BTreeMap<Key, u64>,
    gauges: BTreeMap<Key, f64>,
    histograms: BTreeMap<Key, Histogram>,
}

/// Process-wide registry of counters, gauges and histograms.
///
/// Every mutator is a no-op while the registry is disabled (the
/// default); enabling it never changes run results, only adds the
/// bookkeeping. Obtain the global instance via [`metrics`].
pub struct MetricsRegistry {
    enabled: AtomicBool,
    inner: Mutex<MetricsInner>,
}

impl MetricsRegistry {
    fn new() -> MetricsRegistry {
        MetricsRegistry {
            enabled: AtomicBool::new(false),
            inner: Mutex::new(MetricsInner::default()),
        }
    }

    /// Turn metric recording on or off (off by default).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// True when recording is on — the hot-path fast check.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Drop every recorded value (the enabled flag is untouched).
    pub fn reset(&self) {
        *self.lock() = MetricsInner::default();
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MetricsInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Add `delta` to a monotonic counter (name it `*_total`).
    pub fn counter_add(&self, name: &'static str, labels: &[(&str, &str)], delta: u64) {
        if !self.enabled() {
            return;
        }
        *self.lock().counters.entry((name, label_string(labels))).or_insert(0) += delta;
    }

    /// Set a gauge to `value`.
    pub fn gauge_set(&self, name: &'static str, labels: &[(&str, &str)], value: f64) {
        if !self.enabled() {
            return;
        }
        self.lock().gauges.insert((name, label_string(labels)), value);
    }

    /// Record one histogram observation (name it `*_seconds` for times).
    pub fn observe(&self, name: &'static str, labels: &[(&str, &str)], value: f64) {
        if !self.enabled() {
            return;
        }
        self.lock()
            .histograms
            .entry((name, label_string(labels)))
            .or_default()
            .observe(value);
    }

    /// Current value of one counter (0 when never incremented) — for
    /// tests and the `stats` verb.
    pub fn counter_value(&self, name: &'static str, labels: &[(&str, &str)]) -> u64 {
        self.lock().counters.get(&(name, label_string(labels))).copied().unwrap_or(0)
    }

    /// Sum of a counter across every label combination.
    pub fn counter_sum(&self, name: &'static str) -> u64 {
        self.lock()
            .counters
            .iter()
            .filter(|((n, _), _)| *n == name)
            .map(|(_, v)| *v)
            .sum()
    }

    /// Render every metric in the Prometheus text exposition format
    /// (names prefixed `mc2a_`), ready to serve on a scrape endpoint.
    pub fn render_prometheus(&self) -> String {
        let inner = self.lock();
        let mut out = String::with_capacity(1024);
        let mut last = "";
        for ((name, labels), value) in &inner.counters {
            if *name != last {
                let _ = writeln!(out, "# TYPE mc2a_{name} counter");
                last = name;
            }
            let _ = writeln!(out, "mc2a_{name}{} {value}", braced(labels));
        }
        last = "";
        for ((name, labels), value) in &inner.gauges {
            if *name != last {
                let _ = writeln!(out, "# TYPE mc2a_{name} gauge");
                last = name;
            }
            let _ = writeln!(out, "mc2a_{name}{} {value}", braced(labels));
        }
        last = "";
        for ((name, labels), h) in &inner.histograms {
            if *name != last {
                let _ = writeln!(out, "# TYPE mc2a_{name} histogram");
                last = name;
            }
            for (bound, count) in HISTOGRAM_BOUNDS.iter().zip(h.buckets) {
                let le = join_labels(labels, &format!("le=\"{bound}\""));
                let _ = writeln!(out, "mc2a_{name}_bucket{{{le}}} {count}");
            }
            let le = join_labels(labels, "le=\"+Inf\"");
            let _ = writeln!(out, "mc2a_{name}_bucket{{{le}}} {}", h.count);
            let _ = writeln!(out, "mc2a_{name}_sum{} {}", braced(labels), h.sum);
            let _ = writeln!(out, "mc2a_{name}_count{} {}", braced(labels), h.count);
        }
        out
    }
}

/// `k1="v1",k2="v2"` (no braces; empty for no labels).
fn label_string(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"{}\"", escape_json(v));
    }
    out
}

/// Wrap a rendered label string in braces, or nothing when empty.
fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

/// Append one more label pair to a rendered label string.
fn join_labels(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        extra.to_string()
    } else {
        format!("{labels},{extra}")
    }
}

/// The process-wide metrics registry.
pub fn metrics() -> &'static MetricsRegistry {
    static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();
    REGISTRY.get_or_init(MetricsRegistry::new)
}

/// True when metric recording is on — guard any label formatting that
/// would allocate with this before calling the registry.
pub fn enabled() -> bool {
    metrics().enabled()
}

/// Fold one finished chain into the registry: updates/accepts per
/// kernel, categorical draws per sampler family, and — on accelerator
/// chains — the cycle/stall breakdown. One call per chain, after the
/// run, so no kernel inner loop carries instrumentation.
pub fn record_chain_result(kernel: &str, sampler: &str, backend: &str, c: &ChainResult) {
    let m = metrics();
    if !m.enabled() {
        return;
    }
    m.counter_add("chains_completed_total", &[("backend", backend)], 1);
    m.counter_add("chain_steps_total", &[("kernel", kernel)], c.steps as u64);
    m.counter_add("chain_updates_total", &[("kernel", kernel)], c.stats.updates);
    m.counter_add("chain_accepts_total", &[("kernel", kernel)], c.stats.accepted);
    m.counter_add("sampler_draws_total", &[("sampler", sampler)], c.stats.cost.samples);
    if let Some(rep) = &c.sim {
        m.counter_add("sim_cycles_total", &[], rep.cycles);
        m.counter_add("sim_stall_sync_cycles_total", &[], rep.stall_sync);
        m.counter_add("sim_stall_xbar_cycles_total", &[], rep.stall_xbar);
        m.counter_add("sim_xfer_words_total", &[], rep.xfer_words);
    }
}

// ---- span tracing -----------------------------------------------------

/// Spans kept before the tracer starts dropping (memory backstop for
/// long-lived daemons).
const MAX_TRACE_EVENTS: usize = 1 << 20;

/// One completed span (Chrome trace-event "X" phase).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Human-readable span name.
    pub name: String,
    /// Category ("job", "round", "sim", "pool", …).
    pub cat: &'static str,
    /// Start, µs since the tracer started.
    pub ts_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Emitting thread (hashed thread id).
    pub tid: u64,
}

struct TracerInner {
    t0: Option<Instant>,
    events: Vec<SpanEvent>,
    dropped: u64,
}

/// Process-wide span collector; obtain via [`tracer`]. Disabled by
/// default — [`span`] returns `None` without any allocation.
pub struct Tracer {
    enabled: AtomicBool,
    inner: Mutex<TracerInner>,
}

impl Tracer {
    fn new() -> Tracer {
        Tracer {
            enabled: AtomicBool::new(false),
            inner: Mutex::new(TracerInner { t0: None, events: Vec::new(), dropped: 0 }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TracerInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Clear any previous trace and start collecting spans now.
    pub fn start(&self) {
        {
            let mut inner = self.lock();
            inner.t0 = Some(Instant::now());
            inner.events.clear();
            inner.dropped = 0;
        }
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Stop collecting (the recorded spans stay available).
    pub fn stop(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// True while spans are being collected.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Spans recorded so far.
    pub fn event_count(&self) -> usize {
        self.lock().events.len()
    }

    /// Record one completed span from explicit endpoints — for spans
    /// that start and end on different threads (job lifecycle).
    pub fn record(&self, name: String, cat: &'static str, start: Instant, end: Instant) {
        if !self.is_enabled() {
            return;
        }
        let tid = thread_tid();
        let mut inner = self.lock();
        let Some(t0) = inner.t0 else { return };
        if inner.events.len() >= MAX_TRACE_EVENTS {
            inner.dropped += 1;
            return;
        }
        let ts_us = start.saturating_duration_since(t0).as_micros() as u64;
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        inner.events.push(SpanEvent { name, cat, ts_us, dur_us, tid });
    }

    /// Render the collected spans as Chrome trace-event JSON — an
    /// array of complete ("ph":"X") events, loadable in Perfetto.
    pub fn to_chrome_json(&self) -> String {
        let inner = self.lock();
        let mut out = String::with_capacity(64 + inner.events.len() * 96);
        out.push_str("[\n");
        for (i, e) in inner.events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":1,\"tid\":{}}}",
                escape_json(&e.name),
                e.cat,
                e.ts_us,
                e.dur_us,
                e.tid
            );
        }
        out.push_str("\n]\n");
        out
    }

    /// Write the Chrome trace JSON to `path`.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json())
    }
}

/// The process-wide span tracer.
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(Tracer::new)
}

/// True while span collection is on.
pub fn tracing() -> bool {
    tracer().is_enabled()
}

/// RAII span: records a complete trace event on drop. `None` (and no
/// allocation) while tracing is off — bind with
/// `let _span = telemetry::span(…);`.
pub fn span(name: impl Into<String>, cat: &'static str) -> Option<Span> {
    if !tracing() {
        return None;
    }
    Some(Span { name: name.into(), cat, t0: Instant::now() })
}

/// [`span`] with a lazily-built name: `name` runs only while tracing
/// is on, so call sites pay no `format!` allocation when it is off.
pub fn span_with(cat: &'static str, name: impl FnOnce() -> String) -> Option<Span> {
    if !tracing() {
        return None;
    }
    Some(Span { name: name(), cat, t0: Instant::now() })
}

/// In-flight span handle returned by [`span`] / [`span_with`].
pub struct Span {
    name: String,
    cat: &'static str,
    t0: Instant,
}

impl Drop for Span {
    fn drop(&mut self) {
        tracer().record(std::mem::take(&mut self.name), self.cat, self.t0, Instant::now());
    }
}

/// Compact per-thread id for trace rows (hashed [`std::thread::ThreadId`]).
fn thread_tid() -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    h.finish() & 0xFFFF
}

/// Serialize tests — across modules — that flip the process-wide
/// registry or tracer state; `cargo test` runs tests concurrently in
/// one process, so unguarded toggles race with each other's asserts.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let _g = guard();
        let m = metrics();
        m.set_enabled(false);
        m.reset();
        m.counter_add("noop_total", &[], 5);
        m.gauge_set("noop_gauge", &[], 1.0);
        m.observe("noop_seconds", &[], 0.5);
        assert_eq!(m.counter_value("noop_total", &[]), 0);
        assert_eq!(m.render_prometheus(), "");
    }

    #[test]
    fn counters_gauges_histograms_render_as_prometheus() {
        let _g = guard();
        let m = metrics();
        m.set_enabled(true);
        m.reset();
        m.counter_add("steals_total", &[], 3);
        m.counter_add("draws_total", &[("sampler", "gumbel")], 7);
        m.counter_add("draws_total", &[("sampler", "cdf")], 2);
        m.gauge_set("queue_depth", &[("class", "high")], 4.0);
        m.observe("write_seconds", &[], 0.005);
        m.observe("write_seconds", &[], 2.0);
        let text = m.render_prometheus();
        m.set_enabled(false);
        assert!(text.contains("# TYPE mc2a_steals_total counter"));
        assert!(text.contains("mc2a_steals_total 3"));
        assert!(text.contains("mc2a_draws_total{sampler=\"gumbel\"} 7"));
        assert!(text.contains("mc2a_draws_total{sampler=\"cdf\"} 2"));
        assert!(text.contains("mc2a_queue_depth{class=\"high\"} 4"));
        // Cumulative buckets: 0.005 lands in le=0.01 and wider; 2.0
        // only from le=10 up; +Inf carries the full count.
        assert!(text.contains("mc2a_write_seconds_bucket{le=\"0.01\"} 1"));
        assert!(text.contains("mc2a_write_seconds_bucket{le=\"10\"} 2"));
        assert!(text.contains("mc2a_write_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("mc2a_write_seconds_count 2"));
        assert_eq!(m.counter_sum("draws_total"), 9);
    }

    #[test]
    fn spans_collect_only_while_tracing() {
        let _g = guard();
        let t = tracer();
        t.stop();
        assert!(span("ignored", "test").is_none());
        t.start();
        {
            let _s = span("visible", "test");
            std::thread::sleep(Duration::from_millis(1));
        }
        t.record("manual".into(), "test", Instant::now(), Instant::now());
        t.stop();
        assert_eq!(t.event_count(), 2);
        let json = t.to_chrome_json();
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"name\":\"visible\""));
        assert!(json.contains("\"ph\":\"X\""));
        // Restarting clears the previous trace.
        t.start();
        t.stop();
        assert_eq!(t.event_count(), 0);
    }
}
