//! On-disk layout of the job directory.
//!
//! One [`JobEnvelope`] per job at `job-<id>.json`, plus one chain
//! record per *completed* chain at `job-<id>.chain-<c>.json`. Both are
//! written atomically (tmp + rename). Recovery loads every envelope,
//! reattaches the chain records whose step count matches the job's
//! budget, and re-runs only the missing chains — which is sound
//! because each chain's trajectory is a pure function of
//! `(model, spec, chain_id)`.
//!
//! Chain records keep the software-visible result (objective, state,
//! traces, step statistics); simulator reports (`sim` / `multicore` /
//! `tempering`) and wall-clock time are not persisted — a recovered
//! accelerator job keeps its sampling results but loses the
//! cycle-accounting of chains that completed before the restart.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::ChainResult;
use crate::energy::OpCost;
use crate::engine::checkpoint::{array_field, bad, scalar_field, JobEnvelope};
use crate::engine::error::Mc2aError;
use crate::mcmc::StepStats;

use super::JobId;

/// Path of a job's envelope file.
pub(super) fn envelope_path(dir: &Path, id: JobId) -> PathBuf {
    dir.join(format!("job-{id}.json"))
}

/// Path of one chain's result record.
pub(super) fn chain_path(dir: &Path, id: JobId, chain: usize) -> PathBuf {
    dir.join(format!("job-{id}.chain-{chain}.json"))
}

fn chain_to_json(c: &ChainResult) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(
        128 + c.best_x.len() * 4 + (c.marginal0.len() + c.objective_trace.len()) * 8,
    );
    write!(
        out,
        "{{\"chain_id\":{},\"steps\":{},\"best_objective\":{},\"updates\":{},\
         \"accepted\":{},\"ops\":{},\"bytes\":{},\"samples\":{}",
        c.chain_id,
        c.steps,
        c.best_objective,
        c.stats.updates,
        c.stats.accepted,
        c.stats.cost.ops,
        c.stats.cost.bytes,
        c.stats.cost.samples,
    )
    .unwrap();
    for (key, values) in [("marginal0", &c.marginal0), ("objective_trace", &c.objective_trace)] {
        write!(out, ",\"{key}\":[").unwrap();
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "{v}").unwrap();
        }
        out.push(']');
    }
    out.push_str(",\"best_x\":[");
    for (i, v) in c.best_x.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write!(out, "{v}").unwrap();
    }
    out.push_str("]}");
    out
}

fn f64_array(s: &str, key: &str) -> Result<Vec<f64>, Mc2aError> {
    let mut values = Vec::new();
    for tok in array_field(s, key)?.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        values.push(tok.parse::<f64>().map_err(|e| bad(key, &e.to_string()))?);
    }
    Ok(values)
}

fn chain_from_json(s: &str) -> Result<ChainResult, Mc2aError> {
    let num =
        |key: &str| -> Result<u64, Mc2aError> {
            scalar_field(s, key)?.parse::<u64>().map_err(|e| bad(key, &e.to_string()))
        };
    let mut best_x = Vec::new();
    for tok in array_field(s, "best_x")?.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        best_x.push(tok.parse::<u32>().map_err(|e| bad("best_x", &e.to_string()))?);
    }
    Ok(ChainResult {
        chain_id: num("chain_id")? as usize,
        best_objective: scalar_field(s, "best_objective")?
            .parse::<f64>()
            .map_err(|e| bad("best_objective", &e.to_string()))?,
        steps: num("steps")? as usize,
        stats: StepStats {
            updates: num("updates")?,
            accepted: num("accepted")?,
            cost: OpCost { ops: num("ops")?, bytes: num("bytes")?, samples: num("samples")? },
        },
        sim: None,
        multicore: None,
        tempering: None,
        wall: Duration::ZERO,
        marginal0: f64_array(s, "marginal0")?,
        best_x,
        objective_trace: f64_array(s, "objective_trace")?,
    })
}

/// Atomically write one completed chain's record.
pub(super) fn save_chain(dir: &Path, id: JobId, c: &ChainResult) -> Result<(), Mc2aError> {
    let path = chain_path(dir, id, c.chain_id);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, chain_to_json(c))
        .map_err(|e| Mc2aError::Server(format!("writing {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, &path)
        .map_err(|e| Mc2aError::Server(format!("renaming to {}: {e}", path.display())))
}

/// Load whatever chain records exist for a job. A record only counts
/// when it carries the full step budget for the right chain slot;
/// anything else (stale budget after a spec edit, unreadable file) is
/// treated as missing and re-run.
pub(super) fn load_chains(
    dir: &Path,
    id: JobId,
    chains: usize,
    steps: usize,
) -> Result<Vec<Option<ChainResult>>, Mc2aError> {
    let mut results = vec![None; chains];
    for (chain, slot) in results.iter_mut().enumerate() {
        let path = chain_path(dir, id, chain);
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        match chain_from_json(&text) {
            Ok(c) if c.chain_id == chain && c.steps == steps => *slot = Some(c),
            Ok(_) => {}
            Err(e) => eprintln!("mc2a serve: skipping {}: {e}", path.display()),
        }
    }
    Ok(results)
}

/// Load every job envelope in the directory (unsorted). Unreadable
/// envelopes are skipped with a warning rather than aborting the whole
/// recovery.
pub(super) fn load_envelopes(dir: &Path) -> Result<Vec<JobEnvelope>, Mc2aError> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Mc2aError::Server(format!("reading job dir {}: {e}", dir.display())))?;
    let mut envelopes = Vec::new();
    for entry in entries {
        let entry =
            entry.map_err(|e| Mc2aError::Server(format!("reading job dir entry: {e}")))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with("job-") || !name.ends_with(".json") || name.contains(".chain-") {
            continue;
        }
        match JobEnvelope::load(entry.path()) {
            Ok(env) => envelopes.push(env),
            Err(e) => eprintln!("mc2a serve: skipping {}: {e}", entry.path().display()),
        }
    }
    Ok(envelopes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_chain(chain_id: usize, steps: usize) -> ChainResult {
        ChainResult {
            chain_id,
            best_objective: -33.5,
            steps,
            stats: StepStats {
                updates: 1200,
                accepted: 800,
                cost: OpCost { ops: 5000, bytes: 9000, samples: 1200 },
            },
            sim: None,
            multicore: None,
            tempering: None,
            wall: Duration::from_millis(7),
            marginal0: vec![0.25, 0.75],
            best_x: vec![1, 0, 2, 1],
            objective_trace: vec![-40.0, -35.5, -33.5],
        }
    }

    #[test]
    fn chain_record_round_trips() {
        let c = sample_chain(2, 300);
        let r = chain_from_json(&chain_to_json(&c)).unwrap();
        assert_eq!(r.chain_id, c.chain_id);
        assert_eq!(r.steps, c.steps);
        assert_eq!(r.best_objective, c.best_objective);
        assert_eq!(r.stats.updates, c.stats.updates);
        assert_eq!(r.stats.accepted, c.stats.accepted);
        assert_eq!(r.stats.cost.ops, c.stats.cost.ops);
        assert_eq!(r.stats.cost.bytes, c.stats.cost.bytes);
        assert_eq!(r.stats.cost.samples, c.stats.cost.samples);
        assert_eq!(r.marginal0, c.marginal0);
        assert_eq!(r.best_x, c.best_x);
        assert_eq!(r.objective_trace, c.objective_trace);
        // Wall time and simulator reports are not persisted.
        assert_eq!(r.wall, Duration::ZERO);
        assert!(r.sim.is_none());
    }

    #[test]
    fn load_chains_filters_wrong_budget_and_slot() {
        let dir = std::env::temp_dir().join("mc2a_persist_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        save_chain(&dir, 1, &sample_chain(0, 300)).unwrap();
        save_chain(&dir, 1, &sample_chain(1, 200)).unwrap(); // stale budget
        std::fs::write(chain_path(&dir, 1, 2), "garbage").unwrap();
        let loaded = load_chains(&dir, 1, 4, 300).unwrap();
        assert!(loaded[0].is_some());
        assert!(loaded[1].is_none(), "wrong step budget must not count");
        assert!(loaded[2].is_none(), "corrupt record must not count");
        assert!(loaded[3].is_none(), "never-written chain is missing");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_envelopes_ignores_chain_records() {
        let dir = std::env::temp_dir().join("mc2a_persist_env_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        save_chain(&dir, 3, &sample_chain(0, 100)).unwrap();
        assert!(load_envelopes(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
