//! TCP front-end for the job server: one thread per connection,
//! newline-delimited JSON ([`super::proto`]) over `std::net` — no
//! async runtime.
//!
//! Each request line gets one response line, except:
//!
//! * `stream` — the connection becomes a one-way event feed and closes
//!   after the job's `done` event;
//! * `shutdown` — the server acknowledges, stops accepting, drains the
//!   pool (persisting interrupted jobs as resumable) and the accept
//!   loop returns.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::proto::{self, Request};
use super::{JobId, JobServer};
use crate::engine::error::Mc2aError;
use crate::engine::observer::StreamEvent;

/// Bind and serve until a client sends `shutdown`. Blocks the calling
/// thread for the server's lifetime.
pub fn serve(server: JobServer, addr: &str) -> Result<(), Mc2aError> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Mc2aError::Server(format!("binding {addr}: {e}")))?;
    serve_on(server, listener)
}

/// [`serve`] over an already-bound listener (tests bind port 0 and
/// read the assigned address back).
pub fn serve_on(server: JobServer, listener: TcpListener) -> Result<(), Mc2aError> {
    let local = listener
        .local_addr()
        .map_err(|e| Mc2aError::Server(format!("reading local addr: {e}")))?;
    eprintln!("mc2a serve: listening on {local}");
    let stop_accept = Arc::new(AtomicBool::new(false));
    let mut conns = Vec::new();
    for stream in listener.incoming() {
        if stop_accept.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(sock) => {
                let server = server.clone();
                let stop_accept = Arc::clone(&stop_accept);
                conns.push(std::thread::spawn(move || {
                    handle_conn(server, sock, &stop_accept, local);
                }));
            }
            Err(e) => eprintln!("mc2a serve: accept failed: {e}"),
        }
    }
    for conn in conns {
        let _ = conn.join();
    }
    Ok(())
}

fn handle_conn(
    server: JobServer,
    mut sock: TcpStream,
    stop_accept: &AtomicBool,
    local: SocketAddr,
) {
    let Ok(read_half) = sock.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match proto::parse_request(trimmed) {
            Ok(Request::Stream { job }) => {
                stream_events(&server, job, &mut sock);
                return;
            }
            Ok(Request::Shutdown) => {
                let _ = writeln!(sock, "{}", proto::ok_shutdown());
                stop_accept.store(true, Ordering::SeqCst);
                server.shutdown();
                // Wake the accept loop so it observes the flag.
                let _ = TcpStream::connect(local);
                return;
            }
            Ok(req) => {
                if writeln!(sock, "{}", handle_request(&server, req)).is_err() {
                    return;
                }
            }
            Err(e) => {
                if writeln!(sock, "{}", proto::err_line(&e)).is_err() {
                    return;
                }
            }
        }
    }
}

fn handle_request(server: &JobServer, req: Request) -> String {
    match req {
        Request::Submit(spec) => match server.submit(spec) {
            Ok(id) => proto::ok_submit(id),
            Err(e) => proto::err_line(&e),
        },
        Request::Status { job: Some(id) } => match server.status(id) {
            Ok(status) => proto::ok_status(std::slice::from_ref(&status)),
            Err(e) => proto::err_line(&e),
        },
        Request::Status { job: None } => proto::ok_status(&server.status_all()),
        Request::Result { job } => match server.result(job) {
            Ok(result) => proto::ok_result(&result),
            Err(e) => proto::err_line(&e),
        },
        Request::Cancel { job } => match server.cancel(job) {
            Ok(state) => proto::ok_cancel(job, state.name()),
            Err(e) => proto::err_line(&e),
        },
        Request::Ping => proto::ok_ping(),
        Request::Metrics => {
            proto::ok_metrics(&crate::engine::telemetry::metrics().render_prometheus())
        }
        Request::Stats => proto::ok_stats(&server.stats()),
        // Stream and Shutdown never reach here; the connection loop
        // intercepts them.
        Request::Stream { .. } | Request::Shutdown => {
            proto::err_line(&Mc2aError::Protocol("request handled by connection loop".into()))
        }
    }
}

fn stream_events(server: &JobServer, job: JobId, sock: &mut TcpStream) {
    match server.stream(job) {
        Ok(stream) => {
            while let Some(ev) = stream.recv() {
                let done = matches!(ev, StreamEvent::Done { .. });
                if writeln!(sock, "{}", proto::event_line(&ev)).is_err() {
                    return;
                }
                if done {
                    return;
                }
            }
        }
        Err(e) => {
            let _ = writeln!(sock, "{}", proto::err_line(&e));
        }
    }
}

/// Spawn a detached thread serving the process metrics registry in
/// Prometheus text exposition format over bare HTTP/1.1 on `addr` —
/// the `mc2a serve --metrics-addr` scrape endpoint. Every request path
/// returns the full registry dump (scrapers conventionally use
/// `/metrics`); the listener lives until the process exits.
pub fn spawn_metrics_http(addr: &str) -> Result<SocketAddr, Mc2aError> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Mc2aError::Server(format!("binding metrics addr {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| Mc2aError::Server(format!("reading metrics local addr: {e}")))?;
    std::thread::Builder::new()
        .name("mc2a-metrics-http".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut sock) = stream else { continue };
                // Drain the request head (up to the blank line) so the
                // client sees a well-formed exchange, then answer.
                let Ok(read_half) = sock.try_clone() else { continue };
                let mut reader = BufReader::new(read_half);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) if line.trim().is_empty() => break,
                        Ok(_) => {}
                    }
                }
                let body = crate::engine::telemetry::metrics().render_prometheus();
                let _ = write!(
                    sock,
                    "HTTP/1.1 200 OK\r\n\
                     Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
                     Content-Length: {}\r\n\
                     Connection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
            }
        })
        .map_err(|e| Mc2aError::Server(format!("spawning metrics listener: {e}")))?;
    Ok(local)
}

/// Connect, retrying every 250 ms up to `retries` times — the CLI uses
/// this to tolerate a daemon that is still binding its port.
pub fn connect_with_retry(addr: &str, retries: u32) -> Result<TcpStream, Mc2aError> {
    let mut attempt = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(sock) => return Ok(sock),
            Err(_) if attempt < retries => {
                attempt += 1;
                std::thread::sleep(Duration::from_millis(250));
            }
            Err(e) => {
                return Err(Mc2aError::Server(format!("connecting to {addr}: {e}")));
            }
        }
    }
}

/// One request line in, one response line out.
pub fn client_request(addr: &str, line: &str, retries: u32) -> Result<String, Mc2aError> {
    let mut sock = connect_with_retry(addr, retries)?;
    writeln!(sock, "{line}")
        .map_err(|e| Mc2aError::Server(format!("sending to {addr}: {e}")))?;
    let mut reader = BufReader::new(sock);
    let mut response = String::new();
    reader
        .read_line(&mut response)
        .map_err(|e| Mc2aError::Server(format!("reading from {addr}: {e}")))?;
    if response.is_empty() {
        return Err(Mc2aError::Server(format!("{addr} closed the connection")));
    }
    Ok(response.trim_end().to_string())
}

/// Send one request line, then feed every response line to `on_line`
/// until it returns `false` or the server closes the feed.
pub fn client_stream(
    addr: &str,
    line: &str,
    mut on_line: impl FnMut(&str) -> bool,
) -> Result<(), Mc2aError> {
    let mut sock = connect_with_retry(addr, 0)?;
    writeln!(sock, "{line}")
        .map_err(|e| Mc2aError::Server(format!("sending to {addr}: {e}")))?;
    let mut reader = BufReader::new(sock);
    let mut buf = String::new();
    loop {
        buf.clear();
        let n = reader
            .read_line(&mut buf)
            .map_err(|e| Mc2aError::Server(format!("reading from {addr}: {e}")))?;
        if n == 0 || !on_line(buf.trim_end()) {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_and_shutdown_round_trip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = JobServer::in_memory(1);
        let handle = std::thread::spawn(move || serve_on(server, listener));
        let pong = client_request(&addr, &proto::ping_line(), 4).unwrap();
        assert!(proto::response_is_ok(&pong), "{pong}");
        let bad = client_request(&addr, "not json", 0).unwrap();
        assert_eq!(proto::response_kind(&bad).as_deref(), Some("protocol"));
        let metrics = client_request(&addr, &proto::metrics_line(), 0).unwrap();
        assert!(proto::response_is_ok(&metrics), "{metrics}");
        let stats = client_request(&addr, &proto::stats_line(), 0).unwrap();
        assert!(proto::response_is_ok(&stats), "{stats}");
        assert!(stats.contains("\"threads\":1"), "{stats}");
        let bye = client_request(&addr, &proto::shutdown_line(), 0).unwrap();
        assert!(proto::response_is_ok(&bye), "{bye}");
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn metrics_http_endpoint_serves_exposition() {
        use std::io::Read;
        let addr = spawn_metrics_http("127.0.0.1:0").unwrap();
        let mut sock = TcpStream::connect(addr).unwrap();
        write!(sock, "GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut out = String::new();
        sock.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        assert!(out.contains("text/plain; version=0.0.4"), "{out}");
    }
}
