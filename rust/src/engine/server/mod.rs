//! Sampling-as-a-service: a persistent, multi-tenant job server.
//!
//! A [`JobServer`] lifts the run-scoped engine into a long-lived,
//! process-scoped service: many heterogeneous jobs (different
//! workloads, algorithms, samplers, backends, budgets) are multiplexed
//! over ONE shared [`crate::engine::scheduler::WorkPool`] with strict
//! priority classes and per-job round-robin fair sharing. Each job's
//! chains are the pool's work items — and because chain `i` always
//! draws from `Rng::fork(seed, i)`, a job's results are bit-identical
//! to running the same spec solo through [`crate::engine::Engine`],
//! no matter how its chains interleave with other tenants'.
//!
//! The server surfaces five operations — [`JobServer::submit`],
//! [`JobServer::status`], [`JobServer::stream`] (live
//! [`StreamEvent`]s), [`JobServer::cancel`], [`JobServer::result`] —
//! plus [`JobServer::wait`] for blocking callers.
//!
//! **Durability.** With a job directory configured, every submit,
//! chain completion and state change persists a
//! [`crate::engine::checkpoint::JobEnvelope`] (and per-chain result
//! records). [`JobServer::recover`] rebuilds the job table from disk:
//! terminal jobs reload their records, in-flight jobs re-run exactly
//! the chains that had not completed — deterministically, so the
//! recovered result is bit-identical to an uninterrupted run. Because
//! the trajectory is a pure function of `(model, spec, chain_id)`,
//! recovery may even resume on a *different* backend
//! ([`JobServer::recover_with`] with a [`ServeBackend`] override).
//!
//! The TCP front-end lives in [`net`] (newline-delimited JSON, see
//! [`proto`]); the CLI's `mc2a serve` / `mc2a client` wrap it.

pub mod net;
mod persist;
pub mod proto;

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::ChainResult;
use crate::energy::EnergyModel;
use crate::engine::backend::{
    AcceleratorBackend, ChainCtx, ChainSpec, ExecutionBackend, SoftwareBackend,
};
use crate::engine::checkpoint::{Checkpoint, JobEnvelope};
use crate::engine::error::Mc2aError;
use crate::engine::observer::{
    raw_stream, DiagnosticsReport, DiagnosticsTracker, EventStream, ProgressEvent, RateTracker,
    StreamEvent,
};
use crate::engine::profile;
use crate::engine::registry;
use crate::engine::scheduler::{TaskTag, WorkPool};
use crate::engine::telemetry;
use crate::isa::{HwConfig, MultiHwConfig};
use crate::mcmc::{effective_sample_size, split_r_hat, AlgoKind, BetaSchedule, SamplerKind};
use crate::roofline::RooflineObservation;

/// Server-assigned job identifier (monotone from 1).
pub type JobId = u64;

/// Scheduling priority class. The pool serves classes strictly
/// (everything `High` before anything `Normal` before anything `Low`)
/// and round-robins one chain at a time across the jobs inside a
/// class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Background work: runs only when nothing else is queued.
    Low,
    /// The default.
    Normal,
    /// Jumps every queued `Normal`/`Low` chain.
    High,
}

impl Priority {
    /// The pool class this priority maps to (higher serves first).
    pub(crate) fn class(self) -> u8 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }

    /// Wire/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a wire/CLI name.
    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// Life-cycle state of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted; chains waiting for pool slots (also the persisted
    /// state of a job interrupted by server shutdown — resumable).
    Queued,
    /// At least one chain has started.
    Running,
    /// Every chain completed its full step budget.
    Done,
    /// Cancelled by the client; completed chains are kept.
    Cancelled,
    /// A chain returned an error or panicked.
    Failed,
}

impl JobState {
    /// Wire/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }

    /// Parse a wire/CLI name.
    pub fn parse(s: &str) -> Option<JobState> {
        match s.to_ascii_lowercase().as_str() {
            "queued" => Some(JobState::Queued),
            "running" => Some(JobState::Running),
            "done" => Some(JobState::Done),
            "cancelled" => Some(JobState::Cancelled),
            "failed" => Some(JobState::Failed),
            _ => None,
        }
    }

    /// Terminal states stop changing and have a result.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Cancelled | JobState::Failed)
    }
}

/// Which execution backend a job's chains run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeBackend {
    /// Thread-per-chain software MCMC ([`SoftwareBackend`]).
    Software,
    /// Cycle-accurate accelerator simulator with the paper-default
    /// hardware ([`AcceleratorBackend`]).
    Accelerator,
}

impl ServeBackend {
    /// Wire/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ServeBackend::Software => "sw",
            ServeBackend::Accelerator => "sim",
        }
    }

    /// Parse a wire/CLI name.
    pub fn parse(s: &str) -> Option<ServeBackend> {
        match s.to_ascii_lowercase().as_str() {
            "sw" | "software" => Some(ServeBackend::Software),
            "sim" | "accel" | "accelerator" => Some(ServeBackend::Accelerator),
            _ => None,
        }
    }
}

/// Everything needed to run one job: the workload, the run shape, and
/// the scheduling metadata.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Registry workload name (or a free-form label for
    /// [`JobServer::submit_custom`] jobs).
    pub workload: String,
    /// Algorithm override; `None` uses the workload's Table I pairing.
    pub algo: Option<AlgoKind>,
    /// Categorical sampler.
    pub sampler: SamplerKind,
    /// Steps per chain.
    pub steps: usize,
    /// Number of chains.
    pub chains: usize,
    /// Base RNG seed; chain `i` draws stream `i`.
    pub seed: u64,
    /// Inverse temperature (constant schedule).
    pub beta: f32,
    /// Execution backend.
    pub backend: ServeBackend,
    /// Scheduling priority class.
    pub priority: Priority,
    /// Progress-event cadence in steps; 0 means the engine default
    /// (`steps / 20`, at least 1).
    pub observe_every: usize,
    /// PAS path length override; `None` uses the workload's value.
    pub pas_flips: Option<usize>,
    /// Opt this job into process-wide telemetry: enables the metrics
    /// registry and (if not already running) the span tracer for the
    /// job's lifetime. Purely observational — results are bit-identical
    /// either way — and not persisted across restarts.
    pub trace: bool,
    /// Compute a measured-roofline [`RooflineObservation`] when the
    /// job completes (surfaced via [`JobResult::observation`] and the
    /// `stats` verb). Purely observational — results are bit-identical
    /// either way — and not persisted across restarts.
    pub profile: bool,
}

impl JobSpec {
    /// A spec with the same defaults as the CLI's `run` subcommand.
    pub fn new(workload: impl Into<String>) -> JobSpec {
        JobSpec {
            workload: workload.into(),
            algo: None,
            sampler: SamplerKind::Gumbel,
            steps: 200,
            chains: 1,
            seed: 1,
            beta: 1.0,
            backend: ServeBackend::Software,
            priority: Priority::Normal,
            observe_every: 0,
            pas_flips: None,
            trace: false,
            profile: false,
        }
    }
}

/// Point-in-time snapshot of one job.
#[derive(Clone, Debug)]
pub struct JobStatus {
    /// Job id.
    pub id: JobId,
    /// Canonical workload name (or custom label).
    pub workload: String,
    /// Current life-cycle state.
    pub state: JobState,
    /// Scheduling class.
    pub priority: Priority,
    /// Execution backend.
    pub backend: ServeBackend,
    /// Algorithm actually running.
    pub algo: AlgoKind,
    /// Total chains.
    pub chains: usize,
    /// Chains that completed their full budget.
    pub chains_done: usize,
    /// Per-chain step budget.
    pub steps: usize,
    /// Steps observed so far, summed over chains.
    pub steps_done: usize,
    /// Best objective seen so far (−∞ before the first observation).
    pub best_objective: f64,
    /// Cross-chain split R-hat: the final full-trace value for
    /// terminal jobs, else the latest completed streaming round.
    pub r_hat: Option<f64>,
    /// Minimum per-chain effective sample size, same provenance as
    /// [`JobStatus::r_hat`].
    pub min_ess: Option<f64>,
    /// First chain error, for `Failed` jobs.
    pub error: Option<String>,
}

/// Final outcome of a terminal job.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job id.
    pub id: JobId,
    /// Terminal state ([`JobState::Done`] / `Cancelled` / `Failed`).
    pub state: JobState,
    /// Best objective across completed chains.
    pub best_objective: f64,
    /// Completed chains (all of them for `Done`; the subset that
    /// finished before the stop for `Cancelled`).
    pub chains: Vec<ChainResult>,
    /// First chain error, for `Failed` jobs.
    pub error: Option<String>,
    /// Measured-roofline projection, for jobs submitted with
    /// [`JobSpec::profile`] that ran to completion.
    pub observation: Option<RooflineObservation>,
}

/// One job's convergence/profiling summary inside [`ServerStats`].
#[derive(Clone, Debug, PartialEq)]
pub struct JobStatSummary {
    /// Job id.
    pub id: JobId,
    /// Current life-cycle state.
    pub state: JobState,
    /// Split R-hat (final full-trace value for terminal jobs, latest
    /// streaming round otherwise).
    pub r_hat: Option<f64>,
    /// Minimum per-chain effective sample size, same provenance.
    pub min_ess: Option<f64>,
    /// Measured boundedness verdict, for profiled finished jobs.
    pub verdict: Option<&'static str>,
    /// Measured-vs-predicted throughput drift (%), for profiled
    /// finished jobs.
    pub drift_pct: Option<f64>,
}

/// Aggregate point-in-time server statistics ([`JobServer::stats`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServerStats {
    /// Jobs in the table (all states).
    pub jobs_total: usize,
    /// Jobs accepted but not yet started.
    pub queued: usize,
    /// Jobs with at least one chain running.
    pub running: usize,
    /// Jobs that completed their full budget.
    pub done: usize,
    /// Jobs cancelled by clients.
    pub cancelled: usize,
    /// Jobs that failed.
    pub failed: usize,
    /// Chain tasks still owed a completion (queued or running).
    pub chains_pending: usize,
    /// Worker threads in the shared pool.
    pub threads: usize,
    /// Per-job convergence/profiling summaries, in id order.
    pub jobs: Vec<JobStatSummary>,
}

/// Construction parameters for [`JobServer::new`].
#[derive(Clone, Debug, Default)]
pub struct JobServerConfig {
    /// Pool worker threads; 0 means `available_parallelism`.
    pub threads: usize,
    /// Job directory for durability; `None` runs in memory only.
    pub dir: Option<PathBuf>,
}

struct Job {
    spec: JobSpec,
    algo: AlgoKind,
    cspec: ChainSpec,
    durable: bool,
    /// When the job entered the table (phase-timing anchor).
    submitted: Instant,
    /// When the first chain started running, if any has.
    started: Option<Instant>,
    state: JobState,
    cancelled: bool,
    stop: Arc<AtomicBool>,
    /// Chains still owed a [`chain_finished`] call (queued or running).
    pending: usize,
    results: Vec<Option<ChainResult>>,
    steps_done: Vec<usize>,
    best_objective: f64,
    tracker: DiagnosticsTracker,
    last_diag: Option<DiagnosticsReport>,
    /// Final full-trace diagnostics `(split R-hat, min ESS)`, set when
    /// the job reaches a terminal state.
    final_diag: Option<(Option<f64>, f64)>,
    /// Measured-roofline projection for profiled jobs, set at `Done`.
    observation: Option<RooflineObservation>,
    /// Stamps progress events with steps/sec + ETA on the pump thread.
    rate: RateTracker,
    subs: Vec<Sender<StreamEvent>>,
    error: Option<String>,
}

struct Inner {
    pool: WorkPool,
    jobs: Mutex<BTreeMap<JobId, Job>>,
    /// Signalled whenever a job reaches a terminal state.
    done: Condvar,
    next_id: AtomicU64,
    dir: Option<PathBuf>,
    closing: AtomicBool,
}

/// The job server. Cheap to clone (all clones share one pool and one
/// job table); the TCP front-end hands a clone to every connection.
#[derive(Clone)]
pub struct JobServer {
    inner: Arc<Inner>,
}

impl JobServer {
    /// Start a server. Creates the job directory if configured.
    pub fn new(cfg: JobServerConfig) -> Result<JobServer, Mc2aError> {
        if let Some(dir) = &cfg.dir {
            std::fs::create_dir_all(dir).map_err(|e| {
                Mc2aError::Server(format!("creating job dir {}: {e}", dir.display()))
            })?;
        }
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            cfg.threads
        };
        Ok(JobServer {
            inner: Arc::new(Inner {
                pool: WorkPool::new(threads),
                jobs: Mutex::new(BTreeMap::new()),
                done: Condvar::new(),
                next_id: AtomicU64::new(1),
                dir: cfg.dir,
                closing: AtomicBool::new(false),
            }),
        })
    }

    /// An in-memory server (no durability) — the library-embedding and
    /// test entry point.
    pub fn in_memory(threads: usize) -> JobServer {
        JobServer::new(JobServerConfig { threads, dir: None })
            .expect("in-memory server construction cannot fail")
    }

    /// Rebuild a server from a job directory: terminal jobs reload
    /// their results, interrupted jobs re-run exactly their missing
    /// chains (bit-identical to an uninterrupted run).
    pub fn recover(dir: impl Into<PathBuf>) -> Result<JobServer, Mc2aError> {
        JobServer::recover_with(
            JobServerConfig { threads: 0, dir: Some(dir.into()) },
            None,
        )
    }

    /// [`JobServer::recover`] with full config and an optional backend
    /// override — resume every recovered job on `backend` regardless
    /// of what it originally ran on (results are backend-independent).
    pub fn recover_with(
        cfg: JobServerConfig,
        backend: Option<ServeBackend>,
    ) -> Result<JobServer, Mc2aError> {
        let dir = cfg
            .dir
            .clone()
            .ok_or_else(|| Mc2aError::Server("recover requires a job directory".into()))?;
        let server = JobServer::new(cfg)?;
        let mut envelopes = persist::load_envelopes(&dir)?;
        envelopes.sort_by_key(|e| e.job_id);
        let mut max_id = 0;
        for env in envelopes {
            max_id = max_id.max(env.job_id);
            server.restore_job(env, backend, &dir)?;
        }
        server.inner.next_id.store(max_id + 1, Ordering::SeqCst);
        Ok(server)
    }

    /// Submit a registry workload. Returns the job id immediately;
    /// chains run as pool slots free up.
    pub fn submit(&self, mut spec: JobSpec) -> Result<JobId, Mc2aError> {
        let entry = registry::find(&spec.workload).ok_or_else(|| Mc2aError::UnknownWorkload {
            name: spec.workload.clone(),
            known: registry::names().iter().map(|s| s.to_string()).collect(),
        })?;
        let wl = entry.build();
        spec.workload = entry.name.to_string();
        let algo = spec.algo.unwrap_or(wl.algorithm);
        if spec.pas_flips.is_none() {
            spec.pas_flips = Some(wl.pas_flips);
        }
        let model: Arc<dyn EnergyModel> = Arc::from(wl.model);
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        self.install(id, spec, algo, model, true, Vec::new())?;
        Ok(id)
    }

    /// Submit a caller-supplied model under a free-form label. Custom
    /// jobs are not persisted (the model cannot be rebuilt from disk),
    /// so they do not survive restart.
    pub fn submit_custom(
        &self,
        label: impl Into<String>,
        model: Arc<dyn EnergyModel>,
        mut spec: JobSpec,
    ) -> Result<JobId, Mc2aError> {
        spec.workload = label.into();
        let algo = spec.algo.unwrap_or(AlgoKind::Gibbs);
        if spec.pas_flips.is_none() {
            spec.pas_flips = Some(1);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        self.install(id, spec, algo, model, false, Vec::new())?;
        Ok(id)
    }

    /// Snapshot one job.
    pub fn status(&self, id: JobId) -> Result<JobStatus, Mc2aError> {
        let jobs = self.inner.jobs.lock().unwrap();
        let job = jobs.get(&id).ok_or(Mc2aError::UnknownJob { id })?;
        Ok(status_of(id, job))
    }

    /// Snapshot every job, in id order.
    pub fn status_all(&self) -> Vec<JobStatus> {
        let jobs = self.inner.jobs.lock().unwrap();
        jobs.iter().map(|(id, job)| status_of(*id, job)).collect()
    }

    /// Cancel a job: queued chains are purged from the pool, running
    /// chains stop at their next observation boundary. Already-terminal
    /// jobs are left untouched. Returns the state after the call.
    pub fn cancel(&self, id: JobId) -> Result<JobState, Mc2aError> {
        // Purge the pool first — the pool lock and the job-table lock
        // are never held together.
        let purged = self.inner.pool.cancel_job(id);
        let mut jobs = self.inner.jobs.lock().unwrap();
        let job = jobs.get_mut(&id).ok_or(Mc2aError::UnknownJob { id })?;
        if job.state.is_terminal() {
            return Ok(job.state);
        }
        job.cancelled = true;
        job.stop.store(true, Ordering::SeqCst);
        job.pending = job.pending.saturating_sub(purged);
        if job.pending == 0 {
            finalize_locked(&self.inner, id, job);
            self.inner.done.notify_all();
        }
        Ok(job.state)
    }

    /// The final result of a terminal job; an error while it is still
    /// queued or running (poll [`JobServer::status`] or use
    /// [`JobServer::wait`]).
    pub fn result(&self, id: JobId) -> Result<JobResult, Mc2aError> {
        let jobs = self.inner.jobs.lock().unwrap();
        let job = jobs.get(&id).ok_or(Mc2aError::UnknownJob { id })?;
        if !job.state.is_terminal() {
            return Err(Mc2aError::Server(format!(
                "job {id} is not finished (state {})",
                job.state.name()
            )));
        }
        Ok(result_of(id, job))
    }

    /// Block until the job reaches a terminal state (or `timeout`).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Result<JobResult, Mc2aError> {
        let deadline = Instant::now() + timeout;
        let mut jobs = self.inner.jobs.lock().unwrap();
        loop {
            match jobs.get(&id) {
                None => return Err(Mc2aError::UnknownJob { id }),
                Some(job) if job.state.is_terminal() => return Ok(result_of(id, job)),
                Some(_) if self.inner.closing.load(Ordering::SeqCst) => {
                    return Err(Mc2aError::Server(format!(
                        "server shut down before job {id} finished"
                    )));
                }
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Mc2aError::Server(format!("timed out waiting for job {id}")));
            }
            let (guard, _) = self.inner.done.wait_timeout(jobs, deadline - now).unwrap();
            jobs = guard;
        }
    }

    /// Subscribe to a job's live diagnostics. Terminal jobs yield one
    /// immediate [`StreamEvent::Done`]; live jobs stream progress and
    /// diagnostics until they finish.
    pub fn stream(&self, id: JobId) -> Result<EventStream, Mc2aError> {
        let (tx, stream) = raw_stream();
        let mut jobs = self.inner.jobs.lock().unwrap();
        let job = jobs.get_mut(&id).ok_or(Mc2aError::UnknownJob { id })?;
        if job.state.is_terminal() {
            let _ = tx.send(StreamEvent::Done {
                state: job.state.name().to_string(),
                best_objective: job.best_objective,
            });
        } else {
            job.subs.push(tx);
        }
        Ok(stream)
    }

    /// Graceful stop: reject new submits, drop queued chains, let
    /// running chains exit at their next boundary, join the pool, and
    /// persist every interrupted durable job as `queued` so
    /// [`JobServer::recover`] resumes it. Idempotent.
    pub fn shutdown(&self) {
        self.inner.closing.store(true, Ordering::SeqCst);
        {
            let jobs = self.inner.jobs.lock().unwrap();
            for job in jobs.values() {
                job.stop.store(true, Ordering::SeqCst);
            }
        }
        self.inner.pool.shutdown();
        let mut jobs = self.inner.jobs.lock().unwrap();
        for (&id, job) in jobs.iter_mut() {
            if !job.state.is_terminal() {
                // Queued tasks were dropped with the pool queue; no
                // chain_finished call is coming for them.
                job.pending = 0;
                finalize_locked(&self.inner, id, job);
            }
        }
        self.inner.done.notify_all();
    }

    /// Worker-thread count of the shared pool.
    pub fn threads(&self) -> usize {
        self.inner.pool.threads()
    }

    /// Aggregate point-in-time statistics for the admin surface
    /// (the `stats` protocol verb).
    pub fn stats(&self) -> ServerStats {
        let jobs = self.inner.jobs.lock().unwrap();
        let mut s = ServerStats {
            threads: self.inner.pool.threads(),
            jobs_total: jobs.len(),
            ..ServerStats::default()
        };
        for (&id, job) in jobs.iter() {
            match job.state {
                JobState::Queued => s.queued += 1,
                JobState::Running => s.running += 1,
                JobState::Done => s.done += 1,
                JobState::Cancelled => s.cancelled += 1,
                JobState::Failed => s.failed += 1,
            }
            s.chains_pending += job.pending;
            let (r_hat, min_ess) = diag_of(job);
            s.jobs.push(JobStatSummary {
                id,
                state: job.state,
                r_hat,
                min_ess,
                verdict: job.observation.as_ref().map(|o| o.verdict.name()),
                drift_pct: job.observation.as_ref().map(|o| o.drift.drift_pct),
            });
        }
        s
    }

    fn restore_job(
        &self,
        env: JobEnvelope,
        backend: Option<ServeBackend>,
        dir: &Path,
    ) -> Result<(), Mc2aError> {
        let broken = |what: &str, value: &str| {
            Mc2aError::Server(format!("recovering job {}: bad {what} `{value}`", env.job_id))
        };
        let state = JobState::parse(&env.state).ok_or_else(|| broken("state", &env.state))?;
        let algo = AlgoKind::parse(&env.algo).ok_or_else(|| broken("algo", &env.algo))?;
        let sampler =
            SamplerKind::parse(&env.sampler).map_err(|_| broken("sampler", &env.sampler))?;
        let priority =
            Priority::parse(&env.priority).ok_or_else(|| broken("priority", &env.priority))?;
        let backend = match backend {
            Some(b) => b,
            None => ServeBackend::parse(&env.backend)
                .ok_or_else(|| broken("backend", &env.backend))?,
        };
        let entry = registry::find(&env.workload).ok_or_else(|| Mc2aError::UnknownWorkload {
            name: env.workload.clone(),
            known: registry::names().iter().map(|s| s.to_string()).collect(),
        })?;
        let wl = entry.build();
        let model: Arc<dyn EnergyModel> = Arc::from(wl.model);
        let spec = JobSpec {
            workload: entry.name.to_string(),
            algo: Some(algo),
            sampler,
            steps: env.steps,
            chains: env.chains,
            seed: env.seed,
            beta: env.beta as f32,
            backend,
            priority,
            observe_every: env.observe_every,
            pas_flips: Some(env.pas_flips),
            trace: false,
            profile: false,
        };
        let preloaded = persist::load_chains(dir, env.job_id, env.chains, env.steps)?;
        if state.is_terminal() {
            self.insert_finished(env.job_id, spec, algo, state, preloaded)
        } else {
            self.install(env.job_id, spec, algo, model, true, preloaded)
        }
    }

    /// Re-insert a terminal recovered job so status/result still answer
    /// for it — without scheduling anything.
    fn insert_finished(
        &self,
        id: JobId,
        spec: JobSpec,
        algo: AlgoKind,
        state: JobState,
        results: Vec<Option<ChainResult>>,
    ) -> Result<(), Mc2aError> {
        let cspec = chain_spec_of(&spec, algo);
        let best_objective = results
            .iter()
            .flatten()
            .map(|c| c.best_objective)
            .fold(f64::NEG_INFINITY, f64::max);
        let steps_done = results.iter().map(|r| r.as_ref().map_or(0, |c| c.steps)).collect();
        let job = Job {
            tracker: DiagnosticsTracker::new(spec.chains),
            rate: RateTracker::new(spec.steps),
            spec,
            algo,
            cspec,
            durable: true,
            submitted: Instant::now(),
            started: None,
            state,
            cancelled: state == JobState::Cancelled,
            stop: Arc::new(AtomicBool::new(true)),
            pending: 0,
            final_diag: final_diag_of(&results),
            results,
            steps_done,
            best_objective,
            last_diag: None,
            observation: None,
            subs: Vec::new(),
            error: None,
        };
        let mut jobs = self.inner.jobs.lock().unwrap();
        if jobs.insert(id, job).is_some() {
            return Err(Mc2aError::Server(format!("duplicate job id {id}")));
        }
        Ok(())
    }

    /// Validate a spec, persist its envelope, insert it into the table
    /// and enqueue its missing chains. `preloaded` carries recovered
    /// chain results (empty on fresh submits).
    fn install(
        &self,
        id: JobId,
        mut spec: JobSpec,
        algo: AlgoKind,
        model: Arc<dyn EnergyModel>,
        durable: bool,
        preloaded: Vec<Option<ChainResult>>,
    ) -> Result<(), Mc2aError> {
        if self.inner.closing.load(Ordering::SeqCst) {
            return Err(Mc2aError::Server("server is shutting down".into()));
        }
        if spec.chains == 0 {
            return Err(Mc2aError::InvalidConfig("chains must be ≥ 1".into()));
        }
        if spec.steps == 0 {
            return Err(Mc2aError::InvalidConfig("steps must be ≥ 1".into()));
        }
        let schedule = BetaSchedule::Constant(spec.beta);
        schedule.validate().map_err(Mc2aError::InvalidConfig)?;
        if spec.observe_every == 0 {
            // Mirror EngineBuilder's default so server jobs are
            // bit-identical to solo runs of the same flags.
            spec.observe_every = (spec.steps / 20).max(1);
        }
        let backend: Arc<dyn ExecutionBackend> = match spec.backend {
            ServeBackend::Software => Arc::new(SoftwareBackend),
            ServeBackend::Accelerator => {
                let hw = HwConfig::paper_default();
                hw.validate().map_err(Mc2aError::InvalidHardware)?;
                Arc::new(AcceleratorBackend::new(hw))
            }
        };
        let cspec = chain_spec_of(&spec, algo);
        let mut results = preloaded;
        results.resize(spec.chains, None);
        let missing: Vec<usize> =
            (0..spec.chains).filter(|&c| results[c].is_none()).collect();
        let best_objective = results
            .iter()
            .flatten()
            .map(|c| c.best_objective)
            .fold(f64::NEG_INFINITY, f64::max);
        let steps_done =
            results.iter().map(|r| r.as_ref().map_or(0, |c| c.steps)).collect();
        let stop = Arc::new(AtomicBool::new(false));
        let class = spec.priority.class();
        if spec.trace {
            telemetry::metrics().set_enabled(true);
            if !telemetry::tracer().is_enabled() {
                telemetry::tracer().start();
            }
        }
        let mut job = Job {
            tracker: DiagnosticsTracker::new(spec.chains),
            rate: RateTracker::new(spec.steps),
            spec,
            algo,
            cspec: cspec.clone(),
            durable,
            submitted: Instant::now(),
            started: None,
            state: if missing.is_empty() { JobState::Done } else { JobState::Queued },
            cancelled: false,
            stop: Arc::clone(&stop),
            pending: missing.len(),
            results,
            steps_done,
            best_objective,
            last_diag: None,
            final_diag: None,
            observation: None,
            subs: Vec::new(),
            error: None,
        };
        if job.state == JobState::Done {
            // Fully preloaded from disk: surface final diagnostics (and
            // the profile projection) just like a freshly finished job.
            job.final_diag = final_diag_of(&job.results);
            if job.spec.profile {
                job.observation = observe_job(&job);
            }
        }
        if durable {
            if let Some(dir) = &self.inner.dir {
                // Persist before the first chain can run, so a crash at
                // any later point finds a resumable envelope on disk.
                envelope_of(id, &job).save(persist::envelope_path(dir, id))?;
            }
        }
        let done_already = missing.is_empty();
        {
            let mut jobs = self.inner.jobs.lock().unwrap();
            if jobs.insert(id, job).is_some() {
                return Err(Mc2aError::Server(format!("duplicate job id {id}")));
            }
        }
        if done_already {
            self.inner.done.notify_all();
            return Ok(());
        }
        let (tx, rx) = mpsc::channel::<ProgressEvent>();
        let pump_inner = Arc::clone(&self.inner);
        std::thread::Builder::new()
            .name(format!("mc2a-job-{id}"))
            .spawn(move || pump_events(&pump_inner, id, rx))
            .map_err(|e| Mc2aError::Server(format!("spawning event pump: {e}")))?;
        for chain in missing {
            let inner = Arc::clone(&self.inner);
            let model = Arc::clone(&model);
            let backend = Arc::clone(&backend);
            let cspec = cspec.clone();
            let stop = Arc::clone(&stop);
            let tx = tx.clone();
            self.inner.pool.submit(TaskTag { job: id, class }, move || {
                run_chain_task(&inner, id, chain, &model, &backend, &cspec, &stop, tx);
            });
        }
        // Drop the original sender: the pump exits once the last chain
        // task's clone is gone.
        drop(tx);
        Ok(())
    }
}

/// The [`ChainSpec`] a spec maps to — shared by submit, recovery and
/// the finished-job path so all three agree bit-for-bit.
fn chain_spec_of(spec: &JobSpec, algo: AlgoKind) -> ChainSpec {
    ChainSpec {
        algo,
        sampler: spec.sampler,
        schedule: BetaSchedule::Constant(spec.beta),
        beta_offset: 0,
        steps: spec.steps,
        seed: spec.seed,
        pas_flips: spec.pas_flips.unwrap_or(1).max(1),
        observe_every: spec.observe_every,
        init_state: None,
    }
}

fn status_of(id: JobId, job: &Job) -> JobStatus {
    let (r_hat, min_ess) = diag_of(job);
    JobStatus {
        id,
        workload: job.spec.workload.clone(),
        state: job.state,
        priority: job.spec.priority,
        backend: job.spec.backend,
        algo: job.algo,
        chains: job.spec.chains,
        chains_done: job.results.iter().flatten().count(),
        steps: job.cspec.steps,
        steps_done: job.steps_done.iter().sum(),
        best_objective: job.best_objective,
        r_hat,
        min_ess,
        error: job.error.clone(),
    }
}

fn result_of(id: JobId, job: &Job) -> JobResult {
    JobResult {
        id,
        state: job.state,
        best_objective: job.best_objective,
        chains: job.results.iter().flatten().cloned().collect(),
        error: job.error.clone(),
        observation: job.observation.clone(),
    }
}

/// The diagnostics pair `(split R-hat, min ESS)` a status surface
/// should show: the final full-trace values once computed, else the
/// latest streaming round.
fn diag_of(job: &Job) -> (Option<f64>, Option<f64>) {
    match job.final_diag {
        Some((r_hat, min_ess)) => (r_hat, Some(min_ess)),
        None => (
            job.last_diag.and_then(|d| d.r_hat),
            job.last_diag.map(|d| d.min_ess),
        ),
    }
}

/// Final cross-chain diagnostics over the completed chains' full
/// objective traces; `None` when no chain kept a trace.
fn final_diag_of(results: &[Option<ChainResult>]) -> Option<(Option<f64>, f64)> {
    let traces: Vec<Vec<f64>> = results
        .iter()
        .flatten()
        .map(|c| c.objective_trace.clone())
        .filter(|t| !t.is_empty())
        .collect();
    if traces.is_empty() {
        return None;
    }
    let r_hat = if traces.len() >= 2 {
        split_r_hat(&traces)
    } else {
        None
    };
    let min_ess = traces
        .iter()
        .map(|t| effective_sample_size(t))
        .fold(f64::INFINITY, f64::min);
    Some((r_hat, min_ess))
}

/// The measured-roofline observation for a finished profiled job.
/// Rebuilds the workload model from the registry; custom-model jobs
/// (nothing to rebuild) and empty result sets yield `None`.
fn observe_job(job: &Job) -> Option<RooflineObservation> {
    let entry = registry::find(&job.spec.workload)?;
    let wl = entry.build();
    let chains: Vec<ChainResult> = job.results.iter().flatten().cloned().collect();
    if chains.is_empty() {
        return None;
    }
    let sim_hw = match job.spec.backend {
        ServeBackend::Software => None,
        ServeBackend::Accelerator => Some(MultiHwConfig::new(HwConfig::paper_default(), 1)),
    };
    let wall = job.started.unwrap_or(job.submitted).elapsed();
    Some(profile::observe_run(
        &job.spec.workload,
        wl.model.as_ref(),
        job.cspec.algo,
        job.cspec.sampler,
        job.cspec.pas_flips,
        job.spec.backend.name(),
        sim_hw,
        &chains,
        job.cspec.steps,
        wall,
    ))
}

/// One pool task: run one chain to completion (or to the stop flag).
#[allow(clippy::too_many_arguments)]
fn run_chain_task(
    inner: &Arc<Inner>,
    id: JobId,
    chain: usize,
    model: &Arc<dyn EnergyModel>,
    backend: &Arc<dyn ExecutionBackend>,
    cspec: &ChainSpec,
    stop: &Arc<AtomicBool>,
    tx: Sender<ProgressEvent>,
) {
    if stop.load(Ordering::SeqCst) {
        chain_finished(inner, id, chain, None);
        return;
    }
    mark_running(inner, id);
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let ctx = ChainCtx { stop: &**stop, events: Some(tx), restart: None };
        backend.run_chain(model.as_ref(), cspec, chain, &ctx)
    }));
    let res = match outcome {
        Ok(r) => r,
        Err(_) => Err(Mc2aError::ChainPanicked { chain_id: chain }),
    };
    chain_finished(inner, id, chain, Some(res));
}

fn mark_running(inner: &Inner, id: JobId) {
    let mut jobs = inner.jobs.lock().unwrap();
    if let Some(job) = jobs.get_mut(&id) {
        if job.state == JobState::Queued {
            job.state = JobState::Running;
            job.started = Some(Instant::now());
            if telemetry::enabled() {
                telemetry::metrics().observe(
                    "job_queued_seconds",
                    &[("priority", job.spec.priority.name())],
                    job.submitted.elapsed().as_secs_f64(),
                );
            }
        }
    }
}

/// Bookkeeping for one finished (or skipped) chain task. `None` means
/// the task never ran (stop flag was already up).
fn chain_finished(
    inner: &Arc<Inner>,
    id: JobId,
    chain: usize,
    res: Option<Result<ChainResult, Mc2aError>>,
) {
    let mut jobs = inner.jobs.lock().unwrap();
    let Some(job) = jobs.get_mut(&id) else { return };
    if job.state.is_terminal() {
        // A cancel/shutdown already finalized this job while the task
        // was in flight.
        return;
    }
    job.pending = job.pending.saturating_sub(1);
    match res {
        Some(Ok(r)) if r.steps == job.cspec.steps => {
            // Server chains run through `run_chain` directly (not
            // `Engine::run`), so fold them into the registry here.
            if telemetry::enabled() {
                telemetry::record_chain_result(
                    job.cspec.algo.name(),
                    job.cspec.sampler.name(),
                    job.spec.backend.name(),
                    &r,
                );
            }
            job.steps_done[chain] = r.steps;
            job.best_objective = job.best_objective.max(r.best_objective);
            if job.durable {
                if let Some(dir) = &inner.dir {
                    let t0 = telemetry::enabled().then(Instant::now);
                    if let Err(e) = persist::save_chain(dir, id, &r) {
                        eprintln!("mc2a serve: persisting job {id} chain {chain}: {e}");
                    }
                    if let Some(t0) = t0 {
                        telemetry::metrics().observe(
                            "job_persist_seconds",
                            &[("priority", job.spec.priority.name())],
                            t0.elapsed().as_secs_f64(),
                        );
                    }
                }
            }
            job.results[chain] = Some(r);
        }
        Some(Ok(_partial)) => {
            // Stopped early (cancel or shutdown): discard — recovery
            // re-runs the chain from step 0 for bit-identical results.
        }
        Some(Err(e)) => {
            if job.error.is_none() {
                job.error = Some(e.to_string());
            }
            // Fail fast: siblings exit at their next boundary, queued
            // siblings see the flag before starting.
            job.stop.store(true, Ordering::SeqCst);
        }
        None => {}
    }
    if job.pending == 0 {
        finalize_locked(inner, id, job);
        inner.done.notify_all();
    }
}

/// Move a job with no outstanding chain tasks to its resting state,
/// notify stream subscribers, and persist the final envelope.
fn finalize_locked(inner: &Inner, id: JobId, job: &mut Job) {
    let complete = job.results.iter().all(Option::is_some);
    job.state = if job.cancelled {
        JobState::Cancelled
    } else if job.error.is_some() {
        JobState::Failed
    } else if complete {
        JobState::Done
    } else {
        // Interrupted by server shutdown: stays resumable on disk.
        JobState::Queued
    };
    if job.state.is_terminal() {
        job.final_diag = final_diag_of(&job.results);
        if job.spec.profile && job.state == JobState::Done {
            job.observation = observe_job(job);
        }
    }
    let now = Instant::now();
    if telemetry::enabled() {
        let m = telemetry::metrics();
        let run_t0 = job.started.unwrap_or(job.submitted);
        m.observe(
            "job_run_seconds",
            &[("priority", job.spec.priority.name())],
            now.duration_since(run_t0).as_secs_f64(),
        );
        m.counter_add("jobs_finished_total", &[("state", job.state.name())], 1);
    }
    if telemetry::tracing() {
        telemetry::tracer().record(
            format!("job {id} {} ({})", job.spec.workload, job.state.name()),
            "job",
            job.submitted,
            now,
        );
    }
    let event = StreamEvent::Done {
        state: job.state.name().to_string(),
        best_objective: job.best_objective,
    };
    for sub in job.subs.drain(..) {
        let _ = sub.send(event.clone());
    }
    if job.durable {
        if let Some(dir) = &inner.dir {
            if let Err(e) = envelope_of(id, job).save(persist::envelope_path(dir, id)) {
                eprintln!("mc2a serve: persisting job {id} envelope: {e}");
            }
        }
    }
}

/// The durable record of a job's current shape and progress.
fn envelope_of(id: JobId, job: &Job) -> JobEnvelope {
    let best = job.results.iter().flatten().max_by(|a, b| {
        a.best_objective
            .partial_cmp(&b.best_objective)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let checkpoint = Checkpoint {
        seed: job.cspec.seed,
        steps: best.map_or(0, |c| c.steps),
        best_objective: best.map_or(f64::NEG_INFINITY, |c| c.best_objective),
        best_x: best.map(|c| c.best_x.clone()).unwrap_or_default(),
        anneal: None,
        temper: None,
        workload: Some(job.spec.workload.clone()),
        sampler: Some(job.cspec.sampler.spec()),
        chains: Some(job.spec.chains),
    };
    JobEnvelope {
        job_id: id,
        workload: job.spec.workload.clone(),
        algo: job.algo.name().to_ascii_lowercase(),
        sampler: job.cspec.sampler.spec(),
        backend: job.spec.backend.name().to_string(),
        priority: job.spec.priority.name().to_string(),
        state: job.state.name().to_string(),
        steps: job.cspec.steps,
        chains: job.spec.chains,
        observe_every: job.cspec.observe_every,
        pas_flips: job.cspec.pas_flips,
        chains_done: job.results.iter().flatten().count(),
        seed: job.cspec.seed,
        beta: job.spec.beta as f64,
        checkpoint,
    }
}

/// Per-job event pump: folds chain progress into the job's status
/// fields and forwards events to stream subscribers. One thread per
/// live job; exits when every chain task has dropped its sender.
fn pump_events(inner: &Inner, id: JobId, rx: mpsc::Receiver<ProgressEvent>) {
    while let Ok(event) = rx.recv() {
        let mut jobs = inner.jobs.lock().unwrap();
        let Some(job) = jobs.get_mut(&id) else { break };
        let mut event = event;
        job.rate.stamp(&mut event);
        if let Some(slot) = job.steps_done.get_mut(event.chain_id) {
            *slot = (*slot).max(event.step);
        }
        job.best_objective = job.best_objective.max(event.best_objective);
        let diag = job.tracker.record(&event);
        if let Some(d) = diag {
            job.last_diag = Some(d);
        }
        if !job.subs.is_empty() {
            let forward = StreamEvent::Progress(event);
            job.subs.retain(|sub| sub.send(forward.clone()).is_ok());
            if let Some(d) = diag {
                let forward = StreamEvent::Diagnostics(d);
                job.subs.retain(|sub| sub.send(forward.clone()).is_ok());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(workload: &str, steps: usize, chains: usize, seed: u64) -> JobSpec {
        let mut spec = JobSpec::new(workload);
        spec.steps = steps;
        spec.chains = chains;
        spec.seed = seed;
        spec
    }

    #[test]
    fn submit_wait_result_round_trip() {
        let server = JobServer::in_memory(2);
        let id = server.submit(quick_spec("earthquake", 60, 2, 5)).unwrap();
        let result = server.wait(id, Duration::from_secs(60)).unwrap();
        assert_eq!(result.state, JobState::Done);
        assert_eq!(result.chains.len(), 2);
        let status = server.status(id).unwrap();
        assert_eq!(status.chains_done, 2);
        assert_eq!(status.steps_done, 120);
        server.shutdown();
    }

    #[test]
    fn profiled_job_surfaces_final_diagnostics_and_observation() {
        let server = JobServer::in_memory(2);
        let mut spec = quick_spec("earthquake", 60, 2, 5);
        spec.profile = true;
        let id = server.submit(spec).unwrap();
        let result = server.wait(id, Duration::from_secs(60)).unwrap();
        assert_eq!(result.state, JobState::Done);
        let obs = result.observation.expect("profiled job carries an observation");
        assert_eq!(obs.backend, "sw");
        assert!(obs.samples > 0);

        // Finished jobs answer status with *final* full-trace
        // diagnostics, and the stats verb summarizes the same.
        let status = server.status(id).unwrap();
        assert!(status.min_ess.is_some(), "final min-ESS for a finished job");
        let stats = server.stats();
        let summary = stats.jobs.iter().find(|j| j.id == id).unwrap();
        assert_eq!(summary.verdict, Some(obs.verdict.name()));
        assert_eq!(summary.min_ess, status.min_ess);

        // An unprofiled sibling gets diagnostics but no observation.
        let id2 = server.submit(quick_spec("earthquake", 60, 2, 5)).unwrap();
        let result2 = server.wait(id2, Duration::from_secs(60)).unwrap();
        assert!(result2.observation.is_none());
        server.shutdown();
    }

    #[test]
    fn unknown_workload_and_unknown_job_are_typed() {
        let server = JobServer::in_memory(1);
        assert!(matches!(
            server.submit(JobSpec::new("nope")),
            Err(Mc2aError::UnknownWorkload { .. })
        ));
        assert!(matches!(server.status(99), Err(Mc2aError::UnknownJob { id: 99 })));
        assert!(matches!(server.cancel(99), Err(Mc2aError::UnknownJob { id: 99 })));
        server.shutdown();
    }

    #[test]
    fn result_before_terminal_is_an_error() {
        let server = JobServer::in_memory(1);
        let mut spec = quick_spec("earthquake", 50_000, 1, 5);
        spec.observe_every = 50;
        let id = server.submit(spec).unwrap();
        // Either still queued/running (the common case) or already
        // done on a fast machine — only the non-terminal path must
        // error.
        match server.result(id) {
            Err(Mc2aError::Server(msg)) => assert!(msg.contains("not finished"), "{msg}"),
            Ok(r) => assert_eq!(r.state, JobState::Done),
            Err(e) => panic!("unexpected error: {e}"),
        }
        server.cancel(id).unwrap();
        server.wait(id, Duration::from_secs(60)).unwrap();
        server.shutdown();
    }

    #[test]
    fn stream_ends_with_done_event() {
        let server = JobServer::in_memory(2);
        let mut spec = quick_spec("earthquake", 100, 2, 5);
        spec.observe_every = 10;
        let id = server.submit(spec).unwrap();
        let stream = server.stream(id).unwrap();
        let mut saw_progress = false;
        let mut last = None;
        while let Some(ev) = stream.recv_timeout(Duration::from_secs(60)) {
            match &ev {
                StreamEvent::Progress(_) => saw_progress = true,
                StreamEvent::Done { .. } => {
                    last = Some(ev);
                    break;
                }
                StreamEvent::Diagnostics(_) => {}
            }
        }
        assert!(saw_progress, "expected at least one progress event");
        match last {
            Some(StreamEvent::Done { state, .. }) => assert_eq!(state, "done"),
            other => panic!("expected Done, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_submits() {
        let server = JobServer::in_memory(1);
        server.shutdown();
        assert!(matches!(
            server.submit(quick_spec("earthquake", 10, 1, 1)),
            Err(Mc2aError::Server(_))
        ));
    }
}
