//! The serve/client wire protocol: newline-delimited JSON.
//!
//! Every request and most responses are **flat** JSON objects (no
//! nesting), hand-rolled both ways because the crate carries no serde.
//! One request line yields one response line, except `stream`, which
//! turns the connection into a one-way event feed.
//!
//! Requests (`op` selects the operation):
//!
//! ```json
//! {"op":"submit","workload":"optsicom","steps":500,"chains":4,"seed":7,
//!  "beta":2.0,"sampler":"gumbel","backend":"sw","priority":"high"}
//! {"op":"status"}            {"op":"status","job":3}
//! {"op":"result","job":3}    {"op":"cancel","job":3}
//! {"op":"stream","job":3}    {"op":"ping"}    {"op":"shutdown"}
//! {"op":"metrics"}           {"op":"stats"}
//! ```
//!
//! Responses carry `"ok":true` plus operation payload, or `"ok":false`
//! with a machine-readable `kind` and a human `error`:
//!
//! ```json
//! {"ok":true,"job":3}
//! {"ok":false,"kind":"unknown-job","error":"unknown job id 99"}
//! ```
//!
//! Non-finite floats (an untouched best objective is −∞) serialize as
//! `null`.

use super::{
    JobId, JobResult, JobSpec, JobStatSummary, JobStatus, Priority, ServeBackend, ServerStats,
};
use crate::engine::error::Mc2aError;
use crate::engine::observer::StreamEvent;
use crate::mcmc::{AlgoKind, SamplerKind};

/// A parsed flat-JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JVal {
    /// A JSON string.
    Str(String),
    /// Any JSON number.
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `null`.
    Null,
}

fn perr(line: &str, why: &str) -> Mc2aError {
    let snippet: String = line.chars().take(80).collect();
    Mc2aError::Protocol(format!("{why} in `{snippet}`"))
}

/// Parse one flat JSON object (`{"k":v,…}`, no nested objects or
/// arrays) into key/value pairs, preserving order.
pub fn parse_flat_object(line: &str) -> Result<Vec<(String, JVal)>, Mc2aError> {
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0usize;
    let skip_ws = |i: &mut usize| {
        while *i < chars.len() && chars[*i].is_ascii_whitespace() {
            *i += 1;
        }
    };
    let parse_string = |i: &mut usize| -> Result<String, Mc2aError> {
        // Caller has consumed the opening quote.
        let mut out = String::new();
        while *i < chars.len() {
            let c = chars[*i];
            *i += 1;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let e = *chars.get(*i).ok_or_else(|| perr(line, "truncated escape"))?;
                    *i += 1;
                    match e {
                        '"' | '\\' | '/' => out.push(e),
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'b' => out.push('\u{0008}'),
                        'f' => out.push('\u{000C}'),
                        'u' => {
                            if *i + 4 > chars.len() {
                                return Err(perr(line, "truncated \\u escape"));
                            }
                            let hex: String = chars[*i..*i + 4].iter().collect();
                            *i += 4;
                            let code = u32::from_str_radix(&hex, 16)
                                .map_err(|_| perr(line, "bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| perr(line, "bad \\u code point"))?,
                            );
                        }
                        _ => return Err(perr(line, "unknown escape")),
                    }
                }
                other => out.push(other),
            }
        }
        Err(perr(line, "unterminated string"))
    };

    let mut fields = Vec::new();
    skip_ws(&mut i);
    if chars.get(i) != Some(&'{') {
        return Err(perr(line, "expected `{`"));
    }
    i += 1;
    skip_ws(&mut i);
    if chars.get(i) == Some(&'}') {
        i += 1;
    } else {
        loop {
            skip_ws(&mut i);
            if chars.get(i) != Some(&'"') {
                return Err(perr(line, "expected a key string"));
            }
            i += 1;
            let key = parse_string(&mut i)?;
            skip_ws(&mut i);
            if chars.get(i) != Some(&':') {
                return Err(perr(line, "expected `:`"));
            }
            i += 1;
            skip_ws(&mut i);
            let value = match chars.get(i) {
                Some('"') => {
                    i += 1;
                    JVal::Str(parse_string(&mut i)?)
                }
                Some('t') if chars[i..].starts_with(&['t', 'r', 'u', 'e']) => {
                    i += 4;
                    JVal::Bool(true)
                }
                Some('f') if chars[i..].starts_with(&['f', 'a', 'l', 's', 'e']) => {
                    i += 5;
                    JVal::Bool(false)
                }
                Some('n') if chars[i..].starts_with(&['n', 'u', 'l', 'l']) => {
                    i += 4;
                    JVal::Null
                }
                Some(c) if *c == '-' || c.is_ascii_digit() => {
                    let start = i;
                    while i < chars.len()
                        && matches!(chars[i], '-' | '+' | '.' | 'e' | 'E' | '0'..='9')
                    {
                        i += 1;
                    }
                    let tok: String = chars[start..i].iter().collect();
                    JVal::Num(tok.parse::<f64>().map_err(|_| perr(line, "bad number"))?)
                }
                _ => return Err(perr(line, "expected a value")),
            };
            fields.push((key, value));
            skip_ws(&mut i);
            match chars.get(i) {
                Some(',') => i += 1,
                Some('}') => {
                    i += 1;
                    break;
                }
                _ => return Err(perr(line, "expected `,` or `}`")),
            }
        }
    }
    skip_ws(&mut i);
    if i != chars.len() {
        return Err(perr(line, "trailing garbage"));
    }
    Ok(fields)
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Submit a new job.
    Submit(JobSpec),
    /// Status of one job, or of every job when `job` is `None`.
    Status {
        /// Target job, if any.
        job: Option<JobId>,
    },
    /// Final result of a terminal job.
    Result {
        /// Target job.
        job: JobId,
    },
    /// Cancel a job.
    Cancel {
        /// Target job.
        job: JobId,
    },
    /// Turn the connection into an event feed for a job.
    Stream {
        /// Target job.
        job: JobId,
    },
    /// Liveness check.
    Ping,
    /// Prometheus-format dump of the process metrics registry.
    Metrics,
    /// Aggregate server statistics (jobs by state, pool load).
    Stats,
    /// Graceful server stop.
    Shutdown,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, Mc2aError> {
    let fields = parse_flat_object(line)?;
    let get = |key: &str| fields.iter().find(|(k, _)| k == key).map(|(_, v)| v);
    let usize_of = |key: &str| -> Result<Option<usize>, Mc2aError> {
        match get(key) {
            None | Some(JVal::Null) => Ok(None),
            Some(JVal::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => Ok(Some(*n as usize)),
            Some(_) => Err(perr(line, &format!("`{key}` must be a non-negative integer"))),
        }
    };
    let u64_of = |key: &str| -> Result<Option<u64>, Mc2aError> {
        match get(key) {
            None | Some(JVal::Null) => Ok(None),
            Some(JVal::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => Ok(Some(*n as u64)),
            Some(_) => Err(perr(line, &format!("`{key}` must be a non-negative integer"))),
        }
    };
    let required_job = |key: &str| -> Result<JobId, Mc2aError> {
        u64_of(key)?.ok_or_else(|| perr(line, "missing `job`"))
    };
    let op = match get("op") {
        Some(JVal::Str(s)) => s.clone(),
        _ => return Err(perr(line, "missing `op`")),
    };
    match op.as_str() {
        "submit" => {
            let workload = match get("workload") {
                Some(JVal::Str(s)) => s.clone(),
                _ => return Err(perr(line, "submit requires `workload`")),
            };
            let mut spec = JobSpec::new(workload);
            if let Some(v) = usize_of("steps")? {
                spec.steps = v;
            }
            if let Some(v) = usize_of("chains")? {
                spec.chains = v;
            }
            if let Some(v) = u64_of("seed")? {
                spec.seed = v;
            }
            if let Some(v) = usize_of("observe_every")? {
                spec.observe_every = v;
            }
            spec.pas_flips = usize_of("pas_flips")?;
            if let Some(JVal::Num(b)) = get("beta") {
                spec.beta = *b as f32;
            }
            if let Some(JVal::Str(s)) = get("algo") {
                spec.algo = Some(
                    AlgoKind::parse(s)
                        .ok_or_else(|| perr(line, &format!("unknown algo `{s}`")))?,
                );
            }
            if let Some(JVal::Str(s)) = get("sampler") {
                spec.sampler =
                    SamplerKind::parse(s).map_err(|e| perr(line, &e.to_string()))?;
            }
            if let Some(JVal::Str(s)) = get("backend") {
                spec.backend = ServeBackend::parse(s)
                    .ok_or_else(|| perr(line, &format!("unknown backend `{s}`")))?;
            }
            if let Some(JVal::Str(s)) = get("priority") {
                spec.priority = Priority::parse(s)
                    .ok_or_else(|| perr(line, &format!("unknown priority `{s}`")))?;
            }
            if let Some(JVal::Bool(b)) = get("trace") {
                spec.trace = *b;
            }
            if let Some(JVal::Bool(b)) = get("profile") {
                spec.profile = *b;
            }
            Ok(Request::Submit(spec))
        }
        "status" => Ok(Request::Status { job: u64_of("job")? }),
        "result" => Ok(Request::Result { job: required_job("job")? }),
        "cancel" => Ok(Request::Cancel { job: required_job("job")? }),
        "stream" => Ok(Request::Stream { job: required_job("job")? }),
        "ping" => Ok(Request::Ping),
        "metrics" => Ok(Request::Metrics),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(perr(line, &format!("unknown op `{other}`"))),
    }
}

/// A number for the wire: non-finite becomes `null`.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn jstr(s: &str) -> String {
    format!("\"{}\"", crate::engine::checkpoint::escape_json(s))
}

fn jopt_str(s: &Option<String>) -> String {
    match s {
        Some(s) => jstr(s),
        None => "null".to_string(),
    }
}

fn jopt_num(x: Option<f64>) -> String {
    match x {
        Some(v) => jnum(v),
        None => "null".to_string(),
    }
}

/// `{"ok":true,"job":N}` — submit accepted.
pub fn ok_submit(id: JobId) -> String {
    format!("{{\"ok\":true,\"job\":{id}}}")
}

/// `{"ok":true,"pong":true}`.
pub fn ok_ping() -> String {
    "{\"ok\":true,\"pong\":true}".to_string()
}

/// `{"ok":true,"stopping":true}`.
pub fn ok_shutdown() -> String {
    "{\"ok\":true,\"stopping\":true}".to_string()
}

/// `{"ok":true,"job":N,"state":"…"}` — state after a cancel.
pub fn ok_cancel(id: JobId, state: &str) -> String {
    format!("{{\"ok\":true,\"job\":{id},\"state\":{}}}", jstr(state))
}

/// `{"ok":true,"metrics":"…"}` — the Prometheus exposition text as one
/// escaped string (newlines become `\n` on the wire).
pub fn ok_metrics(text: &str) -> String {
    format!("{{\"ok\":true,\"metrics\":{}}}", jstr(text))
}

/// `{"ok":true,"jobs":N,…,"job_stats":[…]}` — aggregate server
/// statistics plus one convergence/profile summary per job.
pub fn ok_stats(s: &ServerStats) -> String {
    let jobs: Vec<String> = s.jobs.iter().map(job_stat_json).collect();
    format!(
        "{{\"ok\":true,\"jobs\":{},\"queued\":{},\"running\":{},\"done\":{},\
         \"cancelled\":{},\"failed\":{},\"chains_pending\":{},\"threads\":{},\
         \"job_stats\":[{}]}}",
        s.jobs_total,
        s.queued,
        s.running,
        s.done,
        s.cancelled,
        s.failed,
        s.chains_pending,
        s.threads,
        jobs.join(","),
    )
}

fn job_stat_json(j: &JobStatSummary) -> String {
    let verdict = match j.verdict {
        Some(v) => jstr(v),
        None => "null".to_string(),
    };
    format!(
        "{{\"job\":{},\"state\":{},\"r_hat\":{},\"min_ess\":{},\"verdict\":{},\
         \"drift_pct\":{}}}",
        j.id,
        jstr(j.state.name()),
        jopt_num(j.r_hat),
        jopt_num(j.min_ess),
        verdict,
        jopt_num(j.drift_pct),
    )
}

fn status_json(s: &JobStatus) -> String {
    format!(
        "{{\"job\":{},\"workload\":{},\"state\":{},\"priority\":{},\"backend\":{},\
         \"algo\":{},\"chains\":{},\"chains_done\":{},\"steps\":{},\"steps_done\":{},\
         \"best_objective\":{},\"r_hat\":{},\"min_ess\":{},\"error\":{}}}",
        s.id,
        jstr(&s.workload),
        jstr(s.state.name()),
        jstr(s.priority.name()),
        jstr(s.backend.name()),
        jstr(s.algo.name()),
        s.chains,
        s.chains_done,
        s.steps,
        s.steps_done,
        jnum(s.best_objective),
        jopt_num(s.r_hat),
        jopt_num(s.min_ess),
        jopt_str(&s.error),
    )
}

/// `{"ok":true,"jobs":[…]}` — one status object per job.
pub fn ok_status(list: &[JobStatus]) -> String {
    let jobs: Vec<String> = list.iter().map(status_json).collect();
    format!("{{\"ok\":true,\"jobs\":[{}]}}", jobs.join(","))
}

/// `{"ok":true,"job":N,"state":"…","best_objective":…,"chains":[…]}`.
pub fn ok_result(r: &JobResult) -> String {
    let chains: Vec<String> = r
        .chains
        .iter()
        .map(|c| {
            let best_x: Vec<String> = c.best_x.iter().map(|v| v.to_string()).collect();
            let mut obj = format!(
                "{{\"chain\":{},\"steps\":{},\"best_objective\":{},\"updates\":{},\
                 \"trace_len\":{},\"best_x\":[{}]",
                c.chain_id,
                c.steps,
                jnum(c.best_objective),
                c.stats.updates,
                c.objective_trace.len(),
                best_x.join(","),
            );
            // Simulated chains carry the cycle/stall/utilization
            // breakdown the co-design loop needs (absent on software
            // chains, so software responses are unchanged).
            if let Some(rep) = &c.sim {
                obj.push_str(&format!(
                    ",\"sim_cycles\":{},\"sim_stall_sync\":{},\"sim_stall_xbar\":{},\
                     \"sim_xfer_words\":{},\"sim_cu_util\":{},\"sim_su_util\":{}",
                    rep.cycles,
                    rep.stall_sync,
                    rep.stall_xbar,
                    rep.xfer_words,
                    jnum(rep.cu_utilization()),
                    jnum(rep.su_utilization()),
                ));
            }
            obj.push('}');
            obj
        })
        .collect();
    // Profiled jobs append their measured-roofline observation (one
    // nested object); unprofiled responses are unchanged.
    let observation = match &r.observation {
        Some(obs) => format!(",\"observation\":{}", obs.to_json()),
        None => String::new(),
    };
    format!(
        "{{\"ok\":true,\"job\":{},\"state\":{},\"best_objective\":{},\"error\":{},\
         \"chains\":[{}]{}}}",
        r.id,
        jstr(r.state.name()),
        jnum(r.best_objective),
        jopt_str(&r.error),
        chains.join(","),
        observation,
    )
}

/// The machine-readable failure class of an error.
pub fn error_kind(e: &Mc2aError) -> &'static str {
    match e {
        Mc2aError::InvalidConfig(_) => "invalid-config",
        Mc2aError::InvalidHardware(_) => "invalid-hardware",
        Mc2aError::UnknownWorkload { .. } => "unknown-workload",
        Mc2aError::UnknownBench { .. } => "unknown-bench",
        Mc2aError::Checkpoint(_) => "checkpoint",
        Mc2aError::CheckpointMismatch { .. } => "checkpoint-mismatch",
        Mc2aError::RuntimeUnavailable(_) => "runtime-unavailable",
        Mc2aError::Runtime(_) => "runtime",
        Mc2aError::ChainPanicked { .. } | Mc2aError::BackendPanicked => "panic",
        Mc2aError::Server(msg) if msg.contains("is not finished") => "not-finished",
        Mc2aError::Server(_) => "server",
        Mc2aError::Protocol(_) => "protocol",
        Mc2aError::UnknownJob { .. } => "unknown-job",
    }
}

/// `{"ok":false,"kind":"…","error":"…"}`.
pub fn err_line(e: &Mc2aError) -> String {
    format!(
        "{{\"ok\":false,\"kind\":{},\"error\":{}}}",
        jstr(error_kind(e)),
        jstr(&e.to_string())
    )
}

/// One stream event as a wire line.
pub fn event_line(ev: &StreamEvent) -> String {
    match ev {
        StreamEvent::Progress(p) => format!(
            "{{\"event\":\"progress\",\"chain\":{},\"step\":{},\"beta\":{},\
             \"objective\":{},\"best\":{},\"updates\":{},\"steps_per_sec\":{},\
             \"eta_seconds\":{}}}",
            p.chain_id,
            p.step,
            jnum(p.beta as f64),
            jnum(p.objective),
            jnum(p.best_objective),
            p.updates,
            jopt_num(p.steps_per_sec),
            jopt_num(p.eta_seconds),
        ),
        StreamEvent::Diagnostics(d) => {
            let r_hat = match d.r_hat {
                Some(r) => jnum(r),
                None => "null".to_string(),
            };
            format!(
                "{{\"event\":\"diagnostics\",\"round\":{},\"step\":{},\"r_hat\":{},\
                 \"min_ess\":{},\"best\":{}}}",
                d.round,
                d.step,
                r_hat,
                jnum(d.min_ess),
                jnum(d.best_objective),
            )
        }
        StreamEvent::Done { state, best_objective } => format!(
            "{{\"event\":\"done\",\"state\":{},\"best\":{}}}",
            jstr(state),
            jnum(*best_objective),
        ),
    }
}

// ---- Client-side line builders (used by `mc2a client` and tests) ----

/// Build a submit request line from a spec.
pub fn submit_line(spec: &JobSpec) -> String {
    let mut line = format!(
        "{{\"op\":\"submit\",\"workload\":{},\"steps\":{},\"chains\":{},\"seed\":{},\
         \"beta\":{},\"sampler\":{},\"backend\":{},\"priority\":{}",
        jstr(&spec.workload),
        spec.steps,
        spec.chains,
        spec.seed,
        spec.beta,
        jstr(&spec.sampler.spec()),
        jstr(spec.backend.name()),
        jstr(spec.priority.name()),
    );
    if let Some(algo) = spec.algo {
        line.push_str(&format!(",\"algo\":{}", jstr(&algo.name().to_ascii_lowercase())));
    }
    if spec.observe_every > 0 {
        line.push_str(&format!(",\"observe_every\":{}", spec.observe_every));
    }
    if let Some(p) = spec.pas_flips {
        line.push_str(&format!(",\"pas_flips\":{p}"));
    }
    if spec.trace {
        line.push_str(",\"trace\":true");
    }
    if spec.profile {
        line.push_str(",\"profile\":true");
    }
    line.push('}');
    line
}

/// Build a status request line.
pub fn status_line(job: Option<JobId>) -> String {
    match job {
        Some(id) => format!("{{\"op\":\"status\",\"job\":{id}}}"),
        None => "{\"op\":\"status\"}".to_string(),
    }
}

/// Build a result request line.
pub fn result_line(job: JobId) -> String {
    format!("{{\"op\":\"result\",\"job\":{job}}}")
}

/// Build a cancel request line.
pub fn cancel_line(job: JobId) -> String {
    format!("{{\"op\":\"cancel\",\"job\":{job}}}")
}

/// Build a stream request line.
pub fn stream_line(job: JobId) -> String {
    format!("{{\"op\":\"stream\",\"job\":{job}}}")
}

/// Build a ping request line.
pub fn ping_line() -> String {
    "{\"op\":\"ping\"}".to_string()
}

/// Build a metrics request line.
pub fn metrics_line() -> String {
    "{\"op\":\"metrics\"}".to_string()
}

/// Build a stats request line.
pub fn stats_line() -> String {
    "{\"op\":\"stats\"}".to_string()
}

/// Build a shutdown request line.
pub fn shutdown_line() -> String {
    "{\"op\":\"shutdown\"}".to_string()
}

/// Did the server accept the request? (Responses always lead with the
/// `ok` field.)
pub fn response_is_ok(line: &str) -> bool {
    line.trim_start().starts_with("{\"ok\":true")
}

/// The `kind` of an error response (`None` on success lines).
pub fn response_kind(line: &str) -> Option<String> {
    if response_is_ok(line) {
        return None;
    }
    let fields = parse_flat_object(line).ok()?;
    fields.into_iter().find_map(|(k, v)| match (k.as_str(), v) {
        ("kind", JVal::Str(s)) => Some(s),
        _ => None,
    })
}

/// The `job` id of a flat success response (submit/cancel).
pub fn response_job(line: &str) -> Option<JobId> {
    let fields = parse_flat_object(line).ok()?;
    fields.into_iter().find_map(|(k, v)| match (k.as_str(), v) {
        ("job", JVal::Num(n)) if n >= 0.0 && n.fract() == 0.0 => Some(n as JobId),
        _ => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_object_parses_all_value_kinds() {
        let fields = parse_flat_object(
            r#"{"s":"a\"b\\cA","n":-2.5e1,"t":true,"f":false,"z":null}"#,
        )
        .unwrap();
        assert_eq!(fields[0], ("s".into(), JVal::Str("a\"b\\cA".into())));
        assert_eq!(fields[1], ("n".into(), JVal::Num(-25.0)));
        assert_eq!(fields[2], ("t".into(), JVal::Bool(true)));
        assert_eq!(fields[3], ("f".into(), JVal::Bool(false)));
        assert_eq!(fields[4], ("z".into(), JVal::Null));
    }

    #[test]
    fn malformed_lines_are_protocol_errors() {
        for line in [
            "",
            "not json",
            "{\"a\":}",
            "{\"a\":1,}",
            "{\"a\":1} extra",
            "{\"a\":\"unterminated}",
            "{\"op\":\"nope\"}",
            "{\"op\":\"result\"}",
            "{\"steps\":5}",
        ] {
            assert!(
                matches!(parse_request(line), Err(Mc2aError::Protocol(_))),
                "accepted: {line}"
            );
        }
    }

    #[test]
    fn submit_line_round_trips_through_parse_request() {
        let mut spec = JobSpec::new("optsicom");
        spec.steps = 500;
        spec.chains = 4;
        spec.seed = 7;
        spec.beta = 2.5;
        spec.algo = Some(AlgoKind::Pas);
        spec.sampler = SamplerKind::Cdf;
        spec.backend = ServeBackend::Accelerator;
        spec.priority = Priority::High;
        spec.observe_every = 50;
        spec.pas_flips = Some(3);
        spec.trace = true;
        spec.profile = true;
        let parsed = match parse_request(&submit_line(&spec)).unwrap() {
            Request::Submit(s) => s,
            other => panic!("expected submit, got {other:?}"),
        };
        assert_eq!(parsed.workload, "optsicom");
        assert_eq!(parsed.steps, 500);
        assert_eq!(parsed.chains, 4);
        assert_eq!(parsed.seed, 7);
        assert_eq!(parsed.beta, 2.5);
        assert_eq!(parsed.algo, Some(AlgoKind::Pas));
        assert_eq!(parsed.sampler, SamplerKind::Cdf);
        assert_eq!(parsed.backend, ServeBackend::Accelerator);
        assert_eq!(parsed.priority, Priority::High);
        assert_eq!(parsed.observe_every, 50);
        assert_eq!(parsed.pas_flips, Some(3));
        assert!(parsed.trace);
        assert!(parsed.profile);
    }

    #[test]
    fn admin_request_lines_parse() {
        assert!(matches!(parse_request(&metrics_line()), Ok(Request::Metrics)));
        assert!(matches!(parse_request(&stats_line()), Ok(Request::Stats)));
    }

    #[test]
    fn stats_response_carries_aggregates_and_job_summaries() {
        let s = ServerStats {
            jobs_total: 3,
            queued: 1,
            running: 1,
            done: 1,
            threads: 4,
            jobs: vec![JobStatSummary {
                id: 7,
                state: crate::engine::server::JobState::Done,
                r_hat: Some(1.01),
                min_ess: Some(42.5),
                verdict: Some("su-bound"),
                drift_pct: Some(-12.5),
            }],
            ..ServerStats::default()
        };
        let line = ok_stats(&s);
        assert!(response_is_ok(&line));
        assert!(line.contains("\"jobs\":3"), "{line}");
        assert!(line.contains("\"running\":1"), "{line}");
        assert!(line.contains("\"threads\":4"), "{line}");
        assert!(
            line.contains(
                "\"job_stats\":[{\"job\":7,\"state\":\"done\",\"r_hat\":1.01,\
                 \"min_ess\":42.5,\"verdict\":\"su-bound\",\"drift_pct\":-12.5}]"
            ),
            "{line}"
        );
        // A job with nothing to report serializes every summary field
        // as null rather than omitting it.
        let bare = JobStatSummary {
            id: 2,
            state: crate::engine::server::JobState::Running,
            r_hat: None,
            min_ess: None,
            verdict: None,
            drift_pct: None,
        };
        assert!(job_stat_json(&bare).contains("\"r_hat\":null"));
        assert!(job_stat_json(&bare).contains("\"verdict\":null"));
    }

    #[test]
    fn progress_events_carry_rate_and_eta_when_stamped() {
        let mut p = crate::engine::observer::ProgressEvent {
            chain_id: 0,
            step: 50,
            beta: 1.0,
            objective: 1.0,
            best_objective: 1.0,
            updates: 50,
            steps_per_sec: None,
            eta_seconds: None,
        };
        let line = event_line(&StreamEvent::Progress(p));
        assert!(line.contains("\"steps_per_sec\":null"), "{line}");
        assert!(line.contains("\"eta_seconds\":null"), "{line}");
        p.steps_per_sec = Some(250.0);
        p.eta_seconds = Some(0.2);
        let line = event_line(&StreamEvent::Progress(p));
        assert!(line.contains("\"steps_per_sec\":250"), "{line}");
        assert!(line.contains("\"eta_seconds\":0.2"), "{line}");
    }

    #[test]
    fn metrics_response_escapes_newlines() {
        let line = ok_metrics("# TYPE mc2a_x counter\nmc2a_x 1\n");
        assert!(response_is_ok(&line));
        let fields = parse_flat_object(&line).unwrap();
        let body = fields
            .iter()
            .find_map(|(k, v)| match (k.as_str(), v) {
                ("metrics", JVal::Str(s)) => Some(s.clone()),
                _ => None,
            })
            .unwrap();
        assert!(body.contains("# TYPE mc2a_x counter\n"));
    }

    #[test]
    fn simple_request_lines_parse() {
        assert!(matches!(parse_request(&ping_line()), Ok(Request::Ping)));
        assert!(matches!(parse_request(&shutdown_line()), Ok(Request::Shutdown)));
        assert!(matches!(
            parse_request(&status_line(None)),
            Ok(Request::Status { job: None })
        ));
        assert!(matches!(
            parse_request(&status_line(Some(3))),
            Ok(Request::Status { job: Some(3) })
        ));
        assert!(matches!(parse_request(&result_line(9)), Ok(Request::Result { job: 9 })));
        assert!(matches!(parse_request(&cancel_line(9)), Ok(Request::Cancel { job: 9 })));
        assert!(matches!(parse_request(&stream_line(9)), Ok(Request::Stream { job: 9 })));
    }

    #[test]
    fn responses_are_classified() {
        assert!(response_is_ok(&ok_submit(4)));
        assert_eq!(response_job(&ok_submit(4)), Some(4));
        let err = err_line(&Mc2aError::UnknownJob { id: 99 });
        assert!(!response_is_ok(&err));
        assert_eq!(response_kind(&err).as_deref(), Some("unknown-job"));
        let busy = err_line(&Mc2aError::Server("job 3 is not finished (state running)".into()));
        assert_eq!(response_kind(&busy).as_deref(), Some("not-finished"));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(jnum(f64::NEG_INFINITY), "null");
        let done = StreamEvent::Done { state: "done".into(), best_objective: f64::NAN };
        assert!(event_line(&done).contains("\"best\":null"));
    }
}
