//! Typed errors for the engine layer.
//!
//! Library code paths surface failures as [`Mc2aError`] values instead
//! of panicking or calling `process::exit` — only `main.rs` is allowed
//! to terminate the process. The enum is deliberately coarse: each
//! variant is one *class* of failure a caller can meaningfully react
//! to (fix the builder call, list the registry, install artifacts,
//! retry on another backend).

use std::fmt;

/// Everything that can go wrong constructing or running an [`crate::engine::Engine`].
#[derive(Debug)]
#[non_exhaustive]
pub enum Mc2aError {
    /// A builder parameter is invalid (zero chains, zero steps, bad
    /// flag value, mismatched initial-state length, …).
    InvalidConfig(String),
    /// The hardware configuration failed [`crate::isa::HwConfig::validate`].
    InvalidHardware(String),
    /// A compiled ISA program (or shard ensemble) failed static
    /// analysis — the accelerator backends refuse to simulate it.
    /// Carries the error-severity findings; `mc2a check` prints the
    /// full report including warnings and info.
    InvalidProgram {
        /// The error-severity diagnostics that failed the gate.
        diagnostics: Vec<crate::compiler::analysis::Diagnostic>,
    },
    /// The requested workload is not in the registry. `known` lists
    /// every registered name so callers can print the menu.
    UnknownWorkload {
        /// The name that failed to resolve.
        name: String,
        /// All registered workload names.
        known: Vec<String>,
    },
    /// The requested bench is not in the harness. `known` lists every
    /// bench name so callers can print the menu (mirrors
    /// [`Mc2aError::UnknownWorkload`]).
    UnknownBench {
        /// The name that failed to resolve.
        name: String,
        /// All bench names.
        known: Vec<String>,
    },
    /// A checkpoint file could not be written, read, or parsed
    /// (`--save-state` / `--init-from`).
    Checkpoint(String),
    /// The PJRT runtime backend cannot be used (feature disabled, or
    /// the artifact directory is missing/unloadable).
    RuntimeUnavailable(String),
    /// The PJRT runtime failed while executing an artifact.
    Runtime(String),
    /// A chain worker thread panicked.
    ChainPanicked {
        /// Which chain (seed-stream index) died.
        chain_id: usize,
    },
    /// The backend's whole-run coordinator panicked outside any
    /// single chain (e.g. while partitioning work items).
    BackendPanicked,
    /// A job-server operation failed (job directory I/O, waiting
    /// timed out, result requested before the job finished, …).
    Server(String),
    /// A malformed request or response line on the serve/client
    /// newline-delimited JSON protocol.
    Protocol(String),
    /// The job id is not in the server's table.
    UnknownJob {
        /// The id that failed to resolve.
        id: u64,
    },
    /// A `--init-from` checkpoint records a different run shape than
    /// the one requested (workload, sampler, chain count, or model RV
    /// count). Both sides are named so the fix is obvious.
    CheckpointMismatch {
        /// Which property disagrees ("workload", "sampler", "chains",
        /// "model RVs").
        what: String,
        /// The requested run's value.
        run: String,
        /// The checkpoint's recorded value.
        checkpoint: String,
    },
}

impl fmt::Display for Mc2aError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Mc2aError::InvalidConfig(msg) => write!(f, "invalid engine configuration: {msg}"),
            Mc2aError::InvalidHardware(msg) => write!(f, "invalid hardware configuration: {msg}"),
            Mc2aError::InvalidProgram { diagnostics } => {
                let codes: Vec<&str> = diagnostics.iter().map(|d| d.code.as_str()).collect();
                write!(
                    f,
                    "program failed static analysis with {} error(s) [{}]",
                    diagnostics.len(),
                    codes.join(", ")
                )?;
                if let Some(first) = diagnostics.first() {
                    write!(f, ": {}", first.render())?;
                }
                write!(f, " (run `mc2a check` for the full report)")
            }
            Mc2aError::UnknownWorkload { name, known } => {
                write!(f, "unknown workload `{name}`; available: {}", known.join(", "))
            }
            Mc2aError::UnknownBench { name, known } => {
                write!(f, "unknown bench `{name}`; available: {}", known.join(", "))
            }
            Mc2aError::Checkpoint(msg) => write!(f, "checkpoint error: {msg}"),
            Mc2aError::RuntimeUnavailable(msg) => write!(f, "PJRT runtime unavailable: {msg}"),
            Mc2aError::Runtime(msg) => write!(f, "PJRT runtime error: {msg}"),
            Mc2aError::ChainPanicked { chain_id } => {
                write!(f, "chain {chain_id} worker thread panicked")
            }
            Mc2aError::BackendPanicked => {
                write!(f, "backend run coordinator panicked outside any chain")
            }
            Mc2aError::Server(msg) => write!(f, "job server error: {msg}"),
            Mc2aError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            Mc2aError::UnknownJob { id } => write!(f, "unknown job id {id}"),
            Mc2aError::CheckpointMismatch { what, run, checkpoint } => write!(
                f,
                "checkpoint does not match this run: {what} is {run} here but the \
                 checkpoint records {checkpoint} (match the flags the checkpoint was \
                 saved with, or drop --init-from)"
            ),
        }
    }
}

impl std::error::Error for Mc2aError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_lists_registry_on_unknown_workload() {
        let e = Mc2aError::UnknownWorkload {
            name: "nope".into(),
            known: vec!["earthquake".into(), "rbm".into()],
        };
        let s = e.to_string();
        assert!(s.contains("nope") && s.contains("earthquake") && s.contains("rbm"), "{s}");
    }

    #[test]
    fn checkpoint_mismatch_names_both_sides() {
        let e = Mc2aError::CheckpointMismatch {
            what: "sampler".into(),
            run: "cdf".into(),
            checkpoint: "gumbel".into(),
        };
        let s = e.to_string();
        assert!(s.contains("sampler") && s.contains("cdf") && s.contains("gumbel"), "{s}");
    }

    #[test]
    fn invalid_program_display_names_codes() {
        use crate::compiler::analysis::{DiagCode, Diagnostic};
        let e = Mc2aError::InvalidProgram {
            diagnostics: vec![Diagnostic::new(DiagCode::RawHazard, "stale read").at_instr(3)],
        };
        let s = e.to_string();
        assert!(s.contains("MC2A012") && s.contains("mc2a check"), "{s}");
    }

    #[test]
    fn error_trait_object_works() {
        let e: Box<dyn std::error::Error> = Box::new(Mc2aError::ChainPanicked { chain_id: 3 });
        assert!(e.to_string().contains("chain 3"));
    }
}
