//! The lockstep adaptive-annealing driver.
//!
//! Adaptive β control ([`crate::mcmc::anneal`]) needs a feedback loop:
//! the controller consumes *cross-chain* diagnostics (split R-hat /
//! min ESS / best-objective plateau) and re-plans β for the next
//! segment. On the free-running backends chains drift apart, so the
//! diagnostics a chain would see depend on scheduling — and the β
//! trajectory would stop being reproducible. This driver therefore
//! runs the fan-out in **lockstep**: every chain advances exactly one
//! observation segment (`observe_every` steps), the driver computes
//! the round's diagnostics synchronously — with the same
//! [`split_r_hat`] / [`effective_sample_size`] functions the streaming
//! observer reports use — feeds them to the controller, and only then
//! plans the next segment's β values. Decisions are a pure function of
//! the diagnostics sequence, so backends with bit-identical chains
//! (scalar vs batched software) produce bit-identical β trajectories.
//!
//! One [`ExecUnit`] wraps whatever a backend advances per segment: a
//! scalar [`Chain`], an SoA [`ChainBatch`], a single-core
//! [`Simulator`] or a sharded [`MultiCoreSim`] (via their segmented
//! `begin_run` / `advance_run` / `finish_run` APIs). Units advance in
//! parallel (one scoped thread each); everything else happens on the
//! driver thread in deterministic unit order.

use std::time::Instant;

use crate::coordinator::ChainResult;
use crate::energy::{EnergyModel, OpCost};
use crate::engine::backend::{ChainCtx, ChainSpec};
use crate::engine::error::Mc2aError;
use crate::engine::observer::ProgressEvent;
use crate::engine::telemetry;
use crate::isa::Program;
use crate::mcmc::anneal::{BetaController, RoundDiagnostics};
use crate::mcmc::{
    effective_sample_size, split_r_hat, BatchMcmc, Chain, ChainBatch, StepStats,
};
use crate::sim::multicore::McRunState;
use crate::sim::{MultiCoreSim, SimReport, Simulator};

/// Per-chain signals collected at a segment boundary.
pub(crate) struct ChainSignal {
    pub(crate) chain_id: usize,
    pub(crate) objective: f64,
    pub(crate) best: f64,
    pub(crate) updates: u64,
}

/// One lockstep-advanceable executor covering one or more chains.
pub(crate) enum ExecUnit<'m> {
    /// A scalar software chain.
    Scalar {
        chain_id: usize,
        chain: Chain<'m>,
        t0: Instant,
    },
    /// An SoA batch of software chains.
    Batch {
        batch: ChainBatch<'m>,
        algo: Box<dyn BatchMcmc>,
        t0: Instant,
    },
    /// A single-core accelerator simulation.
    Sim {
        chain_id: usize,
        sim: Simulator<'m>,
        program: Program,
        rep: SimReport,
        best: f64,
        t0: Instant,
    },
    /// A sharded multi-core accelerator simulation.
    Multi {
        chain_id: usize,
        sim: MultiCoreSim<'m>,
        run: McRunState,
        best: f64,
        t0: Instant,
    },
}

impl<'m> ExecUnit<'m> {
    pub(crate) fn scalar(chain_id: usize, chain: Chain<'m>) -> ExecUnit<'m> {
        ExecUnit::Scalar {
            chain_id,
            chain,
            t0: Instant::now(),
        }
    }

    pub(crate) fn batch(batch: ChainBatch<'m>, algo: Box<dyn BatchMcmc>) -> ExecUnit<'m> {
        ExecUnit::Batch {
            batch,
            algo,
            t0: Instant::now(),
        }
    }

    pub(crate) fn sim(chain_id: usize, mut sim: Simulator<'m>, program: Program) -> ExecUnit<'m> {
        let rep = sim.begin_run(&program);
        ExecUnit::Sim {
            chain_id,
            sim,
            program,
            rep,
            best: f64::NEG_INFINITY,
            t0: Instant::now(),
        }
    }

    pub(crate) fn multi(chain_id: usize, mut sim: MultiCoreSim<'m>) -> ExecUnit<'m> {
        let run = sim.begin_run();
        ExecUnit::Multi {
            chain_id,
            sim,
            run,
            best: f64::NEG_INFINITY,
            t0: Instant::now(),
        }
    }

    /// Advance every chain of this unit by `n` steps, holding chain
    /// `c` at `betas_by_chain[c]` for the whole segment (indexed by
    /// *global* chain id) — the replica-exchange driver's entry point
    /// ([`crate::engine::tempering`]). Scalar and simulator units hold
    /// one chain; batch units slice their contiguous chain range.
    pub(crate) fn advance_per_chain(&mut self, iter0: usize, n: usize, betas_by_chain: &[f32]) {
        match self {
            ExecUnit::Scalar {
                chain_id, chain, ..
            } => {
                let betas = vec![betas_by_chain[*chain_id]; n];
                chain.run_betas(&betas);
            }
            ExecUnit::Batch { batch, algo, .. } => {
                let first = batch.chain_id(0);
                let k = batch.k();
                batch.run_betas_per_chain(algo.as_mut(), &betas_by_chain[first..first + k], n);
            }
            ExecUnit::Sim {
                chain_id,
                sim,
                program,
                rep,
                ..
            } => {
                let betas = vec![betas_by_chain[*chain_id]; n];
                sim.advance_run(program, rep, iter0, n, Some(&betas), &mut |_, _, _| true);
            }
            ExecUnit::Multi {
                chain_id, sim, run, ..
            } => {
                let betas = vec![betas_by_chain[*chain_id]; n];
                sim.advance_run(run, iter0, n, Some(&betas), &mut |_, _, _| true);
            }
        }
    }

    /// Advance every chain of this unit by `betas.len()` steps, using
    /// `betas[j]` at local segment step `j` (`iter0` is the run-local
    /// step index of the segment start).
    fn advance(&mut self, iter0: usize, betas: &[f32]) {
        match self {
            ExecUnit::Scalar { chain, .. } => chain.run_betas(betas),
            ExecUnit::Batch { batch, algo, .. } => batch.run_betas(algo.as_mut(), betas),
            ExecUnit::Sim {
                sim, program, rep, ..
            } => {
                sim.advance_run(program, rep, iter0, betas.len(), Some(betas), &mut |_, _, _| {
                    true
                });
            }
            ExecUnit::Multi { sim, run, .. } => {
                sim.advance_run(run, iter0, betas.len(), Some(betas), &mut |_, _, _| true);
            }
        }
    }

    /// Collect the segment-boundary signals of every chain this unit
    /// owns, in ascending chain-id order.
    pub(crate) fn signals(&mut self, model: &dyn EnergyModel, out: &mut Vec<ChainSignal>) {
        match self {
            ExecUnit::Scalar {
                chain_id, chain, ..
            } => out.push(ChainSignal {
                chain_id: *chain_id,
                objective: model.objective(&chain.x),
                best: chain.best_objective,
                updates: chain.stats.updates,
            }),
            ExecUnit::Batch { batch, .. } => {
                for c in 0..batch.k() {
                    out.push(ChainSignal {
                        chain_id: batch.chain_id(c),
                        objective: batch.objectives[c],
                        best: batch.best_objectives[c],
                        updates: batch.stats[c].updates,
                    });
                }
            }
            ExecUnit::Sim {
                chain_id,
                sim,
                rep,
                best,
                ..
            } => {
                let objective = model.objective(&sim.x);
                *best = (*best).max(objective);
                out.push(ChainSignal {
                    chain_id: *chain_id,
                    objective,
                    best: *best,
                    updates: rep.updates,
                });
            }
            ExecUnit::Multi {
                chain_id,
                sim,
                best,
                ..
            } => {
                let objective = model.objective(&sim.x);
                *best = (*best).max(objective);
                out.push(ChainSignal {
                    chain_id: *chain_id,
                    objective,
                    best: *best,
                    updates: sim.total_updates(),
                });
            }
        }
    }

    /// Finalize into per-chain results (mirrors each backend's fixed-
    /// path result assembly).
    pub(crate) fn finish(self, model: &dyn EnergyModel, traces: &[Vec<f64>], out: &mut Vec<ChainResult>) {
        match self {
            ExecUnit::Scalar {
                chain_id,
                chain,
                t0,
            } => out.push(ChainResult {
                chain_id,
                best_objective: chain.best_objective,
                steps: chain.step_count,
                stats: chain.stats,
                sim: None,
                multicore: None,
                tempering: None,
                wall: t0.elapsed(),
                marginal0: chain.marginal(0),
                best_x: chain.best_assignment().to_vec(),
                objective_trace: traces[chain_id].clone(),
            }),
            ExecUnit::Batch { batch, t0, .. } => {
                for c in 0..batch.k() {
                    let chain_id = batch.chain_id(c);
                    out.push(ChainResult {
                        chain_id,
                        best_objective: batch.best_objectives[c],
                        steps: batch.step_count,
                        stats: batch.stats[c],
                        sim: None,
                        multicore: None,
                        tempering: None,
                        wall: t0.elapsed(),
                        marginal0: batch.marginal0(c),
                        best_x: batch.best_state(c),
                        objective_trace: traces[chain_id].clone(),
                    });
                }
            }
            ExecUnit::Sim {
                chain_id,
                mut sim,
                mut rep,
                best,
                t0,
                program: _,
            } => {
                sim.finish_run(&mut rep);
                let stats = StepStats {
                    updates: rep.updates,
                    accepted: 0,
                    cost: OpCost {
                        ops: 0,
                        bytes: 4 * (rep.load_words + rep.store_words),
                        samples: rep.samples,
                    },
                };
                let final_objective = model.objective(&sim.x);
                out.push(ChainResult {
                    chain_id,
                    best_objective: best.max(final_objective),
                    steps: rep.iterations as usize,
                    stats,
                    marginal0: sim.marginal(0),
                    best_x: sim.x.clone(),
                    sim: Some(rep),
                    multicore: None,
                    tempering: None,
                    wall: t0.elapsed(),
                    objective_trace: traces[chain_id].clone(),
                });
            }
            ExecUnit::Multi {
                chain_id,
                mut sim,
                run,
                best,
                t0,
            } => {
                let report = sim.finish_run(run);
                let merged = report.merged();
                let stats = StepStats {
                    updates: merged.updates,
                    accepted: 0,
                    cost: OpCost {
                        ops: 0,
                        bytes: 4 * (merged.load_words + merged.store_words),
                        samples: merged.samples,
                    },
                };
                let final_objective = model.objective(&sim.x);
                out.push(ChainResult {
                    chain_id,
                    best_objective: best.max(final_objective),
                    steps: merged.iterations as usize,
                    stats,
                    marginal0: sim.marginal(0),
                    best_x: sim.x.clone(),
                    sim: Some(merged),
                    multicore: Some(report),
                    tempering: None,
                    wall: t0.elapsed(),
                    objective_trace: traces[chain_id].clone(),
                });
            }
        }
    }
}

/// Run `units` to completion (or early stop) under `controller`,
/// in lockstep observation rounds. Returns per-chain results ordered
/// by chain id.
pub(crate) fn run_adaptive<'m>(
    model: &'m dyn EnergyModel,
    spec: &ChainSpec,
    chains: usize,
    ctx: &ChainCtx<'_>,
    controller: &mut dyn BetaController,
    mut units: Vec<ExecUnit<'m>>,
) -> Result<Vec<ChainResult>, Mc2aError> {
    let every = spec.observe_every.max(1);
    let mut traces: Vec<Vec<f64>> = vec![Vec::new(); chains];
    let mut signals: Vec<ChainSignal> = Vec::new();
    let mut best_overall = f64::NEG_INFINITY;
    let mut done = 0usize;
    let mut round = 0usize;
    while done < spec.steps {
        if ctx.stop_requested() {
            break;
        }
        let _round_span = telemetry::span_with("lockstep", || format!("adaptive round {round}"));
        telemetry::metrics().counter_add("lockstep_rounds_total", &[("driver", "adaptive")], 1);
        let n = every.min(spec.steps - done);
        // Plan the segment's β values from the controller's current
        // state; the controller works on the *global* step clock so a
        // resumed run continues the ramp where it stopped.
        let betas: Vec<f32> = (0..n)
            .map(|j| controller.beta_at(spec.beta_offset + done + j))
            .collect();
        if units.len() > 1 {
            let betas = &betas;
            std::thread::scope(|scope| {
                for unit in units.iter_mut() {
                    scope.spawn(move || unit.advance(done, betas));
                }
            });
        } else if let Some(unit) = units.first_mut() {
            unit.advance(done, &betas);
        }
        done += n;
        round += 1;
        // Segment boundary: gather signals in deterministic order,
        // stream progress events, close the observation round.
        signals.clear();
        for unit in units.iter_mut() {
            unit.signals(model, &mut signals);
        }
        let last_beta = betas[n - 1];
        for s in &signals {
            traces[s.chain_id].push(s.objective);
            best_overall = best_overall.max(s.best);
            ctx.emit(ProgressEvent {
                chain_id: s.chain_id,
                step: done,
                beta: last_beta,
                objective: s.objective,
                best_objective: s.best,
                updates: s.updates,
                steps_per_sec: None,
                eta_seconds: None,
            });
        }
        let r_hat = if chains >= 2 {
            split_r_hat(&traces)
        } else {
            None
        };
        let min_ess = traces
            .iter()
            .map(|t| effective_sample_size(t))
            .fold(f64::INFINITY, f64::min);
        controller.observe_round(&RoundDiagnostics {
            round,
            step: spec.beta_offset + done,
            r_hat,
            min_ess,
            best_objective: best_overall,
        });
    }
    let mut results = Vec::with_capacity(chains);
    for unit in units {
        unit.finish(model, &traces, &mut results);
    }
    results.sort_by_key(|r| r.chain_id);
    Ok(results)
}
