//! Per-run measured-roofline profiling (the observation layer over
//! [`crate::roofline::observe`]).
//!
//! Off by default, like [`super::telemetry`]: when enabled (the
//! `--profile` CLI flag, the `profile` field on a server job, or
//! [`set_enabled`] from library code) the engine projects each
//! finished run onto the paper's 3-axis roofline as a
//! [`RooflineObservation`] — measured GS/s, measured intensities, a
//! boundedness verdict, and the drift against the a-priori
//! [`crate::roofline::evaluate`] / [`crate::roofline::evaluate_multicore`]
//! prediction. Everything here consumes *already-finished*
//! [`ChainResult`]s: profiling never touches an RNG stream, a float
//! reduction order, or a chain's hot loop, so results with profiling
//! on are bit-identical to results with it off (pinned by
//! `tests/integration_telemetry.rs`).
//!
//! The sim backends are observed in the *cycle domain* (deterministic:
//! the same run always measures the same GS/s); the software backends
//! fall back to wall-clock, where drift against the accelerator
//! roofline is expected to be large and run-to-run noisy.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::compiler::analysis::{self, DiagCode};
use crate::coordinator::ChainResult;
use crate::energy::EnergyModel;
use crate::engine::telemetry;
use crate::graph::partition_balanced;
use crate::isa::{HwConfig, MultiHwConfig};
use crate::mcmc::{AlgoKind, SamplerKind};
use crate::roofline::observe::{classify_cycles, DriftReport, MeasuredBoundedness};
use crate::roofline::{self, MeasuredCounters, RooflineObservation, WorkloadProfile};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn run profiling on or off (process-wide, off by default).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// True when run profiling is on — a single relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Sum the measured counters over a run's finished chains.
///
/// Multi-core chains contribute their barrier-aligned per-core
/// reports (so `cycles` is the full C × makespan core-cycle budget)
/// and their makespan seconds; single-core sim chains contribute
/// their report directly; every chain contributes its
/// `OpCost`-domain op/byte/sample totals.
pub fn accumulate(chains: &[ChainResult], hw: &HwConfig, wall: Duration) -> MeasuredCounters {
    let mut c = MeasuredCounters {
        wall_seconds: wall.as_secs_f64(),
        ..MeasuredCounters::default()
    };
    for ch in chains {
        c.updates += ch.stats.updates;
        c.ops += ch.stats.cost.ops;
        c.bytes += ch.stats.cost.bytes;
        c.samples += ch.stats.cost.samples;
        if let Some(mc) = &ch.multicore {
            for r in &mc.per_core {
                add_sim_cycles(&mut c, r);
            }
            c.sim_seconds += mc.cycles as f64 / (hw.clock_ghz * 1e9);
        } else if let Some(rep) = &ch.sim {
            add_sim_cycles(&mut c, rep);
            c.sim_seconds += rep.seconds(hw);
        }
    }
    c
}

fn add_sim_cycles(c: &mut MeasuredCounters, r: &crate::sim::SimReport) {
    c.cycles += r.cycles;
    c.cu_busy += r.cu_busy;
    c.su_busy += r.su_busy;
    c.mem_busy += r.mem_busy;
    c.stall_mem_bw += r.stall_mem_bw;
    c.stall_bank += r.stall_bank;
    c.stall_sync += r.stall_sync;
    c.stall_xbar += r.stall_xbar;
    c.xfer_words += r.xfer_words;
}

/// Project one finished run onto the measured roofline.
///
/// `sim_hw` is the hardware the backend simulated
/// ([`crate::engine::ExecutionBackend::sim_hw`]); wall-clock backends
/// pass `None` and are compared against the paper-default config. On
/// multi-core hardware the prediction is
/// [`roofline::evaluate_multicore`] at the partitioner's measured
/// boundary fraction, and the interconnect verdict is cross-checked
/// against `compiler::analysis`'s MC2A023 (crossbar + barrier time
/// exceeding compute time) prediction.
#[allow(clippy::too_many_arguments)]
pub fn observe_run(
    workload: &str,
    model: &dyn EnergyModel,
    algo: AlgoKind,
    sampler: SamplerKind,
    pas_flips: usize,
    backend_name: &str,
    sim_hw: Option<MultiHwConfig>,
    chains: &[ChainResult],
    steps: usize,
    wall: Duration,
) -> RooflineObservation {
    let mhw = sim_hw.unwrap_or_else(|| MultiHwConfig::new(HwConfig::paper_default(), 1));
    let hw = mhw.core;
    let counters = accumulate(chains, &hw, wall);
    let w = WorkloadProfile::from_model(model, algo);

    // The a-priori side: single-core envelope, capped by the shared
    // crossbar at the partitioner's boundary fraction when C > 1.
    let single = roofline::evaluate(&hw, &w);
    let (predicted_gsps, predicted_verdict) = if mhw.cores > 1 {
        let g = model.interaction();
        let bf = partition_balanced(g, mhw.cores).boundary_fraction(g);
        let mp = roofline::evaluate_multicore(&mhw, &w, bf);
        let verdict = if mp.interconnect_bound {
            MeasuredBoundedness::InterconnectBound
        } else {
            MeasuredBoundedness::from_predicted(mp.single.bottleneck)
        };
        (mp.tp_gsps, verdict)
    } else {
        (
            single.tp_gsps,
            MeasuredBoundedness::from_predicted(single.bottleneck),
        )
    };

    // The measured side: deterministic cycle domain when the backend
    // simulated, wall-clock otherwise.
    let cycle_domain = counters.has_cycles();
    let (measured_gsps, verdict, utils) = if cycle_domain {
        let gsps = if counters.sim_seconds > 0.0 {
            counters.samples as f64 / counters.sim_seconds / 1e9
        } else {
            0.0
        };
        let total = counters.cycles as f64;
        let utils = (
            Some(counters.cu_busy as f64 / total),
            Some(counters.su_busy as f64 / total),
            Some((counters.mem_busy + counters.stall_mem_bw + counters.stall_bank) as f64 / total),
            Some((counters.stall_sync + counters.stall_xbar) as f64 / total),
        );
        (gsps, classify_cycles(&counters), utils)
    } else {
        let gsps = if counters.wall_seconds > 0.0 {
            counters.samples as f64 / counters.wall_seconds / 1e9
        } else {
            0.0
        };
        // No cycle breakdown exists off-sim; attribute boundedness by
        // projecting the *measured* intensities onto the roofs (which
        // roof would this run hit first on the modeled hardware).
        let measured_w = WorkloadProfile {
            ci: counters.measured_ci().unwrap_or(w.ci),
            mi: counters.measured_mi().unwrap_or(w.mi),
            ..w
        };
        let p = roofline::evaluate(&hw, &measured_w);
        let verdict = MeasuredBoundedness::from_predicted(p.bottleneck);
        (gsps, verdict, (None, None, None, None))
    };

    // MC2A023 cross-check: does static analysis also expect the
    // interconnect to dominate at this (hardware, partition) point?
    let xbar_predicted_bound = if mhw.cores > 1 {
        analysis::analyze_ensemble(model, algo, &mhw, pas_flips)
            .ok()
            .map(|r| {
                r.diagnostics
                    .iter()
                    .any(|d| d.code == DiagCode::XbarSyncBound)
            })
    } else {
        None
    };

    let obs = RooflineObservation {
        workload: workload.to_string(),
        backend: backend_name.to_string(),
        algo: algo.name().to_string(),
        sampler: sampler.name().to_string(),
        chains: chains.len(),
        steps,
        cores: mhw.cores,
        samples: counters.samples,
        updates: counters.updates,
        wall_seconds: counters.wall_seconds,
        measured_gsps,
        measured_ci: counters.measured_ci(),
        measured_mi: counters.measured_mi(),
        cycle_domain,
        verdict,
        cu_util: utils.0,
        su_util: utils.1,
        mem_util: utils.2,
        interconnect_frac: utils.3,
        drift: DriftReport::new(predicted_gsps, measured_gsps, predicted_verdict, verdict),
        xbar_predicted_bound,
    };
    publish_gauges(&obs);
    obs
}

/// Mirror an observation into the Prometheus registry (no-op while
/// telemetry is disabled): measured/predicted GS/s, the signed drift,
/// and a boundedness gauge whose label names the verdict so a scrape
/// can alert when measurement diverges from the model.
pub fn publish_gauges(obs: &RooflineObservation) {
    let m = telemetry::metrics();
    if !m.enabled() {
        return;
    }
    let base = [
        ("workload", obs.workload.as_str()),
        ("backend", obs.backend.as_str()),
    ];
    m.gauge_set("roofline_measured_gsps", &base, obs.measured_gsps);
    m.gauge_set("roofline_predicted_gsps", &base, obs.drift.predicted_gsps);
    m.gauge_set("roofline_drift_pct", &base, obs.drift.drift_pct);
    m.gauge_set(
        "roofline_drift_agree",
        &base,
        if obs.drift.agree { 1.0 } else { 0.0 },
    );
    m.gauge_set(
        "roofline_boundedness",
        &[
            ("workload", obs.workload.as_str()),
            ("backend", obs.backend.as_str()),
            ("verdict", obs.verdict.name()),
        ],
        obs.verdict.code(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunMetrics;
    use crate::engine::Engine;

    fn run_workload(sim: bool) -> (Engine<'static>, RunMetrics) {
        let mut b = Engine::for_workload("earthquake").unwrap();
        b = b.steps(12).chains(2).seed(7);
        if sim {
            b = b.accelerator(HwConfig::paper_default());
        }
        let mut engine = b.build().unwrap();
        let metrics = engine.run().unwrap();
        (engine, metrics)
    }

    fn observe(engine: &Engine<'_>, metrics: &RunMetrics, wall: Duration) -> RooflineObservation {
        observe_run(
            engine.workload_name().unwrap_or("model"),
            engine.model(),
            engine.spec().algo,
            engine.spec().sampler,
            engine.spec().pas_flips,
            engine.backend_name(),
            engine.backend_sim_hw(),
            &metrics.chains,
            engine.spec().steps,
            wall,
        )
    }

    #[test]
    fn sim_observation_is_cycle_domain_and_under_the_roof() {
        let (engine, metrics) = run_workload(true);
        let obs = observe(&engine, &metrics, metrics.wall);
        assert!(obs.cycle_domain);
        assert_eq!(obs.backend, "accelerator");
        assert_eq!(obs.cores, 1);
        assert!(obs.samples > 0);
        assert!(obs.measured_gsps > 0.0, "{obs:?}");
        // The roofline is an upper bound; the cycle-accurate sim can
        // approach but never beat it (generous slack for rounding).
        assert!(
            obs.measured_gsps <= obs.drift.predicted_gsps * 1.05,
            "measured {} > predicted {}",
            obs.measured_gsps,
            obs.drift.predicted_gsps
        );
        assert!(obs.drift.drift_pct <= 5.0);
        // Utilization fractions exist and are sane.
        for u in [obs.cu_util, obs.su_util, obs.mem_util, obs.interconnect_frac] {
            let u = u.expect("cycle-domain run must carry utilizations");
            assert!((0.0..=1.0).contains(&u), "{u}");
        }
        // Single core: no interconnect cross-check applies.
        assert_eq!(obs.xbar_predicted_bound, None);
        // Deterministic: re-observing the same chains under a
        // different wall clock reproduces the cycle-domain numbers.
        let again = observe(&engine, &metrics, Duration::from_millis(999));
        assert_eq!(again.measured_gsps, obs.measured_gsps);
        assert_eq!(again.verdict, obs.verdict);
        assert_eq!(again.drift.drift_pct, obs.drift.drift_pct);
    }

    #[test]
    fn software_observation_is_wall_domain() {
        let (engine, metrics) = run_workload(false);
        let obs = observe(&engine, &metrics, metrics.wall);
        assert!(!obs.cycle_domain);
        assert_eq!(obs.backend, "software");
        assert!(obs.samples > 0);
        assert!(obs.measured_ci.is_some(), "software path has op accounting");
        assert!(obs.cu_util.is_none());
        let j = obs.to_json();
        assert!(j.contains("\"cycle_domain\":false"), "{j}");
    }
}
