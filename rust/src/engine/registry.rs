//! The workload registry: `name → constructor + metadata`.
//!
//! Replaces the hard-coded `match` the CLI used to carry. Each entry
//! names one Table I workload, its aliases, a one-line summary, and a
//! constructor. [`lookup`] resolves names case-insensitively and
//! reports failures as [`Mc2aError::UnknownWorkload`] carrying the full
//! menu, so callers (the CLI in particular) can print what *is*
//! available instead of dying in a usage dump.

use crate::engine::error::Mc2aError;
use crate::workloads::{self, Workload};

/// One registered workload.
pub struct WorkloadEntry {
    /// Canonical lookup name (lowercase).
    pub name: &'static str,
    /// Accepted aliases (lowercase).
    pub aliases: &'static [&'static str],
    /// One-line description for the CLI listing.
    pub summary: &'static str,
    /// Construction or a 10-step run is expensive (full-scale models);
    /// fast regression sweeps should skip these.
    pub heavy: bool,
    ctor: fn() -> Workload,
}

impl WorkloadEntry {
    /// Construct the workload.
    pub fn build(&self) -> Workload {
        (self.ctor)()
    }
}

fn build_imageseg_small() -> Workload {
    workloads::wl_image_seg(false)
}

fn build_imageseg_full() -> Workload {
    workloads::wl_image_seg(true)
}

/// Every registered workload (the Table I suite).
pub const REGISTRY: &[WorkloadEntry] = &[
    WorkloadEntry {
        name: "earthquake",
        aliases: &[],
        summary: "Earthquake Bayes net (5 nodes, Block Gibbs)",
        heavy: false,
        ctor: workloads::wl_earthquake,
    },
    WorkloadEntry {
        name: "survey",
        aliases: &[],
        summary: "Survey Bayes net (6 nodes, Block Gibbs)",
        heavy: false,
        ctor: workloads::wl_survey,
    },
    WorkloadEntry {
        name: "cancer",
        aliases: &[],
        summary: "Cancer Bayes net (5 nodes, Block Gibbs)",
        heavy: false,
        ctor: workloads::wl_cancer,
    },
    WorkloadEntry {
        name: "alarm",
        aliases: &[],
        summary: "Alarm Bayes net (37 nodes, Block Gibbs)",
        heavy: false,
        ctor: workloads::wl_alarm,
    },
    WorkloadEntry {
        name: "imageseg",
        aliases: &[],
        summary: "64×64 image-segmentation MRF (Block Gibbs)",
        heavy: false,
        ctor: build_imageseg_small,
    },
    WorkloadEntry {
        name: "imageseg-full",
        aliases: &[],
        summary: "Table I-scale 150k-node segmentation MRF (Block Gibbs)",
        heavy: true,
        ctor: build_imageseg_full,
    },
    WorkloadEntry {
        name: "er700",
        aliases: &["mis"],
        summary: "ER-1347 Maximum Independent Set (PAS)",
        heavy: false,
        ctor: workloads::wl_mis_er,
    },
    WorkloadEntry {
        name: "twitter",
        aliases: &["maxclique"],
        summary: "Twitter-247 MaxClique (PAS)",
        heavy: false,
        ctor: workloads::wl_maxclique_twitter,
    },
    WorkloadEntry {
        name: "optsicom",
        aliases: &["maxcut"],
        summary: "Optsicom-125 weighted MaxCut (PAS)",
        heavy: false,
        ctor: workloads::wl_maxcut_optsicom,
    },
    WorkloadEntry {
        name: "rbm",
        aliases: &[],
        summary: "Binary RBM 784×25 EBM (PAS)",
        heavy: false,
        ctor: workloads::wl_rbm,
    },
];

/// Find an entry by name or alias (case-insensitive).
pub fn find(name: &str) -> Option<&'static WorkloadEntry> {
    let q = name.to_ascii_lowercase();
    REGISTRY
        .iter()
        .find(|e| e.name == q || e.aliases.contains(&q.as_str()))
}

/// All canonical registry names, in registration order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Build the named workload, or report the full menu on failure.
pub fn lookup(name: &str) -> Result<Workload, Mc2aError> {
    match find(name) {
        Some(e) => Ok(e.build()),
        None => Err(Mc2aError::UnknownWorkload {
            name: name.to_string(),
            known: names().iter().map(|s| s.to_string()).collect(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_and_aliases_resolve() {
        assert!(find("earthquake").is_some());
        assert!(find("EARTHQUAKE").is_some());
        assert!(find("mis").is_some());
        assert!(find("MaxCut").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn lookup_error_carries_menu() {
        match lookup("bogus") {
            Err(Mc2aError::UnknownWorkload { name, known }) => {
                assert_eq!(name, "bogus");
                assert!(known.iter().any(|n| n == "earthquake"));
                assert_eq!(known.len(), REGISTRY.len());
            }
            Ok(_) => panic!("expected UnknownWorkload, got a workload"),
            Err(e) => panic!("expected UnknownWorkload, got {e}"),
        }
    }

    #[test]
    fn registry_names_are_unique_and_lowercase() {
        let ns = names();
        for (i, a) in ns.iter().enumerate() {
            assert_eq!(*a, a.to_ascii_lowercase());
            assert!(!ns[i + 1..].contains(a), "duplicate name {a}");
        }
    }
}
