//! Streaming chain observers: periodic progress callbacks, cross-chain
//! convergence diagnostics (split R-hat / ESS from
//! [`crate::mcmc::metrics`]) and cooperative early stopping.
//!
//! Backends emit a [`ProgressEvent`] every `observe_every` steps; the
//! engine funnels all chains' events into one coordinating thread,
//! which maintains per-chain objective traces, computes a
//! [`DiagnosticsReport`] once per completed observation round, and
//! forwards both to the run's [`ChainObserver`]. Returning
//! [`ObserverAction::Stop`] from any callback raises the shared stop
//! flag, and every chain exits at its next observation boundary.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::ChainResult;
use crate::mcmc::{effective_sample_size, split_r_hat};

/// One periodic progress sample from a running chain.
#[derive(Clone, Copy, Debug)]
pub struct ProgressEvent {
    /// Chain id (seed-stream index).
    pub chain_id: usize,
    /// Steps completed so far on this chain.
    pub step: usize,
    /// Inverse temperature at the last completed step.
    pub beta: f32,
    /// Objective of the *current* state (the diagnostics trace signal).
    pub objective: f64,
    /// Best objective seen so far on this chain.
    pub best_objective: f64,
    /// Cumulative RV updates on this chain.
    pub updates: u64,
    /// Observed sampling rate on this chain, stamped by the engine's
    /// coordinating thread from segment timestamps; `None` on the very
    /// first observation (no elapsed baseline yet).
    pub steps_per_sec: Option<f64>,
    /// Remaining-time estimate for this chain in seconds, derived from
    /// `steps_per_sec` and the run's step budget.
    pub eta_seconds: Option<f64>,
}

/// Cross-chain convergence snapshot, computed once per observation
/// round (i.e. whenever every live chain has reported `round` events).
#[derive(Clone, Copy, Debug)]
pub struct DiagnosticsReport {
    /// Observation round index (1-based).
    pub round: usize,
    /// Steps per chain at this round.
    pub step: usize,
    /// Split potential-scale-reduction over the per-chain objective
    /// traces; `None` until there are ≥ 2 chains with ≥ 4 observations.
    pub r_hat: Option<f64>,
    /// Smallest per-chain effective sample size of the objective trace.
    pub min_ess: f64,
    /// Best objective across all chains so far.
    pub best_objective: f64,
}

/// What the observer wants the run to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObserverAction {
    /// Keep sampling.
    Continue,
    /// Raise the stop flag: all chains halt at the next boundary.
    Stop,
}

/// Streaming callbacks for one engine run. All methods are invoked on
/// the engine's coordinating thread, in event order, so implementations
/// may hold plain mutable state.
pub trait ChainObserver: Send {
    /// Called for every periodic progress sample from every chain.
    fn on_progress(&mut self, _event: &ProgressEvent) -> ObserverAction {
        ObserverAction::Continue
    }

    /// Called once per completed observation round with cross-chain
    /// convergence diagnostics.
    fn on_diagnostics(&mut self, _report: &DiagnosticsReport) -> ObserverAction {
        ObserverAction::Continue
    }

    /// Called after a chain finishes (normally or via early stop).
    fn on_chain_done(&mut self, _result: &ChainResult) {}
}

/// No-op observer (the default when none is configured).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl ChainObserver for NullObserver {}

/// Observer that logs progress and diagnostics lines to stderr — the
/// CLI's `--observe N` mode.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrintObserver;

impl ChainObserver for PrintObserver {
    fn on_progress(&mut self, e: &ProgressEvent) -> ObserverAction {
        let pace = match (e.steps_per_sec, e.eta_seconds) {
            (Some(rate), Some(eta)) => format!("  {rate:.0} steps/s  eta {eta:.1}s"),
            _ => String::new(),
        };
        eprintln!(
            "[chain {}] step {:>8}  beta {:.3}  objective {:.3}  best {:.3}{pace}",
            e.chain_id, e.step, e.beta, e.objective, e.best_objective
        );
        ObserverAction::Continue
    }

    fn on_diagnostics(&mut self, d: &DiagnosticsReport) -> ObserverAction {
        match d.r_hat {
            Some(r) => eprintln!(
                "[diag] round {:>4} step {:>8}  R-hat {:.4}  min ESS {:.1}  best {:.3}",
                d.round, d.step, r, d.min_ess, d.best_objective
            ),
            None => eprintln!(
                "[diag] round {:>4} step {:>8}  R-hat n/a  min ESS {:.1}  best {:.3}",
                d.round, d.step, d.min_ess, d.best_objective
            ),
        }
        ObserverAction::Continue
    }
}

/// Observer that stops the run once split R-hat falls to the target —
/// adaptive chain length instead of a fixed step budget.
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceStop {
    /// Stop when R-hat ≤ this value (1.01 is a common threshold).
    pub r_hat_target: f64,
    /// Require at least this many observation rounds first.
    pub min_rounds: usize,
}

impl ChainObserver for ConvergenceStop {
    fn on_diagnostics(&mut self, d: &DiagnosticsReport) -> ObserverAction {
        match d.r_hat {
            Some(r) if d.round >= self.min_rounds && r <= self.r_hat_target => {
                ObserverAction::Stop
            }
            _ => ObserverAction::Continue,
        }
    }
}

/// One item on an [`EventStream`]: the union of everything a run can
/// report while it is alive, plus a terminal marker.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// A periodic per-chain progress sample.
    Progress(ProgressEvent),
    /// A completed cross-chain observation round.
    Diagnostics(DiagnosticsReport),
    /// The job reached a terminal state; no further events follow on
    /// this stream. Emitted by [`crate::engine::server::JobServer`]
    /// streams; plain engine runs end by disconnect instead (the
    /// observer is dropped, so [`EventStream::recv`] returns `None`).
    Done {
        /// Terminal state name ("done", "cancelled", "failed").
        state: String,
        /// Best objective across all chains at the end.
        best_objective: f64,
    },
}

/// Receiving half of a diagnostics stream: a pull-based alternative to
/// implementing [`ChainObserver`]. Create one with [`event_stream`],
/// pass the paired [`ChannelObserver`] to
/// [`crate::engine::EngineBuilder::observer`] (or get one from
/// [`crate::engine::server::JobServer::stream`]), then drain events
/// from any thread.
pub struct EventStream {
    rx: mpsc::Receiver<StreamEvent>,
}

impl EventStream {
    /// Block until the next event; `None` once the producer is gone
    /// (after `Done`, or if the run was dropped).
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Like [`EventStream::recv`] with a deadline; `None` on timeout
    /// or disconnect.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Drain whatever is queued right now without blocking.
    pub fn drain(&self) -> Vec<StreamEvent> {
        self.rx.try_iter().collect()
    }
}

impl Iterator for &EventStream {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        self.recv()
    }
}

/// Observer that forwards every event into an [`EventStream`]. Send
/// failures (the stream was dropped) are ignored — an abandoned
/// listener must not stop the run.
pub struct ChannelObserver {
    tx: mpsc::Sender<StreamEvent>,
}

impl ChainObserver for ChannelObserver {
    fn on_progress(&mut self, e: &ProgressEvent) -> ObserverAction {
        let _ = self.tx.send(StreamEvent::Progress(*e));
        ObserverAction::Continue
    }

    fn on_diagnostics(&mut self, d: &DiagnosticsReport) -> ObserverAction {
        let _ = self.tx.send(StreamEvent::Diagnostics(*d));
        ObserverAction::Continue
    }
}

/// Build a connected ([`ChannelObserver`], [`EventStream`]) pair.
pub fn event_stream() -> (ChannelObserver, EventStream) {
    let (tx, rx) = mpsc::channel();
    (ChannelObserver { tx }, EventStream { rx })
}

/// Stream with a bare sender — for producers (the job server) that
/// push [`StreamEvent`]s directly instead of going through the
/// [`ChainObserver`] trait.
pub(crate) fn raw_stream() -> (mpsc::Sender<StreamEvent>, EventStream) {
    let (tx, rx) = mpsc::channel();
    (tx, EventStream { rx })
}

/// Per-run diagnostics bookkeeping: accumulates each chain's objective
/// trace and emits a [`DiagnosticsReport`] whenever a new round (one
/// observation from every chain) completes.
pub(crate) struct DiagnosticsTracker {
    traces: Vec<Vec<f64>>,
    rounds_reported: usize,
    best: f64,
}

impl DiagnosticsTracker {
    pub(crate) fn new(chains: usize) -> DiagnosticsTracker {
        DiagnosticsTracker {
            traces: vec![Vec::new(); chains],
            rounds_reported: 0,
            best: f64::NEG_INFINITY,
        }
    }

    /// Record one progress event; returns a report if it completed a
    /// round. Events with an out-of-range chain id (a misbehaving
    /// custom backend) are ignored rather than panicking the run.
    pub(crate) fn record(&mut self, e: &ProgressEvent) -> Option<DiagnosticsReport> {
        self.traces.get_mut(e.chain_id)?.push(e.objective);
        self.best = self.best.max(e.best_objective);
        let round = self.traces.iter().map(Vec::len).min().unwrap_or(0);
        if round <= self.rounds_reported {
            return None;
        }
        self.rounds_reported = round;
        let r_hat = if self.traces.len() >= 2 {
            split_r_hat(&self.traces)
        } else {
            None
        };
        let min_ess = self
            .traces
            .iter()
            .map(|t| effective_sample_size(t))
            .fold(f64::INFINITY, f64::min);
        Some(DiagnosticsReport {
            round,
            step: e.step,
            r_hat,
            min_ess,
            best_objective: self.best,
        })
    }
}

/// Per-run rate bookkeeping: stamps [`ProgressEvent::steps_per_sec`]
/// and [`ProgressEvent::eta_seconds`] on the coordinating thread from
/// segment timestamps, preferring the slope of the last observation
/// segment over the cumulative average once a per-chain baseline
/// exists. A pure event annotation — chain math never sees it.
pub(crate) struct RateTracker {
    total_steps: usize,
    start: Instant,
    last: HashMap<usize, (Instant, usize)>,
}

impl RateTracker {
    pub(crate) fn new(total_steps: usize) -> RateTracker {
        RateTracker {
            total_steps,
            start: Instant::now(),
            last: HashMap::new(),
        }
    }

    /// Annotate one event in place with rate + ETA when a positive,
    /// finite rate can be derived; leaves the fields `None` otherwise
    /// (e.g. sub-timer-resolution segments).
    pub(crate) fn stamp(&mut self, e: &mut ProgressEvent) {
        let now = Instant::now();
        let rate = match self.last.get(&e.chain_id) {
            // A baseline exists: segment slope when the chain advanced,
            // nothing for a stalled segment (no zero/infinite rates).
            Some(&(t0, s0)) if e.step > s0 => {
                let dt = now.duration_since(t0).as_secs_f64();
                if dt > 0.0 {
                    Some((e.step - s0) as f64 / dt)
                } else {
                    None
                }
            }
            Some(_) => None,
            // First observation of this chain: cumulative average
            // since the run started.
            None => {
                let dt = now.duration_since(self.start).as_secs_f64();
                if dt > 0.0 && e.step > 0 {
                    Some(e.step as f64 / dt)
                } else {
                    None
                }
            }
        };
        self.last.insert(e.chain_id, (now, e.step));
        if let Some(rate) = rate.filter(|r| r.is_finite() && *r > 0.0) {
            e.steps_per_sec = Some(rate);
            e.eta_seconds = Some(self.total_steps.saturating_sub(e.step) as f64 / rate);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(chain_id: usize, step: usize, objective: f64) -> ProgressEvent {
        ProgressEvent {
            chain_id,
            step,
            beta: 1.0,
            objective,
            best_objective: objective,
            updates: step as u64,
            steps_per_sec: None,
            eta_seconds: None,
        }
    }

    #[test]
    fn tracker_reports_once_per_complete_round() {
        let mut t = DiagnosticsTracker::new(2);
        assert!(t.record(&ev(0, 10, 1.0)).is_none());
        let d = t.record(&ev(1, 10, 2.0)).expect("round 1 complete");
        assert_eq!(d.round, 1);
        assert_eq!(d.best_objective, 2.0);
        // Second event from the same chain does not complete round 2.
        assert!(t.record(&ev(1, 20, 3.0)).is_none());
        let d = t.record(&ev(0, 20, 1.5)).expect("round 2 complete");
        assert_eq!(d.round, 2);
        assert_eq!(d.best_objective, 3.0);
    }

    #[test]
    fn rate_tracker_stamps_rate_and_eta_from_segments() {
        let mut rate = RateTracker::new(100);
        std::thread::sleep(Duration::from_millis(5));
        let mut first = ev(0, 40, 1.0);
        rate.stamp(&mut first);
        let r = first.steps_per_sec.expect("cumulative baseline rate");
        assert!(r > 0.0 && r.is_finite());
        let eta = first.eta_seconds.expect("eta from rate");
        assert!((eta - 60.0 / r).abs() < 1e-9, "eta covers remaining steps");

        // Second observation on the same chain uses the segment slope.
        std::thread::sleep(Duration::from_millis(5));
        let mut second = ev(0, 80, 1.0);
        rate.stamp(&mut second);
        assert!(second.steps_per_sec.is_some());

        // A stalled chain (no step advance) keeps the fields unset
        // rather than reporting an infinite or zero rate.
        let mut stalled = ev(0, 80, 1.0);
        rate.stamp(&mut stalled);
        assert!(stalled.steps_per_sec.is_none());
        assert!(stalled.eta_seconds.is_none());
    }

    #[test]
    fn rate_tracker_keeps_per_chain_baselines() {
        let mut rate = RateTracker::new(50);
        std::thread::sleep(Duration::from_millis(5));
        let mut a = ev(0, 10, 1.0);
        let mut b = ev(1, 10, 1.0);
        rate.stamp(&mut a);
        rate.stamp(&mut b);
        // Both chains got a cumulative-baseline stamp; neither chain's
        // state interfered with the other's.
        assert!(a.steps_per_sec.is_some());
        assert!(b.steps_per_sec.is_some());
        // ETA never goes negative once a chain overshoots the budget.
        std::thread::sleep(Duration::from_millis(5));
        let mut over = ev(1, 60, 1.0);
        rate.stamp(&mut over);
        assert_eq!(over.eta_seconds, Some(0.0));
    }

    #[test]
    fn convergence_stop_waits_for_min_rounds() {
        let mut obs = ConvergenceStop {
            r_hat_target: 1.05,
            min_rounds: 3,
        };
        let converged = |round| DiagnosticsReport {
            round,
            step: round * 10,
            r_hat: Some(1.0),
            min_ess: 50.0,
            best_objective: 0.0,
        };
        assert_eq!(obs.on_diagnostics(&converged(1)), ObserverAction::Continue);
        assert_eq!(obs.on_diagnostics(&converged(3)), ObserverAction::Stop);
    }

    #[test]
    fn event_stream_forwards_and_ends_on_drop() {
        let (mut obs, stream) = event_stream();
        assert_eq!(obs.on_progress(&ev(0, 10, 1.0)), ObserverAction::Continue);
        match stream.recv() {
            Some(StreamEvent::Progress(p)) => assert_eq!(p.step, 10),
            other => panic!("expected progress, got {other:?}"),
        }
        drop(obs);
        assert!(stream.recv().is_none(), "stream ends when observer drops");
    }

    #[test]
    fn abandoned_stream_does_not_stop_the_run() {
        let (mut obs, stream) = event_stream();
        drop(stream);
        assert_eq!(obs.on_progress(&ev(0, 10, 1.0)), ObserverAction::Continue);
    }

    #[test]
    fn recv_timeout_expires_on_an_idle_stream_without_closing_it() {
        let (mut obs, stream) = event_stream();
        // Nothing queued: the deadline elapses and we get None back,
        // but the channel is still connected and usable afterwards.
        assert!(stream.recv_timeout(Duration::from_millis(10)).is_none());
        obs.on_progress(&ev(0, 10, 1.0));
        match stream.recv_timeout(Duration::from_secs(5)) {
            Some(StreamEvent::Progress(p)) => assert_eq!(p.step, 10),
            other => panic!("expected progress after timeout, got {other:?}"),
        }
    }

    #[test]
    fn drain_after_sender_drop_returns_buffered_events_then_empty() {
        let (mut obs, stream) = event_stream();
        obs.on_progress(&ev(0, 10, 1.0));
        obs.on_progress(&ev(1, 10, 2.0));
        drop(obs);
        // Buffered events survive the sender; drain returns them all
        // in send order, and a second drain on the now-disconnected
        // stream is empty rather than an error.
        let events = stream.drain();
        assert_eq!(events.len(), 2);
        match (&events[0], &events[1]) {
            (StreamEvent::Progress(a), StreamEvent::Progress(b)) => {
                assert_eq!(a.chain_id, 0);
                assert_eq!(b.chain_id, 1);
            }
            other => panic!("expected two progress events, got {other:?}"),
        }
        assert!(stream.drain().is_empty());
        assert!(stream.recv().is_none());
    }

    #[test]
    fn done_arrives_after_all_progress_sent_before_it() {
        // Server-style producer: progress events, then a terminal Done
        // pushed on the same channel. mpsc is FIFO, so a consumer must
        // see every earlier progress event before the Done marker.
        let (tx, stream) = raw_stream();
        tx.send(StreamEvent::Progress(ev(0, 10, 1.0))).unwrap();
        tx.send(StreamEvent::Progress(ev(0, 20, 2.0))).unwrap();
        tx.send(StreamEvent::Done {
            state: "done".into(),
            best_objective: 2.0,
        })
        .unwrap();
        drop(tx);
        let events: Vec<StreamEvent> = (&stream).collect();
        assert_eq!(events.len(), 3);
        assert!(matches!(&events[0], StreamEvent::Progress(p) if p.step == 10));
        assert!(matches!(&events[1], StreamEvent::Progress(p) if p.step == 20));
        match &events[2] {
            StreamEvent::Done { state, best_objective } => {
                assert_eq!(state, "done");
                assert_eq!(*best_objective, 2.0);
            }
            other => panic!("expected Done last, got {other:?}"),
        }
        assert!(stream.recv().is_none(), "nothing follows Done + drop");
    }
}
