//! Work scheduling: the run-scoped work-stealing pool and the
//! process-scoped multi-job pool.
//!
//! Two schedulers live here, one per lifetime:
//!
//! * [`run_stealing`] — **run-scoped**: multiplexes a static set of
//!   work items over a pool of OS threads spawned for one call. Items
//!   are dealt round-robin into per-worker deques, each worker drains
//!   its own deque front-to-back and, when empty, steals from the
//!   *back* of a victim's deque. Large items (e.g. a straggler batch
//!   on a slow core) therefore migrate to idle workers instead of
//!   serializing the tail of the run — the classic Blumofe–Leiserson
//!   discipline, here with mutex-guarded deques (items are coarse —
//!   whole chain batches — so queue operations are nowhere near the
//!   contention point). This is what lets the batched backend run
//!   1024 chains on 8 cores with 8 threads instead of 1024.
//!
//! * [`WorkPool`] — **process-scoped**: a fixed worker set that
//!   outlives any single run and multiplexes tasks from *many jobs*
//!   ([`crate::engine::server::JobServer`]). Every task carries a
//!   [`TaskTag`] (job id + priority class); the pool always serves the
//!   highest non-empty priority class and, within a class, deals tasks
//!   round-robin *across jobs* (fair-share at task granularity), so a
//!   100-task job cannot starve a 2-task neighbor of the same class.
//!   Queued tasks of one job can be purged ([`WorkPool::cancel_job`])
//!   without touching its already-running tasks.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::engine::telemetry;

/// Run every item of `items` exactly once on a pool of `threads`
/// workers. `f` receives `(worker_index, item)` and must be safe to
/// call concurrently from distinct workers.
///
/// Panics in `f` propagate to the caller once all workers have joined
/// (the scope unwinds); callers that need per-item fault isolation
/// wrap `f` in `catch_unwind` themselves.
pub fn run_stealing<I, F>(threads: usize, items: Vec<I>, f: F)
where
    I: Send,
    F: Fn(usize, I) + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    let deques: Vec<Mutex<VecDeque<I>>> = (0..threads)
        .map(|_| Mutex::new(VecDeque::new()))
        .collect();
    for (j, item) in items.into_iter().enumerate() {
        deques[j % threads].lock().unwrap().push_back(item);
    }
    if threads == 1 {
        // Inline fast path: no reason to spawn for a single worker.
        while let Some(item) = deques[0].lock().unwrap().pop_front() {
            f(0, item);
        }
        return;
    }
    std::thread::scope(|scope| {
        for w in 0..threads {
            let deques = &deques;
            let f = &f;
            scope.spawn(move || loop {
                // Own work first (front: FIFO for locality of the
                // round-robin deal)…
                let own = deques[w].lock().unwrap().pop_front();
                if let Some(item) = own {
                    f(w, item);
                    continue;
                }
                // …then steal from a victim's back. The item set is
                // static, so a full empty scan means we are done.
                let mut stolen = None;
                for v in 1..threads {
                    let victim = (w + v) % threads;
                    if let Some(item) = deques[victim].lock().unwrap().pop_back() {
                        telemetry::metrics().counter_add("scheduler_steals_total", &[], 1);
                        stolen = Some(item);
                        break;
                    }
                }
                match stolen {
                    Some(item) => f(w, item),
                    None => break,
                }
            });
        }
    });
}

/// Identity of a pool task: which job it belongs to and how urgent
/// that job is. Higher `class` values are served strictly first
/// (see [`crate::engine::server::Priority`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskTag {
    /// Owning job id; tasks with the same id share one fair-share slot.
    pub job: u64,
    /// Priority class (higher runs first).
    pub class: u8,
}

type PoolTask = Box<dyn FnOnce() + Send + 'static>;

/// Tasks of one priority class: a round-robin rotation of job ids plus
/// each job's FIFO of pending tasks. Invariant: `rotation` holds a job
/// id exactly once iff that job has at least one queued task.
#[derive(Default)]
struct ClassQueue {
    rotation: VecDeque<u64>,
    tasks: HashMap<u64, VecDeque<PoolTask>>,
}

#[derive(Default)]
struct PoolQueue {
    /// class → queue; `BTreeMap` so workers can scan classes
    /// highest-first.
    classes: BTreeMap<u8, ClassQueue>,
    shutdown: bool,
}

impl PoolQueue {
    fn push(&mut self, tag: TaskTag, task: PoolTask) {
        let cq = self.classes.entry(tag.class).or_default();
        match cq.tasks.get_mut(&tag.job) {
            Some(dq) => dq.push_back(task),
            None => {
                cq.tasks.insert(tag.job, VecDeque::from([task]));
                cq.rotation.push_back(tag.job);
            }
        }
    }

    /// Next task: highest non-empty class, round-robin across its jobs.
    fn pop_next(&mut self) -> Option<(u8, PoolTask)> {
        let class = *self.classes.iter().rev().find(|(_, cq)| !cq.rotation.is_empty())?.0;
        let cq = self.classes.get_mut(&class).expect("class just found");
        let job = cq.rotation.pop_front().expect("rotation non-empty");
        let dq = cq.tasks.get_mut(&job).expect("rotation invariant");
        let task = dq.pop_front().expect("rotation invariant");
        if dq.is_empty() {
            cq.tasks.remove(&job);
        } else {
            cq.rotation.push_back(job);
        }
        if cq.rotation.is_empty() {
            self.classes.remove(&class);
        }
        Some((class, task))
    }

    /// Tasks still queued in one priority class.
    fn class_depth(&self, class: u8) -> usize {
        self.classes
            .get(&class)
            .map(|cq| cq.tasks.values().map(VecDeque::len).sum())
            .unwrap_or(0)
    }

    fn purge_job(&mut self, job: u64) -> usize {
        let mut purged = 0;
        for cq in self.classes.values_mut() {
            if let Some(dq) = cq.tasks.remove(&job) {
                purged += dq.len();
                cq.rotation.retain(|j| *j != job);
            }
        }
        self.classes.retain(|_, cq| !cq.rotation.is_empty());
        purged
    }

    fn pending(&self) -> usize {
        self.classes
            .values()
            .flat_map(|cq| cq.tasks.values())
            .map(VecDeque::len)
            .sum()
    }
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    available: Condvar,
}

/// Process-scoped worker pool with job-tagged tasks: spawned once,
/// shared by every job a [`crate::engine::server::JobServer`] accepts
/// over its lifetime. Scheduling is strict-priority across classes and
/// round-robin across jobs within a class; see the module docs.
///
/// Dropping the pool (or calling [`WorkPool::shutdown`]) abandons
/// still-queued tasks, lets running tasks finish, and joins the
/// workers. A task that panics is contained to that task; the worker
/// thread survives.
pub struct WorkPool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    threads: usize,
}

impl WorkPool {
    /// Spawn a pool of `threads` workers (min 1).
    pub fn new(threads: usize) -> WorkPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue::default()),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mc2a-pool-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkPool {
            shared,
            workers: Mutex::new(workers),
            threads,
        }
    }

    /// Worker count the pool was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue one task under `tag`. Tasks submitted after
    /// [`WorkPool::shutdown`] are dropped silently (the closure's
    /// destructor runs; the body never does).
    pub fn submit(&self, tag: TaskTag, task: impl FnOnce() + Send + 'static) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return;
            }
            q.push(tag, Box::new(task));
            if telemetry::enabled() {
                telemetry::metrics().gauge_set(
                    "pool_queue_depth",
                    &[("class", &tag.class.to_string())],
                    q.class_depth(tag.class) as f64,
                );
            }
        }
        self.shared.available.notify_one();
    }

    /// Drop every *queued* task of `job` (running tasks are untouched;
    /// the caller stops those through its own job-level flag). Returns
    /// how many tasks were purged — the caller needs the exact count
    /// to settle its completion accounting.
    pub fn cancel_job(&self, job: u64) -> usize {
        self.shared.queue.lock().unwrap().purge_job(job)
    }

    /// Tasks queued but not yet started, across all jobs.
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().pending()
    }

    /// Stop accepting work, abandon the queue, finish running tasks,
    /// and join every worker. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            q.classes.clear(); // queued tasks are dropped, not run
        }
        self.shared.available.notify_all();
        let workers: Vec<JoinHandle<()>> = self.workers.lock().unwrap().drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some((class, t)) = q.pop_next() {
                    if telemetry::enabled() {
                        telemetry::metrics().gauge_set(
                            "pool_queue_depth",
                            &[("class", &class.to_string())],
                            q.class_depth(class) as f64,
                        );
                        telemetry::metrics().counter_add(
                            "pool_tasks_total",
                            &[("class", &class.to_string())],
                            1,
                        );
                    }
                    break Some((class, t));
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match task {
            // A panicking task must not take the worker (and with it
            // every future job) down; the owning job maps the panic to
            // a typed error through its own bookkeeping.
            Some((class, t)) => {
                let _span = telemetry::span_with("pool", || format!("pool task (class {class})"));
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(t));
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn every_item_runs_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        run_stealing(4, (0..100).collect(), |_w, i: usize| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn idle_workers_steal_the_straggler_tail() {
        // One long item plus many short ones: with stealing, the short
        // items must all complete even though they were dealt to the
        // worker stuck on the long one.
        let done = AtomicUsize::new(0);
        let items: Vec<u64> = std::iter::once(30u64).chain(std::iter::repeat(1).take(20)).collect();
        run_stealing(2, items, |_w, ms| {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 21);
    }

    #[test]
    fn single_thread_and_empty_sets_are_fine() {
        let done = AtomicUsize::new(0);
        run_stealing(1, vec![1, 2, 3], |_w, _i: i32| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 3);
        run_stealing(8, Vec::<i32>::new(), |_w, _i| unreachable!());
    }

    #[test]
    fn worker_indices_are_in_range() {
        run_stealing(3, (0..32).collect(), |w, _i: usize| assert!(w < 3));
    }

    #[test]
    fn uneven_task_costs_all_complete() {
        // Costs cycle through 0..17ms with no structure aligned to the
        // round-robin deal: every item must still run exactly once.
        let hits: Vec<AtomicUsize> = (0..40).map(|_| AtomicUsize::new(0)).collect();
        run_stealing(4, (0..40).collect(), |_w, i: usize| {
            std::thread::sleep(Duration::from_millis((i * 3 % 17) as u64));
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn more_tasks_than_threads_spread_across_workers() {
        // 48 tasks on 3 threads, the first one a straggler: workers 1
        // and 2 must drain their own deques (and steal worker 0's tail)
        // while worker 0 sleeps — so at least two distinct worker
        // indices appear, and no index exceeds the pool size.
        let by_worker: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        let done = AtomicUsize::new(0);
        run_stealing(3, (0..48).collect(), |w, i: usize| {
            if i == 0 {
                std::thread::sleep(Duration::from_millis(40));
            }
            by_worker[w].fetch_add(1, Ordering::Relaxed);
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 48);
        let active = by_worker.iter().filter(|c| c.load(Ordering::Relaxed) > 0).count();
        assert!(active >= 2, "no stealing happened: {by_worker:?}");
    }

    #[test]
    fn results_are_independent_of_thread_count() {
        // The determinism pin behind the process-scoped lift: per-item
        // work depends only on the item, so any thread count yields
        // bit-identical outputs.
        use std::sync::atomic::AtomicU64;
        let compute = |i: u64| {
            // xorshift64* — cheap, but wrong anywhere the item id leaks
            // scheduling state into the value.
            let mut x = i.wrapping_add(0x9E3779B97F4A7C15);
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            x.wrapping_mul(0x2545F4914F6CDD1D)
        };
        let run = |threads: usize| -> Vec<u64> {
            let out: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
            run_stealing(threads, (0..64).collect(), |_w, i: u64| {
                out[i as usize].store(compute(i), Ordering::Relaxed);
            });
            out.into_iter().map(|a| a.into_inner()).collect()
        };
        let single = run(1);
        assert_eq!(run(3), single);
        assert_eq!(run(8), single);
    }

    /// Gate that holds the pool's single worker busy so tests can
    /// stage a queue deterministically before anything else runs.
    fn gated_pool() -> (WorkPool, mpsc::Sender<()>) {
        let pool = WorkPool::new(1);
        let (open, gate) = mpsc::channel::<()>();
        pool.submit(TaskTag { job: u64::MAX, class: 255 }, move || {
            let _ = gate.recv();
        });
        // Make sure the worker picked the gate up before callers queue
        // behind it.
        while pool.pending() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        (pool, open)
    }

    #[test]
    fn pool_serves_higher_priority_class_first() {
        let (pool, open) = gated_pool();
        let (tx, rx) = mpsc::channel::<u64>();
        for job in [1u64, 2, 3] {
            let tx = tx.clone();
            pool.submit(TaskTag { job, class: 0 }, move || tx.send(job).unwrap());
        }
        let tx_hi = tx.clone();
        pool.submit(TaskTag { job: 9, class: 2 }, move || tx_hi.send(9).unwrap());
        open.send(()).unwrap();
        let order: Vec<u64> = (0..4).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(order[0], 9, "high-priority task did not jump the queue: {order:?}");
    }

    #[test]
    fn pool_round_robins_jobs_within_a_class() {
        let (pool, open) = gated_pool();
        let (tx, rx) = mpsc::channel::<u64>();
        // Job 1 enqueues all three tasks before job 2 shows up; fair
        // sharing must still interleave them 1,2,1,2,… once both wait.
        for job in [1u64, 1, 1, 2, 2, 2] {
            let tx = tx.clone();
            pool.submit(TaskTag { job, class: 1 }, move || tx.send(job).unwrap());
        }
        open.send(()).unwrap();
        let order: Vec<u64> = (0..6).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2], "not fair-shared: {order:?}");
    }

    #[test]
    fn pool_cancel_purges_only_the_target_job() {
        let (pool, open) = gated_pool();
        let (tx, rx) = mpsc::channel::<u64>();
        for job in [1u64, 1, 2, 1] {
            let tx = tx.clone();
            pool.submit(TaskTag { job, class: 1 }, move || tx.send(job).unwrap());
        }
        assert_eq!(pool.cancel_job(1), 3);
        assert_eq!(pool.pending(), 1);
        open.send(()).unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        // All of job 1's tasks are gone: the channel drains empty once
        // job 2's lone task is through.
        drop(tx);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn pool_task_panic_does_not_kill_the_worker() {
        let pool = WorkPool::new(1);
        let (tx, rx) = mpsc::channel::<u32>();
        pool.submit(TaskTag { job: 1, class: 1 }, || panic!("task bug"));
        pool.submit(TaskTag { job: 2, class: 1 }, move || tx.send(7).unwrap());
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap(), 7);
        pool.shutdown();
    }

    #[test]
    fn pool_shutdown_drops_queued_tasks_and_joins() {
        let (pool, open) = gated_pool();
        let ran = Arc::new(AtomicUsize::new(0));
        for job in 1..=4u64 {
            let ran = Arc::clone(&ran);
            pool.submit(TaskTag { job, class: 1 }, move || {
                ran.fetch_add(1, Ordering::Relaxed);
            });
        }
        open.send(()).unwrap();
        pool.shutdown();
        // The gate task was running; everything queued behind it may or
        // may not have started before the shutdown flag landed, but
        // after shutdown() returns nothing runs anymore.
        let settled = ran.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ran.load(Ordering::Relaxed), settled);
        assert_eq!(pool.pending(), 0);
    }
}
