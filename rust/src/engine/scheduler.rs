//! A minimal work-stealing scheduler for chain work items.
//!
//! [`run_stealing`] multiplexes a static set of work items over a
//! fixed pool of OS threads: items are dealt round-robin into
//! per-worker deques, each worker drains its own deque front-to-back
//! and, when empty, steals from the *back* of a victim's deque. Large
//! items (e.g. a straggler batch on a slow core) therefore migrate to
//! idle workers instead of serializing the tail of the run — the
//! classic Blumofe–Leiserson discipline, here with mutex-guarded
//! deques (items are coarse — whole chain batches — so queue
//! operations are nowhere near the contention point).
//!
//! This is what lets the batched backend run 1024 chains on 8 cores
//! with 8 threads instead of 1024.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Run every item of `items` exactly once on a pool of `threads`
/// workers. `f` receives `(worker_index, item)` and must be safe to
/// call concurrently from distinct workers.
///
/// Panics in `f` propagate to the caller once all workers have joined
/// (the scope unwinds); callers that need per-item fault isolation
/// wrap `f` in `catch_unwind` themselves.
pub fn run_stealing<I, F>(threads: usize, items: Vec<I>, f: F)
where
    I: Send,
    F: Fn(usize, I) + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    let deques: Vec<Mutex<VecDeque<I>>> = (0..threads)
        .map(|_| Mutex::new(VecDeque::new()))
        .collect();
    for (j, item) in items.into_iter().enumerate() {
        deques[j % threads].lock().unwrap().push_back(item);
    }
    if threads == 1 {
        // Inline fast path: no reason to spawn for a single worker.
        while let Some(item) = deques[0].lock().unwrap().pop_front() {
            f(0, item);
        }
        return;
    }
    std::thread::scope(|scope| {
        for w in 0..threads {
            let deques = &deques;
            let f = &f;
            scope.spawn(move || loop {
                // Own work first (front: FIFO for locality of the
                // round-robin deal)…
                let own = deques[w].lock().unwrap().pop_front();
                if let Some(item) = own {
                    f(w, item);
                    continue;
                }
                // …then steal from a victim's back. The item set is
                // static, so a full empty scan means we are done.
                let mut stolen = None;
                for v in 1..threads {
                    let victim = (w + v) % threads;
                    if let Some(item) = deques[victim].lock().unwrap().pop_back() {
                        stolen = Some(item);
                        break;
                    }
                }
                match stolen {
                    Some(item) => f(w, item),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_item_runs_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        run_stealing(4, (0..100).collect(), |_w, i: usize| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn idle_workers_steal_the_straggler_tail() {
        // One long item plus many short ones: with stealing, the short
        // items must all complete even though they were dealt to the
        // worker stuck on the long one.
        let done = AtomicUsize::new(0);
        let items: Vec<u64> = std::iter::once(30u64).chain(std::iter::repeat(1).take(20)).collect();
        run_stealing(2, items, |_w, ms| {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 21);
    }

    #[test]
    fn single_thread_and_empty_sets_are_fine() {
        let done = AtomicUsize::new(0);
        run_stealing(1, vec![1, 2, 3], |_w, _i: i32| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 3);
        run_stealing(8, Vec::<i32>::new(), |_w, _i| unreachable!());
    }

    #[test]
    fn worker_indices_are_in_range() {
        run_stealing(3, (0..32).collect(), |w, _i: usize| assert!(w < 3));
    }
}
