//! Pluggable execution backends.
//!
//! [`ExecutionBackend`] replaces the old closed `coordinator::Backend`
//! enum: a backend receives a *(model, chain spec, chain id)* triple
//! plus a [`ChainCtx`] (stop flag + event channel) and returns one
//! [`ChainResult`]. The engine fans chains out across OS threads and
//! shares one backend instance between them, so implementations are
//! `Send + Sync` and keep per-chain state on the stack.
//!
//! Five backends ship with the crate:
//!
//! * [`SoftwareBackend`] — the pure-Rust reference chains, one OS
//!   thread per chain,
//! * [`crate::engine::BatchedSoftwareBackend`] — structure-of-arrays
//!   chain batches multiplexed over a fixed work-stealing thread pool,
//! * [`AcceleratorBackend`] — compile to the MC²A VLIW ISA and run the
//!   cycle-accurate simulator, evaluating the β schedule once per
//!   HWLOOP iteration,
//! * [`MultiCoreAcceleratorBackend`] — the sharded C-core MC²A system
//!   (§II-D): one model partitioned across C pipelines that sync at
//!   color-class barriers and share a crossbar + histogram memory,
//! * [`RuntimeBackend`] — the AOT-JAX/PJRT measured-software path,
//!   available when the crate is built with the `xla-runtime` feature
//!   and the artifact directory exists.
//!
//! A backend implements per-chain execution ([`ExecutionBackend::run_chain`])
//! and may override the whole-run entry point
//! ([`ExecutionBackend::run_chains`], default: one OS thread per
//! chain) to control its own scheduling — that is how the batched
//! backend replaces thread-per-chain fan-out without touching any
//! call site. Future sharded / multi-node backends plug in through
//! [`crate::engine::EngineBuilder::backend`] the same way.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::compiler::{analysis, compile_opt};
use crate::coordinator::ChainResult;
use crate::energy::{EnergyModel, OpCost};
use crate::engine::adaptive::{run_adaptive, ExecUnit};
use crate::engine::error::Mc2aError;
use crate::engine::observer::ProgressEvent;
use crate::engine::tempering::run_tempered;
use crate::isa::{HwConfig, MultiHwConfig, Program};
use crate::mcmc::anneal::BetaController;
use crate::mcmc::tempering::ReplicaExchange;
use crate::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind, StepStats};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::{MultiCoreSim, Simulator};

/// Backend-agnostic description of one chain run (the successor of the
/// old `coordinator::RunSpec`, built by [`crate::engine::EngineBuilder`]).
#[derive(Clone, Debug)]
pub struct ChainSpec {
    /// Algorithm to run.
    pub algo: AlgoKind,
    /// Categorical sampler backing the software algorithms.
    pub sampler: SamplerKind,
    /// β (inverse-temperature) schedule, stepped every MCMC step.
    pub schedule: BetaSchedule,
    /// Global-step offset of the schedule clock: a resumed run
    /// evaluates β at `beta_offset + t` (the checkpoint's cumulative
    /// step count), so the annealing ramp continues instead of
    /// restarting at t = 0. See [`ChainSpec::beta`].
    pub beta_offset: usize,
    /// Steps per chain.
    pub steps: usize,
    /// Base RNG seed; chain `i` draws from the stream
    /// [`Rng::fork`]`(seed, i)` (see [`ChainSpec::chain_rng`]).
    pub seed: u64,
    /// PAS path length (ignored by other algorithms).
    pub pas_flips: usize,
    /// Emit a progress event every this many steps.
    pub observe_every: usize,
    /// Optional shared initial assignment (defaults to random).
    pub init_state: Option<Vec<u32>>,
}

impl ChainSpec {
    /// The RNG stream for chain `chain_id`: a pure function of
    /// `(seed, chain_id)`, so chains are bit-identical regardless of
    /// thread count, batch size, or backend.
    pub fn chain_rng(&self, chain_id: usize) -> Rng {
        Rng::fork(self.seed, chain_id as u64)
    }

    /// Raw 64-bit seed for chain `chain_id` — for components that
    /// seed their own generator (the simulator's URNG).
    pub fn chain_seed(&self, chain_id: usize) -> u64 {
        Rng::fork_seed(self.seed, chain_id as u64)
    }

    /// β at run-local step `t`: the schedule evaluated on the global
    /// clock (`beta_offset + t`). Every backend's fixed-ramp path
    /// evaluates β through this helper so checkpoint resume continues
    /// the ramp uniformly.
    pub fn beta(&self, t: usize) -> f32 {
        self.schedule.beta(self.beta_offset + t)
    }
}

/// Cold-chain restart signal (see
/// [`crate::engine::EngineBuilder::restart_on_stagnation`]): the
/// engine's diagnostics loop bumps the epoch when split R-hat stays
/// above threshold for K consecutive observer rounds, and software
/// chains poll it at observation boundaries — on a new epoch a chain
/// re-forks its RNG stream and restarts from its best state so far.
#[derive(Debug, Default)]
pub struct RestartSignal {
    epoch: AtomicUsize,
}

impl RestartSignal {
    /// Current restart epoch (0 = never triggered).
    pub fn epoch(&self) -> usize {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Request a restart: every polling chain re-forks once.
    pub fn trigger(&self) {
        self.epoch.fetch_add(1, Ordering::Relaxed);
    }
}

/// Run context handed to backends: the engine's shared stop flag and
/// a clone of the progress-event channel. One context serves a whole
/// run; backends clone it per worker thread. (The observation cadence
/// lives on [`ChainSpec::observe_every`].)
#[derive(Clone)]
pub struct ChainCtx<'a> {
    /// Cooperative early-stop flag; backends poll it at observation
    /// boundaries and exit early when raised.
    pub stop: &'a AtomicBool,
    /// Progress sink (None when the run has no observer loop).
    pub events: Option<Sender<ProgressEvent>>,
    /// Cold-chain restart signal (None unless enabled on the builder).
    /// Honored by the scalar software chain runner; other backends
    /// ignore it.
    pub restart: Option<&'a RestartSignal>,
}

impl ChainCtx<'_> {
    /// True when the engine (or an observer) requested a stop.
    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Send one progress event (ignored when nobody listens).
    pub fn emit(&self, event: ProgressEvent) {
        if let Some(tx) = &self.events {
            let _ = tx.send(event);
        }
    }
}

/// Where and how chains execute. Implementations are shared across
/// the engine's worker threads.
pub trait ExecutionBackend: Send + Sync {
    /// Short backend name for reports ("software", "batched", …).
    fn name(&self) -> &'static str;

    /// The hardware point this backend *simulates*, when it is a
    /// cycle-accurate simulator (`engine::profile` evaluates the
    /// matching roofline prediction against it). `None` on wall-clock
    /// backends, which are profiled against the paper-default config.
    fn sim_hw(&self) -> Option<MultiHwConfig> {
        None
    }

    /// Run one chain to completion (or early stop) and report it.
    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError>;

    /// Run the whole fan-out under an adaptive β controller
    /// ([`crate::mcmc::anneal`]): all chains advance in lockstep
    /// observation rounds and the controller re-plans β from each
    /// round's cross-chain diagnostics (see
    /// [`crate::engine::EngineBuilder::adaptive`]). The default
    /// rejects the configuration; the software, batched and
    /// accelerator-simulator backends override it via the shared
    /// lockstep driver.
    fn run_chains_adaptive(
        &self,
        _model: &dyn EnergyModel,
        _spec: &ChainSpec,
        _chains: usize,
        _ctx: &ChainCtx<'_>,
        _controller: &mut dyn BetaController,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        Err(Mc2aError::InvalidConfig(format!(
            "the {} backend does not support adaptive annealing",
            self.name()
        )))
    }

    /// Run the whole fan-out under replica exchange (parallel
    /// tempering, [`crate::mcmc::tempering`]): chains advance in
    /// lockstep to each swap boundary, where the per-ensemble
    /// [`ReplicaExchange`] controllers propose even/odd neighbor
    /// temperature swaps from the chains' cached energies (see
    /// [`crate::engine::EngineBuilder::tempering`]). The default
    /// rejects the configuration; the software, batched and
    /// accelerator-simulator backends override it via the shared
    /// lockstep driver.
    fn run_chains_tempered(
        &self,
        _model: &dyn EnergyModel,
        _spec: &ChainSpec,
        _chains: usize,
        _ctx: &ChainCtx<'_>,
        _exchanges: &mut [ReplicaExchange],
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        Err(Mc2aError::InvalidConfig(format!(
            "the {} backend does not support replica exchange (parallel tempering)",
            self.name()
        )))
    }

    /// Run the whole fan-out: chains `0..chains`, results ordered by
    /// chain id. The default spawns one OS thread per chain — correct
    /// everywhere, but a backend that schedules chains itself (the
    /// batched backend's work-stealing pool) overrides this to decouple
    /// chain count from thread count.
    fn run_chains(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let joined: Vec<Result<ChainResult, Mc2aError>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..chains)
                .map(|chain_id| {
                    let ctx = ctx.clone();
                    scope.spawn(move || self.run_chain(model, spec, chain_id, &ctx))
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(chain_id, h)| {
                    h.join()
                        .unwrap_or_else(|_| Err(Mc2aError::ChainPanicked { chain_id }))
                })
                .collect()
        });
        joined.into_iter().collect()
    }
}

/// Run one scalar software chain — shared by [`SoftwareBackend`] and
/// the batched backend's fallback path for algorithms without a
/// batched kernel (PAS, Async Gibbs), so both produce bit-identical
/// chains.
pub(crate) fn run_software_chain(
    model: &dyn EnergyModel,
    spec: &ChainSpec,
    chain_id: usize,
    ctx: &ChainCtx<'_>,
) -> Result<ChainResult, Mc2aError> {
    let t0 = Instant::now();
    let mut chain = software_chain(model, spec, chain_id);
    let every = spec.observe_every.max(1);
    let mut trace = Vec::new();
    let mut done = 0usize;
    let mut seen_epoch = 0usize;
    while done < spec.steps {
        if ctx.stop_requested() {
            break;
        }
        // Cold-chain restart: on a new epoch, re-fork the RNG stream
        // (epoch-disambiguated so the chain explores fresh trajectories)
        // and restart from the best assignment found so far.
        if let Some(signal) = ctx.restart {
            let epoch = signal.epoch();
            if epoch > seen_epoch {
                seen_epoch = epoch;
                let best = chain.best_assignment().to_vec();
                chain.reseed(Rng::fork(spec.seed, chain_id as u64 + ((epoch as u64) << 32)));
                chain.set_state(&best);
            }
        }
        let n = every.min(spec.steps - done);
        chain.run(n);
        done += n;
        let objective = model.objective(&chain.x);
        trace.push(objective);
        ctx.emit(ProgressEvent {
            chain_id,
            step: done,
            beta: spec.beta(done - 1),
            objective,
            best_objective: chain.best_objective,
            updates: chain.stats.updates,
            steps_per_sec: None,
            eta_seconds: None,
        });
    }
    Ok(ChainResult {
        chain_id,
        best_objective: chain.best_objective,
        steps: chain.step_count,
        stats: chain.stats,
        sim: None,
        multicore: None,
        tempering: None,
        wall: t0.elapsed(),
        marginal0: chain.marginal(0),
        best_x: chain.best_assignment().to_vec(),
        objective_trace: trace,
    })
}

/// Pure-Rust software chains (the reference implementation),
/// thread-per-chain.
#[derive(Clone, Copy, Debug, Default)]
pub struct SoftwareBackend;

impl ExecutionBackend for SoftwareBackend {
    fn name(&self) -> &'static str {
        "software"
    }

    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        run_software_chain(model, spec, chain_id, ctx)
    }

    fn run_chains_adaptive(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        controller: &mut dyn BetaController,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let units = (0..chains)
            .map(|chain_id| ExecUnit::scalar(chain_id, software_chain(model, spec, chain_id)))
            .collect();
        run_adaptive(model, spec, chains, ctx, controller, units)
    }

    fn run_chains_tempered(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        exchanges: &mut [ReplicaExchange],
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let units = (0..chains)
            .map(|chain_id| ExecUnit::scalar(chain_id, software_chain(model, spec, chain_id)))
            .collect();
        run_tempered(model, spec, chains, ctx, exchanges, units)
    }
}

/// Construct one scalar software chain exactly as the fixed-ramp
/// runner does (same seeding, init-state and offset sequence), so the
/// adaptive driver's chains stay bit-compatible with the fixed path.
pub(crate) fn software_chain<'m>(
    model: &'m dyn EnergyModel,
    spec: &ChainSpec,
    chain_id: usize,
) -> Chain<'m> {
    let algo = build_algo(spec.algo, spec.sampler, model, spec.pas_flips);
    let mut chain = Chain::with_rng(model, algo, spec.schedule, spec.chain_rng(chain_id));
    if let Some(x0) = &spec.init_state {
        chain.set_state(x0);
    }
    chain.set_step_offset(spec.beta_offset);
    chain
}

/// The cycle-accurate MC²A accelerator simulator: compile the workload
/// to the VLIW ISA, then run it with the β schedule stepped once per
/// HWLOOP iteration.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorBackend {
    hw: HwConfig,
    optimize: bool,
    corrupt: Option<fn(&mut Program)>,
}

impl AcceleratorBackend {
    /// Backend for `hw` with the VLIW load/compute fusion optimizer on
    /// (the production compiler path).
    pub fn new(hw: HwConfig) -> AcceleratorBackend {
        AcceleratorBackend { hw, optimize: true, corrupt: None }
    }

    /// Toggle the compiler optimizer (the §Perf ablation knob).
    pub fn with_optimization(mut self, optimize: bool) -> AcceleratorBackend {
        self.optimize = optimize;
        self
    }

    /// Test-only hook: mutate the compiled program before the static-
    /// analysis gate, proving corrupted programs are rejected with
    /// [`Mc2aError::InvalidProgram`] before they reach the simulator.
    #[doc(hidden)]
    pub fn with_corrupt_hook(mut self, f: fn(&mut Program)) -> AcceleratorBackend {
        self.corrupt = Some(f);
        self
    }

    /// The hardware configuration this backend simulates.
    pub fn hw(&self) -> &HwConfig {
        &self.hw
    }

    /// Compile, apply the test hook, and run the static-analysis gate.
    fn compile_gated(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
    ) -> Result<Program, Mc2aError> {
        let mut program = compile_opt(model, spec.algo, &self.hw, spec.pas_flips, self.optimize)?;
        if let Some(f) = self.corrupt {
            f(&mut program);
        }
        analysis::gate_program(&program, model, &self.hw, spec.algo)?;
        Ok(program)
    }
}

impl ExecutionBackend for AcceleratorBackend {
    fn name(&self) -> &'static str {
        "accelerator"
    }

    fn sim_hw(&self) -> Option<MultiHwConfig> {
        Some(MultiHwConfig::new(self.hw, 1))
    }

    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        let t0 = Instant::now();
        let program = self.compile_gated(model, spec)?;
        let mut sim = Simulator::new(self.hw, model, spec.pas_flips, spec.chain_seed(chain_id));
        if let Some(x0) = &spec.init_state {
            sim.x.copy_from_slice(x0);
        }
        let every = spec.observe_every.max(1);
        let mut trace = Vec::new();
        let mut best = f64::NEG_INFINITY;
        let mut rep = sim.begin_run(&program);
        // β evaluated on the global clock so a resumed run continues
        // the ramp; planned one observation segment at a time so the
        // buffer stays O(observe_every), not O(steps).
        let mut betas: Vec<f32> = Vec::with_capacity(every.min(spec.steps));
        let mut done = 0usize;
        let mut go = true;
        while go && done < spec.steps {
            let n = every.min(spec.steps - done);
            betas.clear();
            betas.extend((done..done + n).map(|t| spec.beta(t)));
            go = sim.advance_run(
                &program,
                &mut rep,
                done,
                n,
                Some(&betas),
                &mut |iter, rep_so_far, x| {
                    let step = iter + 1;
                    if step % every == 0 || step == spec.steps {
                        let objective = model.objective(x);
                        best = best.max(objective);
                        trace.push(objective);
                        ctx.emit(ProgressEvent {
                            chain_id,
                            step,
                            beta: spec.beta(iter),
                            objective,
                            best_objective: best,
                            updates: rep_so_far.updates,
                            steps_per_sec: None,
                            eta_seconds: None,
                        });
                    }
                    !ctx.stop_requested()
                },
            );
            done += n;
        }
        sim.finish_run(&mut rep);
        let stats = StepStats {
            updates: rep.updates,
            accepted: 0,
            cost: OpCost {
                ops: 0,
                bytes: 4 * (rep.load_words + rep.store_words),
                samples: rep.samples,
            },
        };
        let final_objective = model.objective(&sim.x);
        Ok(ChainResult {
            chain_id,
            best_objective: best.max(final_objective),
            steps: rep.iterations as usize,
            stats,
            marginal0: sim.marginal(0),
            best_x: sim.x.clone(),
            sim: Some(rep),
            multicore: None,
            tempering: None,
            wall: t0.elapsed(),
            objective_trace: trace,
        })
    }

    fn run_chains_adaptive(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        controller: &mut dyn BetaController,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        // One compile serves every chain — the program depends only on
        // (model, algo, hw), not on the chain id.
        let program = self.compile_gated(model, spec)?;
        let units = (0..chains)
            .map(|chain_id| {
                let mut sim =
                    Simulator::new(self.hw, model, spec.pas_flips, spec.chain_seed(chain_id));
                if let Some(x0) = &spec.init_state {
                    sim.x.copy_from_slice(x0);
                }
                ExecUnit::sim(chain_id, sim, program.clone())
            })
            .collect();
        run_adaptive(model, spec, chains, ctx, controller, units)
    }

    fn run_chains_tempered(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        exchanges: &mut [ReplicaExchange],
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let program = self.compile_gated(model, spec)?;
        let units = (0..chains)
            .map(|chain_id| {
                let mut sim =
                    Simulator::new(self.hw, model, spec.pas_flips, spec.chain_seed(chain_id));
                if let Some(x0) = &spec.init_state {
                    sim.x.copy_from_slice(x0);
                }
                ExecUnit::sim(chain_id, sim, program.clone())
            })
            .collect();
        run_tempered(model, spec, chains, ctx, exchanges, units)
    }
}

/// The sharded multi-core MC²A system (§II-D): C single-core
/// pipelines sharing a crossbar and the histogram memory, one model
/// partitioned across them by [`crate::graph::partition_balanced`].
///
/// At `cores = 1` this is bit-identical — cycles, samples, state — to
/// [`AcceleratorBackend`] (the shard compiler emits the same program
/// and no interconnect cost is charged). At `cores > 1` only Block
/// Gibbs and Async Gibbs can be sharded; the builder rejects other
/// algorithms up front.
#[derive(Clone, Copy, Debug)]
pub struct MultiCoreAcceleratorBackend {
    mhw: MultiHwConfig,
    corrupt: Option<fn(&mut Program)>,
}

impl MultiCoreAcceleratorBackend {
    /// A `cores`-core system of identical `hw` pipelines with the
    /// default shared interconnect ([`MultiHwConfig::new`]). The shard
    /// compiler always runs with the fusion optimizer on (the §Perf
    /// ablation knob lives on the single-core [`AcceleratorBackend`]).
    pub fn new(hw: HwConfig, cores: usize) -> MultiCoreAcceleratorBackend {
        MultiCoreAcceleratorBackend { mhw: MultiHwConfig::new(hw, cores), corrupt: None }
    }

    /// Backend over a fully-specified multi-core configuration
    /// (custom crossbar bandwidth / barrier latency).
    pub fn with_config(mhw: MultiHwConfig) -> MultiCoreAcceleratorBackend {
        MultiCoreAcceleratorBackend { mhw, corrupt: None }
    }

    /// Test-only hook: mutate each shard program inside the
    /// static-analysis gate, proving corrupted ensembles are rejected
    /// with [`Mc2aError::InvalidProgram`] before they reach the
    /// simulator.
    #[doc(hidden)]
    pub fn with_corrupt_hook(mut self, f: fn(&mut Program)) -> MultiCoreAcceleratorBackend {
        self.corrupt = Some(f);
        self
    }

    /// The multi-core hardware configuration this backend simulates.
    pub fn hw(&self) -> &MultiHwConfig {
        &self.mhw
    }

    /// Static-analysis gate over the shard ensemble this backend would
    /// run (same partition + shard compiler as [`MultiCoreSim::new`]).
    fn gate(&self, model: &dyn EnergyModel, spec: &ChainSpec) -> Result<(), Mc2aError> {
        analysis::gate_ensemble(model, spec.algo, &self.mhw, spec.pas_flips, self.corrupt)
    }
}

impl ExecutionBackend for MultiCoreAcceleratorBackend {
    fn name(&self) -> &'static str {
        "multicore"
    }

    fn sim_hw(&self) -> Option<MultiHwConfig> {
        Some(self.mhw)
    }

    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        self.gate(model, spec)?;
        let t0 = Instant::now();
        let mut sim = MultiCoreSim::new(
            self.mhw,
            model,
            spec.algo,
            spec.pas_flips,
            spec.chain_seed(chain_id),
        )?;
        if let Some(x0) = &spec.init_state {
            sim.set_state(x0);
        }
        let every = spec.observe_every.max(1);
        let mut trace = Vec::new();
        let mut best = f64::NEG_INFINITY;
        let mut run = sim.begin_run();
        // β on the global clock, planned one observation segment at a
        // time, as in the single-core backend.
        let mut betas: Vec<f32> = Vec::with_capacity(every.min(spec.steps));
        let mut done = 0usize;
        let mut go = true;
        while go && done < spec.steps {
            let n = every.min(spec.steps - done);
            betas.clear();
            betas.extend((done..done + n).map(|t| spec.beta(t)));
            go = sim.advance_run(
                &mut run,
                done,
                n,
                Some(&betas),
                &mut |iter, updates_so_far, x| {
                    let step = iter + 1;
                    if step % every == 0 || step == spec.steps {
                        let objective = model.objective(x);
                        best = best.max(objective);
                        trace.push(objective);
                        ctx.emit(ProgressEvent {
                            chain_id,
                            step,
                            beta: spec.beta(iter),
                            objective,
                            best_objective: best,
                            updates: updates_so_far,
                            steps_per_sec: None,
                            eta_seconds: None,
                        });
                    }
                    !ctx.stop_requested()
                },
            );
            done += n;
        }
        let report = sim.finish_run(run);
        let merged = report.merged();
        let stats = StepStats {
            updates: merged.updates,
            accepted: 0,
            cost: OpCost {
                ops: 0,
                bytes: 4 * (merged.load_words + merged.store_words),
                samples: merged.samples,
            },
        };
        let final_objective = model.objective(&sim.x);
        Ok(ChainResult {
            chain_id,
            best_objective: best.max(final_objective),
            steps: merged.iterations as usize,
            stats,
            marginal0: sim.marginal(0),
            best_x: sim.x.clone(),
            sim: Some(merged),
            multicore: Some(report),
            tempering: None,
            wall: t0.elapsed(),
            objective_trace: trace,
        })
    }

    fn run_chains_adaptive(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        controller: &mut dyn BetaController,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        self.gate(model, spec)?;
        let mut units = Vec::with_capacity(chains);
        for chain_id in 0..chains {
            let mut sim = MultiCoreSim::new(
                self.mhw,
                model,
                spec.algo,
                spec.pas_flips,
                spec.chain_seed(chain_id),
            )?;
            if let Some(x0) = &spec.init_state {
                sim.set_state(x0);
            }
            units.push(ExecUnit::multi(chain_id, sim));
        }
        run_adaptive(model, spec, chains, ctx, controller, units)
    }

    fn run_chains_tempered(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        exchanges: &mut [ReplicaExchange],
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        self.gate(model, spec)?;
        let mut units = Vec::with_capacity(chains);
        for chain_id in 0..chains {
            let mut sim = MultiCoreSim::new(
                self.mhw,
                model,
                spec.algo,
                spec.pas_flips,
                spec.chain_seed(chain_id),
            )?;
            if let Some(x0) = &spec.init_state {
                sim.set_state(x0);
            }
            units.push(ExecUnit::multi(chain_id, sim));
        }
        run_tempered(model, spec, chains, ctx, exchanges, units)
    }
}

/// The AOT-JAX/PJRT measured-software path: every categorical draw is
/// delegated to the `gumbel_sample` artifact, so the chain exercises
/// the exact compiled kernel the CPU baseline measures.
///
/// Requires the `xla-runtime` feature; without it (or without a built
/// artifact directory) [`RuntimeBackend::new`] returns
/// [`Mc2aError::RuntimeUnavailable`] and the builder surfaces that at
/// `build()` time. Only sequential Gibbs-family algorithms are
/// supported (the artifacts encode single-site conditionals).
pub struct RuntimeBackend {
    rt: Runtime,
}

impl RuntimeBackend {
    /// Load the artifact set from `dir` (`<dir>/manifest.txt` + HLO
    /// text files produced by `make artifacts`).
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<RuntimeBackend, Mc2aError> {
        let rt = Runtime::load(dir.as_ref())
            .map_err(|e| Mc2aError::RuntimeUnavailable(format!("{e:#}")))?;
        Ok(RuntimeBackend { rt })
    }

    /// The loaded PJRT runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl ExecutionBackend for RuntimeBackend {
    fn name(&self) -> &'static str {
        "runtime"
    }

    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        if matches!(spec.algo, AlgoKind::Pas) {
            return Err(Mc2aError::InvalidConfig(
                "the runtime backend supports Gibbs-family algorithms only".into(),
            ));
        }
        let art = self
            .rt
            .spec("gumbel_sample")
            .ok_or_else(|| Mc2aError::RuntimeUnavailable("artifact `gumbel_sample` missing".into()))?;
        let dims = art
            .inputs
            .first()
            .map(|a| a.dims.clone())
            .ok_or_else(|| Mc2aError::Runtime("gumbel_sample manifest lists no inputs".into()))?;
        if dims.len() != 2 {
            return Err(Mc2aError::Runtime(format!(
                "gumbel_sample expects a 2-D energy input, manifest says {dims:?}"
            )));
        }
        let (batch, width) = (dims[0], dims[1]);

        let t0 = Instant::now();
        let mut rng = spec.chain_rng(chain_id);
        let mut x = match &spec.init_state {
            Some(x0) => x0.clone(),
            None => crate::energy::random_state(model, &mut rng),
        };
        let n = model.num_vars();
        let mut scratch: Vec<f32> = Vec::new();
        let mut hist0 = vec![0u64; model.num_states(0)];
        let mut stats = StepStats::default();
        let mut best = model.objective(&x);
        let mut trace = Vec::new();
        let every = spec.observe_every.max(1);
        let mut done = 0usize;
        // Prohibitive padding energy: never sampled by the Gumbel argmax.
        const PAD: f32 = 1e30;
        while done < spec.steps {
            if ctx.stop_requested() {
                break;
            }
            let beta = spec.beta(done);
            for i in 0..n {
                model.local_energies(&x, i, &mut scratch);
                if scratch.len() > width {
                    return Err(Mc2aError::Runtime(format!(
                        "RV {i} has {} states, artifact supports ≤ {width}",
                        scratch.len()
                    )));
                }
                let mut e = vec![PAD; batch * width];
                e[..scratch.len()].copy_from_slice(&scratch);
                let u: Vec<f32> = (0..batch * width).map(|_| rng.uniform_open_f32()).collect();
                let out = self
                    .rt
                    .execute_f32("gumbel_sample", &[&e, &u, &[beta]])
                    .map_err(|e| Mc2aError::Runtime(format!("{e:#}")))?;
                let sample = out
                    .first()
                    .and_then(|o| o.first())
                    .copied()
                    .ok_or_else(|| Mc2aError::Runtime("gumbel_sample returned no output".into()))?
                    as usize;
                if sample >= scratch.len() {
                    return Err(Mc2aError::Runtime(format!(
                        "gumbel_sample picked padded state {sample} for RV {i} ({} states)",
                        scratch.len()
                    )));
                }
                x[i] = sample as u32;
                let c = model.update_cost(i);
                stats.updates += 1;
                stats.accepted += 1;
                stats.cost.add(c);
            }
            hist0[x[0] as usize] += 1;
            done += 1;
            let objective = model.objective(&x);
            best = best.max(objective);
            if done % every == 0 || done == spec.steps {
                trace.push(objective);
                ctx.emit(ProgressEvent {
                    chain_id,
                    step: done,
                    beta,
                    objective,
                    best_objective: best,
                    updates: stats.updates,
                    steps_per_sec: None,
                    eta_seconds: None,
                });
            }
        }
        let total: u64 = hist0.iter().sum();
        let marginal0 = hist0
            .iter()
            .map(|&c| c as f64 / total.max(1) as f64)
            .collect();
        Ok(ChainResult {
            chain_id,
            best_objective: best,
            steps: done,
            stats,
            sim: None,
            multicore: None,
            tempering: None,
            wall: t0.elapsed(),
            marginal0,
            best_x: x,
            objective_trace: trace,
        })
    }
}
