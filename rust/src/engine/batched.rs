//! The batched software backend: SoA chain batches scheduled by a
//! work-stealing thread pool.
//!
//! Where [`SoftwareBackend`](crate::engine::SoftwareBackend) spawns
//! one OS thread per chain (1024 chains ⇒ 1024 threads), this backend
//! splits the fan-out into `ceil(chains / batch)` work items, each a
//! [`ChainBatch`] of up to `batch` chains stepped together through the
//! batched kernels, and multiplexes the items over a fixed pool of
//! `threads` workers via [`scheduler::run_stealing`]. Per-variable
//! costs (neighbor-index walks, virtual dispatch, parameter fetches)
//! amortize across each batch; the pool keeps the core count, not the
//! chain count, as the thread count.
//!
//! Chains are **bit-identical** to the scalar backend for every
//! algorithm: Gibbs / Block Gibbs / MH / Async Gibbs / PAS all run
//! batched kernels whose per-chain RNG consumption matches the scalar
//! kernels exactly. The kernels themselves process the K chain
//! columns `LANES` at a time (see [`crate::rng::LaneRng`] and the
//! lane-parallel Gumbel argmax in `mcmc::sampler`), so each work item
//! is SIMD-parallel across its batch as well as amortizing
//! per-variable costs.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::ChainResult;
use crate::energy::EnergyModel;
use crate::engine::adaptive::{run_adaptive, ExecUnit};
use crate::engine::backend::{
    run_software_chain, software_chain, ChainCtx, ChainSpec, ExecutionBackend,
};
use crate::engine::error::Mc2aError;
use crate::engine::observer::ProgressEvent;
use crate::engine::scheduler;
use crate::engine::telemetry;
use crate::engine::tempering::run_tempered;
use crate::mcmc::anneal::BetaController;
use crate::mcmc::tempering::ReplicaExchange;
use crate::mcmc::{batch_supported, build_batch_algo, ChainBatch};
use crate::rng::LANES;

/// Default chains per work item when the caller does not choose one.
pub const DEFAULT_BATCH: usize = 32;

/// Structure-of-arrays software chains over a work-stealing pool.
#[derive(Clone, Copy, Debug)]
pub struct BatchedSoftwareBackend {
    batch: usize,
    threads: usize,
}

impl BatchedSoftwareBackend {
    /// Backend batching `batch` chains per work item (`batch ≥ 1`),
    /// with the thread count defaulting to the machine's available
    /// parallelism.
    pub fn new(batch: usize) -> BatchedSoftwareBackend {
        assert!(batch >= 1, "batch must be ≥ 1");
        BatchedSoftwareBackend { batch, threads: 0 }
    }

    /// Fix the worker-pool size (0 = available parallelism).
    pub fn with_threads(mut self, threads: usize) -> BatchedSoftwareBackend {
        self.threads = threads;
        self
    }

    /// Chains per work item.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Configured worker-pool size (0 = auto).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn resolve_threads(&self, items: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, items.max(1))
    }

    /// The lockstep-driver work decomposition shared by the adaptive
    /// and tempered paths: one [`ChainBatch`] unit per `batch` chains
    /// when the algorithm has a batched kernel, scalar fallback units
    /// otherwise. Chains — and the diagnostics/energies the drivers
    /// see — are bit-identical to the scalar software backend.
    fn lockstep_units<'m>(
        &self,
        model: &'m dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
    ) -> Vec<ExecUnit<'m>> {
        let mut units = Vec::new();
        if batch_supported(spec.algo) {
            let size = self.batch.max(1);
            let mut start = 0usize;
            while start < chains {
                let end = (start + size).min(chains);
                let mut batch = ChainBatch::new(
                    model,
                    spec.schedule,
                    spec.seed,
                    start,
                    end - start,
                    spec.init_state.as_deref(),
                );
                batch.set_step_offset(spec.beta_offset);
                let algo = build_batch_algo(spec.algo, spec.sampler, model, spec.pas_flips)
                    .expect("batched kernel exists");
                units.push(ExecUnit::batch(batch, algo));
                start = end;
            }
        } else {
            for chain_id in 0..chains {
                units.push(ExecUnit::scalar(
                    chain_id,
                    software_chain(model, spec, chain_id),
                ));
            }
        }
        units
    }
}

impl Default for BatchedSoftwareBackend {
    fn default() -> Self {
        BatchedSoftwareBackend::new(DEFAULT_BATCH)
    }
}

/// Run one work item — the chain range `start..end` — to completion
/// (or early stop), on the batched kernels when the algorithm has
/// them, else chain-by-chain on the shared scalar runner.
fn run_batch_item(
    model: &dyn EnergyModel,
    spec: &ChainSpec,
    start: usize,
    end: usize,
    ctx: &ChainCtx<'_>,
) -> Vec<(usize, Result<ChainResult, Mc2aError>)> {
    if !batch_supported(spec.algo) {
        return (start..end)
            .map(|cid| (cid, run_software_chain(model, spec, cid, ctx)))
            .collect();
    }
    let k = end - start;
    if telemetry::enabled() {
        // Lane occupancy: the SIMD kernels process chain columns
        // `LANES` at a time, so a ragged tail item pads to a multiple
        // of `LANES` and wastes the padding lanes.
        let m = telemetry::metrics();
        m.counter_add("batched_items_total", &[], 1);
        m.counter_add("batched_lanes_occupied_total", &[], k as u64);
        m.counter_add(
            "batched_lanes_capacity_total",
            &[],
            (k.div_ceil(LANES) * LANES) as u64,
        );
    }
    let _span = telemetry::span_with("batched", || format!("batch item {start}..{end}"));
    let t0 = Instant::now();
    let mut algo = build_batch_algo(spec.algo, spec.sampler, model, spec.pas_flips)
        .expect("batched kernel exists");
    let mut batch = ChainBatch::new(
        model,
        spec.schedule,
        spec.seed,
        start,
        k,
        spec.init_state.as_deref(),
    );
    batch.set_step_offset(spec.beta_offset);
    let every = spec.observe_every.max(1);
    let mut traces = vec![Vec::new(); batch.k()];
    let mut done = 0usize;
    while done < spec.steps {
        if ctx.stop_requested() {
            break;
        }
        let n = every.min(spec.steps - done);
        batch.run(&mut *algo, n);
        done += n;
        let beta = batch.last_beta();
        for c in 0..batch.k() {
            traces[c].push(batch.objectives[c]);
            ctx.emit(ProgressEvent {
                chain_id: batch.chain_id(c),
                step: done,
                beta,
                objective: batch.objectives[c],
                best_objective: batch.best_objectives[c],
                updates: batch.stats[c].updates,
                steps_per_sec: None,
                eta_seconds: None,
            });
        }
    }
    let wall = t0.elapsed();
    traces
        .into_iter()
        .enumerate()
        .map(|(c, objective_trace)| {
            (
                start + c,
                Ok(ChainResult {
                    chain_id: start + c,
                    best_objective: batch.best_objectives[c],
                    steps: batch.step_count,
                    stats: batch.stats[c],
                    sim: None,
                    multicore: None,
                    tempering: None,
                    wall,
                    marginal0: batch.marginal0(c),
                    best_x: batch.best_state(c),
                    objective_trace,
                }),
            )
        })
        .collect()
}

impl ExecutionBackend for BatchedSoftwareBackend {
    fn name(&self) -> &'static str {
        "batched"
    }

    /// A single chain is a batch of one; the scalar runner produces
    /// the identical trajectory, so use it directly.
    fn run_chain(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chain_id: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<ChainResult, Mc2aError> {
        run_software_chain(model, spec, chain_id, ctx)
    }

    /// Adaptive lockstep over the same work decomposition as
    /// [`BatchedSoftwareBackend::run_chains`]: one [`ChainBatch`] unit
    /// per `batch` chains (scalar fallback units for algorithms
    /// without a batched kernel), all advancing one observation
    /// segment per round. Chains — and therefore the diagnostics the
    /// controller sees — are bit-identical to the scalar software
    /// backend, so the β trajectory is too.
    fn run_chains_adaptive(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        controller: &mut dyn BetaController,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let units = self.lockstep_units(model, spec, chains);
        run_adaptive(model, spec, chains, ctx, controller, units)
    }

    /// Replica exchange over the same work decomposition (and
    /// therefore the same bit-identical chains) as the adaptive path;
    /// the SoA batches run true per-chain β through
    /// [`ChainBatch::run_betas_per_chain`].
    fn run_chains_tempered(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
        exchanges: &mut [ReplicaExchange],
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        let units = self.lockstep_units(model, spec, chains);
        run_tempered(model, spec, chains, ctx, exchanges, units)
    }

    fn run_chains(
        &self,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
        ctx: &ChainCtx<'_>,
    ) -> Result<Vec<ChainResult>, Mc2aError> {
        // Every current algorithm has a batched kernel; the guard keeps
        // chain-granularity stealing for any future kernel that ships
        // scalar-only (a batch of scalar chains would otherwise
        // serialize on one worker).
        let batch = if batch_supported(spec.algo) {
            self.batch.max(1)
        } else {
            1
        };
        let items: Vec<(usize, usize)> = (0..chains)
            .step_by(batch)
            .map(|start| (start, (start + batch).min(chains)))
            .collect();
        let threads = self.resolve_threads(items.len());
        let slots: Mutex<Vec<Option<Result<ChainResult, Mc2aError>>>> =
            Mutex::new((0..chains).map(|_| None).collect());
        scheduler::run_stealing(threads, items, |_w, (start, end)| {
            let out = catch_unwind(AssertUnwindSafe(|| {
                run_batch_item(model, spec, start, end, ctx)
            }));
            let mut slots = slots.lock().unwrap();
            match out {
                Ok(results) => {
                    for (cid, r) in results {
                        slots[cid] = Some(r);
                    }
                }
                Err(_) => {
                    for cid in start..end {
                        slots[cid] = Some(Err(Mc2aError::ChainPanicked { chain_id: cid }));
                    }
                }
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(chain_id, slot)| slot.unwrap_or(Err(Mc2aError::ChainPanicked { chain_id })))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;
    use crate::engine::backend::SoftwareBackend;
    use crate::mcmc::{AlgoKind, BetaSchedule, SamplerKind};
    use std::sync::atomic::AtomicBool;

    fn spec(algo: AlgoKind, steps: usize) -> ChainSpec {
        ChainSpec {
            algo,
            sampler: SamplerKind::Gumbel,
            schedule: BetaSchedule::Constant(0.8),
            beta_offset: 0,
            steps,
            seed: 0xBEEF,
            pas_flips: 4,
            observe_every: 5,
            init_state: None,
        }
    }

    fn run(
        backend: &dyn ExecutionBackend,
        model: &dyn EnergyModel,
        spec: &ChainSpec,
        chains: usize,
    ) -> Vec<ChainResult> {
        let stop = AtomicBool::new(false);
        let ctx = ChainCtx {
            stop: &stop,
            events: None,
            restart: None,
        };
        backend.run_chains(model, spec, chains, &ctx).unwrap()
    }

    #[test]
    fn matches_scalar_backend_for_every_batch_and_thread_count() {
        let m = PottsGrid::new(5, 5, 2, 0.6);
        let spec = spec(AlgoKind::Gibbs, 20);
        let reference = run(&SoftwareBackend, &m, &spec, 7);
        for batch in [1, 2, 3, 7, 16] {
            for threads in [1, 2, 4] {
                let got = run(
                    &BatchedSoftwareBackend::new(batch).with_threads(threads),
                    &m,
                    &spec,
                    7,
                );
                for (a, b) in reference.iter().zip(&got) {
                    assert_eq!(a.chain_id, b.chain_id);
                    assert_eq!(a.best_x, b.best_x, "batch={batch} threads={threads}");
                    assert_eq!(a.best_objective, b.best_objective);
                    assert_eq!(a.marginal0, b.marginal0);
                    assert_eq!(a.objective_trace, b.objective_trace);
                    assert_eq!(a.steps, b.steps);
                }
            }
        }
    }

    #[test]
    fn batched_pas_matches_scalar_backend() {
        // PAS runs the true batched kernel now (it fell back to scalar
        // chains before PR 7); trajectories must stay bit-identical,
        // including the pas_flips path length carried by the spec.
        let m = PottsGrid::new(4, 4, 2, 0.6);
        let spec = spec(AlgoKind::Pas, 10);
        let reference = run(&SoftwareBackend, &m, &spec, 4);
        let got = run(&BatchedSoftwareBackend::new(2).with_threads(2), &m, &spec, 4);
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!(a.best_x, b.best_x);
            assert_eq!(a.objective_trace, b.objective_trace);
        }
    }

    #[test]
    fn batched_async_gibbs_matches_scalar_backend() {
        let m = PottsGrid::new(4, 4, 3, 0.6);
        let spec = spec(AlgoKind::AsyncGibbs, 12);
        let reference = run(&SoftwareBackend, &m, &spec, 5);
        let got = run(&BatchedSoftwareBackend::new(3).with_threads(2), &m, &spec, 5);
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!(a.best_x, b.best_x);
            assert_eq!(a.objective_trace, b.objective_trace);
            assert_eq!(a.marginal0, b.marginal0);
        }
    }

    #[test]
    fn stop_flag_halts_batches_at_observation_boundaries() {
        let m = PottsGrid::new(6, 6, 2, 0.5);
        let mut s = spec(AlgoKind::Gibbs, 1_000_000);
        s.observe_every = 1;
        let stop = AtomicBool::new(true); // raised before the run starts
        let ctx = ChainCtx {
            stop: &stop,
            events: None,
            restart: None,
        };
        let results = BatchedSoftwareBackend::new(4)
            .run_chains(&m, &s, 8, &ctx)
            .unwrap();
        for r in results {
            assert_eq!(r.steps, 0, "chain {} ignored the stop flag", r.chain_id);
        }
    }
}
