//! L3 chain results and multi-chain aggregation.
//!
//! The paper's accelerator targets single-chain acceleration and
//! "can easily be scaled to support multiple chains … by instantiating
//! multiple parallel MC²A cores" (§II-D). The orchestration itself —
//! backend routing, thread fan-out, streaming observation, early stop
//! — lives in [`crate::engine`]; this module owns the data the engine
//! produces: one [`ChainResult`] per chain and the [`RunMetrics`]
//! aggregate with cross-chain convergence diagnostics.
//!
//! (The old closed `Backend` enum and `run_chains` free function were
//! replaced by [`crate::engine::ExecutionBackend`] and
//! [`crate::engine::EngineBuilder`].)

use std::time::Duration;

use crate::mcmc::tempering::TemperingReport;
use crate::mcmc::{effective_sample_size, split_r_hat, StepStats};
use crate::sim::{MultiCoreReport, SimReport};

/// Result of one chain run.
#[derive(Clone, Debug)]
pub struct ChainResult {
    /// Chain id (seed stream index).
    pub chain_id: usize,
    /// Best objective found.
    pub best_objective: f64,
    /// Steps executed (may be fewer than requested on early stop).
    pub steps: usize,
    /// Software-side statistics (updates, ops, samples).
    pub stats: StepStats,
    /// Accelerator report when run on the simulator backend. On the
    /// multi-core backend this is the merged (aggregate) report.
    pub sim: Option<SimReport>,
    /// Per-core breakdown when run on the multi-core accelerator
    /// backend (aggregate GS/s, per-core utilization, sync overhead).
    pub multicore: Option<MultiCoreReport>,
    /// Replica-exchange diagnostics when run under a tempering ladder
    /// ([`crate::engine::EngineBuilder::tempering`]): per-pair swap
    /// rates and per-replica round trips for this chain's ensemble.
    pub tempering: Option<TemperingReport>,
    /// Wall-clock duration of the chain's executor. On thread-per-chain
    /// backends this is the chain's own thread time; on the batched
    /// backend every chain of a work item shares the item's duration
    /// (the chains genuinely ran interleaved, so the time is shared,
    /// not divisible).
    pub wall: Duration,
    /// Marginal of RV 0 (convergence smoke signal).
    pub marginal0: Vec<f64>,
    /// Best assignment found (software) or final state (accelerator).
    pub best_x: Vec<u32>,
    /// Objective sampled at every observation point — the signal the
    /// engine's R-hat/ESS diagnostics run on.
    pub objective_trace: Vec<f64>,
}

/// Aggregated multi-chain metrics.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Per-chain results.
    pub chains: Vec<ChainResult>,
    /// Total wall-clock for the whole fan-out.
    pub wall: Duration,
}

impl RunMetrics {
    /// Best objective across chains.
    pub fn best_objective(&self) -> f64 {
        self.chains
            .iter()
            .map(|c| c.best_objective)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Total RV updates across chains.
    pub fn total_updates(&self) -> u64 {
        self.chains.iter().map(|c| c.stats.updates).sum()
    }

    /// Aggregate software throughput in updates/second (wall-clock).
    pub fn updates_per_sec(&self) -> f64 {
        self.total_updates() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Mean of per-chain marginal-of-RV0 (chain agreement check).
    pub fn mean_marginal0(&self) -> Vec<f64> {
        if self.chains.is_empty() {
            return Vec::new();
        }
        let k = self.chains[0].marginal0.len();
        let mut m = vec![0.0; k];
        for c in &self.chains {
            for (a, b) in m.iter_mut().zip(&c.marginal0) {
                *a += b;
            }
        }
        for v in &mut m {
            *v /= self.chains.len() as f64;
        }
        m
    }

    /// Split R-hat over the chains' objective traces (`None` with
    /// fewer than two chains or fewer than four observations each).
    pub fn split_r_hat(&self) -> Option<f64> {
        if self.chains.len() < 2 {
            return None;
        }
        let traces: Vec<Vec<f64>> = self
            .chains
            .iter()
            .map(|c| c.objective_trace.clone())
            .collect();
        split_r_hat(&traces)
    }

    /// Smallest per-chain effective sample size of the objective trace.
    pub fn min_ess(&self) -> f64 {
        self.chains
            .iter()
            .map(|c| effective_sample_size(&c.objective_trace))
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(chain_id: usize, best: f64, trace: Vec<f64>) -> ChainResult {
        let stats = StepStats {
            updates: 100,
            ..Default::default()
        };
        ChainResult {
            chain_id,
            best_objective: best,
            steps: trace.len() * 10,
            stats,
            sim: None,
            multicore: None,
            tempering: None,
            wall: Duration::from_millis(10),
            marginal0: vec![0.25, 0.75],
            best_x: vec![0, 1],
            objective_trace: trace,
        }
    }

    #[test]
    fn aggregates_best_updates_and_marginals() {
        let m = RunMetrics {
            chains: vec![
                result(0, 5.0, vec![1.0, 2.0, 5.0, 5.0]),
                result(1, 7.0, vec![2.0, 3.0, 7.0, 7.0]),
            ],
            wall: Duration::from_millis(20),
        };
        assert_eq!(m.best_objective(), 7.0);
        assert_eq!(m.total_updates(), 200);
        assert!(m.updates_per_sec() > 0.0);
        assert_eq!(m.mean_marginal0(), vec![0.25, 0.75]);
    }

    #[test]
    fn diagnostics_require_two_chains() {
        let one = RunMetrics {
            chains: vec![result(0, 1.0, vec![1.0; 8])],
            wall: Duration::from_millis(1),
        };
        assert!(one.split_r_hat().is_none());
        let two = RunMetrics {
            chains: vec![result(0, 1.0, vec![1.0; 8]), result(1, 1.0, vec![1.0; 8])],
            wall: Duration::from_millis(1),
        };
        assert_eq!(two.split_r_hat(), Some(1.0));
        assert_eq!(two.min_ess(), 8.0);
    }
}
