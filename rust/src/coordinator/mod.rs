//! L3 coordinator: chain orchestration across execution backends.
//!
//! The paper's accelerator targets single-chain acceleration and
//! "can easily be scaled to support multiple chains … by instantiating
//! multiple parallel MC²A cores" (§II-D). This module is that system
//! layer: it routes a workload to a backend — the cycle-accurate
//! accelerator simulator, the software (Rust) chain, or the AOT-XLA
//! runtime path — fans chains out across OS threads (one per core,
//! mirroring multi-core MC²A instantiation), tracks convergence, and
//! aggregates metrics.
//!
//! Offline-environment note: the vendored crate set has no tokio, so
//! the coordinator uses `std::thread::scope` + channels; the event
//! loop is synchronous but the chains themselves are fully parallel.

use std::time::{Duration, Instant};

use crate::compiler::compile;
use crate::energy::EnergyModel;
use crate::isa::HwConfig;
use crate::mcmc::{build_algo, AlgoKind, BetaSchedule, Chain, SamplerKind, StepStats};
use crate::sim::{SimReport, Simulator};

/// Where a chain executes.
#[derive(Clone, Copy, Debug)]
pub enum Backend {
    /// Pure-Rust software chain (the reference implementation).
    Software(SamplerKind),
    /// The cycle-accurate MC²A simulator with a hardware config.
    Accelerator(HwConfig),
}

/// Result of one chain run.
#[derive(Clone, Debug)]
pub struct ChainResult {
    /// Chain id (seed stream index).
    pub chain_id: usize,
    /// Best objective found.
    pub best_objective: f64,
    /// Steps executed.
    pub steps: usize,
    /// Software-side statistics (updates, ops, samples).
    pub stats: StepStats,
    /// Accelerator report when run on the simulator backend.
    pub sim: Option<SimReport>,
    /// Wall-clock duration of the chain.
    pub wall: Duration,
    /// Marginal of RV 0 (convergence smoke signal).
    pub marginal0: Vec<f64>,
}

/// Aggregated multi-chain metrics.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Per-chain results.
    pub chains: Vec<ChainResult>,
    /// Total wall-clock for the whole fan-out.
    pub wall: Duration,
}

impl RunMetrics {
    /// Best objective across chains.
    pub fn best_objective(&self) -> f64 {
        self.chains
            .iter()
            .map(|c| c.best_objective)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Total RV updates across chains.
    pub fn total_updates(&self) -> u64 {
        self.chains.iter().map(|c| c.stats.updates).sum()
    }

    /// Aggregate software throughput in updates/second (wall-clock).
    pub fn updates_per_sec(&self) -> f64 {
        self.total_updates() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Mean of per-chain marginal-of-RV0 (chain agreement check).
    pub fn mean_marginal0(&self) -> Vec<f64> {
        if self.chains.is_empty() {
            return Vec::new();
        }
        let k = self.chains[0].marginal0.len();
        let mut m = vec![0.0; k];
        for c in &self.chains {
            for (a, b) in m.iter_mut().zip(&c.marginal0) {
                *a += b;
            }
        }
        for v in &mut m {
            *v /= self.chains.len() as f64;
        }
        m
    }
}

/// A chain-run request.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    /// Algorithm to run.
    pub algo: AlgoKind,
    /// β schedule.
    pub schedule: BetaSchedule,
    /// Steps per chain.
    pub steps: usize,
    /// Number of independent chains.
    pub chains: usize,
    /// Base RNG seed (chain i uses `seed + i`).
    pub seed: u64,
    /// PAS path length.
    pub pas_flips: usize,
}

impl Default for RunSpec {
    fn default() -> RunSpec {
        RunSpec {
            algo: AlgoKind::BlockGibbs,
            schedule: BetaSchedule::Constant(1.0),
            steps: 100,
            chains: 1,
            seed: 1,
            pas_flips: 8,
        }
    }
}

/// Run one chain on the chosen backend.
fn run_one(model: &dyn EnergyModel, backend: Backend, spec: &RunSpec, chain_id: usize) -> ChainResult {
    let t0 = Instant::now();
    let seed = spec.seed + chain_id as u64;
    match backend {
        Backend::Software(sampler) => {
            let algo = build_algo(spec.algo, sampler, model, spec.pas_flips);
            let mut chain = Chain::new(model, algo, spec.schedule, seed);
            chain.run(spec.steps);
            ChainResult {
                chain_id,
                best_objective: chain.best_objective,
                steps: chain.step_count,
                stats: chain.stats,
                sim: None,
                wall: t0.elapsed(),
                marginal0: chain.marginal(0),
            }
        }
        Backend::Accelerator(hw) => {
            let program = compile(model, spec.algo, &hw, spec.pas_flips);
            let mut sim = Simulator::new(hw, model, spec.pas_flips, seed);
            sim.set_beta(spec.schedule.beta(spec.steps / 2));
            let rep = sim.run(&program, spec.steps);
            let mut stats = StepStats::default();
            stats.updates = rep.updates;
            stats.cost.samples = rep.samples;
            stats.cost.bytes = 4 * (rep.load_words + rep.store_words);
            let best = model.objective(&sim.x);
            ChainResult {
                chain_id,
                best_objective: best,
                steps: spec.steps,
                stats,
                marginal0: sim.marginal(0),
                sim: Some(rep),
                wall: t0.elapsed(),
            }
        }
    }
}

/// Fan `spec.chains` chains out over OS threads and gather results.
pub fn run_chains(model: &dyn EnergyModel, backend: Backend, spec: RunSpec) -> RunMetrics {
    let t0 = Instant::now();
    let chains: Vec<ChainResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..spec.chains)
            .map(|cid| scope.spawn(move || run_one(model, backend, &spec, cid)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("chain panicked")).collect()
    });
    RunMetrics {
        chains,
        wall: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;

    #[test]
    fn software_chains_run_in_parallel_and_agree() {
        let m = PottsGrid::new(6, 6, 2, 0.3);
        let metrics = run_chains(
            &m,
            Backend::Software(SamplerKind::Gumbel),
            RunSpec {
                chains: 4,
                steps: 2000,
                ..Default::default()
            },
        );
        assert_eq!(metrics.chains.len(), 4);
        // Symmetric Ising at moderate β: marginals near 0.5 for every chain.
        for c in &metrics.chains {
            assert!((c.marginal0[0] - 0.5).abs() < 0.1, "{:?}", c.marginal0);
        }
        assert!(metrics.total_updates() >= 4 * 2000 * 36);
        assert!(metrics.updates_per_sec() > 0.0);
    }

    #[test]
    fn accelerator_backend_reports_cycles() {
        let m = PottsGrid::new(4, 4, 2, 0.5);
        let metrics = run_chains(
            &m,
            Backend::Accelerator(HwConfig::fig10_toy()),
            RunSpec {
                chains: 2,
                steps: 50,
                ..Default::default()
            },
        );
        for c in &metrics.chains {
            let rep = c.sim.as_ref().expect("sim report");
            assert!(rep.cycles > 0);
            assert_eq!(rep.updates, 50 * 16);
        }
    }

    #[test]
    fn chains_use_distinct_seeds() {
        let m = PottsGrid::new(5, 5, 2, 0.5);
        let metrics = run_chains(
            &m,
            Backend::Software(SamplerKind::Gumbel),
            RunSpec {
                chains: 2,
                steps: 50,
                ..Default::default()
            },
        );
        // Two chains with different seeds should not produce identical
        // marginal estimates at this short length.
        assert_ne!(metrics.chains[0].marginal0, metrics.chains[1].marginal0);
    }
}
