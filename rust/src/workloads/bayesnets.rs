//! Small Bayesian networks transcribed from the bnlearn repository
//! (Earthquake, Cancer, Survey) — the paper's irregular-graph
//! workloads (Table I, Fig. 10a, Fig. 14).

use crate::energy::{BayesNet, Cpt};

fn cpt(parents: &[u32], card: u32, table: &[f64]) -> Cpt {
    Cpt {
        parents: parents.to_vec(),
        card,
        table: table.to_vec(),
    }
}

/// Earthquake network (Korb & Nicholson): 5 nodes, 4 edges.
///
/// Node order: 0 Burglary, 1 Earthquake, 2 Alarm, 3 JohnCalls,
/// 4 MaryCalls. State 0 = False, 1 = True.
pub fn earthquake() -> BayesNet {
    let burglary = cpt(&[], 2, &[0.99, 0.01]);
    let quake = cpt(&[], 2, &[0.98, 0.02]);
    // P(Alarm | Burglary, Earthquake); parent cfg order: (B,E) with E fastest.
    let alarm = cpt(
        &[0, 1],
        2,
        &[
            0.999, 0.001, // B=0, E=0
            0.71, 0.29, // B=0, E=1
            0.06, 0.94, // B=1, E=0
            0.05, 0.95, // B=1, E=1
        ],
    );
    let john = cpt(&[2], 2, &[0.95, 0.05, 0.10, 0.90]);
    let mary = cpt(&[2], 2, &[0.99, 0.01, 0.30, 0.70]);
    BayesNet::new(
        "earthquake",
        vec![burglary, quake, alarm, john, mary],
    )
}

/// Cancer network (Korb & Nicholson): 5 nodes, 4 edges.
///
/// Node order: 0 Pollution (0=low,1=high), 1 Smoker, 2 Cancer,
/// 3 Xray (positive), 4 Dyspnoea.
pub fn cancer() -> BayesNet {
    let pollution = cpt(&[], 2, &[0.90, 0.10]);
    let smoker = cpt(&[], 2, &[0.70, 0.30]);
    // P(Cancer | Pollution, Smoker); cfg order (P,S), S fastest.
    let cancer = cpt(
        &[0, 1],
        2,
        &[
            0.999, 0.001, // P=low,  S=0
            0.97, 0.03, // P=low,  S=1
            0.98, 0.02, // P=high, S=0
            0.95, 0.05, // P=high, S=1
        ],
    );
    let xray = cpt(&[2], 2, &[0.80, 0.20, 0.10, 0.90]);
    let dysp = cpt(&[2], 2, &[0.70, 0.30, 0.35, 0.65]);
    BayesNet::new("cancer", vec![pollution, smoker, cancer, xray, dysp])
}

/// Survey network (Scutari & Denis): 6 nodes, 6 edges.
///
/// Node order: 0 Age (young/adult/old), 1 Sex (M/F), 2 Education
/// (high/uni), 3 Occupation (emp/self), 4 Residence (small/big),
/// 5 Travel (car/train/other).
pub fn survey() -> BayesNet {
    let age = cpt(&[], 3, &[0.30, 0.50, 0.20]);
    let sex = cpt(&[], 2, &[0.60, 0.40]);
    // P(E | A, S); cfg order (A,S), S fastest. P(high), P(uni).
    let edu = cpt(
        &[0, 1],
        2,
        &[
            0.75, 0.25, // young, M
            0.64, 0.36, // young, F
            0.72, 0.28, // adult, M
            0.70, 0.30, // adult, F
            0.88, 0.12, // old,   M
            0.90, 0.10, // old,   F
        ],
    );
    let occ = cpt(&[2], 2, &[0.96, 0.04, 0.92, 0.08]);
    let res = cpt(&[2], 2, &[0.25, 0.75, 0.20, 0.80]);
    // P(T | O, R); cfg order (O,R), R fastest. car/train/other.
    let travel = cpt(
        &[3, 4],
        3,
        &[
            0.48, 0.42, 0.10, // emp,  small
            0.58, 0.24, 0.18, // emp,  big
            0.56, 0.36, 0.08, // self, small
            0.70, 0.21, 0.09, // self, big
        ],
    );
    BayesNet::new("survey", vec![age, sex, edu, occ, res, travel])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;

    #[test]
    fn earthquake_shape() {
        let net = earthquake();
        assert_eq!(net.num_vars(), 5);
        assert_eq!(net.num_dag_edges(), 4);
    }

    #[test]
    fn earthquake_alarm_marginal() {
        // P(Alarm) = Σ P(A|B,E)P(B)P(E) = 0.016114 with these CPTs.
        let net = earthquake();
        let m = net.exact_marginal(2);
        assert!((m[1] - 0.016114).abs() < 1e-4, "P(alarm)={}", m[1]);
    }

    #[test]
    fn earthquake_posterior_burglary_given_calls() {
        // Classic query: evidence John=T, Mary=T raises P(Burglary).
        let mut net = earthquake();
        net.set_evidence(3, 1);
        net.set_evidence(4, 1);
        // With the bnlearn priors (P(B)=0.01) the posterior is ≈ 0.556
        // (the classic 0.284 figure assumes P(B)=0.001).
        let m = net.exact_marginal(0);
        assert!(m[1] > 0.50 && m[1] < 0.62, "P(B|j,m)={}", m[1]);
    }

    #[test]
    fn cancer_shape_and_marginal() {
        let net = cancer();
        assert_eq!(net.num_vars(), 5);
        assert_eq!(net.num_dag_edges(), 4);
        let m = net.exact_marginal(2);
        // P(cancer) ≈ 0.0116 with these CPTs
        assert!(m[1] < 0.05 && m[1] > 0.001, "P(c)={}", m[1]);
    }

    #[test]
    fn survey_shape() {
        let net = survey();
        assert_eq!(net.num_vars(), 6);
        assert_eq!(net.num_dag_edges(), 6);
        let m = net.exact_marginal(5);
        assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Car is the dominant travel mode.
        assert!(m[0] > m[1] && m[0] > m[2]);
    }
}
