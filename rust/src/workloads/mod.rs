//! The Table I benchmark suite.
//!
//! Each constructor returns a ready-to-run workload: the energy model,
//! the algorithm the paper pairs it with, and metadata for the
//! benchmark harness. Bayes-net CPTs (Earthquake, Survey, Cancer) are
//! transcribed from the published bnlearn repository networks; the
//! Alarm net uses the published 37-node/46-edge structure with
//! deterministic synthetic CPTs, and the graph instances are size- and
//! degree-matched synthetic stand-ins (DESIGN.md §4).

mod alarm;
mod bayesnets;

pub use alarm::alarm;
pub use bayesnets::{cancer, earthquake, survey};

use crate::energy::{EnergyModel, MaxCliqueModel, MaxCutModel, MisModel, PottsGrid, Rbm};
use crate::graph::{erdos_renyi_with_edges, power_law_graph, random_regular_ish};
use crate::mcmc::AlgoKind;
use crate::rng::Rng;

/// A named benchmark workload (one Table I row).
pub struct Workload {
    /// Table I name.
    pub name: &'static str,
    /// Model family label (Table I "Model").
    pub model_kind: &'static str,
    /// Application description.
    pub application: &'static str,
    /// The MCMC algorithm Table I pairs with this workload.
    pub algorithm: AlgoKind,
    /// PAS path length (ignored by other algorithms).
    pub pas_flips: usize,
    /// The energy model.
    pub model: Box<dyn EnergyModel>,
}

impl Workload {
    /// Node count (Table I column).
    pub fn nodes(&self) -> usize {
        self.model.num_vars()
    }

    /// Edge count of the interaction graph (Table I column).
    pub fn edges(&self) -> usize {
        self.model.interaction().num_edges()
    }
}

/// Earthquake Bayes net (5 nodes / 4 edges, Block Gibbs).
pub fn wl_earthquake() -> Workload {
    Workload {
        name: "Earthquake",
        model_kind: "Bayes Net",
        application: "models the probability of an earthquake occurring",
        algorithm: AlgoKind::BlockGibbs,
        pas_flips: 1,
        model: Box::new(earthquake()),
    }
}

/// Survey Bayes net (6 nodes / 6 edges, Block Gibbs).
pub fn wl_survey() -> Workload {
    Workload {
        name: "Survey",
        model_kind: "Bayes Net",
        application: "models student grades, intelligence, and difficulty relationships",
        algorithm: AlgoKind::BlockGibbs,
        pas_flips: 1,
        model: Box::new(survey()),
    }
}

/// Cancer Bayes net (5 nodes / 4 edges) — used in Fig. 14.
pub fn wl_cancer() -> Workload {
    Workload {
        name: "Cancer",
        model_kind: "Bayes Net",
        application: "pollution/smoking cancer risk model",
        algorithm: AlgoKind::BlockGibbs,
        pas_flips: 1,
        model: Box::new(cancer()),
    }
}

/// Alarm Bayes net (37 nodes / 46 edges) — used in Fig. 14.
pub fn wl_alarm() -> Workload {
    Workload {
        name: "Alarm",
        model_kind: "Bayes Net",
        application: "patient-monitoring diagnostic network",
        algorithm: AlgoKind::BlockGibbs,
        pas_flips: 1,
        model: Box::new(alarm()),
    }
}

/// Image-segmentation MRF. `full` gives the Table I scale (150 k nodes,
/// 600 k edges via 8-connectivity); otherwise a 64×64 miniature.
pub fn wl_image_seg(full: bool) -> Workload {
    let (h, w) = if full { (387, 388) } else { (64, 64) };
    let labels = 2; // Ising-labelled segmentation per Table I
    let mut rng = Rng::new(0x5E6);
    // Synthetic image: two smooth blobs + noise drive the unary terms.
    let mut unary = vec![0.0f32; h * w * labels];
    for r in 0..h {
        for c in 0..w {
            let fr = r as f32 / h as f32 - 0.5;
            let fc = c as f32 / w as f32 - 0.5;
            let signal = (fr * 6.0).sin() * (fc * 6.0).cos();
            let noisy = signal + (rng.uniform_f32() - 0.5) * 0.8;
            let p1 = 1.0 / (1.0 + (-4.0 * noisy).exp());
            let i = r * w + c;
            unary[i * labels] = -(1.0 - p1).max(1e-6).ln();
            unary[i * labels + 1] = -p1.max(1e-6).ln();
        }
    }
    let mut grid = PottsGrid::with_connectivity(h, w, labels, 0.8, true);
    grid.set_unary(unary);
    Workload {
        name: "Image Seg.",
        model_kind: "MRF/Ising",
        application: "using MRF to perform image segmentation",
        algorithm: AlgoKind::BlockGibbs,
        pas_flips: 1,
        model: Box::new(grid),
    }
}

/// ER-1347 Maximum Independent Set (PAS), Table I "ER700" row
/// (1347 nodes / 5978 edges).
pub fn wl_mis_er() -> Workload {
    let g = erdos_renyi_with_edges(1347, 5978, 0xE7);
    Workload {
        name: "ER700",
        model_kind: "MIS",
        application: "Maximum Independent Set (Satlib-style ER graph)",
        algorithm: AlgoKind::Pas,
        pas_flips: 8,
        model: Box::new(MisModel::new(g, 1.5, None)),
    }
}

/// Twitter MaxClique (PAS), 247 nodes / 12 174 edges.
pub fn wl_maxclique_twitter() -> Workload {
    let g = power_law_graph(247, 12_174, 0x7717);
    Workload {
        name: "Twitter",
        model_kind: "Max clique",
        application: "Maximum subset of vertices, all adjacent to each other",
        algorithm: AlgoKind::Pas,
        pas_flips: 8,
        model: Box::new(MaxCliqueModel::new(g, 1.5, None)),
    }
}

/// Optsicom MaxCut (PAS), 125 nodes / 375 edges, small integer weights.
pub fn wl_maxcut_optsicom() -> Workload {
    let (g, _) = random_regular_ish(125, 375, (1, 10), 0x097);
    Workload {
        name: "Optsicom",
        model_kind: "MaxCut",
        application: "Partition vertices into two sets to maximize edge cuts",
        algorithm: AlgoKind::Pas,
        pas_flips: 8,
        model: Box::new(MaxCutModel::new(g, None)),
    }
}

/// Binary RBM 784×25 (PAS), Table I EBM row (809 nodes / ~19 k edges).
pub fn wl_rbm() -> Workload {
    Workload {
        name: "RBM",
        model_kind: "EBM",
        application: "Binary RBM with hidden dimension 25",
        algorithm: AlgoKind::Pas,
        pas_flips: 8,
        model: Box::new(Rbm::synthetic(784, 25, 0xB0)),
    }
}

/// The full Table I suite (full-scale models; slow to construct for the
/// MRF row — prefer [`suite_small`] in tests).
pub fn suite_full() -> Vec<Workload> {
    vec![
        wl_earthquake(),
        wl_survey(),
        wl_image_seg(true),
        wl_mis_er(),
        wl_maxclique_twitter(),
        wl_maxcut_optsicom(),
        wl_rbm(),
    ]
}

/// Scaled-down suite with identical structure (fast tests / CI).
pub fn suite_small() -> Vec<Workload> {
    vec![
        wl_earthquake(),
        wl_survey(),
        wl_image_seg(false),
        Workload {
            name: "ER-small",
            model_kind: "MIS",
            application: "small ER MIS",
            algorithm: AlgoKind::Pas,
            pas_flips: 4,
            model: Box::new(MisModel::new(erdos_renyi_with_edges(120, 530, 0xE7), 1.5, None)),
        },
        Workload {
            name: "Twitter-small",
            model_kind: "Max clique",
            application: "small power-law clique",
            algorithm: AlgoKind::Pas,
            pas_flips: 4,
            model: Box::new(MaxCliqueModel::new(power_law_graph(60, 700, 0x7717), 1.5, None)),
        },
        wl_maxcut_optsicom(),
        Workload {
            name: "RBM-small",
            model_kind: "EBM",
            application: "small binary RBM",
            algorithm: AlgoKind::Pas,
            pas_flips: 4,
            model: Box::new(Rbm::synthetic(64, 8, 0xB0)),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_row_counts() {
        let eq = wl_earthquake();
        assert_eq!(eq.nodes(), 5);
        let sv = wl_survey();
        assert_eq!(sv.nodes(), 6);
        let mis = wl_mis_er();
        assert_eq!(mis.nodes(), 1347);
        assert_eq!(mis.edges(), 5978);
        let tw = wl_maxclique_twitter();
        assert_eq!(tw.nodes(), 247);
        let mc = wl_maxcut_optsicom();
        assert_eq!(mc.nodes(), 125);
        assert_eq!(mc.edges(), 375);
        let rbm = wl_rbm();
        assert_eq!(rbm.nodes(), 809);
        assert_eq!(rbm.edges(), 19_600);
    }

    #[test]
    fn image_seg_full_scale_counts() {
        let seg = wl_image_seg(true);
        // Table I: ~150k nodes, ~600k edges.
        assert!((149_000..=151_000).contains(&seg.nodes()), "{}", seg.nodes());
        assert!((595_000..=605_000).contains(&seg.edges()), "{}", seg.edges());
    }

    #[test]
    fn small_suite_runs_one_step_each() {
        use crate::mcmc::{build_algo, BetaSchedule, Chain, SamplerKind};
        for wl in suite_small() {
            let algo = build_algo(wl.algorithm, SamplerKind::Gumbel, wl.model.as_ref(), wl.pas_flips);
            let mut chain = Chain::new(wl.model.as_ref(), algo, BetaSchedule::Constant(1.0), 9);
            chain.run(1);
            assert!(chain.stats.updates > 0, "{} made no updates", wl.name);
        }
    }
}
