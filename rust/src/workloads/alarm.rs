//! The ALARM network (Beinlich et al.): 37 nodes / 46 edges.
//!
//! The *structure* (nodes, cardinalities, parent sets) is the published
//! one; the CPT entries are deterministic synthetic distributions
//! (Dirichlet-like draws from the workload RNG) because the full
//! parameter tables are not redistributable here — see DESIGN.md §4.
//! Every structural statistic the paper relies on (graph irregularity,
//! Markov-blanket sizes, CPT memory footprint) is preserved.

use crate::energy::{BayesNet, Cpt};
use crate::rng::Rng;

/// Node ids follow this table's order.
const NODES: &[(&str, u32, &[u32])] = &[
    ("HYPOVOLEMIA", 2, &[]),            // 0
    ("LVFAILURE", 2, &[]),              // 1
    ("HISTORY", 2, &[1]),               // 2
    ("LVEDVOLUME", 3, &[0, 1]),         // 3
    ("CVP", 3, &[3]),                   // 4
    ("PCWP", 3, &[3]),                  // 5
    ("STROKEVOLUME", 3, &[0, 1]),       // 6
    ("ERRLOWOUTPUT", 2, &[]),           // 7
    ("ERRCAUTER", 2, &[]),              // 8
    ("INSUFFANESTH", 2, &[]),           // 9
    ("ANAPHYLAXIS", 2, &[]),            // 10
    ("TPR", 3, &[10]),                  // 11
    ("KINKEDTUBE", 2, &[]),             // 12
    ("FIO2", 2, &[]),                   // 13
    ("PULMEMBOLUS", 2, &[]),            // 14
    ("PAP", 3, &[14]),                  // 15
    ("INTUBATION", 3, &[]),             // 16
    ("SHUNT", 2, &[16, 14]),            // 17
    ("DISCONNECT", 2, &[]),             // 18
    ("MINVOLSET", 3, &[]),              // 19
    ("VENTMACH", 4, &[19]),             // 20
    ("VENTTUBE", 4, &[18, 20]),         // 21
    ("PRESS", 4, &[16, 12, 21]),        // 22
    ("VENTLUNG", 4, &[16, 12, 21]),     // 23
    ("MINVOL", 4, &[16, 23]),           // 24
    ("VENTALV", 4, &[16, 23]),          // 25
    ("ARTCO2", 3, &[25]),               // 26
    ("EXPCO2", 4, &[26, 23]),           // 27
    ("PVSAT", 3, &[13, 25]),            // 28
    ("SAO2", 3, &[28, 17]),             // 29
    ("CATECHOL", 2, &[26, 9, 29, 11]),  // 30
    ("HR", 3, &[30]),                   // 31
    ("HRBP", 3, &[7, 31]),              // 32
    ("HREKG", 3, &[8, 31]),             // 33
    ("HRSAT", 3, &[8, 31]),             // 34
    ("CO", 3, &[31, 6]),                // 35
    ("BP", 3, &[35, 11]),               // 36
];

/// Build the ALARM network with deterministic synthetic CPTs.
pub fn alarm() -> BayesNet {
    let mut rng = Rng::new(0xA1A2);
    let cards: Vec<u32> = NODES.iter().map(|&(_, c, _)| c).collect();
    let cpts: Vec<Cpt> = NODES
        .iter()
        .map(|&(_, card, parents)| {
            let cfgs: usize = parents
                .iter()
                .map(|&p| cards[p as usize] as usize)
                .product();
            let mut table = Vec::with_capacity(cfgs * card as usize);
            for _ in 0..cfgs {
                // Peaked Dirichlet-like row: one dominant state per
                // configuration, like real diagnostic CPTs.
                let dominant = rng.below(card as usize);
                let mut row: Vec<f64> = (0..card as usize)
                    .map(|s| {
                        let base = if s == dominant { 4.0 } else { 0.4 };
                        base + rng.uniform_f64()
                    })
                    .collect();
                let z: f64 = row.iter().sum();
                for v in &mut row {
                    *v /= z;
                }
                table.extend(row);
            }
            Cpt {
                parents: parents.to_vec(),
                card,
                table,
            }
        })
        .collect();
    BayesNet::new("alarm", cpts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;

    #[test]
    fn alarm_structure_counts() {
        let net = alarm();
        assert_eq!(net.num_vars(), 37);
        assert_eq!(net.num_dag_edges(), 46);
    }

    #[test]
    fn alarm_cpts_normalized_and_deterministic() {
        let a = alarm();
        let b = alarm();
        for i in 0..37 {
            assert!(a.cpt(i).is_normalized(1e-9));
            assert_eq!(a.cpt(i).table, b.cpt(i).table);
        }
    }

    #[test]
    fn alarm_markov_blankets_irregular() {
        let net = alarm();
        let g = net.interaction();
        let degs: Vec<usize> = (0..37).map(|i| g.degree(i)).collect();
        // CATECHOL has 4 parents + 1 child (HR): blanket of ≥ 5.
        assert!(degs[30] >= 5);
        // Irregularity: spread between min and max blanket size.
        assert!(degs.iter().max().unwrap() - degs.iter().min().unwrap() >= 5);
    }

    #[test]
    fn alarm_energy_finite() {
        let net = alarm();
        let x = vec![0u32; 37];
        assert!(net.energy(&x).is_finite());
    }
}
