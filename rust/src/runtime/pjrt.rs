//! The real PJRT client (`xla-runtime` feature): one CPU client plus
//! the compiled artifact set. Requires the vendored `xla` crate — see
//! the feature note in `Cargo.toml`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{parse_manifest, ArtifactSpec};

/// A loaded, compiled artifact ready for execution.
pub struct LoadedArtifact {
    /// Manifest metadata.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + the compiled artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and load every artifact listed in
    /// `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?;
        let mut artifacts = HashMap::new();
        for spec in parse_manifest(&manifest)? {
            let path = dir.join(format!("{}.hlo.txt", spec.name));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
            artifacts.insert(spec.name.clone(), LoadedArtifact { spec, exe });
        }
        Ok(Runtime {
            client,
            artifacts,
            dir,
        })
    }

    /// PJRT platform name (should be "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Metadata for one artifact.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name).map(|a| &a.spec)
    }

    /// Execute artifact `name` on f32 buffers (one slice per argument,
    /// shapes validated against the manifest). Returns the flattened
    /// f32 contents of each tuple output.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have {:?}", self.names()))?;
        if inputs.len() != art.spec.inputs.len() {
            bail!(
                "{name}: got {} inputs, manifest says {}",
                inputs.len(),
                art.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (&data, spec)) in inputs.iter().zip(&art.spec.inputs).enumerate() {
            if data.len() != spec.elements() {
                bail!(
                    "{name}: input {k} has {} elements, expected {} ({:?})",
                    data.len(),
                    spec.elements(),
                    spec.dims
                );
            }
            let lit = if spec.dims.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("{name}: reshape input {k}: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name}: execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetch: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("{name}: untuple: {e:?}"))?;
        let mut flat = Vec::with_capacity(outs.len());
        for (k, o) in outs.into_iter().enumerate() {
            flat.push(
                o.to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output {k} to f32: {e:?}"))?,
            );
        }
        Ok(flat)
    }
}
