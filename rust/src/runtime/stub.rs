//! Stub runtime compiled when the `xla-runtime` feature is off.
//!
//! Presents the exact same surface as the real PJRT [`Runtime`] so
//! every caller typechecks, but `load` always fails with a message
//! naming the missing feature. The struct is uninhabited (it wraps an
//! empty enum), so the remaining methods are statically unreachable —
//! no panics, no dead code paths at runtime.

use std::path::Path;

use anyhow::{bail, Result};

use super::ArtifactSpec;

enum Never {}

/// Uninhabited stand-in for the PJRT runtime (`xla-runtime` feature off).
pub struct Runtime {
    _never: Never,
}

impl Runtime {
    /// Always fails: the crate was built without the `xla-runtime`
    /// feature, so no PJRT client exists to load artifacts with.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        bail!(
            "mc2a was built without the `xla-runtime` feature, so the PJRT \
             path is unavailable (artifact dir: {}); rebuild with \
             `--features xla-runtime` and the vendored `xla` crate",
            dir.as_ref().display()
        )
    }

    /// PJRT platform name (unreachable on the stub).
    pub fn platform(&self) -> String {
        match self._never {}
    }

    /// Artifact directory (unreachable on the stub).
    pub fn dir(&self) -> &Path {
        match self._never {}
    }

    /// Names of all loaded artifacts (unreachable on the stub).
    pub fn names(&self) -> Vec<&str> {
        match self._never {}
    }

    /// Metadata for one artifact (unreachable on the stub).
    pub fn spec(&self, _name: &str) -> Option<&ArtifactSpec> {
        match self._never {}
    }

    /// Execute an artifact (unreachable on the stub).
    pub fn execute_f32(&self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        match self._never {}
    }
}
