//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! This is the request-path half of the three-layer architecture:
//! Python runs once at build time (`make artifacts`); afterwards the
//! Rust binary is self-contained — every software-baseline measurement
//! (Fig. 5d, Fig. 14 "CPU") goes through this module, never through a
//! Python interpreter.
//!
//! The XLA/PJRT dependency is gated behind the off-by-default
//! `xla-runtime` cargo feature. Without it, [`Runtime`] is a stub
//! whose `load` always fails with a clear message, so every caller
//! (CLI `runtime-check`, Fig. 14's measured rows, the engine's
//! `RuntimeBackend`) degrades gracefully instead of failing to build.
//! The manifest format and its parser are feature-independent.

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{LoadedArtifact, Runtime};

#[cfg(not(feature = "xla-runtime"))]
mod stub;
#[cfg(not(feature = "xla-runtime"))]
pub use stub::Runtime;

use anyhow::{anyhow, bail, Context, Result};

/// Shape+dtype of one artifact argument (dtype is always f32 in this
/// reproduction; scalars have an empty dims list).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgSpec {
    /// Dimensions; empty = scalar.
    pub dims: Vec<usize>,
}

impl ArgSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<ArgSpec> {
        let (shape, dtype) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad arg spec {s:?}"))?;
        if dtype != "f32" {
            bail!("unsupported dtype {dtype}");
        }
        if shape == "scalar" {
            return Ok(ArgSpec { dims: Vec::new() });
        }
        let dims = shape
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec { dims })
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Entry-point name (file stem).
    pub name: String,
    /// Argument shapes in call order.
    pub inputs: Vec<ArgSpec>,
    /// Number of tuple outputs.
    pub num_outputs: usize,
    /// Static-parameter note from the AOT step (informational).
    pub static_params: String,
}

/// Parse `manifest.txt` (`name|in0,in1,...|out_count|static`).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('|').collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 4 fields", lineno + 1);
        }
        let inputs = parts[1]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(ArgSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        specs.push(ArtifactSpec {
            name: parts[0].to_string(),
            inputs,
            num_outputs: parts[2].parse().context("bad output count")?,
            static_params: parts[3].to_string(),
        });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_spec_parsing() {
        assert_eq!(ArgSpec::parse("4x8:f32").unwrap().dims, vec![4, 8]);
        assert_eq!(ArgSpec::parse("scalar:f32").unwrap().dims, Vec::<usize>::new());
        assert_eq!(ArgSpec::parse("scalar:f32").unwrap().elements(), 1);
        assert_eq!(ArgSpec::parse("4x8:f32").unwrap().elements(), 32);
        assert!(ArgSpec::parse("4x8:i64").is_err());
        assert!(ArgSpec::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parsing() {
        let text = "\
# comment
gumbel_sample|64x256:f32,64x256:f32,scalar:f32|1|B=64,N=256
ising_step|64x64:f32,64x64:f32,64x64:f32,scalar:f32,scalar:f32|1|H=64,W=64
";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "gumbel_sample");
        assert_eq!(specs[0].inputs.len(), 3);
        assert_eq!(specs[0].num_outputs, 1);
        assert_eq!(specs[1].inputs[0].dims, vec![64, 64]);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("name|only|three").is_err());
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stub_runtime_load_fails_with_feature_hint() {
        let err = match Runtime::load("artifacts") {
            Ok(_) => panic!("stub runtime loaded"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("xla-runtime"), "{err:#}");
    }
}
