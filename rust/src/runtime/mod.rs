//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! This is the request-path half of the three-layer architecture:
//! Python runs once at build time (`make artifacts`); afterwards the
//! Rust binary is self-contained — every software-baseline measurement
//! (Fig. 5d, Fig. 14 "CPU") goes through this module, never through a
//! Python interpreter.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Shape+dtype of one artifact argument (dtype is always f32 in this
/// reproduction; scalars have an empty dims list).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgSpec {
    /// Dimensions; empty = scalar.
    pub dims: Vec<usize>,
}

impl ArgSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<ArgSpec> {
        let (shape, dtype) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad arg spec {s:?}"))?;
        if dtype != "f32" {
            bail!("unsupported dtype {dtype}");
        }
        if shape == "scalar" {
            return Ok(ArgSpec { dims: Vec::new() });
        }
        let dims = shape
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec { dims })
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Entry-point name (file stem).
    pub name: String,
    /// Argument shapes in call order.
    pub inputs: Vec<ArgSpec>,
    /// Number of tuple outputs.
    pub num_outputs: usize,
    /// Static-parameter note from the AOT step (informational).
    pub static_params: String,
}

/// Parse `manifest.txt` (`name|in0,in1,...|out_count|static`).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('|').collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 4 fields", lineno + 1);
        }
        let inputs = parts[1]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(ArgSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        specs.push(ArtifactSpec {
            name: parts[0].to_string(),
            inputs,
            num_outputs: parts[2].parse().context("bad output count")?,
            static_params: parts[3].to_string(),
        });
    }
    Ok(specs)
}

/// A loaded, compiled artifact ready for execution.
pub struct LoadedArtifact {
    /// Manifest metadata.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + the compiled artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and load every artifact listed in
    /// `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?;
        let mut artifacts = HashMap::new();
        for spec in parse_manifest(&manifest)? {
            let path = dir.join(format!("{}.hlo.txt", spec.name));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
            artifacts.insert(spec.name.clone(), LoadedArtifact { spec, exe });
        }
        Ok(Runtime {
            client,
            artifacts,
            dir,
        })
    }

    /// PJRT platform name (should be "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Metadata for one artifact.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name).map(|a| &a.spec)
    }

    /// Execute artifact `name` on f32 buffers (one slice per argument,
    /// shapes validated against the manifest). Returns the flattened
    /// f32 contents of each tuple output.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have {:?}", self.names()))?;
        if inputs.len() != art.spec.inputs.len() {
            bail!(
                "{name}: got {} inputs, manifest says {}",
                inputs.len(),
                art.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (&data, spec)) in inputs.iter().zip(&art.spec.inputs).enumerate() {
            if data.len() != spec.elements() {
                bail!(
                    "{name}: input {k} has {} elements, expected {} ({:?})",
                    data.len(),
                    spec.elements(),
                    spec.dims
                );
            }
            let lit = if spec.dims.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("{name}: reshape input {k}: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name}: execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetch: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("{name}: untuple: {e:?}"))?;
        let mut flat = Vec::with_capacity(outs.len());
        for (k, o) in outs.into_iter().enumerate() {
            flat.push(
                o.to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: output {k} to f32: {e:?}"))?,
            );
        }
        Ok(flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_spec_parsing() {
        assert_eq!(ArgSpec::parse("4x8:f32").unwrap().dims, vec![4, 8]);
        assert_eq!(ArgSpec::parse("scalar:f32").unwrap().dims, Vec::<usize>::new());
        assert_eq!(ArgSpec::parse("scalar:f32").unwrap().elements(), 1);
        assert_eq!(ArgSpec::parse("4x8:f32").unwrap().elements(), 32);
        assert!(ArgSpec::parse("4x8:i64").is_err());
        assert!(ArgSpec::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parsing() {
        let text = "\
# comment
gumbel_sample|64x256:f32,64x256:f32,scalar:f32|1|B=64,N=256
ising_step|64x64:f32,64x64:f32,64x64:f32,scalar:f32,scalar:f32|1|H=64,W=64
";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "gumbel_sample");
        assert_eq!(specs[0].inputs.len(), 3);
        assert_eq!(specs[0].num_outputs, 1);
        assert_eq!(specs[1].inputs[0].dims, vec![64, 64]);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("name|only|three").is_err());
    }
}
