//! Standalone Sampler-Unit behavioral models — the Fig. 9(d) / Fig. 13
//! comparison between the baseline CDF sampler (SPU/PGMA-style) and the
//! MC²A Gumbel sampler.
//!
//! The CDF unit must (1) exponentiate each energy, (2) accumulate the
//! cumulative distribution table into an internal register file, then
//! (3) sequentially search it: `O(2N + 1)` cycles and an internal CDT
//! RF that caps the supported distribution size. The Gumbel unit
//! streams bins through noise-add + compare in `O(N)` fully-pipelined
//! cycles with no CDT storage, so its utilization stays flat as N
//! grows (and nothing caps N architecturally).

use crate::isa::HwConfig;

/// Result of sampling one size-`n` categorical on a hardware SU model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SuCost {
    /// Cycles to produce one sample.
    pub cycles: u64,
    /// Fraction of datapath slots doing useful work in those cycles.
    pub utilization: f64,
    /// Whether the unit supports this distribution at all.
    pub supported: bool,
}

/// Baseline CDF sampler unit (Fig. 9b), as in SPU / PGMA / CoopMC.
#[derive(Clone, Copy, Debug)]
pub struct CdfSuModel {
    /// Internal CDT register-file capacity (entries). SPU/PGMA-class
    /// designs are reported with 64–128-entry tables; distributions
    /// beyond the capacity are unsupported (Fig. 13 "fails at 256").
    pub cdt_capacity: usize,
    /// exp-unit latency per bin (cycles).
    pub exp_latency: u64,
}

impl Default for CdfSuModel {
    fn default() -> CdfSuModel {
        CdfSuModel {
            cdt_capacity: 128,
            exp_latency: 1,
        }
    }
}

impl CdfSuModel {
    /// Cost of drawing one sample from a size-`n` distribution.
    pub fn sample_cost(&self, n: usize) -> SuCost {
        if n > self.cdt_capacity {
            return SuCost {
                cycles: u64::MAX,
                utilization: 0.0,
                supported: false,
            };
        }
        // exp+accumulate pass (N cycles, sequential because of the
        // running CDT sum), then scale (1) and sequential search
        // (expected N/2, worst N). Matches the paper's O(2N+1).
        let cycles = self.exp_latency * n as u64 + 1 + n as u64;
        // Useful work = N bins processed; the datapath is single-lane,
        // and the search phase re-touches bins: utilization decays with
        // the search overhead.
        let useful = n as f64;
        SuCost {
            cycles,
            utilization: useful / cycles as f64,
            supported: true,
        }
    }

    /// Samples per second at `clock_ghz`.
    pub fn throughput_sps(&self, n: usize, clock_ghz: f64) -> f64 {
        let c = self.sample_cost(n);
        if !c.supported {
            0.0
        } else {
            clock_ghz * 1e9 / c.cycles as f64
        }
    }
}

/// MC²A Gumbel sampler unit (Fig. 9c), temporal or spatial mode.
#[derive(Clone, Copy, Debug)]
pub struct GumbelSuModel {
    /// Number of sample elements (spatial-mode tree width).
    pub s: usize,
}

impl GumbelSuModel {
    /// From a hardware config.
    pub fn from_hw(hw: &HwConfig) -> GumbelSuModel {
        GumbelSuModel { s: hw.s }
    }

    /// Temporal mode: one SE walks the N bins, 1 bin/cycle, running
    /// argmax in the comparator — O(N), fully pipelined with the CU.
    pub fn sample_cost_temporal(&self, n: usize) -> SuCost {
        SuCost {
            cycles: n as u64,
            utilization: 1.0,
            supported: true,
        }
    }

    /// Spatial mode: the S SEs form a comparator tree and chew S bins
    /// per cycle: `ceil(N/S)` cycles per sample.
    pub fn sample_cost_spatial(&self, n: usize) -> SuCost {
        let cycles = (n as u64).div_ceil(self.s as u64);
        let useful = n as f64;
        SuCost {
            cycles,
            utilization: useful / (cycles as f64 * self.s as f64),
            supported: true,
        }
    }

    /// Temporal-mode samples per second for one SE.
    pub fn throughput_sps_temporal(&self, n: usize, clock_ghz: f64) -> f64 {
        clock_ghz * 1e9 / self.sample_cost_temporal(n).cycles as f64
    }

    /// Spatial-mode samples per second.
    pub fn throughput_sps_spatial(&self, n: usize, clock_ghz: f64) -> f64 {
        clock_ghz * 1e9 / self.sample_cost_spatial(n).cycles as f64
    }
}

/// One row of the Fig. 13 comparison.
#[derive(Clone, Copy, Debug)]
pub struct Fig13Row {
    /// Distribution size.
    pub n: usize,
    /// CDF sampler throughput (samples/s); 0 when unsupported.
    pub cdf_sps: f64,
    /// CDF hardware utilization.
    pub cdf_util: f64,
    /// Gumbel sampler (temporal) throughput.
    pub gumbel_sps: f64,
    /// Gumbel utilization (stays ≈ 1).
    pub gumbel_util: f64,
}

/// Generate the Fig. 13 sweep over distribution sizes.
pub fn fig13_sweep(hw: &HwConfig, sizes: &[usize]) -> Vec<Fig13Row> {
    let cdf = CdfSuModel::default();
    let gum = GumbelSuModel::from_hw(hw);
    sizes
        .iter()
        .map(|&n| {
            let c = cdf.sample_cost(n);
            Fig13Row {
                n,
                cdf_sps: cdf.throughput_sps(n, hw.clock_ghz),
                cdf_util: if c.supported { c.utilization } else { 0.0 },
                gumbel_sps: gum.throughput_sps_temporal(n, hw.clock_ghz),
                gumbel_util: gum.sample_cost_temporal(n).utilization,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_is_2n_plus_1() {
        let cdf = CdfSuModel::default();
        assert_eq!(cdf.sample_cost(64).cycles, 129);
        assert_eq!(cdf.sample_cost(8).cycles, 17);
    }

    #[test]
    fn gumbel_is_n() {
        let g = GumbelSuModel { s: 64 };
        assert_eq!(g.sample_cost_temporal(64).cycles, 64);
        assert_eq!(g.sample_cost_spatial(64).cycles, 1);
        assert_eq!(g.sample_cost_spatial(256).cycles, 4);
    }

    #[test]
    fn gumbel_always_2x_faster_than_cdf() {
        // Fig. 9(d): the pipeline reduces time complexity by ~2×.
        let cdf = CdfSuModel::default();
        let g = GumbelSuModel { s: 64 };
        for n in [8usize, 16, 32, 64, 128] {
            let ratio = cdf.sample_cost(n).cycles as f64
                / g.sample_cost_temporal(n).cycles as f64;
            assert!(ratio >= 2.0, "n={n} ratio={ratio}");
        }
    }

    #[test]
    fn cdf_fails_at_256() {
        // Fig. 13: CDF "fails at size-256" (CDT RF capacity).
        let cdf = CdfSuModel::default();
        assert!(!cdf.sample_cost(256).supported);
        assert_eq!(cdf.throughput_sps(256, 0.5), 0.0);
    }

    #[test]
    fn cdf_utilization_drops_with_size() {
        let cdf = CdfSuModel::default();
        let u8 = cdf.sample_cost(8).utilization;
        let u128 = cdf.sample_cost(128).utilization;
        assert!(u128 < u8 || (u128 - u8).abs() < 0.05);
        // Gumbel stays flat at 1.0.
        let g = GumbelSuModel { s: 64 };
        assert_eq!(g.sample_cost_temporal(8).utilization, 1.0);
        assert_eq!(g.sample_cost_temporal(128).utilization, 1.0);
    }

    #[test]
    fn fig13_sweep_shape() {
        let hw = HwConfig::paper_default();
        let rows = fig13_sweep(&hw, &[8, 16, 32, 64, 128, 256]);
        assert_eq!(rows.len(), 6);
        // Gumbel throughput consistent across sizes (scales as 1/N for
        // both, but Gumbel ≥ 2× CDF wherever CDF works, and Gumbel
        // still works at 256 where CDF is zero).
        for r in &rows {
            if r.cdf_sps > 0.0 {
                assert!(r.gumbel_sps >= 2.0 * r.cdf_sps, "n={}", r.n);
            }
        }
        assert_eq!(rows[5].cdf_sps, 0.0);
        assert!(rows[5].gumbel_sps > 0.0);
    }
}
