//! Cycle-accurate simulator of the MC²A accelerator (Fig. 7a).
//!
//! The simulator is split the way architecture simulators usually are:
//!
//! * **Timing model** — consumes only the *architectural* instruction
//!   fields (loads, routes, CU/SU control, stores) and advances a cycle
//!   counter modeling the 4-stage pipeline: VLIW issue (1 instr/cycle),
//!   memory-bandwidth stalls on Load, RF bank-conflict stalls on reads
//!   and writes, CU occupancy (K+1-stage pipelined tree) and SU
//!   occupancy (temporal: 1 bin/SE/cycle; spatial: S bins/cycle). The
//!   HWLOOP unit repeats the body once per MCMC iteration.
//! * **Functional model** — consumes the compiler-attached
//!   [`Semantics`] markers to evolve the actual MCMC state using the
//!   hardware Gumbel-LUT sampler, so the simulator produces *real
//!   samples*: its marginals are validated against the software chains
//!   in the integration tests.
//!
//! The paper's own evaluation is built on exactly such a simulator
//! ("A cycle-accurate simulator is developed to profile the
//! accelerator", §VI-A).

pub mod energy;
pub mod multicore;
pub mod su;

pub use energy::{EnergyBreakdown, EnergyParams};
pub use multicore::{MultiCoreReport, MultiCoreSim};

use crate::energy::EnergyModel;
use crate::isa::{CtrlType, HwConfig, Instr, Program, Semantics, SuMode};
use crate::mcmc::sampler::{CategoricalSampler, GumbelLutSampler};
use crate::mcmc::{BetaSchedule, Mcmc, PathAuxiliarySampler};
use crate::rng::Rng;

/// Aggregated simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Total cycles simulated.
    pub cycles: u64,
    /// Instructions issued (incl. NOPs).
    pub instrs: u64,
    /// NOPs issued (hazard fillers).
    pub nops: u64,
    /// Extra cycles from memory-bandwidth saturation.
    pub stall_mem_bw: u64,
    /// Extra cycles from RF bank conflicts.
    pub stall_bank: u64,
    /// Cycles spent idle at multi-core synchronization barriers,
    /// waiting for slower shards (0 on single-core runs).
    pub stall_sync: u64,
    /// Cycles spent on the shared crossbar / histogram port moving
    /// boundary state between cores (0 on single-core runs).
    pub stall_xbar: u64,
    /// 32-bit words exchanged over the inter-core crossbar (boundary
    /// broadcasts + shared-histogram commits; 0 on single-core runs).
    pub xfer_words: u64,
    /// Cycles where the CU had work.
    pub cu_busy: u64,
    /// Cycles where the SU had work.
    pub su_busy: u64,
    /// Cycles where the memory interface had work.
    pub mem_busy: u64,
    /// 32-bit words loaded from on-chip memory.
    pub load_words: u64,
    /// 32-bit words stored to on-chip memory.
    pub store_words: u64,
    /// RV updates committed.
    pub updates: u64,
    /// Categorical samples drawn.
    pub samples: u64,
    /// MCMC iterations (HWLOOP trips) completed.
    pub iterations: u64,
    /// Energy breakdown.
    pub energy: EnergyBreakdown,
}

impl SimReport {
    /// Wall-clock seconds at the configured clock.
    pub fn seconds(&self, hw: &HwConfig) -> f64 {
        self.cycles as f64 / (hw.clock_ghz * 1e9)
    }

    /// Throughput in Giga-samples per second (the paper's TP axis).
    pub fn gsps(&self, hw: &HwConfig) -> f64 {
        let s = self.seconds(hw);
        if s <= 0.0 {
            0.0
        } else {
            self.samples as f64 / s / 1e9
        }
    }

    /// RV updates per second.
    pub fn updates_per_sec(&self, hw: &HwConfig) -> f64 {
        let s = self.seconds(hw);
        if s <= 0.0 {
            0.0
        } else {
            self.updates as f64 / s
        }
    }

    /// CU utilization in [0, 1].
    pub fn cu_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.cu_busy as f64 / self.cycles as f64
        }
    }

    /// SU utilization in [0, 1].
    pub fn su_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.su_busy as f64 / self.cycles as f64
        }
    }

    /// Fraction of cycles lost to multi-core synchronization (barrier
    /// waits + shared-interconnect transfers); 0 on single-core runs.
    pub fn sync_overhead(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            (self.stall_sync + self.stall_xbar) as f64 / self.cycles as f64
        }
    }

    /// Average power in watts.
    pub fn watts(&self, hw: &HwConfig) -> f64 {
        self.energy.avg_watts(self.seconds(hw))
    }

    /// Energy efficiency in GS/s/W (Fig. 15 metric).
    pub fn gsps_per_watt(&self, hw: &HwConfig) -> f64 {
        let w = self.watts(hw);
        if w <= 0.0 {
            0.0
        } else {
            self.gsps(hw) / w
        }
    }
}

/// Build the flattened per-RV state-count layout shared by the
/// sample/histogram memories: per-RV offsets (length `n + 1`) plus the
/// total word count.
fn hist_layout(model: &dyn EnergyModel) -> (Vec<usize>, usize) {
    let mut offsets = Vec::with_capacity(model.num_vars() + 1);
    let mut acc = 0usize;
    for i in 0..model.num_vars() {
        offsets.push(acc);
        acc += model.num_states(i);
    }
    offsets.push(acc);
    (offsets, acc)
}

/// Empirical marginal of RV `i` from a flattened histogram.
fn marginal_of(hist: &[u64], offsets: &[usize], i: usize) -> Vec<f64> {
    let span = &hist[offsets[i]..offsets[i + 1]];
    let total: u64 = span.iter().sum();
    span.iter().map(|&c| c as f64 / total.max(1) as f64).collect()
}

/// The MC²A accelerator simulator bound to a workload model.
pub struct Simulator<'m> {
    hw: HwConfig,
    eparams: EnergyParams,
    model: &'m dyn EnergyModel,
    /// Architectural state: the sample memory (current assignment).
    pub x: Vec<u32>,
    /// Histogram memory (flattened per-RV state counts).
    hist: Vec<u64>,
    hist_offsets: Vec<usize>,
    sampler: GumbelLutSampler,
    pas: PathAuxiliarySampler,
    rng: Rng,
    snapshot: Option<Vec<u32>>,
    scratch: Vec<f32>,
    beta: f32,
}

impl<'m> Simulator<'m> {
    /// Create a simulator with a random initial state.
    pub fn new(
        hw: HwConfig,
        model: &'m dyn EnergyModel,
        pas_flips: usize,
        seed: u64,
    ) -> Simulator<'m> {
        hw.validate().expect("invalid hardware config");
        let mut rng = Rng::new(seed);
        let x = crate::energy::random_state(model, &mut rng);
        let (hist_offsets, acc) = hist_layout(model);
        Simulator {
            sampler: GumbelLutSampler::new(hw.lut_size, hw.lut_bits),
            hw,
            eparams: EnergyParams::default(),
            model,
            x,
            hist: vec![0; acc],
            hist_offsets,
            pas: PathAuxiliarySampler::new(pas_flips.max(1)),
            rng,
            snapshot: None,
            scratch: Vec::new(),
            beta: 1.0,
        }
    }

    /// Set the inverse temperature used by the functional model.
    pub fn set_beta(&mut self, beta: f32) {
        self.beta = beta;
    }

    /// Current inverse temperature of the functional model.
    pub fn beta(&self) -> f32 {
        self.beta
    }

    /// Override energy parameters.
    pub fn set_energy_params(&mut self, p: EnergyParams) {
        self.eparams = p;
    }

    /// Empirical marginal of RV `i` from the histogram memory.
    pub fn marginal(&self, i: usize) -> Vec<f64> {
        marginal_of(&self.hist, &self.hist_offsets, i)
    }

    /// Run `iterations` HWLOOP trips of `program`, returning the report.
    pub fn run(&mut self, program: &Program, iterations: usize) -> SimReport {
        self.run_observed(program, iterations, None, &mut |_, _, _| true)
    }

    /// [`Simulator::run`] with two engine hooks: an optional β
    /// `schedule` evaluated once per HWLOOP iteration (so annealed
    /// runs sweep the schedule instead of holding one temperature),
    /// and an `observe(iter, report_so_far, state)` callback invoked
    /// after every iteration; returning `false` stops the run early
    /// (the engine's cooperative early-stop path).
    pub fn run_observed(
        &mut self,
        program: &Program,
        iterations: usize,
        schedule: Option<BetaSchedule>,
        observe: &mut dyn FnMut(usize, &SimReport, &[u32]) -> bool,
    ) -> SimReport {
        let betas: Option<Vec<f32>> =
            schedule.map(|s| (0..iterations).map(|t| s.beta(t)).collect());
        let mut rep = self.begin_run(program);
        self.advance_run(program, &mut rep, 0, iterations, betas.as_deref(), observe);
        self.finish_run(&mut rep);
        rep
    }

    /// Begin a segmented run: execute the prologue into a fresh
    /// report. Together with [`Simulator::advance_run`] and
    /// [`Simulator::finish_run`] this is the engine's adaptive-
    /// annealing entry point — the controller advances the simulator
    /// one observation segment at a time, choosing each segment's β
    /// values from the previous segment's diagnostics.
    pub fn begin_run(&mut self, program: &Program) -> SimReport {
        let mut rep = SimReport::default();
        for instr in &program.prologue {
            self.execute(instr, &mut rep);
        }
        rep
    }

    /// Advance `n` HWLOOP iterations (global indices `iter0 .. iter0 +
    /// n`), accumulating into `rep`. `betas[j]` (when given) is
    /// applied before iteration `iter0 + j`; `observe` runs after
    /// every iteration and returning `false` stops the run. Returns
    /// `false` when the run was stopped early.
    #[allow(clippy::too_many_arguments)]
    pub fn advance_run(
        &mut self,
        program: &Program,
        rep: &mut SimReport,
        iter0: usize,
        n: usize,
        betas: Option<&[f32]>,
        observe: &mut dyn FnMut(usize, &SimReport, &[u32]) -> bool,
    ) -> bool {
        let _span = crate::engine::telemetry::span_with("sim", || {
            format!("sim segment {iter0}..{}", iter0 + n)
        });
        for j in 0..n {
            let iter = iter0 + j;
            if let Some(b) = betas {
                self.beta = b[j];
            }
            for instr in &program.body {
                self.execute(instr, rep);
            }
            // Pipeline drain at the loop boundary: the HWLOOP must not
            // start re-reading sample memory while stores are in flight.
            let drain = self.hw.cu_latency() as u64;
            rep.cycles += drain;
            rep.energy.ifetch += drain as f64 * self.eparams.pj_ifetch;
            rep.iterations += 1;
            // Histogram memory update (one per RV per iteration).
            for i in 0..self.model.num_vars() {
                self.hist[self.hist_offsets[i] + self.x[i] as usize] += 1;
            }
            if !observe(iter, rep, &self.x) {
                return false;
            }
        }
        true
    }

    /// Close a segmented run: charge static energy for the elapsed
    /// cycles.
    pub fn finish_run(&mut self, rep: &mut SimReport) {
        rep.energy.static_ +=
            self.eparams.static_watts * rep.cycles as f64 / (self.hw.clock_ghz * 1e9) * 1e12;
    }

    /// Execute one instruction: timing first, then functional commit.
    fn execute(&mut self, instr: &Instr, rep: &mut SimReport) {
        rep.instrs += 1;
        // ---------- timing ----------
        let mut cycles = 1u64;
        let e = &self.eparams;
        if matches!(instr.ctrl, CtrlType::Nop) {
            rep.nops += 1;
        }
        // Memory port: loads limited by B words/cycle.
        if !instr.loads.is_empty() {
            let words = instr.loads.len() as u64;
            let need = words.div_ceil(self.hw.bw_words as u64);
            if need > cycles {
                rep.stall_mem_bw += need - cycles;
                cycles = need;
            }
            rep.mem_busy += need;
            rep.load_words += words;
            rep.energy.sram += words as f64 * e.pj_sram_word;
            rep.energy.rf += words as f64 * e.pj_rf_word; // RF write side
            // RF write-port conflicts: one *row* write per bank per
            // cycle (banks have 2^K-word row-wide write ports).
            let row_w = 1usize << self.hw.k;
            let mut rows_per_bank: std::collections::HashMap<u16, std::collections::HashSet<u16>> =
                std::collections::HashMap::new();
            for l in &instr.loads {
                rows_per_bank
                    .entry(l.rf_bank)
                    .or_default()
                    .insert(l.rf_reg / row_w as u16);
            }
            let max_bank = rows_per_bank
                .values()
                .map(|rows| rows.len() as u64)
                .max()
                .unwrap_or(0);
            if max_bank > cycles {
                rep.stall_bank += max_bank - cycles;
                cycles = max_bank;
            }
        }
        // Crossbar reads: 2 *row-wide* read ports per RF bank per cycle
        // (a lane's whole operand tuple arrives in one row read, like
        // the write side).
        if !instr.routes.is_empty() {
            let row_w = 1u16 << self.hw.k;
            let mut per_bank: std::collections::HashMap<u16, std::collections::HashSet<u16>> =
                std::collections::HashMap::new();
            for r in &instr.routes {
                per_bank
                    .entry(r.rf_bank)
                    .or_default()
                    .insert(r.rf_reg / row_w);
            }
            let max_reads = per_bank
                .values()
                .map(|rows| rows.len() as u64)
                .max()
                .unwrap_or(0);
            let need = max_reads.div_ceil(2);
            if need > cycles {
                rep.stall_bank += need - cycles;
                cycles = need;
            }
            rep.energy.rf += instr.routes.len() as f64 * e.pj_rf_word;
            rep.energy.xbar += instr.routes.len() as f64 * e.pj_xbar_word;
        }
        // CU occupancy + energy.
        if let Some(cu) = &instr.cu {
            rep.cu_busy += cycles;
            let ops = cu.lanes as u64 * ((1u64 << self.hw.k) + 2);
            rep.energy.cu += ops as f64 * e.pj_cu_op;
        }
        // SU occupancy + energy.
        if let Some(suc) = &instr.su {
            rep.su_busy += cycles;
            let bins = match suc.mode {
                SuMode::Temporal => suc.lanes as u64, // 1 bin per active SE
                SuMode::Spatial => (suc.dist_size as u64).min(self.hw.s as u64),
            };
            rep.energy.su += bins as f64 * e.pj_se_op;
        }
        // Stores.
        if !instr.stores.is_empty() {
            let words = instr.stores.len() as u64;
            rep.store_words += words;
            rep.energy.sram += words as f64 * e.pj_sram_word;
            let need = words.div_ceil(self.hw.bw_words as u64);
            if need > cycles {
                rep.stall_mem_bw += need - cycles;
                cycles = need;
            }
            rep.mem_busy += need;
        }
        rep.energy.ifetch += e.pj_ifetch;
        rep.cycles += cycles;

        // ---------- functional ----------
        match &instr.sem {
            Semantics::None => {}
            Semantics::Snapshot => {
                self.snapshot = Some(self.x.clone());
            }
            Semantics::UpdateRvs(rvs) => {
                for &rv in rvs {
                    let i = rv as usize;
                    // Async Gibbs reads the stale snapshot; (Block)
                    // Gibbs reads live state (safe: the compiler
                    // guarantees conditional independence per commit).
                    if let Some(snap) = &self.snapshot {
                        self.model.local_energies(snap, i, &mut self.scratch);
                    } else {
                        self.model.local_energies(&self.x, i, &mut self.scratch);
                    }
                    let s = self.sampler.sample(&self.scratch, self.beta, &mut self.rng);
                    self.x[i] = s as u32;
                    rep.updates += 1;
                    rep.samples += 1;
                }
            }
            Semantics::PasIterate => {
                let stats = self
                    .pas
                    .step(self.model, &mut self.x, self.beta, &mut self.rng);
                rep.updates += stats.updates;
                rep.samples += stats.cost.samples;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{CuCtrl, CuMode, LoadSlot, MemSpace, StoreSlot, SuCtrl};

    use crate::energy::PottsGrid;

    fn toy_model() -> PottsGrid {
        PottsGrid::new(4, 4, 2, 1.0)
    }

    fn mk_sim(model: &PottsGrid) -> Simulator<'_> {
        Simulator::new(HwConfig::fig10_toy(), model, 1, 42)
    }

    #[test]
    fn nop_costs_one_cycle() {
        let m = toy_model();
        let mut sim = mk_sim(&m);
        let mut p = Program::default();
        p.body.push(Instr::nop());
        let rep = sim.run(&p, 10);
        // 10 iterations × (1 nop + drain 2) = 30 cycles
        assert_eq!(rep.nops, 10);
        assert_eq!(rep.cycles, 10 * (1 + 2));
    }

    #[test]
    fn load_exceeding_bandwidth_stalls() {
        let m = toy_model();
        let mut sim = mk_sim(&m); // B = 12 words/cycle
        let mut i = Instr::nop();
        i.ctrl = CtrlType::Load;
        i.loads = (0..30)
            .map(|k| LoadSlot {
                mem: MemSpace::Input,
                addr: k,
                rf_bank: (k % 8) as u16,
                rf_reg: (k / 8 % 8) as u16,
            })
            .collect();
        let mut p = Program::default();
        p.body.push(i);
        let rep = sim.run(&p, 1);
        // ceil(30/12) = 3 cycles for the load.
        assert!(rep.stall_mem_bw >= 2, "stall={}", rep.stall_mem_bw);
        assert_eq!(rep.load_words, 30);
    }

    #[test]
    fn bank_conflict_write_stalls() {
        let m = toy_model();
        let mut sim = mk_sim(&m); // K = 1 → row width 2
        let mut i = Instr::nop();
        i.ctrl = CtrlType::Load;
        // 8 words into 4 distinct rows of bank 0: 4 row-write cycles.
        i.loads = (0..8)
            .map(|k| LoadSlot {
                mem: MemSpace::Input,
                addr: k,
                rf_bank: 0,
                rf_reg: k as u16,
            })
            .collect();
        let mut p = Program::default();
        p.body.push(i);
        let rep = sim.run(&p, 1);
        assert!(rep.stall_bank >= 3, "bank stalls={}", rep.stall_bank);
    }

    #[test]
    fn functional_update_commits_samples() {
        let m = toy_model();
        let mut sim = mk_sim(&m);
        let mut i = Instr::nop();
        i.ctrl = CtrlType::ComputeSampleStore;
        i.cu = Some(CuCtrl {
            mode: CuMode::ReducedSum,
            lanes: 4,
            scale_beta: true,
            accumulate: false,
        });
        i.su = Some(SuCtrl {
            mode: SuMode::Temporal,
            lanes: 4,
            dist_size: 2,
            first: true,
            last: true,
        });
        i.stores = vec![StoreSlot {
            mem: MemSpace::Sample,
            addr: 0,
            su_lane: 0,
        }];
        i.sem = Semantics::UpdateRvs(vec![0, 3, 12, 15]); // corners: independent
        let mut p = Program::default();
        p.body.push(i);
        p.updates_per_iter = 4;
        let rep = sim.run(&p, 100);
        assert_eq!(rep.updates, 400);
        assert_eq!(rep.samples, 400);
        assert!(rep.cu_utilization() > 0.0 && rep.su_utilization() > 0.0);
        assert!(rep.gsps(&HwConfig::fig10_toy()) > 0.0);
        assert!(rep.energy.total_pj() > 0.0);
    }

    #[test]
    fn schedule_steps_beta_every_iteration() {
        use crate::mcmc::BetaSchedule;
        let m = toy_model();
        let mut sim = mk_sim(&m);
        let mut p = Program::default();
        p.body.push(Instr::nop());
        let schedule = BetaSchedule::Linear {
            from: 0.0,
            to: 1.0,
            steps: 10,
        };
        let mut seen = Vec::new();
        // Can't observe sim.beta inside the callback (sim is mutably
        // borrowed), so recompute the expectation and check the final β.
        sim.run_observed(&p, 10, Some(schedule), &mut |iter, _, _| {
            seen.push(iter);
            true
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert_eq!(sim.beta(), schedule.beta(9), "β frozen instead of stepped");
    }

    #[test]
    fn observe_false_stops_early() {
        let m = toy_model();
        let mut sim = mk_sim(&m);
        let mut p = Program::default();
        p.body.push(Instr::nop());
        let rep = sim.run_observed(&p, 100, None, &mut |iter, _, _| iter < 4);
        assert_eq!(rep.iterations, 5);
    }

    #[test]
    fn report_units_consistent() {
        let hw = HwConfig::paper_default();
        let rep = SimReport {
            cycles: 500_000_000, // 1 second at 0.5 GHz
            samples: 2_000_000_000,
            ..Default::default()
        };
        assert!((rep.seconds(&hw) - 1.0).abs() < 1e-9);
        assert!((rep.gsps(&hw) - 2.0).abs() < 1e-9);
    }
}
