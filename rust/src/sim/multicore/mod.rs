//! Sharded multi-core MC²A simulation (§II-D).
//!
//! The paper's system-level claim is that single-core MC²A "can easily
//! be scaled to support multiple chains … by instantiating multiple
//! parallel MC²A cores" sharing a crossbar and the histogram memory.
//! [`MultiCoreSim`] models exactly that system for the *one-model,
//! many-cores* axis: one workload is sharded across C single-core
//! [`Simulator`] pipelines by [`crate::graph::partition_balanced`],
//! each core runs the shard program emitted by
//! [`crate::compiler::compile_shard`], and the cores synchronize at
//! color-class boundaries where they exchange boundary state over the
//! shared crossbar.
//!
//! **Timing model.** Per synchronization round, every core advances
//! independently through its shard's instructions (the single-core
//! 4-stage pipeline model, reused verbatim), then the round closes
//! with a barrier: faster cores idle until the slowest finishes
//! (`stall_sync`), and the boundary words all cores broadcast drain
//! through the shared crossbar at `xbar_words_per_cycle`, plus a fixed
//! arbitration latency (`stall_xbar`). Once per iteration the cores
//! also commit their RV states to the *shared histogram memory*
//! (banked by shard; each core's commits cross the crossbar, and the
//! critical path pays for the largest shard). All inter-core costs are charged only
//! when C > 1 — a 1-core system is cycle-identical (and sample-
//! identical) to the plain single-core [`Simulator`].
//!
//! **Functional model.** Correctness across shards comes from the
//! coloring: within one color class every RV — on any core — is
//! conditionally independent of every other, so cores can update
//! concurrently as long as boundary state is exchanged *between*
//! classes. The simulator enforces exactly that: a master assignment
//! is broadcast to all cores at the start of each round and each
//! core's committed updates are merged back at the end, so the sampled
//! distribution is the same as the single-core Block Gibbs chain
//! (Async Gibbs keeps its snapshot semantics; boundary staleness is
//! the algorithm's own contract).

use crate::compiler::compile_shard;
use crate::energy::EnergyModel;
use crate::engine::error::Mc2aError;
use crate::graph::{partition_balanced, Partition};
use crate::isa::{HwConfig, MultiHwConfig, Program, Semantics};
use crate::mcmc::{AlgoKind, BetaSchedule};
use crate::rng::Rng;
use crate::sim::{SimReport, Simulator};

/// Aggregate of a multi-core run: per-core reports plus the
/// synchronized (barrier-aligned) totals.
#[derive(Clone, Debug)]
pub struct MultiCoreReport {
    /// One report per core, barrier-aligned: every core's `cycles`
    /// includes its sync waits, so all cores finish at [`MultiCoreReport::cycles`].
    pub per_core: Vec<SimReport>,
    /// Makespan in cycles (all cores, barriers included).
    pub cycles: u64,
    /// MCMC iterations completed.
    pub iterations: u64,
    /// Total 32-bit words moved over the shared crossbar (boundary
    /// broadcasts + shared-histogram commits).
    pub xfer_words: u64,
    /// Total core-cycles spent idle at barriers (summed over cores).
    pub stall_sync: u64,
    /// Critical-path cycles spent draining the shared crossbar.
    pub stall_xbar: u64,
    /// Cross-shard edges of the partition (the locality the
    /// partitioner achieved).
    pub cut_edges: u64,
    /// Synchronization rounds executed (color classes × iterations).
    pub sync_rounds: u64,
}

impl MultiCoreReport {
    /// Number of cores.
    pub fn cores(&self) -> usize {
        self.per_core.len()
    }

    /// Categorical samples drawn across all cores.
    pub fn samples(&self) -> u64 {
        self.per_core.iter().map(|r| r.samples).sum()
    }

    /// RV updates committed across all cores.
    pub fn updates(&self) -> u64 {
        self.per_core.iter().map(|r| r.updates).sum()
    }

    /// Aggregate throughput in Giga-samples/s: all cores' samples over
    /// the synchronized makespan at the per-core clock.
    pub fn aggregate_gsps(&self, hw: &HwConfig) -> f64 {
        let s = self.cycles as f64 / (hw.clock_ghz * 1e9);
        if s <= 0.0 {
            0.0
        } else {
            self.samples() as f64 / s / 1e9
        }
    }

    /// Per-core busy fraction: 1 − (barrier waits + crossbar stalls) /
    /// makespan. A straggling shard shows up as high utilization on
    /// its core and low on the others.
    pub fn core_utilization(&self) -> Vec<f64> {
        self.per_core
            .iter()
            .map(|r| {
                if r.cycles == 0 {
                    0.0
                } else {
                    1.0 - (r.stall_sync + r.stall_xbar) as f64 / r.cycles as f64
                }
            })
            .collect()
    }

    /// Fraction of all core-cycles lost to synchronization (barrier
    /// idling + shared-crossbar transfers), in [0, 1].
    pub fn sync_overhead_fraction(&self) -> f64 {
        let total: u64 = self.per_core.iter().map(|r| r.cycles).sum();
        if total == 0 {
            return 0.0;
        }
        let lost: u64 = self
            .per_core
            .iter()
            .map(|r| r.stall_sync + r.stall_xbar)
            .sum();
        lost as f64 / total as f64
    }

    /// Parallel efficiency against a measured 1-core throughput:
    /// `aggregate / (C × single)`, 1.0 = perfect linear scaling.
    pub fn parallel_efficiency(&self, single_core_gsps: f64, hw: &HwConfig) -> f64 {
        if single_core_gsps <= 0.0 {
            0.0
        } else {
            self.aggregate_gsps(hw) / (single_core_gsps * self.cores() as f64)
        }
    }

    /// Collapse into one [`SimReport`]: makespan cycles, work and
    /// energy summed over cores — except the two sync-stall fields,
    /// which are *averaged* per core so that
    /// [`SimReport::sync_overhead`] stays a fraction of the makespan
    /// (summing them across cores could exceed the makespan). With one
    /// core this is exactly that core's report, so downstream
    /// consumers (`ChainResult.sim`, the CLI's GS/s line) keep working
    /// unchanged.
    pub fn merged(&self) -> SimReport {
        let mut m = SimReport {
            cycles: self.cycles,
            iterations: self.iterations,
            ..SimReport::default()
        };
        for r in &self.per_core {
            m.instrs += r.instrs;
            m.nops += r.nops;
            m.stall_mem_bw += r.stall_mem_bw;
            m.stall_bank += r.stall_bank;
            m.stall_sync += r.stall_sync;
            m.stall_xbar += r.stall_xbar;
            m.xfer_words += r.xfer_words;
            m.cu_busy += r.cu_busy;
            m.su_busy += r.su_busy;
            m.mem_busy += r.mem_busy;
            m.load_words += r.load_words;
            m.store_words += r.store_words;
            m.updates += r.updates;
            m.samples += r.samples;
            m.energy.cu += r.energy.cu;
            m.energy.su += r.energy.su;
            m.energy.rf += r.energy.rf;
            m.energy.sram += r.energy.sram;
            m.energy.ifetch += r.energy.ifetch;
            m.energy.xbar += r.energy.xbar;
            m.energy.static_ += r.energy.static_;
        }
        let c = self.per_core.len().max(1) as u64;
        m.stall_sync /= c;
        m.stall_xbar /= c;
        m
    }
}

/// In-flight bookkeeping for a segmented multi-core run (created by
/// [`MultiCoreSim::begin_run`], threaded through
/// [`MultiCoreSim::advance_run`], consumed by
/// [`MultiCoreSim::finish_run`]). Opaque to callers.
pub struct McRunState {
    xfer_total: u64,
    stall_xbar_path: u64,
    sync_rounds: u64,
    spent: Vec<u64>,
    seg_start: Vec<usize>,
}

/// Validate a *(model size, algorithm, core count)* sharding request —
/// the single authority shared by the engine builder, the simulator
/// constructor and the roofline CLI, so accept/reject behavior and
/// error text cannot drift apart.
pub fn validate_shard_config(num_vars: usize, algo: AlgoKind, cores: usize) -> Result<(), String> {
    if cores == 0 {
        return Err("core count must be ≥ 1".into());
    }
    if cores > num_vars {
        return Err(format!("cores ({cores}) exceed the model's {num_vars} RVs"));
    }
    if cores > 1 && !matches!(algo, AlgoKind::BlockGibbs | AlgoKind::AsyncGibbs) {
        return Err(format!(
            "multi-core simulation supports Block Gibbs and Async Gibbs at cores > 1 \
             (got {}); use cores = 1 or switch the algorithm",
            algo.name()
        ));
    }
    Ok(())
}

/// One shard: a single-core pipeline bound to its slice of the model.
struct Core<'m> {
    sim: Simulator<'m>,
    program: Program,
    /// Body index just past each synchronization round.
    seg_ends: Vec<usize>,
    /// RV ids this core owns (ascending).
    owned: Vec<u32>,
    /// Boundary words this core broadcasts per round.
    seg_xfer_words: Vec<u64>,
    /// Accumulating report (reset at the start of each run).
    rep: SimReport,
}

/// C single-core MC²A pipelines sharing a crossbar and the histogram
/// memory, executing one sharded model.
pub struct MultiCoreSim<'m> {
    mhw: MultiHwConfig,
    model: &'m dyn EnergyModel,
    cores: Vec<Core<'m>>,
    partition: Partition,
    /// Master assignment (the merged, authoritative state).
    pub x: Vec<u32>,
    /// Shared histogram memory (flattened per-RV state counts).
    hist: Vec<u64>,
    hist_offsets: Vec<usize>,
    num_segments: usize,
    cut_edges: u64,
}

impl<'m> MultiCoreSim<'m> {
    /// Shard `model` across `mhw.cores` pipelines. Fails with a typed
    /// [`Mc2aError`] when the hardware configuration is invalid, when
    /// there are more cores than RVs, or when `algo` cannot be sharded
    /// at C > 1 — the global-move-table PAS and the
    /// sequentially-dependent Gibbs/MH chains only run single-core.
    pub fn new(
        mhw: MultiHwConfig,
        model: &'m dyn EnergyModel,
        algo: AlgoKind,
        pas_flips: usize,
        seed: u64,
    ) -> Result<MultiCoreSim<'m>, Mc2aError> {
        mhw.validate().map_err(Mc2aError::InvalidHardware)?;
        let n = model.num_vars();
        let c = mhw.cores;
        validate_shard_config(n, algo, c).map_err(Mc2aError::InvalidConfig)?;
        let partition = partition_balanced(model.interaction(), c);
        let boundary = partition.boundary_mask(model.interaction());
        let mut cores = Vec::with_capacity(c);
        let mut num_segments = 0usize;
        for (cid, owned) in partition.parts().into_iter().enumerate() {
            let (program, seg_ends) =
                compile_shard(model, algo, &mhw.core, pas_flips, &owned, true)?;
            let mut seg_xfer_words = vec![0u64; seg_ends.len()];
            let mut start = 0usize;
            for (s, &end) in seg_ends.iter().enumerate() {
                for instr in &program.body[start..end] {
                    if let Semantics::UpdateRvs(rvs) = &instr.sem {
                        seg_xfer_words[s] +=
                            rvs.iter().filter(|&&rv| boundary[rv as usize]).count() as u64;
                    }
                }
                start = end;
            }
            if cid == 0 {
                num_segments = seg_ends.len();
            } else {
                assert_eq!(num_segments, seg_ends.len(), "shard programs disagree on round count");
            }
            // Core 0 draws from the chain seed so a 1-core system is
            // RNG-identical to the single-core simulator.
            let sim_seed = if cid == 0 {
                seed
            } else {
                Rng::fork_seed(seed, cid as u64)
            };
            let sim = Simulator::new(mhw.core, model, pas_flips, sim_seed);
            cores.push(Core {
                sim,
                program,
                seg_ends,
                owned,
                seg_xfer_words,
                rep: SimReport::default(),
            });
        }
        let x = cores[0].sim.x.clone();
        for core in &mut cores[1..] {
            core.sim.x.copy_from_slice(&x);
        }
        let (hist_offsets, acc) = crate::sim::hist_layout(model);
        let cut_edges = partition.cut_edges(model.interaction()) as u64;
        Ok(MultiCoreSim {
            mhw,
            model,
            cores,
            partition,
            x,
            hist: vec![0; acc],
            hist_offsets,
            num_segments,
            cut_edges,
        })
    }

    /// Number of cores.
    pub fn cores(&self) -> usize {
        self.cores.len()
    }

    /// The shard assignment.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Set the inverse temperature on every core's functional model.
    pub fn set_beta(&mut self, beta: f32) {
        for core in &mut self.cores {
            core.sim.set_beta(beta);
        }
    }

    /// Overwrite the master assignment (and every core's copy).
    pub fn set_state(&mut self, x0: &[u32]) {
        self.x.copy_from_slice(x0);
        for core in &mut self.cores {
            core.sim.x.copy_from_slice(x0);
        }
    }

    /// Empirical marginal of RV `i` from the shared histogram memory.
    pub fn marginal(&self, i: usize) -> Vec<f64> {
        crate::sim::marginal_of(&self.hist, &self.hist_offsets, i)
    }

    /// Run `iterations` synchronized HWLOOP trips.
    pub fn run(&mut self, iterations: usize) -> MultiCoreReport {
        self.run_observed(iterations, None, &mut |_, _, _| true)
    }

    /// [`MultiCoreSim::run`] with the engine hooks: an optional β
    /// `schedule` evaluated once per iteration, and an
    /// `observe(iter, updates_so_far, master_state)` callback after
    /// every iteration; returning `false` stops the run early.
    pub fn run_observed(
        &mut self,
        iterations: usize,
        schedule: Option<BetaSchedule>,
        observe: &mut dyn FnMut(usize, u64, &[u32]) -> bool,
    ) -> MultiCoreReport {
        let betas: Option<Vec<f32>> =
            schedule.map(|s| (0..iterations).map(|t| s.beta(t)).collect());
        let mut run = self.begin_run();
        self.advance_run(&mut run, 0, iterations, betas.as_deref(), observe);
        self.finish_run(run)
    }

    /// RV updates committed across all cores so far in the current
    /// run (the `updates_so_far` the observe callback reports).
    pub fn total_updates(&self) -> u64 {
        self.cores.iter().map(|c| c.rep.updates).sum()
    }

    /// Begin a segmented run: reset every core's report and execute
    /// the shard prologues. Together with
    /// [`MultiCoreSim::advance_run`] and [`MultiCoreSim::finish_run`]
    /// this is the engine's adaptive-annealing entry point.
    pub fn begin_run(&mut self) -> McRunState {
        for core in &mut self.cores {
            core.rep = SimReport::default();
            let Core { sim, program, rep, .. } = core;
            for instr in &program.prologue {
                sim.execute(instr, rep);
            }
        }
        McRunState {
            xfer_total: 0,
            stall_xbar_path: 0,
            sync_rounds: 0,
            spent: vec![0u64; self.cores.len()],
            seg_start: vec![0usize; self.cores.len()],
        }
    }

    /// Advance `n` synchronized HWLOOP iterations (global indices
    /// `iter0 .. iter0 + n`). `betas[j]` (when given) is applied to
    /// every core before iteration `iter0 + j`; `observe` runs after
    /// every iteration and returning `false` stops the run. Returns
    /// `false` when the run was stopped early.
    pub fn advance_run(
        &mut self,
        run: &mut McRunState,
        iter0: usize,
        n_iters: usize,
        betas: Option<&[f32]>,
        observe: &mut dyn FnMut(usize, u64, &[u32]) -> bool,
    ) -> bool {
        let ncores = self.cores.len();
        let multi = ncores > 1;
        let n = self.model.num_vars();
        let McRunState {
            xfer_total,
            stall_xbar_path,
            sync_rounds,
            spent,
            seg_start,
        } = run;
        for j in 0..n_iters {
            let iter = iter0 + j;
            if let Some(b) = betas {
                for core in &mut self.cores {
                    core.sim.set_beta(b[j]);
                }
            }
            seg_start.fill(0);
            for seg in 0..self.num_segments {
                // Broadcast the merged master state so every core reads
                // fresh boundary values for this round. (A single core
                // is already authoritative — skip the copy traffic; its
                // state is pulled into the master once per iteration.)
                if multi {
                    for core in &mut self.cores {
                        core.sim.x.copy_from_slice(&self.x);
                    }
                }
                let mut max_cycles = 0u64;
                let mut round_words = 0u64;
                for (c, core) in self.cores.iter_mut().enumerate() {
                    let Core { sim, program, rep, seg_ends, seg_xfer_words, .. } = core;
                    let before = rep.cycles;
                    let end = seg_ends[seg];
                    for instr in &program.body[seg_start[c]..end] {
                        sim.execute(instr, rep);
                    }
                    seg_start[c] = end;
                    spent[c] = rep.cycles - before;
                    max_cycles = max_cycles.max(spent[c]);
                    round_words += seg_xfer_words[seg];
                }
                // Merge each core's committed updates into the master.
                if multi {
                    for core in &self.cores {
                        for &rv in &core.owned {
                            self.x[rv as usize] = core.sim.x[rv as usize];
                        }
                    }
                    // Barrier: faster shards idle for the slowest.
                    for (c, core) in self.cores.iter_mut().enumerate() {
                        let wait = max_cycles - spent[c];
                        core.rep.stall_sync += wait;
                        core.rep.cycles += wait;
                    }
                    // Boundary broadcast through the shared crossbar,
                    // plus the fixed barrier/arbitration latency.
                    let xfer = round_words.div_ceil(self.mhw.xbar_words_per_cycle as u64)
                        + self.mhw.sync_latency as u64;
                    for core in &mut self.cores {
                        core.rep.stall_xbar += xfer;
                        core.rep.cycles += xfer;
                        let words = core.seg_xfer_words[seg];
                        core.rep.xfer_words += words;
                        core.rep.energy.xbar += words as f64 * core.sim.eparams.pj_xbar_word;
                    }
                    *xfer_total += round_words;
                    *stall_xbar_path += xfer;
                    *sync_rounds += 1;
                }
            }
            if !multi {
                self.x.copy_from_slice(&self.cores[0].sim.x);
            }
            // Pipeline drain at the loop boundary (same as the
            // single-core simulator's HWLOOP model).
            let drain = self.mhw.core.cu_latency() as u64;
            for core in &mut self.cores {
                core.rep.cycles += drain;
                core.rep.energy.ifetch += drain as f64 * core.sim.eparams.pj_ifetch;
                core.rep.iterations += 1;
            }
            // Shared histogram memory: every core commits its shard's
            // states once per iteration. The histogram is banked by
            // shard, so commits drain in parallel — one crossbar port
            // per core — and the critical path pays for the largest
            // shard. A single core owns its port outright (free, as in
            // the single-core model); C > 1 pay the crossbar hop.
            if multi {
                let max_owned = self.cores.iter().map(|c| c.owned.len() as u64).max().unwrap_or(0);
                let hist_cost = max_owned.div_ceil(self.mhw.xbar_words_per_cycle as u64);
                for core in &mut self.cores {
                    core.rep.stall_xbar += hist_cost;
                    core.rep.cycles += hist_cost;
                    core.rep.xfer_words += core.owned.len() as u64;
                }
                *xfer_total += n as u64;
                *stall_xbar_path += hist_cost;
            }
            for i in 0..n {
                self.hist[self.hist_offsets[i] + self.x[i] as usize] += 1;
            }
            let updates: u64 = self.cores.iter().map(|c| c.rep.updates).sum();
            if !observe(iter, updates, &self.x) {
                return false;
            }
        }
        true
    }

    /// Close a segmented run: charge static energy and assemble the
    /// barrier-aligned [`MultiCoreReport`].
    pub fn finish_run(&mut self, run: McRunState) -> MultiCoreReport {
        let clock_hz = self.mhw.core.clock_ghz * 1e9;
        for core in &mut self.cores {
            let seconds = core.rep.cycles as f64 / clock_hz;
            core.rep.energy.static_ += core.sim.eparams.static_watts * seconds * 1e12;
        }
        let per_core: Vec<SimReport> = self.cores.iter().map(|c| c.rep.clone()).collect();
        let cycles = per_core.iter().map(|r| r.cycles).max().unwrap_or(0);
        let iterations = per_core.first().map(|r| r.iterations).unwrap_or(0);
        let stall_sync = per_core.iter().map(|r| r.stall_sync).sum();
        MultiCoreReport {
            per_core,
            cycles,
            iterations,
            xfer_words: run.xfer_total,
            stall_sync,
            stall_xbar: run.stall_xbar_path,
            cut_edges: self.cut_edges,
            sync_rounds: run.sync_rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::energy::PottsGrid;

    fn mhw(cores: usize) -> MultiHwConfig {
        MultiHwConfig::new(HwConfig::paper_default(), cores)
    }

    #[test]
    fn one_core_is_cycle_and_sample_identical_to_single_core() {
        let m = PottsGrid::new(6, 6, 2, 0.8);
        let hw = HwConfig::paper_default();
        let program = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let mut single = Simulator::new(hw, &m, 1, 0xA11CE);
        let single_rep = single.run(&program, 40);

        let mut mc = MultiCoreSim::new(mhw(1), &m, AlgoKind::BlockGibbs, 1, 0xA11CE).unwrap();
        let report = mc.run(40);
        let merged = report.merged();
        assert_eq!(merged.cycles, single_rep.cycles);
        assert_eq!(merged.samples, single_rep.samples);
        assert_eq!(merged.updates, single_rep.updates);
        assert_eq!(merged.instrs, single_rep.instrs);
        assert_eq!(merged.stall_mem_bw, single_rep.stall_mem_bw);
        assert_eq!(merged.stall_bank, single_rep.stall_bank);
        assert_eq!(merged.stall_sync, 0);
        assert_eq!(merged.stall_xbar, 0);
        assert_eq!(mc.x, single.x, "functional state diverged");
        for i in 0..m.num_vars() {
            assert_eq!(mc.marginal(i), single.marginal(i), "marginal {i}");
        }
    }

    #[test]
    fn more_cores_cut_the_makespan() {
        let m = PottsGrid::new(16, 16, 2, 0.8);
        let cycles = |cores: usize| {
            let mut mc = MultiCoreSim::new(mhw(cores), &m, AlgoKind::BlockGibbs, 1, 7).unwrap();
            mc.run(10).cycles
        };
        let c1 = cycles(1);
        let c4 = cycles(4);
        assert!(c4 < c1, "4-core {c4} not faster than 1-core {c1}");
    }

    #[test]
    fn multicore_report_accounts_sync_and_interconnect() {
        let m = PottsGrid::new(12, 12, 2, 0.8);
        let mut mc = MultiCoreSim::new(mhw(4), &m, AlgoKind::BlockGibbs, 1, 3).unwrap();
        let r = mc.run(5);
        assert_eq!(r.cores(), 4);
        assert_eq!(r.iterations, 5);
        assert!(r.xfer_words > 0, "no interconnect traffic modeled");
        assert!(r.stall_xbar > 0);
        assert!(r.sync_rounds >= 5 * 2, "rounds={}", r.sync_rounds);
        assert!(r.cut_edges > 0);
        assert!(r.sync_overhead_fraction() > 0.0 && r.sync_overhead_fraction() < 1.0);
        let util = r.core_utilization();
        assert_eq!(util.len(), 4);
        assert!(util.iter().all(|&u| (0.0..=1.0).contains(&u)));
        // Barrier alignment: every core finishes at the makespan.
        assert!(r.per_core.iter().all(|c| c.cycles == r.cycles));
        // All RVs updated once per iteration across the shards.
        assert_eq!(r.updates(), 144 * 5);
    }

    #[test]
    fn rejects_unshardable_configs() {
        let m = PottsGrid::new(4, 4, 2, 0.5);
        assert!(MultiCoreSim::new(mhw(32), &m, AlgoKind::BlockGibbs, 1, 1).is_err());
        assert!(MultiCoreSim::new(mhw(2), &m, AlgoKind::Pas, 4, 1).is_err());
        assert!(MultiCoreSim::new(mhw(2), &m, AlgoKind::Gibbs, 1, 1).is_err());
        assert!(MultiCoreSim::new(mhw(1), &m, AlgoKind::Pas, 4, 1).is_ok());
        assert!(MultiCoreSim::new(mhw(2), &m, AlgoKind::AsyncGibbs, 1, 1).is_ok());
    }

    #[test]
    fn early_stop_halts_all_cores() {
        let m = PottsGrid::new(8, 8, 2, 0.5);
        let mut mc = MultiCoreSim::new(mhw(2), &m, AlgoKind::BlockGibbs, 1, 1).unwrap();
        let r = mc.run_observed(100, None, &mut |iter, _, _| iter < 4);
        assert_eq!(r.iterations, 5);
    }
}
