//! Accelerator energy model.
//!
//! The paper synthesizes the RTL at Intel 16 nm / 500 MHz and reports
//! energy efficiency in GS/s/W (Fig. 15). We cannot run Genus here, so
//! the simulator charges per-event energies from published 16 nm-class
//! constants (FP32 ALU ≈ 1 pJ, small-SRAM access ≈ 5 pJ/word, RF access
//! ≈ 0.06 pJ/word) plus a static-power floor. Absolute watts are
//! therefore estimates; the *ratios* against the CPU/GPU/TPU baseline
//! models (which use the same constants philosophy) are the reproduced
//! quantity. See DESIGN.md §4.

/// Per-event energy constants in picojoules (16 nm-class).
#[derive(Clone, Copy, Debug)]
pub struct EnergyParams {
    /// One CU arithmetic op (add/mult averaged).
    pub pj_cu_op: f64,
    /// One SE event (LUT lookup + add + compare).
    pub pj_se_op: f64,
    /// One 32-bit RF read or write.
    pub pj_rf_word: f64,
    /// One 32-bit on-chip SRAM access (8 KB bank).
    pub pj_sram_word: f64,
    /// Instruction fetch + decode per cycle.
    pub pj_ifetch: f64,
    /// Crossbar traversal per routed word.
    pub pj_xbar_word: f64,
    /// Static (leakage + clock tree) power in watts.
    pub static_watts: f64,
}

impl Default for EnergyParams {
    fn default() -> EnergyParams {
        EnergyParams {
            pj_cu_op: 1.0,
            pj_se_op: 0.4,
            pj_rf_word: 0.06,
            pj_sram_word: 5.0,
            pj_ifetch: 3.0,
            pj_xbar_word: 0.15,
            static_watts: 0.05,
        }
    }
}

/// Accumulated energy breakdown in picojoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    /// CU arithmetic.
    pub cu: f64,
    /// SU sampling.
    pub su: f64,
    /// Register file traffic.
    pub rf: f64,
    /// On-chip SRAM traffic.
    pub sram: f64,
    /// Instruction fetch/decode.
    pub ifetch: f64,
    /// Crossbar.
    pub xbar: f64,
    /// Static energy (leakage × time).
    pub static_: f64,
}

impl EnergyBreakdown {
    /// Total energy in picojoules.
    pub fn total_pj(&self) -> f64 {
        self.cu + self.su + self.rf + self.sram + self.ifetch + self.xbar + self.static_
    }

    /// Total energy in joules.
    pub fn total_j(&self) -> f64 {
        self.total_pj() * 1e-12
    }

    /// Average power in watts over `seconds`.
    pub fn avg_watts(&self, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            0.0
        } else {
            self.total_j() / seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let b = EnergyBreakdown {
            cu: 1.0,
            su: 2.0,
            rf: 3.0,
            sram: 4.0,
            ifetch: 5.0,
            xbar: 6.0,
            static_: 7.0,
        };
        assert_eq!(b.total_pj(), 28.0);
        assert!((b.total_j() - 28.0e-12).abs() < 1e-24);
    }

    #[test]
    fn power_at_one_second() {
        let b = EnergyBreakdown {
            cu: 1e12, // 1 J
            ..Default::default()
        };
        assert!((b.avg_watts(1.0) - 1.0).abs() < 1e-9);
        assert_eq!(b.avg_watts(0.0), 0.0);
    }

    #[test]
    fn sram_dominates_alu_per_word() {
        // Sanity: memory access must cost more than an ALU op — the
        // premise behind the paper's memory-intensity roofline axis.
        let p = EnergyParams::default();
        assert!(p.pj_sram_word > p.pj_cu_op);
    }
}
