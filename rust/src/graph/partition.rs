//! Graph partitioning for sharded multi-core simulation (§II-D).
//!
//! Scaling one model across C parallel MC²A cores means assigning each
//! RV to exactly one core. The partitioner aims for the two properties
//! the tiled-Gibbs literature (Duke MRF accelerator, AIA) optimizes:
//! **balance** (every core gets `n/C` ± 1 RVs, so no core straggles at
//! the color-class barrier) and **locality** (few cut edges, so little
//! boundary state crosses the shared crossbar per sync round).
//!
//! Cross-shard *correctness* comes from [`super::coloring`]: the
//! multi-core schedule syncs at color-class boundaries, and a proper
//! coloring guarantees that all RVs updated within one class — across
//! all cores — are conditionally independent, so cores never race on a
//! Markov blanket.

use super::Graph;

/// A node → part assignment over `[0, num_parts)`.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Per-node part id.
    pub assignment: Vec<u32>,
    /// Number of parts.
    pub num_parts: u32,
}

impl Partition {
    /// Group node ids by part: `parts()[p]` lists every node of part
    /// `p`, in ascending id order.
    pub fn parts(&self) -> Vec<Vec<u32>> {
        let mut parts = vec![Vec::new(); self.num_parts as usize];
        for (v, &p) in self.assignment.iter().enumerate() {
            parts[p as usize].push(v as u32);
        }
        parts
    }

    /// Part owning node `v`.
    #[inline]
    pub fn part_of(&self, v: usize) -> usize {
        self.assignment[v] as usize
    }

    /// Number of edges with endpoints in different parts — the traffic
    /// the shared crossbar must carry per full sweep.
    pub fn cut_edges(&self, g: &Graph) -> usize {
        let mut cut = 0usize;
        for v in 0..g.num_nodes() {
            for &u in g.neighbors(v) {
                if (u as usize) > v && self.assignment[v] != self.assignment[u as usize] {
                    cut += 1;
                }
            }
        }
        cut
    }

    /// Per-node flag: does `v` have a neighbor in another part? A
    /// boundary node's value must be broadcast after every update, so
    /// this mask prices the per-round interconnect exchange.
    pub fn boundary_mask(&self, g: &Graph) -> Vec<bool> {
        (0..g.num_nodes())
            .map(|v| {
                g.neighbors(v).iter().any(|&u| self.assignment[u as usize] != self.assignment[v])
            })
            .collect()
    }

    /// Fraction of nodes on a shard boundary, in [0, 1] (the
    /// roofline's interconnect-traffic estimate).
    pub fn boundary_fraction(&self, g: &Graph) -> f64 {
        let n = g.num_nodes();
        if n == 0 || self.num_parts <= 1 {
            return 0.0;
        }
        let b = self.boundary_mask(g).iter().filter(|&&x| x).count();
        b as f64 / n as f64
    }

    /// Every node assigned to a valid part, and no part empty (when
    /// `num_parts ≤ n`).
    pub fn is_valid(&self, g: &Graph) -> bool {
        if self.assignment.len() != g.num_nodes() {
            return false;
        }
        let mut seen = vec![false; self.num_parts as usize];
        for &p in &self.assignment {
            if p >= self.num_parts {
                return false;
            }
            seen[p as usize] = true;
        }
        self.num_parts as usize > g.num_nodes() || seen.iter().all(|&s| s)
    }
}

/// Balanced BFS-grown partition: parts are grown one at a time from the
/// lowest unassigned node id, absorbing neighbors first, so connected
/// regions (grid stripes, community clusters) stay on one core. Part
/// sizes are exactly `n/parts` ± 1. On a row-major 2-D grid this
/// reduces to horizontal stripes — the minimum-cut contiguous layout.
///
/// `parts` must satisfy `1 ≤ parts ≤ n` (callers validate; the
/// multi-core backend reports a typed error before getting here).
pub fn partition_balanced(g: &Graph, parts: usize) -> Partition {
    let n = g.num_nodes();
    assert!(parts >= 1, "parts must be ≥ 1");
    assert!(parts <= n.max(1), "parts ({parts}) exceed nodes ({n})");
    let mut assignment = vec![u32::MAX; n];
    let base = n / parts;
    let extra = n % parts;
    let mut next_seed = 0usize;
    for p in 0..parts {
        let target = base + usize::from(p < extra);
        let mut taken = 0usize;
        let mut queue = std::collections::VecDeque::new();
        while taken < target {
            if queue.is_empty() {
                // Next seed: lowest unassigned node (restarts across
                // disconnected components).
                while assignment[next_seed] != u32::MAX {
                    next_seed += 1;
                }
                queue.push_back(next_seed as u32);
                assignment[next_seed] = p as u32;
                taken += 1;
                if taken == target {
                    break;
                }
            }
            let v = queue.pop_front().unwrap();
            for &u in g.neighbors(v as usize) {
                if assignment[u as usize] == u32::MAX {
                    assignment[u as usize] = p as u32;
                    queue.push_back(u);
                    taken += 1;
                    if taken == target {
                        break;
                    }
                }
            }
        }
    }
    Partition {
        assignment,
        num_parts: parts as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{erdos_renyi_with_edges, grid_2d};

    #[test]
    fn partition_covers_and_balances() {
        let g = erdos_renyi_with_edges(103, 400, 7);
        for parts in [1, 2, 4, 8] {
            let p = partition_balanced(&g, parts);
            assert!(p.is_valid(&g), "parts={parts}");
            let sizes: Vec<usize> = p.parts().iter().map(Vec::len).collect();
            assert_eq!(sizes.iter().sum::<usize>(), 103);
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn single_part_has_no_cut() {
        let g = grid_2d(8, 8);
        let p = partition_balanced(&g, 1);
        assert_eq!(p.cut_edges(&g), 0);
        assert_eq!(p.boundary_fraction(&g), 0.0);
    }

    #[test]
    fn grid_partition_cuts_far_fewer_edges_than_total() {
        let g = grid_2d(16, 16);
        let p = partition_balanced(&g, 4);
        let cut = p.cut_edges(&g);
        assert!(cut > 0);
        // BFS growth keeps stripes contiguous: the cut stays near the
        // 3 × 16 stripe-boundary ideal, far below the 480 total edges.
        assert!(cut <= 6 * 16, "cut={cut}");
    }

    #[test]
    fn boundary_mask_matches_cut_structure() {
        let g = grid_2d(6, 6);
        let p = partition_balanced(&g, 2);
        let mask = p.boundary_mask(&g);
        for v in 0..g.num_nodes() {
            let expect = g.neighbors(v).iter().any(|&u| p.part_of(u as usize) != p.part_of(v));
            assert_eq!(mask[v], expect, "node {v}");
        }
    }

    #[test]
    fn parts_equal_nodes_is_fine() {
        let g = grid_2d(3, 3);
        let p = partition_balanced(&g, 9);
        assert!(p.is_valid(&g));
        assert!(p.parts().iter().all(|part| part.len() == 1));
    }

    #[test]
    fn deterministic() {
        let g = erdos_renyi_with_edges(64, 200, 3);
        let a = partition_balanced(&g, 4);
        let b = partition_balanced(&g, 4);
        assert_eq!(a.assignment, b.assignment);
    }
}
