//! Graph substrate: sparse undirected graphs (CSR), generators for the
//! Table I workload suite, the greedy coloring used by Block Gibbs to
//! partition RVs into conditionally-independent blocks, and the
//! balanced partitioner that shards a model across multi-core MC²A
//! simulations.

mod coloring;
mod generators;
mod partition;

pub use coloring::{color_greedy, Coloring};
pub use generators::{
    erdos_renyi_with_edges, grid_2d, grid_2d_conn, power_law_graph, random_regular_ish,
};
pub use partition::{partition_balanced, Partition};

/// An undirected graph in compressed-sparse-row form.
///
/// Node ids are `u32`; adjacency is stored sorted per node so that
/// neighbor queries used by the energy models and the hardware compiler
/// are cache-friendly and deterministic.
#[derive(Clone, Debug)]
pub struct Graph {
    /// CSR row offsets, length `n + 1`.
    pub offsets: Vec<u32>,
    /// CSR column indices (neighbor ids), length `2 * m`.
    pub neighbors: Vec<u32>,
    /// Optional per-edge weight aligned with `neighbors` (same weight
    /// appears for both directions of an edge). Empty ⇒ unweighted (1.0).
    pub weights: Vec<f32>,
}

impl Graph {
    /// Build a graph from an edge list over `n` nodes. Duplicate edges
    /// and self-loops are removed. Weights, when provided, must align
    /// with `edges`.
    pub fn from_edges(n: usize, edges: &[(u32, u32)], weights: Option<&[f32]>) -> Graph {
        if let Some(w) = weights {
            assert_eq!(w.len(), edges.len(), "weights must align with edges");
        }
        // Deduplicate (canonical low-high order), drop self loops.
        let mut canon: Vec<(u32, u32, f32)> = edges
            .iter()
            .enumerate()
            .filter(|(_, &(a, b))| a != b)
            .map(|(i, &(a, b))| {
                let w = weights.map_or(1.0, |w| w[i]);
                if a < b {
                    (a, b, w)
                } else {
                    (b, a, w)
                }
            })
            .collect();
        canon.sort_by_key(|&(a, b, _)| (a, b));
        canon.dedup_by_key(|&mut (a, b, _)| (a, b));

        let mut degree = vec![0u32; n];
        for &(a, b, _) in &canon {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            degree[a as usize] += 1;
            degree[b as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let total = offsets[n] as usize;
        let mut nbrs = vec![0u32; total];
        let mut wts = vec![0.0f32; total];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for &(a, b, w) in &canon {
            let ca = cursor[a as usize] as usize;
            nbrs[ca] = b;
            wts[ca] = w;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            nbrs[cb] = a;
            wts[cb] = w;
            cursor[b as usize] += 1;
        }
        // Sort each adjacency run (weights follow).
        for i in 0..n {
            let (s, e) = (offsets[i] as usize, offsets[i + 1] as usize);
            let mut pairs: Vec<(u32, f32)> =
                nbrs[s..e].iter().copied().zip(wts[s..e].iter().copied()).collect();
            pairs.sort_by_key(|&(v, _)| v);
            for (k, (v, w)) in pairs.into_iter().enumerate() {
                nbrs[s + k] = v;
                wts[s + k] = w;
            }
        }
        let weighted = weights.is_some();
        Graph {
            offsets,
            neighbors: nbrs,
            weights: if weighted { wts } else { Vec::new() },
        }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Neighbors of node `i` (sorted).
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Edge weights aligned with [`Graph::neighbors`]; `None` if unweighted.
    #[inline]
    pub fn neighbor_weights(&self, i: usize) -> Option<&[f32]> {
        if self.weights.is_empty() {
            None
        } else {
            Some(&self.weights[self.offsets[i] as usize..self.offsets[i + 1] as usize])
        }
    }

    /// Degree of node `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Maximum degree over all nodes.
    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes()).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// True if `(a, b)` is an edge (binary search on sorted adjacency).
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.neighbors(a).binary_search(&(b as u32)).is_ok()
    }

    /// The complement graph (used to reduce MaxClique to MIS). Intended
    /// for small/medium `n`: the Twitter workload (n = 247) complements
    /// to ~18 k edges.
    pub fn complement(&self) -> Graph {
        let n = self.num_nodes();
        let mut edges = Vec::new();
        for a in 0..n as u32 {
            let nbrs = self.neighbors(a as usize);
            let mut k = 0usize;
            for b in (a + 1)..n as u32 {
                while k < nbrs.len() && nbrs[k] < b {
                    k += 1;
                }
                if k >= nbrs.len() || nbrs[k] != b {
                    edges.push((a, b));
                }
            }
        }
        Graph::from_edges(n, &edges, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> Graph {
        Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2)], None)
    }

    #[test]
    fn csr_basics() {
        let g = tri();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (2, 2), (0, 1)], None);
        assert_eq!(g.num_edges(), 1);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(2, 2));
    }

    #[test]
    fn weights_follow_both_directions() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)], Some(&[2.5, -1.0]));
        let w0 = g.neighbor_weights(0).unwrap();
        assert_eq!(w0, &[2.5]);
        let w1 = g.neighbor_weights(1).unwrap();
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(w1, &[2.5, -1.0]);
    }

    #[test]
    fn complement_of_triangle() {
        let g = tri().complement();
        // Only node 3 connects to everyone in the complement.
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(3), &[0, 1, 2]);
        assert!(!g.has_edge(0, 1));
    }

    #[test]
    fn has_edge_symmetry() {
        let g = tri();
        for a in 0..4 {
            for b in 0..4 {
                assert_eq!(g.has_edge(a, b), g.has_edge(b, a));
            }
        }
    }
}
