//! Deterministic graph generators for the Table I workload suite.
//!
//! The paper uses instances from Satlib (ER-style MIS graphs), a Twitter
//! snapshot (MaxClique) and the Optsicom set (MaxCut). Those exact files
//! are not redistributable here, so each generator reproduces the node /
//! edge counts and degree statistics of Table I deterministically from a
//! seed (see DESIGN.md §4 Substitutions).

use super::Graph;
use crate::rng::Rng;

/// Erdős–Rényi graph with an *exact* edge count: sample distinct pairs
/// uniformly until `m` edges are placed. Matches Table I rows like
/// ER-1347 with 5978 edges.
pub fn erdos_renyi_with_edges(n: usize, m: usize, seed: u64) -> Graph {
    assert!(m <= n * (n - 1) / 2, "too many edges requested");
    let mut rng = Rng::new(seed);
    let mut chosen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a == b {
            continue;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        if chosen.insert(key) {
            edges.push(key);
        }
    }
    Graph::from_edges(n, &edges, None)
}

/// Power-law-ish social graph via preferential attachment, then random
/// extra edges to hit the exact target edge count. Used for the Twitter
/// MaxClique workload (247 nodes / 12 174 edges — dense, heavy-tailed).
pub fn power_law_graph(n: usize, m: usize, seed: u64) -> Graph {
    assert!(n >= 3 && m <= n * (n - 1) / 2);
    let mut rng = Rng::new(seed);
    let mut chosen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(m);
    // Endpoint pool realizes preferential attachment: nodes appear once
    // per incident edge, so the chance of attracting a new edge is
    // proportional to the current degree.
    let mut pool: Vec<u32> = vec![0, 1, 2, 0, 1, 2];
    let add = |a: u32, b: u32, chosen: &mut std::collections::HashSet<(u32, u32)>,
                   edges: &mut Vec<(u32, u32)>, pool: &mut Vec<u32>| {
        if a == b {
            return false;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        if chosen.insert(key) {
            edges.push(key);
            pool.push(a);
            pool.push(b);
            true
        } else {
            false
        }
    };
    add(0, 1, &mut chosen, &mut edges, &mut pool);
    add(1, 2, &mut chosen, &mut edges, &mut pool);
    add(0, 2, &mut chosen, &mut edges, &mut pool);
    // Attach each remaining node to ~m/n existing high-degree nodes.
    let per_node = (m / n).max(1);
    for v in 3..n as u32 {
        let mut attached = 0;
        let mut attempts = 0;
        while attached < per_node && attempts < 50 * per_node {
            let t = pool[rng.below(pool.len())];
            if add(v, t, &mut chosen, &mut edges, &mut pool) {
                attached += 1;
            }
            attempts += 1;
        }
    }
    // Fill to the exact count with preferential pairs, falling back to
    // uniform pairs when the pool saturates.
    let mut stall = 0;
    while edges.len() < m {
        let (a, b) = if stall < 1000 {
            (pool[rng.below(pool.len())], pool[rng.below(pool.len())])
        } else {
            (rng.below(n) as u32, rng.below(n) as u32)
        };
        if add(a, b, &mut chosen, &mut edges, &mut pool) {
            stall = 0;
        } else {
            stall += 1;
        }
    }
    Graph::from_edges(n, &edges, None)
}

/// 2D grid graph (4-neighborhood) of `h × w` nodes — the Ising / MRF
/// image-segmentation substrate. Node id = `r * w + c`.
pub fn grid_2d(h: usize, w: usize) -> Graph {
    grid_2d_conn(h, w, false)
}

/// 2D grid with selectable 4- or 8-neighborhood. Table I's
/// image-segmentation MRF (150 k nodes, 600 k edges) implies the
/// 8-connected variant (~4 edges per node).
pub fn grid_2d_conn(h: usize, w: usize, eight: bool) -> Graph {
    let mut edges = Vec::with_capacity(if eight { 4 * h * w } else { 2 * h * w });
    for r in 0..h {
        for c in 0..w {
            let id = (r * w + c) as u32;
            if c + 1 < w {
                edges.push((id, id + 1));
            }
            if r + 1 < h {
                edges.push((id, id + w as u32));
                if eight {
                    if c + 1 < w {
                        edges.push((id, id + w as u32 + 1));
                    }
                    if c > 0 {
                        edges.push((id, id + w as u32 - 1));
                    }
                }
            }
        }
    }
    Graph::from_edges(h * w, &edges, None)
}

/// Sparse weighted graph with near-uniform degree `2m/n` and weights
/// drawn uniformly from `weight_range` — matches the Optsicom MaxCut
/// instances (125 nodes / 375 edges, small integer weights).
pub fn random_regular_ish(
    n: usize,
    m: usize,
    weight_range: (i32, i32),
    seed: u64,
) -> (Graph, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut chosen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    // Half the edges from a ring (guarantees connectivity + uniform base
    // degree), the rest uniform random.
    for i in 0..n.min(m) {
        let a = i as u32;
        let b = ((i + 1) % n) as u32;
        let key = if a < b { (a, b) } else { (b, a) };
        if chosen.insert(key) {
            edges.push(key);
        }
    }
    while edges.len() < m {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a == b {
            continue;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        if chosen.insert(key) {
            edges.push(key);
        }
    }
    let span = (weight_range.1 - weight_range.0 + 1).max(1) as usize;
    let weights: Vec<f32> = (0..edges.len())
        .map(|_| (weight_range.0 + rng.below(span) as i32) as f32)
        .collect();
    let g = Graph::from_edges(n, &edges, Some(&weights));
    (g, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_exact_counts() {
        let g = erdos_renyi_with_edges(100, 300, 7);
        assert_eq!(g.num_nodes(), 100);
        assert_eq!(g.num_edges(), 300);
    }

    #[test]
    fn er_deterministic() {
        let a = erdos_renyi_with_edges(50, 100, 3);
        let b = erdos_renyi_with_edges(50, 100, 3);
        assert_eq!(a.neighbors, b.neighbors);
    }

    #[test]
    fn power_law_counts_and_tail() {
        let g = power_law_graph(247, 12_174, 11);
        assert_eq!(g.num_nodes(), 247);
        assert_eq!(g.num_edges(), 12_174);
        // Heavy tail: max degree well above the mean (2m/n ≈ 98.6).
        assert!(g.max_degree() > 130, "max_degree={}", g.max_degree());
    }

    #[test]
    fn grid_structure() {
        let g = grid_2d(3, 4);
        assert_eq!(g.num_nodes(), 12);
        assert_eq!(g.num_edges(), 3 * 3 + 2 * 4); // h*(w-1) + (h-1)*w
        assert_eq!(g.neighbors(0), &[1, 4]);
        assert_eq!(g.degree(5), 4); // interior node
    }

    #[test]
    fn regular_ish_weights_in_range() {
        let (g, _) = random_regular_ish(125, 375, (1, 10), 5);
        assert_eq!(g.num_nodes(), 125);
        assert_eq!(g.num_edges(), 375);
        for i in 0..g.num_nodes() {
            if let Some(ws) = g.neighbor_weights(i) {
                for &w in ws {
                    assert!((1.0..=10.0).contains(&w));
                }
            }
        }
    }
}
