//! Greedy graph coloring.
//!
//! Block Gibbs sampling (§II-A) partitions RVs into blocks such that no
//! two RVs in the same block are Markov-blanket neighbors; a proper
//! vertex coloring of the interaction graph gives exactly that
//! partition (chessboard decomposition on grids falls out as the
//! 2-coloring). The MC²A compiler also uses colorings to schedule
//! conflict-free parallel RV updates onto the CU/SU array.

use super::Graph;

/// A proper vertex coloring: `color[v]` ∈ `[0, num_colors)` and no edge
/// has both endpoints the same color.
#[derive(Clone, Debug)]
pub struct Coloring {
    /// Per-node color id.
    pub color: Vec<u32>,
    /// Total number of colors used.
    pub num_colors: u32,
}

impl Coloring {
    /// Group node ids by color: `blocks()[c]` lists every node of color `c`.
    pub fn blocks(&self) -> Vec<Vec<u32>> {
        let mut blocks = vec![Vec::new(); self.num_colors as usize];
        for (v, &c) in self.color.iter().enumerate() {
            blocks[c as usize].push(v as u32);
        }
        blocks
    }

    /// Check properness against a graph (used by tests and proptest).
    pub fn is_proper(&self, g: &Graph) -> bool {
        (0..g.num_nodes()).all(|v| {
            g.neighbors(v)
                .iter()
                .all(|&u| self.color[v] != self.color[u as usize])
        })
    }
}

/// Greedy coloring in largest-degree-first order. Uses at most
/// `max_degree + 1` colors; on bipartite-friendly structures (grids) it
/// finds the natural chessboard 2-coloring.
pub fn color_greedy(g: &Graph) -> Coloring {
    let n = g.num_nodes();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(g.degree(v as usize)));

    let mut color = vec![u32::MAX; n];
    let mut used = vec![false; g.max_degree() + 2];
    let mut num_colors = 0u32;
    for &v in &order {
        for &u in g.neighbors(v as usize) {
            let c = color[u as usize];
            if c != u32::MAX {
                used[c as usize] = true;
            }
        }
        let c = (0..).find(|&c| !used[c as usize]).unwrap();
        color[v as usize] = c;
        num_colors = num_colors.max(c + 1);
        for &u in g.neighbors(v as usize) {
            let cu = color[u as usize];
            if cu != u32::MAX {
                used[cu as usize] = false;
            }
        }
    }
    Coloring { color, num_colors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{erdos_renyi_with_edges, grid_2d};

    #[test]
    fn grid_is_two_colorable() {
        let g = grid_2d(8, 8);
        let c = color_greedy(&g);
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors, 2, "grid should chessboard 2-color");
    }

    #[test]
    fn er_coloring_proper_and_bounded() {
        let g = erdos_renyi_with_edges(200, 800, 13);
        let c = color_greedy(&g);
        assert!(c.is_proper(&g));
        assert!(c.num_colors as usize <= g.max_degree() + 1);
    }

    #[test]
    fn blocks_partition_all_nodes() {
        let g = erdos_renyi_with_edges(100, 250, 2);
        let c = color_greedy(&g);
        let total: usize = c.blocks().iter().map(|b| b.len()).sum();
        assert_eq!(total, g.num_nodes());
    }

    #[test]
    fn empty_graph_one_color() {
        let g = Graph::from_edges(5, &[], None);
        let c = color_greedy(&g);
        assert_eq!(c.num_colors, 1);
        assert!(c.is_proper(&g));
    }
}
