//! Bayesian networks with conditional probability tables (CPTs).
//!
//! The paper's irregular-graph workloads (Table I: Earthquake, Survey;
//! Fig. 14 additionally Cancer and Alarm) are Bayes nets sampled with
//! (Block) Gibbs. Energies are `-log P` so the hardware's log-domain
//! add/compare pipeline applies (Fig. 3); evidence is supported by
//! clamping RVs.

use super::{EnergyModel, OpCost};
use crate::graph::Graph;

/// A conditional probability table for one node.
#[derive(Clone, Debug)]
pub struct Cpt {
    /// Parent node ids (order defines the stride layout of `table`).
    pub parents: Vec<u32>,
    /// Cardinality of this node.
    pub card: u32,
    /// `P(node = s | parents = cfg)` flattened as
    /// `table[cfg_index * card + s]`, where `cfg_index` iterates parent
    /// states with the **last parent fastest** (C order).
    pub table: Vec<f64>,
}

impl Cpt {
    /// Index of a parent configuration given the full assignment.
    fn cfg_index(&self, x: &[u32], cards: &[u32]) -> usize {
        let mut idx = 0usize;
        for &p in &self.parents {
            idx = idx * cards[p as usize] as usize + x[p as usize] as usize;
        }
        idx
    }

    /// `P(node = s | parents(x))`.
    pub fn prob(&self, x: &[u32], s: u32, cards: &[u32]) -> f64 {
        self.table[self.cfg_index(x, cards) * self.card as usize + s as usize]
    }

    /// Validate: each parent-configuration row sums to 1.
    pub fn is_normalized(&self, tol: f64) -> bool {
        self.table
            .chunks(self.card as usize)
            .all(|row| (row.iter().sum::<f64>() - 1.0).abs() < tol)
    }
}

/// A Bayesian network: the joint factorizes as
/// `P(x) = Π_i P(x_i | pa(x_i))`, so
/// `E(x) = -Σ_i log P(x_i | pa(x_i))`.
#[derive(Clone, Debug)]
pub struct BayesNet {
    name: String,
    cpts: Vec<Cpt>,
    cards: Vec<u32>,
    /// Children lists: `children[i]` = nodes having `i` as a parent.
    children: Vec<Vec<u32>>,
    /// Moral graph (parents + children + co-parents) = Markov blankets.
    moral: Graph,
    /// Clamped evidence values; `u32::MAX` = free.
    evidence: Vec<u32>,
}

impl BayesNet {
    /// Build a network from named CPTs. Panics on malformed tables.
    pub fn new(name: &str, cpts: Vec<Cpt>) -> BayesNet {
        let n = cpts.len();
        let cards: Vec<u32> = cpts.iter().map(|c| c.card).collect();
        for (i, c) in cpts.iter().enumerate() {
            let cfgs: usize = c
                .parents
                .iter()
                .map(|&p| cards[p as usize] as usize)
                .product();
            assert_eq!(
                c.table.len(),
                cfgs * c.card as usize,
                "node {i}: CPT size mismatch"
            );
            assert!(c.is_normalized(1e-6), "node {i}: CPT rows must sum to 1");
        }
        let mut children = vec![Vec::new(); n];
        let mut moral_edges = Vec::new();
        for (i, c) in cpts.iter().enumerate() {
            for &p in &c.parents {
                children[p as usize].push(i as u32);
                moral_edges.push((p, i as u32));
            }
            // moralization: co-parents become neighbors
            for (a, &pa) in c.parents.iter().enumerate() {
                for &pb in &c.parents[a + 1..] {
                    moral_edges.push((pa, pb));
                }
            }
        }
        let moral = Graph::from_edges(n, &moral_edges, None);
        BayesNet {
            name: name.to_string(),
            cpts,
            cards,
            children,
            moral,
            evidence: vec![u32::MAX; n],
        }
    }

    /// Network name (e.g. "earthquake").
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Clamp node `i` to value `v` (inference evidence).
    pub fn set_evidence(&mut self, i: usize, v: u32) {
        assert!(v < self.cards[i]);
        self.evidence[i] = v;
    }

    /// True if node `i` is clamped.
    pub fn is_clamped(&self, i: usize) -> bool {
        self.evidence[i] != u32::MAX
    }

    /// Clamped value of node `i`, if any.
    pub fn evidence(&self, i: usize) -> Option<u32> {
        (self.evidence[i] != u32::MAX).then_some(self.evidence[i])
    }

    /// Number of directed edges (Table I's edge count).
    pub fn num_dag_edges(&self) -> usize {
        self.cpts.iter().map(|c| c.parents.len()).sum()
    }

    /// The CPT of node `i`.
    pub fn cpt(&self, i: usize) -> &Cpt {
        &self.cpts[i]
    }

    /// Exact marginal P(node = s) by brute-force enumeration — only for
    /// small nets; used to validate Gibbs histograms in tests.
    pub fn exact_marginal(&self, node: usize) -> Vec<f64> {
        let n = self.cpts.len();
        assert!(
            self.cards.iter().map(|&c| c as usize).product::<usize>() <= 1 << 22,
            "state space too large for enumeration"
        );
        let mut marg = vec![0.0f64; self.cards[node] as usize];
        let mut x = vec![0u32; n];
        let mut total = 0.0f64;
        loop {
            // respect evidence
            let consistent = (0..n).all(|i| self.evidence[i] == u32::MAX || x[i] == self.evidence[i]);
            if consistent {
                let p = (-self.energy(&x)).exp();
                marg[x[node] as usize] += p;
                total += p;
            }
            // odometer increment
            let mut i = 0;
            loop {
                if i == n {
                    let z = total.max(f64::MIN_POSITIVE);
                    for m in &mut marg {
                        *m /= z;
                    }
                    return marg;
                }
                x[i] += 1;
                if x[i] < self.cards[i] {
                    break;
                }
                x[i] = 0;
                i += 1;
            }
        }
    }
}

impl EnergyModel for BayesNet {
    fn num_vars(&self) -> usize {
        self.cpts.len()
    }

    fn num_states(&self, i: usize) -> usize {
        self.cards[i] as usize
    }

    fn interaction(&self) -> &Graph {
        &self.moral
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        let card = self.cards[i] as usize;
        out.clear();
        if let Some(v) = self.evidence(i) {
            // Clamped: infinite energy off the evidence value.
            out.resize(card, f32::INFINITY);
            out[v as usize] = 0.0;
            return;
        }
        out.resize(card, 0.0);
        let mut y = x.to_vec();
        for s in 0..card as u32 {
            y[i] = s;
            // -log P(x_i = s | pa_i)
            let mut e = -self.cpts[i].prob(&y, s, &self.cards).max(1e-30).ln();
            // -log P(child | pa(child) with x_i = s) for each child
            for &c in &self.children[i] {
                let p = self.cpts[c as usize].prob(&y, y[c as usize], &self.cards);
                e -= p.max(1e-30).ln();
            }
            out[s as usize] = e as f32;
        }
    }

    fn energy(&self, x: &[u32]) -> f64 {
        let mut e = 0.0;
        for (i, c) in self.cpts.iter().enumerate() {
            // Same zero-probability clamp as local_energies so that
            // energy differences agree between the two paths.
            e -= c.prob(x, x[i], &self.cards).max(1e-30).ln();
        }
        e
    }

    fn update_cost(&self, i: usize) -> OpCost {
        // Per candidate state: 1 CPT lookup for self + 1 per child, all
        // log-domain adds; CPT entries are 4-byte log-probs in the
        // accelerator's CDT memory (Fig. 10a's indirect access pattern).
        let s = self.cards[i] as u64;
        let kids = self.children[i].len() as u64;
        OpCost {
            ops: s * (kids + 1),
            bytes: 4 * (s * (kids + 1) + self.moral.degree(i) as u64 + 1),
            samples: 1,
        }
    }

    fn param_words_per_state(&self, i: usize) -> usize {
        // Per candidate state: this node's CPT entry + one entry per
        // child CPT (indirectly addressed via the sample memory —
        // Fig. 10a's CDT access pattern).
        1 + self.children[i].len()
    }
}

/// Helper to assemble a CPT row-major table from nested rows.
#[allow(dead_code)]
pub(crate) fn cpt(parents: &[u32], card: u32, rows: &[&[f64]]) -> Cpt {
    let table: Vec<f64> = rows.iter().flat_map(|r| r.iter().copied()).collect();
    Cpt {
        parents: parents.to_vec(),
        card,
        table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::testutil::check_local_consistency;

    /// Classic sprinkler net: Cloudy -> Sprinkler, Rain -> WetGrass.
    pub(crate) fn sprinkler() -> BayesNet {
        let c = cpt(&[], 2, &[&[0.5, 0.5]]);
        let s = cpt(&[0], 2, &[&[0.5, 0.5], &[0.9, 0.1]]);
        let r = cpt(&[0], 2, &[&[0.8, 0.2], &[0.2, 0.8]]);
        let w = cpt(
            &[1, 2],
            2,
            &[&[1.0, 0.0], &[0.1, 0.9], &[0.1, 0.9], &[0.01, 0.99]],
        );
        BayesNet::new("sprinkler", vec![c, s, r, w])
    }

    #[test]
    fn joint_probability_factorizes() {
        let net = sprinkler();
        // P(C=1,S=0,R=1,W=1) = 0.5 * 0.9 * 0.8 * 0.9
        let x = [1, 0, 1, 1];
        let p = (-net.energy(&x)).exp();
        assert!((p - 0.5 * 0.9 * 0.8 * 0.9).abs() < 1e-9);
    }

    #[test]
    fn moral_graph_includes_coparents() {
        let net = sprinkler();
        // Sprinkler(1) and Rain(2) are co-parents of WetGrass(3).
        assert!(net.interaction().has_edge(1, 2));
        assert!(net.interaction().has_edge(0, 1));
        assert!(net.interaction().has_edge(2, 3));
    }

    #[test]
    fn local_energies_consistent() {
        let net = sprinkler();
        for x in [[0, 0, 0, 0], [1, 0, 1, 1], [1, 1, 1, 1]] {
            check_local_consistency(&net, &x, 1e-4);
        }
    }

    #[test]
    fn default_batched_energies_match_scalar_bitwise() {
        // BayesNet has no override: this exercises the trait's default
        // Markov-blanket gather path.
        use crate::energy::testutil::check_batch_consistency;
        check_batch_consistency(&sprinkler(), 6, 31);
    }

    #[test]
    fn exact_marginal_sums_to_one() {
        let net = sprinkler();
        let m = net.exact_marginal(3);
        assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Wet grass is likely a priori in this parameterization.
        assert!(m[1] > 0.5);
    }

    #[test]
    fn evidence_clamps_local_energy() {
        let mut net = sprinkler();
        net.set_evidence(0, 1);
        let mut out = Vec::new();
        net.local_energies(&[0, 0, 0, 0], 0, &mut out);
        assert_eq!(out[1], 0.0);
        assert!(out[0].is_infinite());
    }

    #[test]
    fn evidence_shifts_marginal() {
        let mut net = sprinkler();
        let prior = net.exact_marginal(2)[1];
        net.set_evidence(0, 1); // cloudy ⇒ rain more likely
        let posterior = net.exact_marginal(2)[1];
        assert!(posterior > prior);
    }

    #[test]
    fn dag_edge_count() {
        assert_eq!(sprinkler().num_dag_edges(), 4);
    }
}
