//! Discrete energy models.
//!
//! Every workload in the paper (Table I) is an energy function
//! `E(x) = -log P(x) · 1/β` over a vector of discrete random variables.
//! The MCMC algorithms ([`crate::mcmc`]), the op-count profiler behind
//! Fig. 5, the roofline model, and the hardware compiler all consume the
//! same [`EnergyModel`] trait, so a new application plugs into the whole
//! co-design flow by implementing one trait.

mod bayesnet;
mod cop;
mod potts;
mod rbm;

pub use bayesnet::{BayesNet, Cpt};
pub use cop::{MaxCliqueModel, MaxCutModel, MisModel};
pub use potts::PottsGrid;
pub use rbm::Rbm;

use crate::graph::Graph;

/// Per-RV-update hardware cost of evaluating the conditional energy
/// distribution, used by the Fig. 5 profiler and the roofline model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    /// Arithmetic ops (adds/mults) to build the conditional distribution.
    pub ops: u64,
    /// Bytes moved from state/parameter memory.
    pub bytes: u64,
    /// Number of categorical samples drawn.
    pub samples: u64,
}

impl OpCost {
    /// Component-wise accumulation.
    pub fn add(&mut self, other: OpCost) {
        self.ops += other.ops;
        self.bytes += other.bytes;
        self.samples += other.samples;
    }
}

/// Reusable scratch buffers for the default (loop-over-scalar) batched
/// energy kernels, so the hot loop performs no per-call allocation.
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// Full-length gathered assignment for one chain of the batch.
    pub x: Vec<u32>,
    /// Scalar conditional-energy buffer for one chain.
    pub e: Vec<f32>,
}

/// A discrete energy model: the target distribution is
/// `P(x) ∝ exp(-β E(x))` over assignment vectors `x` with
/// `x[i] ∈ [0, num_states(i))`.
///
/// # Contract
///
/// [`EnergyModel::local_energies`] (and [`EnergyModel::delta_energy`])
/// may only read `x` at position `i` and at `i`'s neighbors in
/// [`EnergyModel::interaction`] — the Markov blanket. The batched
/// execution path relies on this to gather one chain's conditional
/// context out of a structure-of-arrays state block without
/// materializing the full assignment.
pub trait EnergyModel: Send + Sync {
    /// Number of random variables.
    fn num_vars(&self) -> usize;

    /// Cardinality of RV `i`.
    fn num_states(&self, i: usize) -> usize;

    /// The interaction graph: RV `i`'s Markov blanket is exactly its
    /// neighborhood here. Block Gibbs colors this graph; the hardware
    /// compiler uses it for crossbar routing and RF-bank placement.
    fn interaction(&self) -> &Graph;

    /// Conditional (local) energies of RV `i`: fills `out[s]` with the
    /// energy of the assignment `x` modified so `x[i] = s`, **up to an
    /// additive constant shared across `s`** (constants cancel in the
    /// conditional distribution). `out` is resized to `num_states(i)`.
    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>);

    /// Batched conditional energies of RV `i` for `k` chains held in a
    /// structure-of-arrays state block: `xs[j * k + c]` is chain `c`'s
    /// value of RV `j` (column-major per variable). Fills `out` with
    /// `k * num_states(i)` entries, **state-major**: `out[s * k + c]`
    /// is chain `c`'s energy for candidate state `s`, so the K-wide
    /// row for one candidate state is a contiguous slice — the layout
    /// the lane-parallel sampler kernels consume directly.
    ///
    /// The default gathers each chain's Markov blanket into
    /// `scratch.x` and evaluates the scalar kernel, so every model
    /// works unchanged; models with vectorizable structure (Potts,
    /// MaxCut, MIS) override it to amortize the neighbor-index walk
    /// across the whole batch.
    fn local_energies_batch(
        &self,
        xs: &[u32],
        k: usize,
        i: usize,
        out: &mut Vec<f32>,
        scratch: &mut BatchScratch,
    ) {
        let s = self.num_states(i);
        out.clear();
        out.resize(k * s, 0.0);
        scratch.x.resize(self.num_vars(), 0);
        let nbrs = self.interaction().neighbors(i);
        for c in 0..k {
            scratch.x[i] = xs[i * k + c];
            for &nb in nbrs {
                scratch.x[nb as usize] = xs[nb as usize * k + c];
            }
            self.local_energies(&scratch.x, i, &mut scratch.e);
            for (st, &v) in scratch.e.iter().enumerate() {
                out[st * k + c] = v;
            }
        }
    }

    /// Total energy of assignment `x`.
    fn energy(&self, x: &[u32]) -> f64;

    /// Application-level objective (higher is better), e.g. cut weight
    /// for MaxCut or set size for MIS. Defaults to `-E(x)`.
    fn objective(&self, x: &[u32]) -> f64 {
        -self.energy(x)
    }

    /// Best known objective for this instance, when available — used to
    /// report the "accuracy" metric of Fig. 5 (objective / best-known).
    fn best_known(&self) -> Option<f64> {
        None
    }

    /// Hardware cost of one conditional-distribution evaluation + sample
    /// for RV `i` (paper §II-C's three steps). The default derives it
    /// from the Markov-blanket size: for each of the `S` candidate
    /// states, one weighted term per neighbor plus the unary term, all
    /// f32 (4-byte) traffic, one categorical sample per update.
    fn update_cost(&self, i: usize) -> OpCost {
        let s = self.num_states(i) as u64;
        let d = self.interaction().degree(i) as u64;
        OpCost {
            // per state: d multiply-accumulates + 1 unary add
            ops: s * (2 * d + 1),
            // read d neighbor states + per-state parameters + write 1 state
            bytes: 4 * (d + s * (d + 1) + 1),
            samples: 1,
        }
    }

    /// Energy delta of setting `x[i] = s` (positive = uphill). Default
    /// computes it from [`EnergyModel::local_energies`]; models with
    /// cheap incremental structure (Ising, MaxCut) override this.
    fn delta_energy(&self, x: &[u32], i: usize, s: u32, scratch: &mut Vec<f32>) -> f32 {
        self.local_energies(x, i, scratch);
        scratch[s as usize] - scratch[x[i] as usize]
    }

    // ---- hardware-compiler hints (memory layout of one RV update) ----

    /// 32-bit words that must be fetched once per update of RV `i`
    /// regardless of the candidate state (neighbor values, and for
    /// weighted models the edge weights). Default: one word per
    /// Markov-blanket neighbor.
    fn neighbor_words(&self, i: usize) -> usize {
        self.interaction().degree(i)
    }

    /// Additional 32-bit words fetched **per candidate state** (unary
    /// potentials, CPT entries). Default: 1 (one parameter per state).
    fn param_words_per_state(&self, _i: usize) -> usize {
        1
    }
}

/// Convenience: a deterministic initial assignment (all zeros).
pub fn zero_state(model: &dyn EnergyModel) -> Vec<u32> {
    vec![0; model.num_vars()]
}

/// Convenience: a uniformly random assignment.
pub fn random_state(model: &dyn EnergyModel, rng: &mut crate::rng::Rng) -> Vec<u32> {
    (0..model.num_vars())
        .map(|i| rng.below(model.num_states(i)) as u32)
        .collect()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Check that `local_energies_batch` reproduces the scalar kernel
    /// **bit-exactly** for `k` random chains packed into an SoA block.
    pub fn check_batch_consistency(model: &dyn EnergyModel, k: usize, seed: u64) {
        let mut rng = crate::rng::Rng::new(seed);
        let n = model.num_vars();
        let chains: Vec<Vec<u32>> = (0..k).map(|_| random_state(model, &mut rng)).collect();
        let mut xs = vec![0u32; n * k];
        for (c, x) in chains.iter().enumerate() {
            for i in 0..n {
                xs[i * k + c] = x[i];
            }
        }
        let mut out = Vec::new();
        let mut scratch = BatchScratch::default();
        let mut e = Vec::new();
        for i in 0..n {
            let s = model.num_states(i);
            model.local_energies_batch(&xs, k, i, &mut out, &mut scratch);
            assert_eq!(out.len(), k * s, "var {i}: wrong batch output length");
            for (c, x) in chains.iter().enumerate() {
                model.local_energies(x, i, &mut e);
                for (st, &want) in e.iter().enumerate() {
                    assert_eq!(
                        out[st * k + c].to_bits(),
                        want.to_bits(),
                        "var {i} chain {c} state {st}: batched energy diverges from scalar"
                    );
                }
            }
        }
    }

    /// Exhaustively check that `local_energies` differences agree with
    /// full-energy differences for every var/state on small models.
    pub fn check_local_consistency(model: &dyn EnergyModel, x: &[u32], tol: f32) {
        let mut out = Vec::new();
        let base = model.energy(x);
        for i in 0..model.num_vars() {
            model.local_energies(x, i, &mut out);
            let cur = out[x[i] as usize];
            for s in 0..model.num_states(i) as u32 {
                let mut y = x.to_vec();
                y[i] = s;
                let want = (model.energy(&y) - base) as f32;
                let got = out[s as usize] - cur;
                assert!(
                    (want - got).abs() <= tol * (1.0 + want.abs()),
                    "var {i} state {s}: local diff {got} vs full diff {want}"
                );
            }
        }
    }
}
