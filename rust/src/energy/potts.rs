//! Potts / Ising models on a 2D grid.
//!
//! The paper's MRF image-segmentation workload (Table I: 150 k nodes,
//! 600 k edges) and the Fig. 6 Ising roofline example both live here.
//! With `num_labels == 2` and no unary term this is the standard Ising
//! model; with `L` labels and per-pixel unary potentials it is the
//! image-segmentation MRF of Fig. 3.

use super::{BatchScratch, EnergyModel, OpCost};
use crate::graph::{grid_2d_conn, Graph};

/// A Potts model on an `h × w` 4-neighbor grid.
///
/// Energy:
/// `E(x) = Σ_i unary[i][x_i] - coupling · Σ_{(i,j)∈E} [x_i == x_j]`
///
/// (`coupling > 0` is ferromagnetic / smoothing, the image-segmentation
/// setting).
#[derive(Clone, Debug)]
pub struct PottsGrid {
    h: usize,
    w: usize,
    num_labels: usize,
    coupling: f32,
    /// Row-major per-node unary potentials, `unary[i * L + s]`; empty ⇒ 0.
    unary: Vec<f32>,
    graph: Graph,
}

impl PottsGrid {
    /// Pure Potts/Ising grid (4-neighborhood) without unary terms.
    pub fn new(h: usize, w: usize, num_labels: usize, coupling: f32) -> PottsGrid {
        Self::with_connectivity(h, w, num_labels, coupling, false)
    }

    /// Potts grid with selectable 4-/8-neighborhood (the Table I
    /// image-segmentation MRF is 8-connected).
    pub fn with_connectivity(
        h: usize,
        w: usize,
        num_labels: usize,
        coupling: f32,
        eight: bool,
    ) -> PottsGrid {
        assert!(num_labels >= 2);
        PottsGrid {
            h,
            w,
            num_labels,
            coupling,
            unary: Vec::new(),
            graph: grid_2d_conn(h, w, eight),
        }
    }

    /// Image-segmentation MRF: unary data terms per pixel per label.
    pub fn with_unary(
        h: usize,
        w: usize,
        num_labels: usize,
        coupling: f32,
        unary: Vec<f32>,
    ) -> PottsGrid {
        assert_eq!(unary.len(), h * w * num_labels);
        let mut g = PottsGrid::new(h, w, num_labels, coupling);
        g.unary = unary;
        g
    }

    /// Attach (or replace) unary data terms after construction.
    pub fn set_unary(&mut self, unary: Vec<f32>) {
        assert_eq!(unary.len(), self.h * self.w * self.num_labels);
        self.unary = unary;
    }

    /// Grid height.
    pub fn height(&self) -> usize {
        self.h
    }

    /// Grid width.
    pub fn width(&self) -> usize {
        self.w
    }

    /// Pairwise coupling strength.
    pub fn coupling(&self) -> f32 {
        self.coupling
    }

    #[inline]
    fn unary_at(&self, i: usize, s: usize) -> f32 {
        if self.unary.is_empty() {
            0.0
        } else {
            self.unary[i * self.num_labels + s]
        }
    }
}

impl EnergyModel for PottsGrid {
    fn num_vars(&self) -> usize {
        self.h * self.w
    }

    fn num_states(&self, _i: usize) -> usize {
        self.num_labels
    }

    fn interaction(&self) -> &Graph {
        &self.graph
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.num_labels, 0.0);
        for (s, e) in out.iter_mut().enumerate() {
            *e = self.unary_at(i, s);
        }
        // -coupling for every agreeing neighbor.
        for &nb in self.graph.neighbors(i) {
            let lbl = x[nb as usize] as usize;
            out[lbl] -= self.coupling;
        }
    }

    fn local_energies_batch(
        &self,
        xs: &[u32],
        k: usize,
        i: usize,
        out: &mut Vec<f32>,
        _scratch: &mut BatchScratch,
    ) {
        let l = self.num_labels;
        out.clear();
        if self.unary.is_empty() {
            out.resize(k * l, 0.0);
        } else {
            out.reserve(k * l);
            for s in 0..l {
                out.resize((s + 1) * k, self.unary[i * l + s]);
            }
        }
        // One neighbor-index fetch serves the whole batch. State-major
        // output makes each label's K-wide row contiguous, so the inner
        // loop is a branch-free compare-and-subtract over the row that
        // the compiler lowers to a vector mask + blend.
        for &nb in self.graph.neighbors(i) {
            let col = &xs[nb as usize * k..nb as usize * k + k];
            for lbl in 0..l {
                let row = &mut out[lbl * k..lbl * k + k];
                for (o, &v) in row.iter_mut().zip(col) {
                    if v as usize == lbl {
                        *o -= self.coupling;
                    }
                }
            }
        }
    }

    fn energy(&self, x: &[u32]) -> f64 {
        let mut e = 0.0f64;
        for i in 0..self.num_vars() {
            e += self.unary_at(i, x[i] as usize) as f64;
            for &nb in self.graph.neighbors(i) {
                if nb as usize > i && x[nb as usize] == x[i] {
                    e -= self.coupling as f64;
                }
            }
        }
        e
    }

    fn update_cost(&self, i: usize) -> OpCost {
        // Fig. 6(c)'s Ising accounting: read 4 neighbor values, ~10 ops
        // to build the distribution, 1 sample. Generalized to L labels
        // and boundary degrees.
        let d = self.graph.degree(i) as u64;
        let l = self.num_labels as u64;
        OpCost {
            ops: d + 2 * l, // neighbor agreement adds + per-label unary & β-scale
            bytes: 4 * (d + 1) + if self.unary.is_empty() { 0 } else { 4 * l },
            samples: 1,
        }
    }

    fn param_words_per_state(&self, _i: usize) -> usize {
        // Pure Potts couplings are a single registered constant; only
        // the image-segmentation variant streams per-label unary terms.
        if self.unary.is_empty() {
            0
        } else {
            1
        }
    }

    fn delta_energy(&self, x: &[u32], i: usize, s: u32, _scratch: &mut Vec<f32>) -> f32 {
        let cur = x[i];
        if s == cur {
            return 0.0;
        }
        let mut agree_new = 0u32;
        let mut agree_cur = 0u32;
        for &nb in self.graph.neighbors(i) {
            let lbl = x[nb as usize];
            agree_new += (lbl == s) as u32;
            agree_cur += (lbl == cur) as u32;
        }
        self.unary_at(i, s as usize) - self.unary_at(i, cur as usize)
            - self.coupling * (agree_new as f32 - agree_cur as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::testutil::check_local_consistency;
    use crate::energy::random_state;
    use crate::rng::Rng;

    #[test]
    fn ising_ground_state_energy() {
        // 3x3 ferromagnetic Ising: all-equal labels minimize energy.
        let m = PottsGrid::new(3, 3, 2, 1.0);
        let uniform = vec![0u32; 9];
        assert_eq!(m.energy(&uniform), -12.0); // 12 grid edges all agree
        let mut checker = vec![0u32; 9];
        for (i, v) in checker.iter_mut().enumerate() {
            *v = ((i / 3 + i % 3) % 2) as u32;
        }
        assert_eq!(m.energy(&checker), 0.0); // no agreeing edges
    }

    #[test]
    fn local_energies_consistent_with_full() {
        let m = PottsGrid::new(4, 3, 3, 0.7);
        let mut rng = Rng::new(1);
        let x = random_state(&m, &mut rng);
        check_local_consistency(&m, &x, 1e-5);
    }

    #[test]
    fn local_energies_with_unary_consistent() {
        let mut rng = Rng::new(2);
        let unary: Vec<f32> = (0..4 * 4 * 2).map(|_| rng.uniform_f32() * 3.0).collect();
        let m = PottsGrid::with_unary(4, 4, 2, 0.5, unary);
        let x = random_state(&m, &mut rng);
        check_local_consistency(&m, &x, 1e-4);
    }

    #[test]
    fn batched_energies_match_scalar_bitwise() {
        use crate::energy::testutil::check_batch_consistency;
        check_batch_consistency(&PottsGrid::new(5, 4, 3, 0.7), 6, 11);
        let mut rng = Rng::new(12);
        let unary: Vec<f32> = (0..4 * 4 * 2).map(|_| rng.uniform_f32() * 3.0).collect();
        check_batch_consistency(&PottsGrid::with_unary(4, 4, 2, 0.5, unary), 5, 13);
    }

    #[test]
    fn delta_energy_matches_local() {
        let m = PottsGrid::new(5, 5, 4, 1.3);
        let mut rng = Rng::new(3);
        let x = random_state(&m, &mut rng);
        let mut scratch = Vec::new();
        for i in 0..m.num_vars() {
            m.local_energies(&x, i, &mut scratch);
            let cur = scratch[x[i] as usize];
            let locals = scratch.clone();
            for s in 0..4u32 {
                let d = m.delta_energy(&x, i, s, &mut scratch);
                assert!((d - (locals[s as usize] - cur)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn update_cost_interior_matches_fig6() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        // interior node id: row 3, col 3
        let c = m.update_cost(3 * 8 + 3);
        assert_eq!(c.samples, 1);
        assert_eq!(c.bytes, 4 * 5); // 4 neighbors + 1 state write
        assert!(c.ops >= 8); // ~10 in the paper's accounting
    }
}
