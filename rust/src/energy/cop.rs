//! Combinatorial-optimization energy models: MaxCut, Maximum
//! Independent Set (MIS) and MaxClique.
//!
//! These follow the penalized binary formulations of the DISCS benchmark
//! the paper evaluates (§VI-A): binary RVs, energy = -objective +
//! λ·constraint-violations, sampled with PAS / MH / Block Gibbs.

use super::{BatchScratch, EnergyModel, OpCost};
use crate::graph::Graph;

/// MaxCut: partition nodes into two sets maximizing the weight of cut
/// edges. `E(x) = -Σ_{(i,j)∈E} w_ij · [x_i ≠ x_j]`.
#[derive(Clone, Debug)]
pub struct MaxCutModel {
    graph: Graph,
    best_known: Option<f64>,
}

impl MaxCutModel {
    /// Wrap a (possibly weighted) graph as a MaxCut instance.
    pub fn new(graph: Graph, best_known: Option<f64>) -> MaxCutModel {
        MaxCutModel { graph, best_known }
    }

    /// Total cut weight of assignment `x`.
    pub fn cut_weight(&self, x: &[u32]) -> f64 {
        let mut cut = 0.0f64;
        for i in 0..self.graph.num_nodes() {
            let nbrs = self.graph.neighbors(i);
            let ws = self.graph.neighbor_weights(i);
            for (k, &j) in nbrs.iter().enumerate() {
                if (j as usize) > i && x[i] != x[j as usize] {
                    cut += ws.map_or(1.0, |w| w[k]) as f64;
                }
            }
        }
        cut
    }
}

impl EnergyModel for MaxCutModel {
    fn num_vars(&self) -> usize {
        self.graph.num_nodes()
    }

    fn num_states(&self, _i: usize) -> usize {
        2
    }

    fn interaction(&self) -> &Graph {
        &self.graph
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(2, 0.0);
        let nbrs = self.graph.neighbors(i);
        let ws = self.graph.neighbor_weights(i);
        // Energy contribution of node i on side b: -Σ_j w_ij [b ≠ x_j]
        let mut e0 = 0.0f32;
        let mut e1 = 0.0f32;
        for (k, &j) in nbrs.iter().enumerate() {
            let w = ws.map_or(1.0, |w| w[k]);
            if x[j as usize] == 0 {
                e1 -= w;
            } else {
                e0 -= w;
            }
        }
        out[0] = e0;
        out[1] = e1;
    }

    fn local_energies_batch(
        &self,
        xs: &[u32],
        k: usize,
        i: usize,
        out: &mut Vec<f32>,
        _scratch: &mut BatchScratch,
    ) {
        out.clear();
        out.resize(k * 2, 0.0);
        let nbrs = self.graph.neighbors(i);
        let ws = self.graph.neighbor_weights(i);
        // Each (neighbor, weight) pair is fetched once and applied to
        // all K chains. State-major rows keep both candidate sides as
        // contiguous K-wide slices; the select form lowers to a vector
        // compare + masked subtract over each row.
        let (row0, row1) = out.split_at_mut(k);
        for (e, &j) in nbrs.iter().enumerate() {
            let w = ws.map_or(1.0, |w| w[e]);
            let col = &xs[j as usize * k..j as usize * k + k];
            // Neighbor on side 0 rewards side 1 (edge cut) and vice
            // versa, as in the scalar kernel.
            for c in 0..k {
                if col[c] == 0 {
                    row1[c] -= w;
                } else {
                    row0[c] -= w;
                }
            }
        }
    }

    fn energy(&self, x: &[u32]) -> f64 {
        -self.cut_weight(x)
    }

    fn objective(&self, x: &[u32]) -> f64 {
        self.cut_weight(x)
    }

    fn best_known(&self) -> Option<f64> {
        self.best_known
    }

    fn delta_energy(&self, x: &[u32], i: usize, s: u32, _scratch: &mut Vec<f32>) -> f32 {
        if s == x[i] {
            return 0.0;
        }
        // Flipping i toggles every incident edge's cut membership.
        let nbrs = self.graph.neighbors(i);
        let ws = self.graph.neighbor_weights(i);
        let mut delta = 0.0f32;
        for (k, &j) in nbrs.iter().enumerate() {
            let w = ws.map_or(1.0, |w| w[k]);
            if x[j as usize] == x[i] {
                delta -= w; // becomes cut: energy down
            } else {
                delta += w; // leaves cut: energy up
            }
        }
        delta
    }

    fn update_cost(&self, i: usize) -> OpCost {
        let d = self.graph.degree(i) as u64;
        OpCost {
            ops: 2 * d + 2,
            bytes: 4 * (2 * d + 1), // neighbor states + weights + write-back
            samples: 1,
        }
    }

    fn neighbor_words(&self, i: usize) -> usize {
        // Neighbor side bits + edge weights.
        2 * self.graph.degree(i)
    }

    fn param_words_per_state(&self, _i: usize) -> usize {
        0
    }
}

/// Maximum Independent Set with quadratic penalty:
/// `E(x) = -Σ_i x_i + λ Σ_{(i,j)∈E} x_i x_j`, `x_i ∈ {0,1}`.
#[derive(Clone, Debug)]
pub struct MisModel {
    graph: Graph,
    penalty: f32,
    best_known: Option<f64>,
}

impl MisModel {
    /// `penalty` (λ) must exceed 1 for the optimum to be a valid
    /// independent set; DISCS uses λ ≈ 1.0–2.0.
    pub fn new(graph: Graph, penalty: f32, best_known: Option<f64>) -> MisModel {
        assert!(penalty > 1.0, "penalty must exceed 1");
        MisModel {
            graph,
            penalty,
            best_known,
        }
    }

    /// Number of selected vertices.
    pub fn set_size(&self, x: &[u32]) -> usize {
        x.iter().filter(|&&v| v == 1).count()
    }

    /// Number of violated edges (both endpoints selected).
    pub fn violations(&self, x: &[u32]) -> usize {
        let mut v = 0;
        for i in 0..self.graph.num_nodes() {
            if x[i] == 1 {
                for &j in self.graph.neighbors(i) {
                    if (j as usize) > i && x[j as usize] == 1 {
                        v += 1;
                    }
                }
            }
        }
        v
    }
}

impl EnergyModel for MisModel {
    fn num_vars(&self) -> usize {
        self.graph.num_nodes()
    }

    fn num_states(&self, _i: usize) -> usize {
        2
    }

    fn interaction(&self) -> &Graph {
        &self.graph
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(2, 0.0);
        let selected_nbrs = self
            .graph
            .neighbors(i)
            .iter()
            .filter(|&&j| x[j as usize] == 1)
            .count() as f32;
        out[0] = 0.0;
        out[1] = -1.0 + self.penalty * selected_nbrs;
    }

    fn param_words_per_state(&self, _i: usize) -> usize {
        0
    }

    fn local_energies_batch(
        &self,
        xs: &[u32],
        k: usize,
        i: usize,
        out: &mut Vec<f32>,
        _scratch: &mut BatchScratch,
    ) {
        out.clear();
        out.resize(k * 2, 0.0);
        // Accumulate the selected-neighbor count in the state-1 row
        // (`out[k..2k]`, contiguous in the state-major layout), then
        // fold in the reward/penalty. Counts are small integers, so the
        // f32 accumulation matches the scalar `count() as f32` exactly.
        let row1 = &mut out[k..];
        for &j in self.graph.neighbors(i) {
            let col = &xs[j as usize * k..j as usize * k + k];
            for (o, &b) in row1.iter_mut().zip(col) {
                if b == 1 {
                    *o += 1.0;
                }
            }
        }
        for o in row1.iter_mut() {
            *o = -1.0 + self.penalty * *o;
        }
    }

    fn energy(&self, x: &[u32]) -> f64 {
        -(self.set_size(x) as f64) + self.penalty as f64 * self.violations(x) as f64
    }

    /// Objective: penalized set size (matches DISCS's reported metric).
    fn objective(&self, x: &[u32]) -> f64 {
        self.set_size(x) as f64 - self.penalty as f64 * self.violations(x) as f64
    }

    fn best_known(&self) -> Option<f64> {
        self.best_known
    }

    fn delta_energy(&self, x: &[u32], i: usize, s: u32, scratch: &mut Vec<f32>) -> f32 {
        if s == x[i] {
            return 0.0;
        }
        self.local_energies(x, i, scratch);
        scratch[s as usize] - scratch[x[i] as usize]
    }
}

/// MaxClique reduced to MIS on the complement graph: a clique in `G` is
/// an independent set in `Ḡ`.
#[derive(Clone, Debug)]
pub struct MaxCliqueModel {
    /// MIS model over the complement graph.
    inner: MisModel,
    /// The original graph (for clique validation / reporting).
    original: Graph,
}

impl MaxCliqueModel {
    /// Build from the original graph.
    pub fn new(graph: Graph, penalty: f32, best_known: Option<f64>) -> MaxCliqueModel {
        let complement = graph.complement();
        MaxCliqueModel {
            inner: MisModel::new(complement, penalty, best_known),
            original: graph,
        }
    }

    /// Size of the selected set.
    pub fn clique_size(&self, x: &[u32]) -> usize {
        self.inner.set_size(x)
    }

    /// True if the selected vertices form a clique in the original graph.
    pub fn is_clique(&self, x: &[u32]) -> bool {
        let sel: Vec<usize> = (0..x.len()).filter(|&i| x[i] == 1).collect();
        sel.iter().enumerate().all(|(a, &i)| {
            sel[a + 1..].iter().all(|&j| self.original.has_edge(i, j))
        })
    }

    /// The original (un-complemented) graph.
    pub fn original_graph(&self) -> &Graph {
        &self.original
    }
}

impl EnergyModel for MaxCliqueModel {
    fn num_vars(&self) -> usize {
        self.inner.num_vars()
    }

    fn num_states(&self, i: usize) -> usize {
        self.inner.num_states(i)
    }

    fn interaction(&self) -> &Graph {
        self.inner.interaction()
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        self.inner.local_energies(x, i, out)
    }

    fn local_energies_batch(
        &self,
        xs: &[u32],
        k: usize,
        i: usize,
        out: &mut Vec<f32>,
        scratch: &mut BatchScratch,
    ) {
        self.inner.local_energies_batch(xs, k, i, out, scratch)
    }

    fn energy(&self, x: &[u32]) -> f64 {
        self.inner.energy(x)
    }

    fn objective(&self, x: &[u32]) -> f64 {
        self.inner.objective(x)
    }

    fn best_known(&self) -> Option<f64> {
        self.inner.best_known()
    }

    fn update_cost(&self, i: usize) -> OpCost {
        self.inner.update_cost(i)
    }

    fn delta_energy(&self, x: &[u32], i: usize, s: u32, scratch: &mut Vec<f32>) -> f32 {
        self.inner.delta_energy(x, i, s, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::testutil::check_local_consistency;
    use crate::energy::random_state;
    use crate::graph::{erdos_renyi_with_edges, Graph};
    use crate::rng::Rng;

    fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)], None)
    }

    #[test]
    fn maxcut_path_optimum() {
        let m = MaxCutModel::new(path4(), Some(3.0));
        assert_eq!(m.cut_weight(&[0, 1, 0, 1]), 3.0);
        assert_eq!(m.energy(&[0, 1, 0, 1]), -3.0);
        assert_eq!(m.cut_weight(&[0, 0, 0, 0]), 0.0);
    }

    #[test]
    fn maxcut_weighted() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)], Some(&[2.0, 5.0]));
        let m = MaxCutModel::new(g, None);
        assert_eq!(m.cut_weight(&[0, 1, 0]), 7.0);
        assert_eq!(m.cut_weight(&[0, 0, 1]), 5.0);
    }

    #[test]
    fn maxcut_local_and_delta_consistent() {
        let g = erdos_renyi_with_edges(30, 90, 17);
        let m = MaxCutModel::new(g, None);
        let mut rng = Rng::new(4);
        let x = random_state(&m, &mut rng);
        check_local_consistency(&m, &x, 1e-4);
        let mut scratch = Vec::new();
        for i in 0..m.num_vars() {
            let d = m.delta_energy(&x, i, 1 - x[i], &mut scratch);
            let mut y = x.clone();
            y[i] = 1 - x[i];
            let want = (m.energy(&y) - m.energy(&x)) as f32;
            assert!((d - want).abs() < 1e-4, "i={i} {d} vs {want}");
        }
    }

    #[test]
    fn batched_energies_match_scalar_bitwise() {
        use crate::energy::testutil::check_batch_consistency;
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)], Some(&[2.0, 5.0]));
        check_batch_consistency(&MaxCutModel::new(g, None), 4, 21);
        check_batch_consistency(
            &MaxCutModel::new(erdos_renyi_with_edges(30, 90, 17), None),
            7,
            22,
        );
        check_batch_consistency(
            &MisModel::new(erdos_renyi_with_edges(25, 60, 23), 1.5, None),
            5,
            23,
        );
        check_batch_consistency(
            &MaxCliqueModel::new(erdos_renyi_with_edges(20, 80, 31), 1.5, None),
            5,
            24,
        );
    }

    #[test]
    fn mis_penalty_beats_violation() {
        let m = MisModel::new(path4(), 1.5, None);
        // Selecting adjacent 1,2 is penalized below selecting {0,2}.
        assert!(m.energy(&[1, 0, 1, 0]) < m.energy(&[0, 1, 1, 0]));
        // Optimal independent set {0,2} (or {1,3} or {0,3}): size 2.
        assert_eq!(m.energy(&[1, 0, 1, 0]), -2.0);
        assert_eq!(m.violations(&[0, 1, 1, 0]), 1);
    }

    #[test]
    fn mis_local_consistent() {
        let g = erdos_renyi_with_edges(25, 60, 23);
        let m = MisModel::new(g, 1.5, None);
        let mut rng = Rng::new(6);
        let x = random_state(&m, &mut rng);
        check_local_consistency(&m, &x, 1e-4);
    }

    #[test]
    fn clique_is_complement_mis() {
        // Triangle + pendant: max clique {0,1,2}.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)], None);
        let m = MaxCliqueModel::new(g, 1.5, Some(3.0));
        let x = [1, 1, 1, 0];
        assert!(m.is_clique(&x));
        assert_eq!(m.clique_size(&x), 3);
        assert_eq!(m.energy(&x), -3.0);
        // {1,2,3} is not a clique (1-3 missing) and is penalized.
        let bad = [0, 1, 1, 1];
        assert!(!m.is_clique(&bad));
        assert!(m.energy(&bad) > m.energy(&x));
    }

    #[test]
    fn clique_local_consistent() {
        let g = erdos_renyi_with_edges(20, 80, 31);
        let m = MaxCliqueModel::new(g, 1.5, None);
        let mut rng = Rng::new(8);
        let x = random_state(&m, &mut rng);
        check_local_consistency(&m, &x, 1e-4);
    }
}
