//! Restricted Boltzmann Machine (binary units).
//!
//! Table I's EBM workload: a binary RBM with 784 visible and 25 hidden
//! units (809 RVs, ~19.6 k edges — the bipartite connection graph).
//! `E(v, h) = -a·v - b·h - vᵀ W h`. The bipartite structure 2-colors,
//! so Block Gibbs alternates full visible / hidden sweeps; PAS treats
//! all 809 units uniformly.

use super::{EnergyModel, OpCost};
use crate::graph::Graph;
use crate::rng::Rng;

/// Binary RBM over `nv` visible + `nh` hidden units. RV ids `0..nv` are
/// visible, `nv..nv+nh` hidden.
#[derive(Clone, Debug)]
pub struct Rbm {
    nv: usize,
    nh: usize,
    /// Weights, row-major `w[i * nh + j]` connecting visible i, hidden j.
    w: Vec<f32>,
    /// Visible biases.
    a: Vec<f32>,
    /// Hidden biases.
    b: Vec<f32>,
    graph: Graph,
}

impl Rbm {
    /// Build from explicit parameters.
    pub fn new(nv: usize, nh: usize, w: Vec<f32>, a: Vec<f32>, b: Vec<f32>) -> Rbm {
        assert_eq!(w.len(), nv * nh);
        assert_eq!(a.len(), nv);
        assert_eq!(b.len(), nh);
        let mut edges = Vec::with_capacity(nv * nh);
        for i in 0..nv as u32 {
            for j in 0..nh as u32 {
                edges.push((i, nv as u32 + j));
            }
        }
        let graph = Graph::from_edges(nv + nh, &edges, None);
        Rbm {
            nv,
            nh,
            w,
            a,
            b,
            graph,
        }
    }

    /// A deterministic "trained-like" RBM: weights are a low-rank
    /// stripe structure plus noise, giving a multi-modal energy
    /// landscape comparable to an MNIST-trained model (DESIGN.md §4).
    pub fn synthetic(nv: usize, nh: usize, seed: u64) -> Rbm {
        let mut rng = Rng::new(seed);
        let mut w = vec![0.0f32; nv * nh];
        for i in 0..nv {
            for j in 0..nh {
                // Stripe: each hidden unit prefers a contiguous band of
                // visibles (like stroke detectors), scaled ~N(0, 0.3).
                let band = (i * nh) / nv;
                let structure = if band == j { 1.2 } else { -0.1 };
                let noise = (rng.uniform_f32() - 0.5) * 0.6;
                w[i * nh + j] = structure + noise;
            }
        }
        let a: Vec<f32> = (0..nv).map(|_| (rng.uniform_f32() - 0.7) * 0.5).collect();
        let b: Vec<f32> = (0..nh).map(|_| (rng.uniform_f32() - 0.5) * 0.2).collect();
        Rbm::new(nv, nh, w, a, b)
    }

    /// Number of visible units.
    pub fn num_visible(&self) -> usize {
        self.nv
    }

    /// Number of hidden units.
    pub fn num_hidden(&self) -> usize {
        self.nh
    }

    /// Pre-activation of hidden j given visible assignment.
    fn hidden_field(&self, x: &[u32], j: usize) -> f32 {
        let mut f = self.b[j];
        for i in 0..self.nv {
            if x[i] == 1 {
                f += self.w[i * self.nh + j];
            }
        }
        f
    }

    /// Pre-activation of visible i given hidden assignment.
    fn visible_field(&self, x: &[u32], i: usize) -> f32 {
        let mut f = self.a[i];
        let h = &x[self.nv..];
        for (j, &hj) in h.iter().enumerate() {
            if hj == 1 {
                f += self.w[i * self.nh + j];
            }
        }
        f
    }
}

impl EnergyModel for Rbm {
    fn num_vars(&self) -> usize {
        self.nv + self.nh
    }

    fn num_states(&self, _i: usize) -> usize {
        2
    }

    fn interaction(&self) -> &Graph {
        &self.graph
    }

    fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(2, 0.0);
        let field = if i < self.nv {
            self.visible_field(x, i)
        } else {
            self.hidden_field(x, i - self.nv)
        };
        // E contribution of unit=1 is -field; unit=0 contributes 0.
        out[0] = 0.0;
        out[1] = -field;
    }

    fn energy(&self, x: &[u32]) -> f64 {
        let (v, h) = x.split_at(self.nv);
        let mut e = 0.0f64;
        for (i, &vi) in v.iter().enumerate() {
            if vi == 1 {
                e -= self.a[i] as f64;
                for (j, &hj) in h.iter().enumerate() {
                    if hj == 1 {
                        e -= self.w[i * self.nh + j] as f64;
                    }
                }
            }
        }
        for (j, &hj) in h.iter().enumerate() {
            if hj == 1 {
                e -= self.b[j] as f64;
            }
        }
        e
    }

    fn update_cost(&self, i: usize) -> OpCost {
        let d = if i < self.nv { self.nh } else { self.nv } as u64;
        OpCost {
            ops: 2 * d + 2,
            bytes: 4 * (2 * d + 1),
            samples: 1,
        }
    }

    fn neighbor_words(&self, i: usize) -> usize {
        // Opposite-layer unit values + the connecting weight row.
        2 * self.interaction().degree(i)
    }

    fn param_words_per_state(&self, _i: usize) -> usize {
        0
    }

    fn delta_energy(&self, x: &[u32], i: usize, s: u32, _scratch: &mut Vec<f32>) -> f32 {
        if s == x[i] {
            return 0.0;
        }
        let field = if i < self.nv {
            self.visible_field(x, i)
        } else {
            self.hidden_field(x, i - self.nv)
        };
        if s == 1 {
            -field
        } else {
            field
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::testutil::check_local_consistency;
    use crate::energy::random_state;

    #[test]
    fn default_batched_energies_match_scalar_bitwise() {
        // RBM has no override: exercises the default blanket gather on
        // a bipartite (dense-blanket) interaction graph.
        use crate::energy::testutil::check_batch_consistency;
        let mut rng = crate::rng::Rng::new(41);
        let (nv, nh) = (6, 4);
        let w: Vec<f32> = (0..nv * nh).map(|_| rng.uniform_f32() - 0.5).collect();
        let a: Vec<f32> = (0..nv).map(|_| rng.uniform_f32() - 0.5).collect();
        let b: Vec<f32> = (0..nh).map(|_| rng.uniform_f32() - 0.5).collect();
        check_batch_consistency(&Rbm::new(nv, nh, w, a, b), 5, 42);
    }

    #[test]
    fn energy_by_hand() {
        // 2 visible, 1 hidden; only v0 & h on.
        let rbm = Rbm::new(2, 1, vec![0.5, -0.3], vec![0.1, 0.2], vec![0.4]);
        let x = [1, 0, 1];
        // E = -a0 - b0 - w00 = -0.1 - 0.4 - 0.5
        assert!((rbm.energy(&x) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn bipartite_interaction() {
        let rbm = Rbm::synthetic(6, 3, 1);
        let g = rbm.interaction();
        assert_eq!(g.num_edges(), 18);
        // no visible-visible edges
        assert!(!g.has_edge(0, 1));
        assert!(g.has_edge(0, 6));
    }

    #[test]
    fn local_consistent() {
        let rbm = Rbm::synthetic(8, 4, 3);
        let mut rng = Rng::new(5);
        let x = random_state(&rbm, &mut rng);
        check_local_consistency(&rbm, &x, 1e-4);
    }

    #[test]
    fn delta_matches_full_energy() {
        let rbm = Rbm::synthetic(10, 5, 7);
        let mut rng = Rng::new(9);
        let x = random_state(&rbm, &mut rng);
        let mut scratch = Vec::new();
        for i in 0..rbm.num_vars() {
            let s = 1 - x[i];
            let d = rbm.delta_energy(&x, i, s, &mut scratch);
            let mut y = x.clone();
            y[i] = s;
            let want = (rbm.energy(&y) - rbm.energy(&x)) as f32;
            assert!((d - want).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn table1_scale() {
        // Table I: 809 nodes, ~19k edges for RBM-784x25.
        let rbm = Rbm::synthetic(784, 25, 42);
        assert_eq!(rbm.num_vars(), 809);
        assert_eq!(rbm.interaction().num_edges(), 784 * 25);
    }
}
