//! The MC²A accelerator architecture definition: hardware parameters
//! (Fig. 7a) and the VLIW instruction set (Fig. 7c).
//!
//! The ISA has six pipeline-control types (§V-B): **Load**, **Compute**,
//! **Sample**, **Compute-Sample**, **Compute-Sample-Store** and **NOP**.
//! Instructions are VLIW bundles naming, in one word: the load slots
//! (memory → RF), the crossbar routing (RF → CU input ports), the CU
//! configuration (mode/β/accumulate), the SU configuration
//! (temporal/spatial, distribution size) and the store slots. Field
//! widths are derived from the chosen [`HwConfig`] at design time and
//! densely packed ([`InstrLayout`]), matching the paper's
//! "dense packing approach … to minimize the instruction memory
//! overhead".

mod encode;

pub use encode::InstrLayout;

/// Design-time hardware parameters (the knobs of Fig. 7a, chosen via
/// the 3D roofline DSE in §VI-B).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwConfig {
    /// CU: number of parallel processing elements `T`.
    pub t: usize,
    /// CU: PE tree depth `K` (each PE reduces `2^K` inputs + 1 reuse).
    pub k: usize,
    /// SU: number of sample elements `S` (= `2^M`).
    pub s: usize,
    /// SU: depth `M`.
    pub m: usize,
    /// Memory bandwidth `B` in 32-bit words per cycle.
    pub bw_words: usize,
    /// Clock frequency in GHz (paper: 0.5 GHz @ Intel 16 nm).
    pub clock_ghz: f64,
    /// Register file banks (multi-bank for conflict-free CU feeding).
    pub rf_banks: usize,
    /// 32-bit registers per RF bank.
    pub rf_regs_per_bank: usize,
    /// Gumbel LUT entries (Fig. 12 ablation; paper picks 16).
    pub lut_size: usize,
    /// Gumbel LUT fixed-point precision in bits (paper picks 8).
    pub lut_bits: u32,
    /// Maximum categorical distribution size supported (paper: 256).
    pub max_dist_size: usize,
}

impl HwConfig {
    /// The paper's chosen configuration (§VI-B): T=64, K=3, S=64, M=6,
    /// B=320 words/cycle, 500 MHz, LUT 16×8-bit, max distribution 256.
    pub fn paper_default() -> HwConfig {
        HwConfig {
            t: 64,
            k: 3,
            s: 64,
            m: 6,
            bw_words: 320,
            clock_ghz: 0.5,
            rf_banks: 64,
            rf_regs_per_bank: 16,
            lut_size: 16,
            lut_bits: 8,
            max_dist_size: 256,
        }
    }

    /// The small S=T=4, K=1, B=12 configuration used by the Fig. 10
    /// walk-through schedules.
    pub fn fig10_toy() -> HwConfig {
        HwConfig {
            t: 4,
            k: 1,
            s: 4,
            m: 2,
            bw_words: 12,
            clock_ghz: 0.5,
            rf_banks: 8,
            rf_regs_per_bank: 8,
            lut_size: 16,
            lut_bits: 8,
            max_dist_size: 256,
        }
    }

    /// Peak CU throughput in ops/cycle: each PE reduces `2^K` inputs
    /// through its adder tree plus a multiply (β) and an accumulate.
    pub fn cu_peak_ops_per_cycle(&self) -> u64 {
        (self.t * ((1 << self.k) + 2)) as u64
    }

    /// Peak SU throughput in samples/cycle. In *temporal* mode each SE
    /// retires one distribution **bin** per cycle, so a size-N
    /// categorical costs N cycles; the peak (bin-level) rate is S/cycle.
    pub fn su_peak_bins_per_cycle(&self) -> u64 {
        self.s as u64
    }

    /// Peak memory bandwidth in bytes/cycle.
    pub fn mem_peak_bytes_per_cycle(&self) -> u64 {
        (self.bw_words * 4) as u64
    }

    /// CU pipeline latency in cycles (K+1 stages, §V-C).
    pub fn cu_latency(&self) -> usize {
        self.k + 1
    }

    /// Sanity-check internal consistency (S = 2^M, sizes nonzero).
    pub fn validate(&self) -> Result<(), String> {
        if self.s != (1 << self.m) {
            return Err(format!("S={} must equal 2^M (M={})", self.s, self.m));
        }
        if self.t == 0 || self.bw_words == 0 || self.rf_banks == 0 {
            return Err("zero-sized hardware unit".into());
        }
        if self.lut_size < 2 {
            return Err("LUT must have ≥ 2 entries".into());
        }
        Ok(())
    }
}

/// Design-time parameters of a C-core MC²A system (§II-D): C identical
/// single-core pipelines sharing a crossbar and the histogram memory.
///
/// The shared interconnect is characterized by its word bandwidth and a
/// fixed per-barrier latency; both are charged by the multi-core
/// simulator only when `cores > 1` (a single core owns its ports, which
/// keeps the 1-core system cycle-identical to [`HwConfig`] alone).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiHwConfig {
    /// Per-core configuration (all cores are identical).
    pub core: HwConfig,
    /// Number of parallel MC²A cores `C`.
    pub cores: usize,
    /// Shared crossbar / histogram-port bandwidth in 32-bit words per
    /// cycle (boundary-state broadcast + histogram commits contend on
    /// this).
    pub xbar_words_per_cycle: usize,
    /// Fixed barrier cost per synchronization round in cycles
    /// (crossbar arbitration + barrier release).
    pub sync_latency: usize,
}

/// Default per-barrier latency: one crossbar-arbitration cycle plus
/// one barrier-release cycle.
pub const DEFAULT_SYNC_LATENCY: usize = 2;

impl MultiHwConfig {
    /// A `cores`-core system of identical `core` pipelines with the
    /// default interconnect: the crossbar matches one core's memory
    /// bandwidth and a barrier costs [`DEFAULT_SYNC_LATENCY`] cycles.
    pub fn new(core: HwConfig, cores: usize) -> MultiHwConfig {
        MultiHwConfig {
            cores,
            xbar_words_per_cycle: core.bw_words,
            sync_latency: DEFAULT_SYNC_LATENCY,
            core,
        }
    }

    /// Sanity-check internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        self.core.validate()?;
        if self.cores == 0 {
            return Err("core count must be ≥ 1".into());
        }
        if self.xbar_words_per_cycle == 0 {
            return Err("shared crossbar bandwidth must be ≥ 1 word/cycle".into());
        }
        Ok(())
    }
}

/// The six pipeline-control types of the VLIW ISA (§V-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CtrlType {
    /// Memory → RF data movement only.
    Load,
    /// CU-only mode (multi-cycle energy computation, SU bypassed).
    Compute,
    /// SU-only mode (re-sampling a resident distribution, CU bypassed).
    Sample,
    /// Pipelined energy computation + sampling.
    ComputeSample,
    /// Compute-Sample plus result store to sample/histogram memory.
    ComputeSampleStore,
    /// Pipeline-hazard filler.
    Nop,
}

impl CtrlType {
    /// Encoding value (3 bits).
    pub fn code(&self) -> u8 {
        match self {
            CtrlType::Load => 0,
            CtrlType::Compute => 1,
            CtrlType::Sample => 2,
            CtrlType::ComputeSample => 3,
            CtrlType::ComputeSampleStore => 4,
            CtrlType::Nop => 5,
        }
    }

    /// Decode from a 3-bit code.
    pub fn from_code(c: u8) -> Option<CtrlType> {
        Some(match c {
            0 => CtrlType::Load,
            1 => CtrlType::Compute,
            2 => CtrlType::Sample,
            3 => CtrlType::ComputeSample,
            4 => CtrlType::ComputeSampleStore,
            5 => CtrlType::Nop,
            _ => return None,
        })
    }

    /// Does this type activate the CU?
    pub fn uses_cu(&self) -> bool {
        matches!(
            self,
            CtrlType::Compute | CtrlType::ComputeSample | CtrlType::ComputeSampleStore
        )
    }

    /// Does this type activate the SU?
    pub fn uses_su(&self) -> bool {
        matches!(
            self,
            CtrlType::Sample | CtrlType::ComputeSample | CtrlType::ComputeSampleStore
        )
    }
}

/// On-chip memory spaces (Fig. 7a).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemSpace {
    /// Input data / weights / CPT ("CDT") memory.
    Input,
    /// Current sample (state) memory.
    Sample,
    /// Histogram (posterior accumulation) memory.
    Histogram,
}

impl MemSpace {
    /// 2-bit encoding.
    pub fn code(&self) -> u8 {
        match self {
            MemSpace::Input => 0,
            MemSpace::Sample => 1,
            MemSpace::Histogram => 2,
        }
    }

    /// Decode from a 2-bit code.
    pub fn from_code(c: u8) -> Option<MemSpace> {
        Some(match c {
            0 => MemSpace::Input,
            1 => MemSpace::Sample,
            2 => MemSpace::Histogram,
            _ => return None,
        })
    }
}

/// One load slot: `mem[addr] → rf[bank][reg]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadSlot {
    /// Source memory space.
    pub mem: MemSpace,
    /// Word address within the space.
    pub addr: u32,
    /// Destination RF bank.
    pub rf_bank: u16,
    /// Destination register within the bank.
    pub rf_reg: u16,
}

/// One crossbar route: `rf[bank][reg] → CU lane `cu`, input port `port``.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct XbarRoute {
    /// Source RF bank.
    pub rf_bank: u16,
    /// Source register.
    pub rf_reg: u16,
    /// Destination CU lane (PE index).
    pub cu: u16,
    /// Destination input port within the PE (`0..2^K`).
    pub port: u16,
}

/// CU (PE array) operating mode (§V-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CuMode {
    /// Route inputs straight to the SU.
    Bypass,
    /// Dot-product of the routed inputs against weights.
    DotProduct,
    /// Reduced sum of the routed inputs.
    ReducedSum,
    /// Partial reduction accumulated over multiple cycles.
    Partial,
}

impl CuMode {
    /// 2-bit encoding.
    pub fn code(&self) -> u8 {
        match self {
            CuMode::Bypass => 0,
            CuMode::DotProduct => 1,
            CuMode::ReducedSum => 2,
            CuMode::Partial => 3,
        }
    }

    /// Decode from a 2-bit code.
    pub fn from_code(c: u8) -> Option<CuMode> {
        Some(match c {
            0 => CuMode::Bypass,
            1 => CuMode::DotProduct,
            2 => CuMode::ReducedSum,
            3 => CuMode::Partial,
            _ => return None,
        })
    }
}

/// CU control word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CuCtrl {
    /// Operating mode.
    pub mode: CuMode,
    /// Active PE lanes (`1..=T`).
    pub lanes: u16,
    /// Apply the β (inverse-temperature) multiplier.
    pub scale_beta: bool,
    /// Accumulate onto the in-place partial sum.
    pub accumulate: bool,
}

/// SU operating mode (§V-D Reconfigurability).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuMode {
    /// One comparator per SE, iterating over bins (1 bin/cycle/SE).
    Temporal,
    /// SEs fused into a comparator tree: S bins of one distribution per cycle.
    Spatial,
}

/// SU control word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SuCtrl {
    /// Operating mode.
    pub mode: SuMode,
    /// Active SE lanes (`1..=S`).
    pub lanes: u16,
    /// Total distribution size being sampled.
    pub dist_size: u16,
    /// First bin group of a distribution (resets the running max).
    pub first: bool,
    /// Last bin group (commits the argmax as the sample).
    pub last: bool,
}

/// One store slot: SU lane result → `mem[addr]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StoreSlot {
    /// Destination memory space.
    pub mem: MemSpace,
    /// Word address.
    pub addr: u32,
    /// Source SU lane.
    pub su_lane: u16,
}

/// Functional semantics attached by the compiler (metadata — not
/// encoded in the instruction word; the timing model uses only the
/// architectural fields, the functional model uses these).
#[derive(Clone, Debug, PartialEq)]
pub enum Semantics {
    /// Pure timing (loads, partial computes, NOPs).
    None,
    /// Commit Gibbs-style resampling of `rvs` (conditionally
    /// independent within one commit — guaranteed by the compiler).
    UpdateRvs(Vec<u32>),
    /// Commit one full PAS iteration (ΔE build + L path flips + MH).
    PasIterate,
    /// Snapshot the state (Async Gibbs reads stale values).
    Snapshot,
}

/// One VLIW instruction bundle.
#[derive(Clone, Debug, PartialEq)]
pub struct Instr {
    /// Pipeline-control type.
    pub ctrl: CtrlType,
    /// Load slots (≤ bandwidth/cycle; larger loads are split by the
    /// compiler into multiple Load instructions).
    pub loads: Vec<LoadSlot>,
    /// Crossbar routes for this cycle's CU operands.
    pub routes: Vec<XbarRoute>,
    /// CU control (None = bypass/idle).
    pub cu: Option<CuCtrl>,
    /// SU control (None = idle).
    pub su: Option<SuCtrl>,
    /// Store slots.
    pub stores: Vec<StoreSlot>,
    /// Compiler-attached functional semantics.
    pub sem: Semantics,
}

impl Instr {
    /// A NOP (hazard filler).
    pub fn nop() -> Instr {
        Instr {
            ctrl: CtrlType::Nop,
            loads: Vec::new(),
            routes: Vec::new(),
            cu: None,
            su: None,
            stores: Vec::new(),
            sem: Semantics::None,
        }
    }

    /// Words moved from memory by this instruction.
    pub fn load_words(&self) -> usize {
        self.loads.len()
    }

    /// Words written back to memory.
    pub fn store_words(&self) -> usize {
        self.stores.len()
    }
}

/// A compiled program: a prologue (one-time setup), a steady-state loop
/// body executed once per MCMC iteration under HWLOOP control, and
/// compile-time statistics.
#[derive(Clone, Debug, Default)]
pub struct Program {
    /// One-time setup instructions.
    pub prologue: Vec<Instr>,
    /// Loop body (one MCMC step of Alg. 1).
    pub body: Vec<Instr>,
    /// RV updates per loop iteration (for GS/s accounting).
    pub updates_per_iter: u64,
    /// Categorical samples drawn per loop iteration.
    pub samples_per_iter: u64,
    /// Human-readable name.
    pub name: String,
}

impl Program {
    /// Total instruction count (prologue + body).
    pub fn len(&self) -> usize {
        self.prologue.len() + self.body.len()
    }

    /// True if the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of instructions by control type in the body.
    pub fn body_histogram(&self) -> std::collections::HashMap<CtrlType, usize> {
        let mut h = std::collections::HashMap::new();
        for i in &self.body {
            *h.entry(i.ctrl).or_insert(0) += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_valid() {
        let c = HwConfig::paper_default();
        assert!(c.validate().is_ok());
        assert_eq!(c.cu_latency(), 4);
        assert_eq!(c.su_peak_bins_per_cycle(), 64);
        assert_eq!(c.mem_peak_bytes_per_cycle(), 1280);
        // T=64 PEs × (8 adds + mult + acc) = 640 ops/cycle
        assert_eq!(c.cu_peak_ops_per_cycle(), 640);
    }

    #[test]
    fn toy_config_valid() {
        assert!(HwConfig::fig10_toy().validate().is_ok());
    }

    #[test]
    fn multi_core_config_validates() {
        let m = MultiHwConfig::new(HwConfig::paper_default(), 8);
        assert!(m.validate().is_ok());
        assert_eq!(m.xbar_words_per_cycle, 320);
        assert_eq!(m.sync_latency, DEFAULT_SYNC_LATENCY);
        let mut bad = m;
        bad.cores = 0;
        assert!(bad.validate().is_err());
        let mut bad = m;
        bad.xbar_words_per_cycle = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = HwConfig::paper_default();
        c.s = 48; // not 2^M
        assert!(c.validate().is_err());
        let mut c2 = HwConfig::paper_default();
        c2.t = 0;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn ctrl_type_codes_roundtrip() {
        for t in [
            CtrlType::Load,
            CtrlType::Compute,
            CtrlType::Sample,
            CtrlType::ComputeSample,
            CtrlType::ComputeSampleStore,
            CtrlType::Nop,
        ] {
            assert_eq!(CtrlType::from_code(t.code()), Some(t));
        }
        assert_eq!(CtrlType::from_code(7), None);
    }

    #[test]
    fn ctrl_unit_usage() {
        assert!(CtrlType::Compute.uses_cu() && !CtrlType::Compute.uses_su());
        assert!(!CtrlType::Sample.uses_cu() && CtrlType::Sample.uses_su());
        assert!(CtrlType::ComputeSample.uses_cu() && CtrlType::ComputeSample.uses_su());
        assert!(!CtrlType::Nop.uses_cu() && !CtrlType::Nop.uses_su());
    }

    #[test]
    fn program_histogram() {
        let mut p = Program::default();
        p.body.push(Instr::nop());
        p.body.push(Instr::nop());
        let h = p.body_histogram();
        assert_eq!(h[&CtrlType::Nop], 2);
        assert_eq!(p.len(), 2);
    }
}
