//! Dense VLIW instruction encoding (Fig. 7c).
//!
//! "The bitwidth of each instruction field varies on hardware
//! parameters chosen at the design time … We define a dense packing
//! approach for this VLIW ISA to minimize the instruction memory
//! overhead." — §V-B.
//!
//! [`InstrLayout`] derives every field width from a [`HwConfig`]
//! (e.g. an RF bank id takes `ceil(log2(rf_banks))` bits) and packs
//! instructions into a raw bit stream. Encoding and decoding round-trip
//! exactly; the decoder validates ranges so corrupted streams fail
//! loudly instead of mis-executing.

use super::{
    CtrlType, CuCtrl, CuMode, HwConfig, Instr, LoadSlot, MemSpace, Semantics, StoreSlot, SuCtrl,
    SuMode, XbarRoute,
};

/// Number of bits needed to represent values in `[0, n)`.
fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        1
    } else {
        (usize::BITS - (n - 1).leading_zeros()).max(1)
    }
}

/// Bit widths for every instruction field, derived from the hardware
/// configuration at design time.
#[derive(Clone, Copy, Debug)]
pub struct InstrLayout {
    /// Control type (3 bits — 6 values).
    pub ctrl_bits: u32,
    /// Memory-space selector.
    pub mem_bits: u32,
    /// Word address within a memory space.
    pub addr_bits: u32,
    /// RF bank id.
    pub bank_bits: u32,
    /// Register id within a bank.
    pub reg_bits: u32,
    /// CU lane id.
    pub cu_lane_bits: u32,
    /// PE input port id.
    pub port_bits: u32,
    /// SU lane id.
    pub su_lane_bits: u32,
    /// Distribution-size field.
    pub dist_bits: u32,
    /// Load-slot count field.
    pub load_cnt_bits: u32,
    /// Route count field.
    pub route_cnt_bits: u32,
    /// Store count field.
    pub store_cnt_bits: u32,
}

impl InstrLayout {
    /// Derive the layout from a hardware configuration.
    pub fn new(hw: &HwConfig) -> InstrLayout {
        InstrLayout {
            ctrl_bits: 3,
            mem_bits: 2,
            addr_bits: 20, // 1M words per space (4 MB) — matches 4.8 MB SRAM
            bank_bits: bits_for(hw.rf_banks),
            reg_bits: bits_for(hw.rf_regs_per_bank),
            cu_lane_bits: bits_for(hw.t),
            port_bits: bits_for(1 << hw.k),
            su_lane_bits: bits_for(hw.s),
            dist_bits: bits_for(hw.max_dist_size + 1),
            load_cnt_bits: bits_for(hw.bw_words + 1),
            route_cnt_bits: bits_for(hw.t * (1 << hw.k) + 1),
            store_cnt_bits: bits_for(hw.s + 1),
        }
    }

    /// Bits for one load slot.
    pub fn load_slot_bits(&self) -> u32 {
        self.mem_bits + self.addr_bits + self.bank_bits + self.reg_bits
    }

    /// Bits for one crossbar route.
    pub fn route_bits(&self) -> u32 {
        self.bank_bits + self.reg_bits + self.cu_lane_bits + self.port_bits
    }

    /// Bits for one store slot.
    pub fn store_slot_bits(&self) -> u32 {
        self.mem_bits + self.addr_bits + self.su_lane_bits
    }

    /// Encoded size of one instruction in bits.
    pub fn instr_bits(&self, i: &Instr) -> u64 {
        let mut b = self.ctrl_bits as u64;
        b += self.load_cnt_bits as u64 + i.loads.len() as u64 * self.load_slot_bits() as u64;
        b += self.route_cnt_bits as u64 + i.routes.len() as u64 * self.route_bits() as u64;
        b += 1; // cu present flag
        if i.cu.is_some() {
            b += 2 + self.cu_lane_bits as u64 + 2; // mode + lanes + scale/acc flags
        }
        b += 1; // su present flag
        if i.su.is_some() {
            b += 1 + self.su_lane_bits as u64 + self.dist_bits as u64 + 2;
        }
        b += self.store_cnt_bits as u64 + i.stores.len() as u64 * self.store_slot_bits() as u64;
        b
    }
}

/// Append-only bit writer.
#[derive(Default)]
struct BitWriter {
    words: Vec<u64>,
    bit_len: u64,
}

impl BitWriter {
    fn push(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value < (1u64 << bits), "value {value} overflows {bits} bits");
        let mut remaining = bits;
        let mut v = value;
        while remaining > 0 {
            let word = (self.bit_len / 64) as usize;
            let off = (self.bit_len % 64) as u32;
            if word == self.words.len() {
                self.words.push(0);
            }
            let take = remaining.min(64 - off);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            self.words[word] |= (v & mask) << off;
            v >>= take.min(63);
            if take == 64 {
                v = 0;
            }
            self.bit_len += take as u64;
            remaining -= take;
        }
    }
}

/// Sequential bit reader.
struct BitReader<'a> {
    words: &'a [u64],
    pos: u64,
    bit_len: u64,
}

impl<'a> BitReader<'a> {
    fn take(&mut self, bits: u32) -> Result<u64, String> {
        if self.pos + bits as u64 > self.bit_len {
            return Err("bitstream underrun".into());
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < bits {
            let word = (self.pos / 64) as usize;
            let off = (self.pos % 64) as u32;
            let take = (bits - got).min(64 - off);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            let chunk = (self.words[word] >> off) & mask;
            out |= chunk << got;
            self.pos += take as u64;
            got += take;
        }
        Ok(out)
    }
}

/// An encoded instruction stream plus its exact bit length.
#[derive(Clone, Debug)]
pub struct EncodedProgram {
    /// Packed little-endian bit stream.
    pub words: Vec<u64>,
    /// Number of valid bits.
    pub bit_len: u64,
    /// Number of instructions encoded.
    pub count: usize,
}

impl InstrLayout {
    /// Encode a sequence of instructions into a dense bit stream.
    /// `Semantics` is compiler metadata and is *not* encoded (it would
    /// not exist in the real instruction memory either).
    pub fn encode(&self, instrs: &[Instr]) -> EncodedProgram {
        let mut w = BitWriter::default();
        for i in instrs {
            w.push(i.ctrl.code() as u64, self.ctrl_bits);
            w.push(i.loads.len() as u64, self.load_cnt_bits);
            for l in &i.loads {
                w.push(l.mem.code() as u64, self.mem_bits);
                w.push(l.addr as u64, self.addr_bits);
                w.push(l.rf_bank as u64, self.bank_bits);
                w.push(l.rf_reg as u64, self.reg_bits);
            }
            w.push(i.routes.len() as u64, self.route_cnt_bits);
            for r in &i.routes {
                w.push(r.rf_bank as u64, self.bank_bits);
                w.push(r.rf_reg as u64, self.reg_bits);
                w.push(r.cu as u64, self.cu_lane_bits);
                w.push(r.port as u64, self.port_bits);
            }
            match &i.cu {
                Some(cu) => {
                    w.push(1, 1);
                    w.push(cu.mode.code() as u64, 2);
                    w.push(cu.lanes as u64 - 1, self.cu_lane_bits);
                    w.push(cu.scale_beta as u64, 1);
                    w.push(cu.accumulate as u64, 1);
                }
                None => w.push(0, 1),
            }
            match &i.su {
                Some(su) => {
                    w.push(1, 1);
                    w.push(matches!(su.mode, SuMode::Spatial) as u64, 1);
                    w.push(su.lanes as u64 - 1, self.su_lane_bits);
                    w.push(su.dist_size as u64, self.dist_bits);
                    w.push(su.first as u64, 1);
                    w.push(su.last as u64, 1);
                }
                None => w.push(0, 1),
            }
            w.push(i.stores.len() as u64, self.store_cnt_bits);
            for s in &i.stores {
                w.push(s.mem.code() as u64, self.mem_bits);
                w.push(s.addr as u64, self.addr_bits);
                w.push(s.su_lane as u64, self.su_lane_bits);
            }
        }
        EncodedProgram {
            words: w.words,
            bit_len: w.bit_len,
            count: instrs.len(),
        }
    }

    /// Decode an encoded stream back to instructions (semantics become
    /// [`Semantics::None`]).
    pub fn decode(&self, enc: &EncodedProgram) -> Result<Vec<Instr>, String> {
        let mut r = BitReader {
            words: &enc.words,
            pos: 0,
            bit_len: enc.bit_len,
        };
        let mut out = Vec::with_capacity(enc.count);
        for _ in 0..enc.count {
            let ctrl = CtrlType::from_code(r.take(self.ctrl_bits)? as u8)
                .ok_or("bad ctrl code")?;
            let nloads = r.take(self.load_cnt_bits)? as usize;
            let mut loads = Vec::with_capacity(nloads);
            for _ in 0..nloads {
                loads.push(LoadSlot {
                    mem: MemSpace::from_code(r.take(self.mem_bits)? as u8)
                        .ok_or("bad mem code")?,
                    addr: r.take(self.addr_bits)? as u32,
                    rf_bank: r.take(self.bank_bits)? as u16,
                    rf_reg: r.take(self.reg_bits)? as u16,
                });
            }
            let nroutes = r.take(self.route_cnt_bits)? as usize;
            let mut routes = Vec::with_capacity(nroutes);
            for _ in 0..nroutes {
                routes.push(XbarRoute {
                    rf_bank: r.take(self.bank_bits)? as u16,
                    rf_reg: r.take(self.reg_bits)? as u16,
                    cu: r.take(self.cu_lane_bits)? as u16,
                    port: r.take(self.port_bits)? as u16,
                });
            }
            let cu = if r.take(1)? == 1 {
                Some(CuCtrl {
                    mode: CuMode::from_code(r.take(2)? as u8).ok_or("bad cu mode")?,
                    lanes: r.take(self.cu_lane_bits)? as u16 + 1,
                    scale_beta: r.take(1)? == 1,
                    accumulate: r.take(1)? == 1,
                })
            } else {
                None
            };
            let su = if r.take(1)? == 1 {
                Some(SuCtrl {
                    mode: if r.take(1)? == 1 {
                        SuMode::Spatial
                    } else {
                        SuMode::Temporal
                    },
                    lanes: r.take(self.su_lane_bits)? as u16 + 1,
                    dist_size: r.take(self.dist_bits)? as u16,
                    first: r.take(1)? == 1,
                    last: r.take(1)? == 1,
                })
            } else {
                None
            };
            let nstores = r.take(self.store_cnt_bits)? as usize;
            let mut stores = Vec::with_capacity(nstores);
            for _ in 0..nstores {
                stores.push(StoreSlot {
                    mem: MemSpace::from_code(r.take(self.mem_bits)? as u8)
                        .ok_or("bad mem code")?,
                    addr: r.take(self.addr_bits)? as u32,
                    su_lane: r.take(self.su_lane_bits)? as u16,
                });
            }
            out.push(Instr {
                ctrl,
                loads,
                routes,
                cu,
                su,
                stores,
                sem: Semantics::None,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_instr(rng: &mut Rng, hw: &HwConfig) -> Instr {
        let ctrl = CtrlType::from_code(rng.below(6) as u8).unwrap();
        let nloads = rng.below(4);
        let loads = (0..nloads)
            .map(|_| LoadSlot {
                mem: MemSpace::from_code(rng.below(3) as u8).unwrap(),
                addr: rng.below(1 << 20) as u32,
                rf_bank: rng.below(hw.rf_banks) as u16,
                rf_reg: rng.below(hw.rf_regs_per_bank) as u16,
            })
            .collect();
        let routes = (0..rng.below(5))
            .map(|_| XbarRoute {
                rf_bank: rng.below(hw.rf_banks) as u16,
                rf_reg: rng.below(hw.rf_regs_per_bank) as u16,
                cu: rng.below(hw.t) as u16,
                port: rng.below(1 << hw.k) as u16,
            })
            .collect();
        let cu = (rng.below(2) == 1).then(|| CuCtrl {
            mode: CuMode::from_code(rng.below(4) as u8).unwrap(),
            lanes: rng.below(hw.t) as u16 + 1,
            scale_beta: rng.below(2) == 1,
            accumulate: rng.below(2) == 1,
        });
        let su = (rng.below(2) == 1).then(|| SuCtrl {
            mode: if rng.below(2) == 1 {
                SuMode::Spatial
            } else {
                SuMode::Temporal
            },
            lanes: rng.below(hw.s) as u16 + 1,
            dist_size: rng.below(hw.max_dist_size + 1) as u16,
            first: rng.below(2) == 1,
            last: rng.below(2) == 1,
        });
        let stores = (0..rng.below(3))
            .map(|_| StoreSlot {
                mem: MemSpace::from_code(rng.below(3) as u8).unwrap(),
                addr: rng.below(1 << 20) as u32,
                su_lane: rng.below(hw.s) as u16,
            })
            .collect();
        Instr {
            ctrl,
            loads,
            routes,
            cu,
            su,
            stores,
            sem: Semantics::None,
        }
    }

    #[test]
    fn roundtrip_random_instructions() {
        let hw = HwConfig::paper_default();
        let layout = InstrLayout::new(&hw);
        let mut rng = Rng::new(0xC0DE);
        for trial in 0..50 {
            let instrs: Vec<Instr> = (0..20).map(|_| random_instr(&mut rng, &hw)).collect();
            let enc = layout.encode(&instrs);
            let dec = layout.decode(&enc).expect("decode");
            assert_eq!(instrs, dec, "trial {trial}");
        }
    }

    #[test]
    fn roundtrip_toy_config() {
        let hw = HwConfig::fig10_toy();
        let layout = InstrLayout::new(&hw);
        let mut rng = Rng::new(0xBEEF);
        let instrs: Vec<Instr> = (0..40).map(|_| random_instr(&mut rng, &hw)).collect();
        let enc = layout.encode(&instrs);
        assert_eq!(layout.decode(&enc).unwrap(), instrs);
    }

    #[test]
    fn dense_packing_beats_byte_alignment() {
        // The whole point of the dense VLIW pack: a NOP must take far
        // fewer bits than a byte-aligned struct encoding would.
        let hw = HwConfig::paper_default();
        let layout = InstrLayout::new(&hw);
        let nop = Instr::nop();
        let enc = layout.encode(&[nop.clone()]);
        assert!(enc.bit_len <= 32, "NOP takes {} bits", enc.bit_len);
        assert_eq!(enc.bit_len, layout.instr_bits(&nop));
    }

    #[test]
    fn instr_bits_matches_encoding() {
        let hw = HwConfig::paper_default();
        let layout = InstrLayout::new(&hw);
        let mut rng = Rng::new(7);
        let instrs: Vec<Instr> = (0..10).map(|_| random_instr(&mut rng, &hw)).collect();
        let total: u64 = instrs.iter().map(|i| layout.instr_bits(i)).sum();
        let enc = layout.encode(&instrs);
        assert_eq!(enc.bit_len, total);
    }

    #[test]
    fn truncated_stream_errors() {
        let hw = HwConfig::paper_default();
        let layout = InstrLayout::new(&hw);
        let mut rng = Rng::new(9);
        let instrs: Vec<Instr> = (0..5).map(|_| random_instr(&mut rng, &hw)).collect();
        let mut enc = layout.encode(&instrs);
        enc.bit_len = enc.bit_len.saturating_sub(16);
        assert!(layout.decode(&enc).is_err());
    }

    #[test]
    fn bits_for_sanity() {
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(64), 6);
        assert_eq!(bits_for(65), 7);
    }
}
