//! Dataflow analysis over one ISA program: RF def-use (read-before-
//! write, dead stores, per-bank register pressure), pipeline RAW-hazard
//! detection across VLIW bundles, and per-bundle resource bounds the
//! classic validator does not cover (load-slot ranges, store-lane
//! ranges, SU distribution size, crossbar port conflicts).
//!
//! The def-use model follows the compiler's RF contract: crossbar
//! routes are the only *register* reads (per-state parameter rows and
//! the PAS distribution stream feed the CU/SU through the direct
//! memory path and are intentionally never routed — overwrites of
//! those staging rows are reported as an informational dead-store
//! aggregate, not an error).

use super::{DiagCode, Diagnostic, Report};
use crate::isa::{HwConfig, Program};

/// Cap on per-instance error diagnostics of one kind, so a corrupted
/// program cannot flood the report (the remainder is summarized).
const MAX_INSTANCES: usize = 8;

/// Run the dataflow family, appending findings to `report`.
pub fn check_dataflow(program: &Program, hw: &HwConfig, report: &mut Report) {
    check_bounds(program, hw, report);
    check_def_use(program, hw, report);
    check_raw_hazards(program, hw, report);
}

/// Per-bundle bounds: load-slot targets, store-lane indices, SU
/// distribution sizes, duplicate crossbar (CU, port) drivers.
fn check_bounds(program: &Program, hw: &HwConfig, report: &mut Report) {
    let mut load_oor = 0usize;
    for (at, instr) in program.prologue.iter().chain(&program.body).enumerate() {
        for l in &instr.loads {
            if l.rf_bank as usize >= hw.rf_banks || l.rf_reg as usize >= hw.rf_regs_per_bank {
                load_oor += 1;
                if load_oor <= MAX_INSTANCES {
                    report.push(
                        Diagnostic::new(
                            DiagCode::LoadOutOfRange,
                            format!(
                                "load targets rf[{}][{}] but the RF is {} banks x {} regs",
                                l.rf_bank, l.rf_reg, hw.rf_banks, hw.rf_regs_per_bank
                            ),
                        )
                        .at_instr(at),
                    );
                }
            }
        }
        for s in &instr.stores {
            if s.su_lane as usize >= hw.s {
                report.push(
                    Diagnostic::new(
                        DiagCode::StoreLaneOutOfRange,
                        format!("store reads SU lane {} but S = {}", s.su_lane, hw.s),
                    )
                    .at_instr(at),
                );
            }
        }
        if let Some(su) = &instr.su {
            if su.dist_size as usize > hw.max_dist_size {
                report.push(
                    Diagnostic::new(
                        DiagCode::DistTooLarge,
                        format!(
                            "SU samples a size-{} distribution but max_dist_size = {}",
                            su.dist_size, hw.max_dist_size
                        ),
                    )
                    .at_instr(at),
                );
            }
        }
        // Each (CU lane, input port) pair has one crossbar output — two
        // routes driving it in one cycle is a structural conflict.
        let mut ports = std::collections::HashSet::new();
        for r in &instr.routes {
            if !ports.insert((r.cu, r.port)) {
                report.push(
                    Diagnostic::new(
                        DiagCode::RoutePortConflict,
                        format!("two routes drive CU lane {} port {} in one bundle", r.cu, r.port),
                    )
                    .at_instr(at),
                );
            }
        }
    }
    if load_oor > MAX_INSTANCES {
        report.push(Diagnostic::new(
            DiagCode::LoadOutOfRange,
            format!("... and {} more out-of-range load slots", load_oor - MAX_INSTANCES),
        ));
    }
}

/// RF def-use in program order (prologue then body): every route must
/// read a register some earlier load wrote (read-before-write is an
/// error — the crossbar would forward garbage); overwrites of
/// never-read *routed-class* registers are counted as dead stores; and
/// the per-bank write high-water mark yields the register-pressure
/// report.
fn check_def_use(program: &Program, hw: &HwConfig, report: &mut Report) {
    use std::collections::{HashMap, HashSet};
    // Registers that are ever read through the crossbar. Writes outside
    // this class stage direct-path operands and are exempt from
    // dead-store accounting by design.
    let mut routed: HashSet<(u16, u16)> = HashSet::new();
    for instr in program.prologue.iter().chain(&program.body) {
        for r in &instr.routes {
            routed.insert((r.rf_bank, r.rf_reg));
        }
    }
    // (bank, reg) -> has the latest write been read yet?
    let mut written: HashMap<(u16, u16), bool> = HashMap::new();
    let mut bank_regs: HashMap<u16, HashSet<u16>> = HashMap::new();
    let mut rbw = 0usize;
    let mut dead = 0usize;
    let mut first_dead: Option<usize> = None;
    let mut writes = 0u64;
    let mut reads = 0u64;
    for (at, instr) in program.prologue.iter().chain(&program.body).enumerate() {
        for r in &instr.routes {
            reads += 1;
            match written.get_mut(&(r.rf_bank, r.rf_reg)) {
                Some(read) => *read = true,
                None => {
                    rbw += 1;
                    if rbw <= MAX_INSTANCES {
                        report.push(
                            Diagnostic::new(
                                DiagCode::ReadBeforeWrite,
                                format!(
                                    "route reads rf[{}][{}] before any load writes it",
                                    r.rf_bank, r.rf_reg
                                ),
                            )
                            .at_instr(at),
                        );
                    }
                }
            }
        }
        for l in &instr.loads {
            writes += 1;
            let key = (l.rf_bank, l.rf_reg);
            if let Some(read) = written.get(&key) {
                if !*read && routed.contains(&key) {
                    dead += 1;
                    first_dead.get_or_insert(at);
                }
            }
            written.insert(key, false);
            bank_regs.entry(l.rf_bank).or_default().insert(l.rf_reg);
        }
    }
    if rbw > MAX_INSTANCES {
        report.push(Diagnostic::new(
            DiagCode::ReadBeforeWrite,
            format!("... and {} more read-before-write routes", rbw - MAX_INSTANCES),
        ));
    }
    if dead > 0 {
        let mut d = Diagnostic::new(
            DiagCode::DeadStore,
            format!(
                "{dead} routed-register writes overwritten before any crossbar read per \
                 iteration (rotating staging rows; direct-path operands are expected here)"
            ),
        );
        if let Some(at) = first_dead {
            d = d.at_instr(at);
        }
        report.push(d);
    }
    // Register-pressure / liveness report: how much of the RF the
    // schedule actually touches, and how hot the busiest bank runs.
    if !bank_regs.is_empty() {
        let max_regs = bank_regs.values().map(|s| s.len()).max().unwrap_or(0);
        let total_regs: usize = bank_regs.values().map(|s| s.len()).sum();
        report.push(Diagnostic::new(
            DiagCode::RegisterPressure,
            format!(
                "RF pressure: {}/{} banks written, busiest bank touches {}/{} regs \
                 (mean {:.1}), {} reg writes / {} crossbar reads per iteration",
                bank_regs.len(),
                hw.rf_banks,
                max_regs,
                hw.rf_regs_per_bank,
                total_regs as f64 / bank_regs.len() as f64,
                writes,
                reads
            ),
        ));
    }
}

/// Pipeline RAW hazards through *memory*: a store at bundle `i` commits
/// at the end of the CU/SU pipeline, so a load of the same
/// (space, address) at bundle `j` with `j - i <= cu_latency` reads the
/// stale value. The compiler's drain NOPs space dependent phases by
/// exactly `cu_latency` bundles, so clean schedules sit one cycle past
/// the window; fused bundles are checked against their own stores too.
fn check_raw_hazards(program: &Program, hw: &HwConfig, report: &mut Report) {
    use std::collections::VecDeque;
    let window = hw.cu_latency();
    // Recent stores: (bundle index, space code, addr).
    let mut recent: VecDeque<(usize, u8, u32)> = VecDeque::new();
    let mut hazards = 0usize;
    for (at, instr) in program.prologue.iter().chain(&program.body).enumerate() {
        while recent.front().is_some_and(|&(i, _, _)| at - i > window) {
            recent.pop_front();
        }
        // A same-bundle store/load overlap is also stale: loads issue at
        // the first pipeline stage, stores commit at the last.
        let own: Vec<(usize, u8, u32)> =
            instr.stores.iter().map(|s| (at, s.mem.code(), s.addr)).collect();
        for l in &instr.loads {
            let key = (l.mem.code(), l.addr);
            if let Some(&(i, _, _)) = recent
                .iter()
                .chain(&own)
                .find(|&&(_, m, a)| (m, a) == key)
            {
                hazards += 1;
                if hazards <= MAX_INSTANCES {
                    report.push(
                        Diagnostic::new(
                            DiagCode::RawHazard,
                            format!(
                                "load of mem[{}]@{} issues {} bundle(s) after the store that \
                                 writes it (needs > {window} for the pipeline to commit)",
                                l.mem.code(),
                                l.addr,
                                at - i
                            ),
                        )
                        .at_instr(at),
                    );
                }
            }
        }
        recent.extend(own);
    }
    if hazards > MAX_INSTANCES {
        report.push(Diagnostic::new(
            DiagCode::RawHazard,
            format!("... and {} more RAW hazards", hazards - MAX_INSTANCES),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::energy::PottsGrid;
    use crate::isa::{CtrlType, Instr, LoadSlot, MemSpace, Semantics, StoreSlot, XbarRoute};
    use crate::mcmc::AlgoKind;

    fn clean_report(p: &Program, hw: &HwConfig) -> Report {
        let mut r = Report::new();
        check_dataflow(p, hw, &mut r);
        r
    }

    #[test]
    fn compiled_programs_have_no_dataflow_errors() {
        let m = PottsGrid::new(8, 8, 3, 1.0);
        for hw in [HwConfig::fig10_toy(), HwConfig::paper_default()] {
            for algo in
                [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::AsyncGibbs, AlgoKind::Pas]
            {
                let p = compile(&m, algo, &hw, 2).unwrap();
                let r = clean_report(&p, &hw);
                assert!(!r.has_errors(), "{algo:?}: {}", r.render_human());
            }
        }
    }

    #[test]
    fn read_before_write_detected() {
        let hw = HwConfig::fig10_toy();
        let mut p = Program::default();
        let mut i = Instr::nop();
        i.ctrl = CtrlType::Compute;
        i.routes.push(XbarRoute { rf_bank: 0, rf_reg: 0, cu: 0, port: 0 });
        p.body.push(i);
        let r = clean_report(&p, &hw);
        assert!(r.diagnostics.iter().any(|d| d.code == DiagCode::ReadBeforeWrite));
    }

    #[test]
    fn raw_hazard_within_latency_window_detected() {
        let hw = HwConfig::paper_default(); // cu_latency = 4
        let mut p = Program::default();
        let mut st = Instr::nop();
        st.ctrl = CtrlType::ComputeSampleStore;
        st.stores.push(StoreSlot { mem: MemSpace::Sample, addr: 42, su_lane: 0 });
        p.body.push(st);
        p.body.push(Instr::nop());
        let mut ld = Instr::nop();
        ld.ctrl = CtrlType::Load;
        ld.loads.push(LoadSlot { mem: MemSpace::Sample, addr: 42, rf_bank: 0, rf_reg: 0 });
        p.body.push(ld); // 2 bundles after the store: inside the window
        let r = clean_report(&p, &hw);
        assert!(
            r.diagnostics.iter().any(|d| d.code == DiagCode::RawHazard),
            "{}",
            r.render_human()
        );
        // Past the window it is clean.
        let mut p2 = Program::default();
        let mut st = Instr::nop();
        st.stores.push(StoreSlot { mem: MemSpace::Sample, addr: 42, su_lane: 0 });
        p2.body.push(st);
        for _ in 0..hw.cu_latency() {
            p2.body.push(Instr::nop());
        }
        let mut ld = Instr::nop();
        ld.loads.push(LoadSlot { mem: MemSpace::Sample, addr: 42, rf_bank: 0, rf_reg: 0 });
        p2.body.push(ld);
        let r2 = clean_report(&p2, &hw);
        assert!(!r2.diagnostics.iter().any(|d| d.code == DiagCode::RawHazard));
    }

    #[test]
    fn bounds_violations_detected() {
        let hw = HwConfig::fig10_toy();
        let mut p = Program::default();
        let mut i = Instr::nop();
        i.loads.push(LoadSlot { mem: MemSpace::Input, addr: 0, rf_bank: 200, rf_reg: 0 });
        i.stores.push(StoreSlot { mem: MemSpace::Sample, addr: 0, su_lane: 99 });
        i.su = Some(crate::isa::SuCtrl {
            mode: crate::isa::SuMode::Temporal,
            lanes: 1,
            dist_size: 10_000,
            first: true,
            last: true,
        });
        i.routes.push(XbarRoute { rf_bank: 0, rf_reg: 0, cu: 1, port: 1 });
        i.routes.push(XbarRoute { rf_bank: 1, rf_reg: 0, cu: 1, port: 1 });
        i.sem = Semantics::None;
        p.body.push(i);
        let r = clean_report(&p, &hw);
        for code in [
            DiagCode::LoadOutOfRange,
            DiagCode::StoreLaneOutOfRange,
            DiagCode::DistTooLarge,
            DiagCode::RoutePortConflict,
        ] {
            assert!(
                r.diagnostics.iter().any(|d| d.code == code),
                "missing {code:?}: {}",
                r.render_human()
            );
        }
    }

    #[test]
    fn pressure_report_emitted_for_real_programs() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let hw = HwConfig::paper_default();
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let r = clean_report(&p, &hw);
        assert!(r.diagnostics.iter().any(|d| d.code == DiagCode::RegisterPressure));
    }
}
