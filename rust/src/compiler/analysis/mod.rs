//! Static analysis & diagnostics over compiled ISA programs, shard
//! ensembles and chromatic schedules (`mc2a check`).
//!
//! [`validate_program`](crate::compiler::validate_program) is the seed
//! this subsystem grows from: its [`Violation`]s become error-severity
//! [`Diagnostic`]s, and three analysis families extend them:
//!
//! * **Dataflow** ([`mod@dataflow`]) — RF def-use analysis
//!   (read-before-write, dead stores, per-bank register pressure),
//!   pipeline RAW-hazard detection across VLIW bundles, and LUT/SU
//!   parameter bounds against the [`HwConfig`].
//! * **Ensemble** ([`mod@ensemble`]) — per-program checks on every
//!   [`compile_shard`](crate::compiler::compile_shard) output plus the
//!   cross-core invariants: barrier/round alignment, single-writer
//!   ownership of every RV, race-freedom of each synchronization
//!   round, and crossbar-bandwidth consistency with the
//!   [`MultiHwConfig`].
//! * **Chromatic** ([`mod@chromatic`]) — color classes are independent
//!   sets w.r.t. the model's *Markov blanket* (checked both
//!   structurally against the interaction graph and functionally by
//!   perturbation probes on `local_energies`), with warnings sizing
//!   the Async-Gibbs hazard window.
//!
//! Every finding is a [`Diagnostic`] with a stable `MC2A0xx` code and a
//! severity; [`Report`] renders them human-readable or as JSON, and the
//! [`gate_program`]/[`gate_ensemble`] entry points turn error-severity
//! findings into [`Mc2aError::InvalidProgram`] so the accelerator
//! backends reject bad programs *before* simulation.

pub mod chromatic;
pub mod dataflow;
pub mod ensemble;

pub use chromatic::analyze_chromatic;
pub use ensemble::{analyze_ensemble, analyze_ensemble_mutated, ShardProgram};

use crate::compiler::validate::{validate_program, Violation};
use crate::energy::EnergyModel;
use crate::engine::error::Mc2aError;
use crate::isa::{HwConfig, MultiHwConfig, Program};
use crate::mcmc::{AlgoKind, SamplerKind};

/// How bad a finding is. `Error` findings make [`Report::has_errors`]
/// true, fail `mc2a check`, and are the only severity the backend
/// gates reject on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Measurement or report — nothing to fix.
    Info,
    /// Suspicious but legal; the program still executes correctly.
    Warning,
    /// A broken invariant: the program must not execute.
    Error,
}

impl Severity {
    /// Lowercase name, as rendered in reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. The numeric ranges group the families:
/// `MC2A00x` are the classic [`Violation`] invariants, `MC2A01x` the
/// dataflow/bounds family, `MC2A02x` the multi-core ensemble family,
/// `MC2A03x` the chromatic-parallelism family. Codes never change
/// meaning; retired codes are not reused.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DiagCode {
    /// Two RVs in one commit are Markov-blanket neighbors.
    DependentParallelUpdate,
    /// A Load bundle exceeds the B words/cycle budget.
    BandwidthExceeded,
    /// Two rows of one RF bank written in one bundle.
    WritePortConflict,
    /// A crossbar route names an out-of-range resource.
    RouteOutOfRange,
    /// An RV is updated ≠ 1 times per iteration.
    BadUpdateCoverage,
    /// An SU control names more lanes than exist.
    SuLanesOutOfRange,
    /// A CU control names more lanes than exist.
    CuLanesOutOfRange,
    /// A route reads an RF register no earlier load wrote.
    ReadBeforeWrite,
    /// Routed registers overwritten before any read (aggregate).
    DeadStore,
    /// A load reads an address stored ≤ CU-latency bundles earlier.
    RawHazard,
    /// An SU distribution exceeds `max_dist_size`.
    DistTooLarge,
    /// A store slot names an SU lane ≥ S.
    StoreLaneOutOfRange,
    /// A load slot targets an out-of-range RF bank/register.
    LoadOutOfRange,
    /// Two routes drive the same (CU lane, port) in one bundle.
    RoutePortConflict,
    /// Per-bank register-file pressure report (aggregate).
    RegisterPressure,
    /// Sampler LUT shape differs from the hardware LUT.
    SamplerLutMismatch,
    /// Shard programs disagree on the synchronization-round count.
    RoundMisalignment,
    /// A core updates an RV another core owns.
    OwnershipViolation,
    /// Two cores update blanket neighbors in the same round.
    CrossCoreRace,
    /// Estimated crossbar + barrier time exceeds compute time.
    XbarSyncBound,
    /// Boundary-traffic / cut-edge report (aggregate).
    EnsembleTraffic,
    /// A color class contains two interaction-graph neighbors.
    ImproperColoring,
    /// `local_energies` depends on a variable outside the declared
    /// Markov blanket (functional probe).
    HiddenDependence,
    /// Async-Gibbs hazard window size (stale-read edge count).
    AsyncHazardWindow,
    /// Coloring-quality report (aggregate).
    ColoringSummary,
}

impl DiagCode {
    /// Every code, in code order (drives the README reference table
    /// and the uniqueness test).
    pub const ALL: &'static [DiagCode] = &[
        DiagCode::DependentParallelUpdate,
        DiagCode::BandwidthExceeded,
        DiagCode::WritePortConflict,
        DiagCode::RouteOutOfRange,
        DiagCode::BadUpdateCoverage,
        DiagCode::SuLanesOutOfRange,
        DiagCode::CuLanesOutOfRange,
        DiagCode::ReadBeforeWrite,
        DiagCode::DeadStore,
        DiagCode::RawHazard,
        DiagCode::DistTooLarge,
        DiagCode::StoreLaneOutOfRange,
        DiagCode::LoadOutOfRange,
        DiagCode::RoutePortConflict,
        DiagCode::RegisterPressure,
        DiagCode::SamplerLutMismatch,
        DiagCode::RoundMisalignment,
        DiagCode::OwnershipViolation,
        DiagCode::CrossCoreRace,
        DiagCode::XbarSyncBound,
        DiagCode::EnsembleTraffic,
        DiagCode::ImproperColoring,
        DiagCode::HiddenDependence,
        DiagCode::AsyncHazardWindow,
        DiagCode::ColoringSummary,
    ];

    /// The stable `MC2A0xx` code string.
    pub fn as_str(&self) -> &'static str {
        match self {
            DiagCode::DependentParallelUpdate => "MC2A001",
            DiagCode::BandwidthExceeded => "MC2A002",
            DiagCode::WritePortConflict => "MC2A003",
            DiagCode::RouteOutOfRange => "MC2A004",
            DiagCode::BadUpdateCoverage => "MC2A005",
            DiagCode::SuLanesOutOfRange => "MC2A006",
            DiagCode::CuLanesOutOfRange => "MC2A007",
            DiagCode::ReadBeforeWrite => "MC2A010",
            DiagCode::DeadStore => "MC2A011",
            DiagCode::RawHazard => "MC2A012",
            DiagCode::DistTooLarge => "MC2A013",
            DiagCode::StoreLaneOutOfRange => "MC2A014",
            DiagCode::LoadOutOfRange => "MC2A015",
            DiagCode::RoutePortConflict => "MC2A016",
            DiagCode::RegisterPressure => "MC2A017",
            DiagCode::SamplerLutMismatch => "MC2A018",
            DiagCode::RoundMisalignment => "MC2A020",
            DiagCode::OwnershipViolation => "MC2A021",
            DiagCode::CrossCoreRace => "MC2A022",
            DiagCode::XbarSyncBound => "MC2A023",
            DiagCode::EnsembleTraffic => "MC2A024",
            DiagCode::ImproperColoring => "MC2A030",
            DiagCode::HiddenDependence => "MC2A031",
            DiagCode::AsyncHazardWindow => "MC2A032",
            DiagCode::ColoringSummary => "MC2A033",
        }
    }

    /// The fixed severity of this code.
    pub fn severity(&self) -> Severity {
        match self {
            DiagCode::DeadStore
            | DiagCode::RegisterPressure
            | DiagCode::EnsembleTraffic
            | DiagCode::ColoringSummary => Severity::Info,
            DiagCode::SamplerLutMismatch
            | DiagCode::XbarSyncBound
            | DiagCode::AsyncHazardWindow => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// One finding: a stable code plus a human-readable message, optionally
/// anchored to an instruction index and/or a core id.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    /// Stable code (severity derives from it).
    pub code: DiagCode,
    /// Human-readable description of the finding.
    pub message: String,
    /// Instruction index (prologue + body order), when the finding
    /// anchors to one bundle.
    pub instr: Option<usize>,
    /// Core id, for multi-core ensemble findings.
    pub core: Option<usize>,
}

impl Diagnostic {
    /// A finding with no location.
    pub fn new(code: DiagCode, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, message: message.into(), instr: None, core: None }
    }

    /// Anchor to an instruction index.
    pub fn at_instr(mut self, at: usize) -> Diagnostic {
        self.instr = Some(at);
        self
    }

    /// The severity of this finding's code.
    pub fn severity(&self) -> Severity {
        self.code.severity()
    }

    /// One-line human rendering: `severity CODE [@instr N] [core C]: message`.
    pub fn render(&self) -> String {
        let mut s = format!("{} {}", self.severity().as_str(), self.code.as_str());
        if let Some(c) = self.core {
            s.push_str(&format!(" [core {c}]"));
        }
        if let Some(i) = self.instr {
            s.push_str(&format!(" [instr {i}]"));
        }
        s.push_str(": ");
        s.push_str(&self.message);
        s
    }

    /// JSON object rendering (hand-rolled, matching the crate's
    /// dependency-free JSON style).
    pub fn to_json(&self) -> String {
        let opt = |v: Option<usize>| match v {
            Some(n) => n.to_string(),
            None => "null".into(),
        };
        format!(
            "{{\"code\":\"{}\",\"severity\":\"{}\",\"message\":\"{}\",\"instr\":{},\"core\":{}}}",
            self.code.as_str(),
            self.severity().as_str(),
            crate::engine::checkpoint::escape_json(&self.message),
            opt(self.instr),
            opt(self.core),
        )
    }
}

/// A collection of diagnostics from one or more analyses.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// All findings, in discovery order.
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    /// An empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Add one finding.
    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// Absorb another report's findings.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    /// Tag every untagged finding with a core id (used when a whole
    /// per-program analysis ran on one shard).
    pub fn tag_core(&mut self, core: usize) {
        for d in &mut self.diagnostics {
            d.core.get_or_insert(core);
        }
    }

    /// Number of findings at `sev`.
    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity() == sev).count()
    }

    /// Any error-severity findings?
    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity() == Severity::Error)
    }

    /// The error-severity findings, cloned (what
    /// [`Mc2aError::InvalidProgram`] carries).
    pub fn errors(&self) -> Vec<Diagnostic> {
        self.diagnostics
            .iter()
            .filter(|d| d.severity() == Severity::Error)
            .cloned()
            .collect()
    }

    /// Multi-line human rendering, one finding per line (empty string
    /// when clean).
    pub fn render_human(&self) -> String {
        self.diagnostics
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// JSON array of the findings.
    pub fn to_json(&self) -> String {
        let items: Vec<String> = self.diagnostics.iter().map(|d| d.to_json()).collect();
        format!("[{}]", items.join(","))
    }
}

/// Convert one classic [`Violation`] into a [`Diagnostic`].
fn violation_diag(v: &Violation) -> Diagnostic {
    match v {
        Violation::DependentParallelUpdate { a, b } => Diagnostic::new(
            DiagCode::DependentParallelUpdate,
            format!("RVs {a} and {b} are Markov-blanket neighbors but share one parallel commit"),
        ),
        Violation::BandwidthExceeded { at, words } => Diagnostic::new(
            DiagCode::BandwidthExceeded,
            format!("bundle loads {words} words, above the B words/cycle budget"),
        )
        .at_instr(*at),
        Violation::WritePortConflict { at, bank } => Diagnostic::new(
            DiagCode::WritePortConflict,
            format!("two rows of RF bank {bank} written in one bundle (one row-wide port/bank)"),
        )
        .at_instr(*at),
        Violation::RouteOutOfRange { at } => Diagnostic::new(
            DiagCode::RouteOutOfRange,
            "crossbar route names an out-of-range bank/register/CU/port".to_string(),
        )
        .at_instr(*at),
        Violation::BadUpdateCoverage { rv, count } => Diagnostic::new(
            DiagCode::BadUpdateCoverage,
            format!("RV {rv} updated {count} times per iteration (want exactly 1)"),
        ),
        Violation::SuLanesOutOfRange { at } => Diagnostic::new(
            DiagCode::SuLanesOutOfRange,
            "SU control names more lanes than S".to_string(),
        )
        .at_instr(*at),
        Violation::CuLanesOutOfRange { at } => Diagnostic::new(
            DiagCode::CuLanesOutOfRange,
            "CU control names more lanes than T".to_string(),
        )
        .at_instr(*at),
    }
}

/// Full single-program analysis: the classic [`validate_program`]
/// invariants plus the dataflow family and (for snapshot programs) the
/// Async-Gibbs hazard-window measurement.
///
/// `expect_full_coverage` asserts that every model RV is updated
/// exactly once per iteration — true for whole-model Gibbs-family
/// programs, false for shard programs (the ensemble analysis owns
/// cross-shard coverage) and for PAS.
pub fn analyze_program(
    program: &Program,
    model: &dyn EnergyModel,
    hw: &HwConfig,
    expect_full_coverage: bool,
) -> Report {
    let mut report = Report::new();
    for v in validate_program(program, model, hw, expect_full_coverage) {
        report.push(violation_diag(&v));
    }
    dataflow::check_dataflow(program, hw, &mut report);
    chromatic::async_hazard_window(program, model, &mut report);
    report
}

/// Does a whole-model program for `algo` update every RV exactly once
/// per iteration? (PAS schedules a global move table instead.)
pub fn algo_expects_full_coverage(algo: AlgoKind) -> bool {
    !matches!(algo, AlgoKind::Pas)
}

/// Sampler-vs-hardware consistency: a [`SamplerKind::GumbelLut`] whose
/// table shape differs from the hardware LUT will not be bit-identical
/// to the silicon it models.
pub fn analyze_sampler(sampler: SamplerKind, hw: &HwConfig) -> Report {
    let mut report = Report::new();
    if let SamplerKind::GumbelLut { size, bits } = sampler {
        if size != hw.lut_size || bits != hw.lut_bits {
            report.push(Diagnostic::new(
                DiagCode::SamplerLutMismatch,
                format!(
                    "sampler LUT {size}x{bits}-bit differs from the hardware LUT {}x{}-bit; \
                     software and simulated chains will diverge bit-wise",
                    hw.lut_size, hw.lut_bits
                ),
            ));
        }
    }
    report
}

/// Gate a compiled single-core program: error-severity findings become
/// [`Mc2aError::InvalidProgram`]. Cheap (linear in program size — no
/// functional probes), so the accelerator backend runs it on every
/// chain before simulation.
pub fn gate_program(
    program: &Program,
    model: &dyn EnergyModel,
    hw: &HwConfig,
    algo: AlgoKind,
) -> Result<(), Mc2aError> {
    let report = analyze_program(program, model, hw, algo_expects_full_coverage(algo));
    if report.has_errors() {
        return Err(Mc2aError::InvalidProgram { diagnostics: report.errors() });
    }
    Ok(())
}

/// Gate a multi-core shard ensemble (compiling the shards exactly as
/// [`crate::sim::MultiCoreSim::new`] will): error-severity findings
/// become [`Mc2aError::InvalidProgram`]. `mutate` is a test-only hook
/// applied to each shard program before analysis.
pub fn gate_ensemble(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    mhw: &MultiHwConfig,
    pas_flips: usize,
    mutate: Option<fn(&mut Program)>,
) -> Result<(), Mc2aError> {
    let report = analyze_ensemble_mutated(model, algo, mhw, pas_flips, mutate)?;
    if report.has_errors() {
        return Err(Mc2aError::InvalidProgram { diagnostics: report.errors() });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::energy::PottsGrid;
    use crate::isa::{Instr, Semantics};

    #[test]
    fn codes_are_unique_stable_and_ordered() {
        let mut seen = std::collections::HashSet::new();
        let mut prev = String::new();
        for c in DiagCode::ALL {
            let s = c.as_str();
            assert!(s.starts_with("MC2A") && s.len() == 7, "{s}");
            assert!(seen.insert(s), "duplicate code {s}");
            assert!(s.to_string() > prev, "codes out of order at {s}");
            prev = s.to_string();
        }
    }

    #[test]
    fn severity_ordering_puts_errors_on_top() {
        assert!(Severity::Error > Severity::Warning);
        assert!(Severity::Warning > Severity::Info);
    }

    #[test]
    fn clean_program_analyzes_clean() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let hw = HwConfig::paper_default();
        let p = compile(&m, crate::mcmc::AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let r = analyze_program(&p, &m, &hw, true);
        assert!(!r.has_errors(), "{}", r.render_human());
    }

    #[test]
    fn violation_mapping_keeps_location() {
        let m = PottsGrid::new(3, 3, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let mut p = Program::default();
        let mut i = Instr::nop();
        i.sem = Semantics::UpdateRvs(vec![0, 1]); // grid neighbors
        p.body.push(i);
        let r = analyze_program(&p, &m, &hw, false);
        assert!(r.has_errors());
        assert!(r
            .diagnostics
            .iter()
            .any(|d| d.code == DiagCode::DependentParallelUpdate));
    }

    #[test]
    fn report_rendering_roundtrips_code_and_severity() {
        let mut r = Report::new();
        r.push(Diagnostic::new(DiagCode::RawHazard, "x \"quoted\"").at_instr(7));
        let human = r.render_human();
        assert!(human.contains("error MC2A012") && human.contains("[instr 7]"), "{human}");
        let json = r.to_json();
        assert!(
            json.contains("\"code\":\"MC2A012\"") && json.contains("\\\"quoted\\\""),
            "{json}"
        );
        assert_eq!(r.count(Severity::Error), 1);
        assert_eq!(r.errors().len(), 1);
    }

    #[test]
    fn sampler_lut_mismatch_warns() {
        let hw = HwConfig::paper_default();
        let ok = analyze_sampler(
            SamplerKind::GumbelLut { size: hw.lut_size, bits: hw.lut_bits },
            &hw,
        );
        assert!(ok.diagnostics.is_empty());
        let bad = analyze_sampler(SamplerKind::GumbelLut { size: 64, bits: 12 }, &hw);
        assert_eq!(bad.count(Severity::Warning), 1);
        assert!(!bad.has_errors());
    }

    #[test]
    fn gate_rejects_corrupted_program() {
        let m = PottsGrid::new(4, 4, 2, 1.0);
        let hw = HwConfig::paper_default();
        let mut p = compile(&m, crate::mcmc::AlgoKind::BlockGibbs, &hw, 1).unwrap();
        // Corrupt one route to an out-of-range bank.
        for i in &mut p.body {
            if let Some(r) = i.routes.first_mut() {
                r.rf_bank = 9999;
                break;
            }
        }
        match gate_program(&p, &m, &hw, crate::mcmc::AlgoKind::BlockGibbs) {
            Err(Mc2aError::InvalidProgram { diagnostics }) => {
                assert!(diagnostics.iter().any(|d| d.code == DiagCode::RouteOutOfRange));
            }
            other => panic!("expected InvalidProgram, got {other:?}"),
        }
    }
}
