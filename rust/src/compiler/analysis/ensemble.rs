//! Cross-shard multicore analysis: run the per-program checks on every
//! [`compile_shard`] output, then verify the *ensemble* invariants the
//! multi-core simulator's correctness rests on — synchronization
//! rounds align across all cores, every RV has exactly one owning
//! writer core, no two cores update Markov-blanket neighbors inside
//! one round, and the boundary traffic is consistent with the
//! [`MultiHwConfig`] crossbar-bandwidth assumptions.

use super::{analyze_program, DiagCode, Diagnostic, Report};
use crate::compiler::compile_shard;
use crate::energy::EnergyModel;
use crate::engine::error::Mc2aError;
use crate::graph::partition_balanced;
use crate::isa::{MultiHwConfig, Program, Semantics};
use crate::mcmc::AlgoKind;
use crate::sim::multicore::validate_shard_config;

/// Cap on per-instance error diagnostics of one kind.
const MAX_INSTANCES: usize = 8;

/// One core's compiled shard, as the ensemble analysis sees it.
#[derive(Clone, Debug)]
pub struct ShardProgram {
    /// Core id (partition index).
    pub core: usize,
    /// RV ids this core owns (ascending).
    pub owned: Vec<u32>,
    /// The shard's VLIW program.
    pub program: Program,
    /// Body index just past each synchronization round.
    pub marks: Vec<usize>,
}

/// Compile and analyze the full shard ensemble for `model` × `algo` on
/// `mhw` — the same partition and shard compiler the multi-core
/// simulator uses, so the verdict applies to exactly the programs that
/// would run.
///
/// Returns `Err` only when the ensemble cannot be *built* (invalid
/// hardware, an unshardable algorithm/core-count combination);
/// program-level findings land in the returned [`Report`].
pub fn analyze_ensemble(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    mhw: &MultiHwConfig,
    pas_flips: usize,
) -> Result<Report, Mc2aError> {
    analyze_ensemble_mutated(model, algo, mhw, pas_flips, None)
}

/// [`analyze_ensemble`] with a test-only hook that corrupts each shard
/// program before analysis (how the integration tests force the gates
/// to fire on otherwise-clean compiler output).
#[doc(hidden)]
pub fn analyze_ensemble_mutated(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    mhw: &MultiHwConfig,
    pas_flips: usize,
    mutate: Option<fn(&mut Program)>,
) -> Result<Report, Mc2aError> {
    mhw.validate().map_err(Mc2aError::InvalidHardware)?;
    validate_shard_config(model.num_vars(), algo, mhw.cores).map_err(Mc2aError::InvalidConfig)?;
    let partition = partition_balanced(model.interaction(), mhw.cores);
    let mut shards = Vec::with_capacity(mhw.cores);
    for (core, owned) in partition.parts().into_iter().enumerate() {
        let (mut program, marks) = compile_shard(model, algo, &mhw.core, pas_flips, &owned, true)?;
        if let Some(f) = mutate {
            f(&mut program);
        }
        shards.push(ShardProgram { core, owned, program, marks });
    }
    let mut report = Report::new();
    for sh in &shards {
        // Coverage is an ensemble property (each shard updates only its
        // own RVs), so per-program coverage is off here.
        let mut r = analyze_program(&sh.program, model, &mhw.core, false);
        r.tag_core(sh.core);
        report.merge(r);
    }
    analyze_shards(&shards, model, mhw, algo, &mut report);
    Ok(report)
}

/// The ensemble-level invariants over already-compiled shards.
pub fn analyze_shards(
    shards: &[ShardProgram],
    model: &dyn EnergyModel,
    mhw: &MultiHwConfig,
    algo: AlgoKind,
    report: &mut Report,
) {
    if shards.is_empty() {
        return;
    }
    // --- Barrier/round alignment: every core must see the same global
    // color classes, i.e. the same number of synchronization rounds.
    let rounds = shards[0].marks.len();
    for sh in &shards[1..] {
        if sh.marks.len() != rounds {
            let mut d = Diagnostic::new(
                DiagCode::RoundMisalignment,
                format!(
                    "core {} schedules {} synchronization rounds but core {} schedules {} — \
                     barriers would deadlock or skew",
                    shards[0].core,
                    rounds,
                    sh.core,
                    sh.marks.len()
                ),
            );
            d.core = Some(sh.core);
            report.push(d);
        }
    }

    // --- Ownership and coverage: each core updates only RVs it owns,
    // and (Gibbs-family) every RV is updated exactly once per iteration
    // across the whole ensemble.
    let n = model.num_vars();
    let mut owner = vec![usize::MAX; n];
    for sh in shards {
        for &rv in &sh.owned {
            owner[rv as usize] = sh.core;
        }
    }
    let mut counts = vec![0u32; n];
    let mut foreign = 0usize;
    for sh in shards {
        for instr in sh.program.prologue.iter().chain(&sh.program.body) {
            if let Semantics::UpdateRvs(rvs) = &instr.sem {
                for &rv in rvs {
                    counts[rv as usize] += 1;
                    if owner[rv as usize] != sh.core {
                        foreign += 1;
                        if foreign <= MAX_INSTANCES {
                            let mut d = Diagnostic::new(
                                DiagCode::OwnershipViolation,
                                format!(
                                    "core {} writes RV {rv}, which core {} owns (every \
                                     boundary RV needs exactly one writer core)",
                                    sh.core, owner[rv as usize] as isize
                                ),
                            );
                            d.core = Some(sh.core);
                            report.push(d);
                        }
                    }
                }
            }
        }
    }
    if foreign > MAX_INSTANCES {
        report.push(Diagnostic::new(
            DiagCode::OwnershipViolation,
            format!("... and {} more foreign-RV writes", foreign - MAX_INSTANCES),
        ));
    }
    if super::algo_expects_full_coverage(algo) {
        let mut bad = 0usize;
        for (rv, &c) in counts.iter().enumerate() {
            if c != 1 {
                bad += 1;
                if bad <= MAX_INSTANCES {
                    report.push(Diagnostic::new(
                        DiagCode::BadUpdateCoverage,
                        format!(
                            "RV {rv} updated {c} times per iteration across all cores \
                             (want exactly 1)"
                        ),
                    ));
                }
            }
        }
        if bad > MAX_INSTANCES {
            report.push(Diagnostic::new(
                DiagCode::BadUpdateCoverage,
                format!("... and {} more mis-covered RVs", bad - MAX_INSTANCES),
            ));
        }
    }

    // --- Race freedom per synchronization round: the union of updates
    // committed by all cores inside one round must be an independent
    // set of the interaction graph. (Async/snapshot programs read stale
    // values by design; their hazard window is measured per program by
    // the chromatic family instead.)
    let is_async = shards.iter().any(|sh| {
        sh.program
            .prologue
            .iter()
            .chain(&sh.program.body)
            .any(|i| matches!(i.sem, Semantics::Snapshot))
    });
    if !is_async {
        let g = model.interaction();
        let mut races = 0usize;
        let mut updated_by: Vec<usize> = vec![usize::MAX; n];
        for round in 0..rounds {
            // Gather (rv -> core) for this round across cores.
            let mut members: Vec<u32> = Vec::new();
            for sh in shards {
                if round >= sh.marks.len() {
                    continue; // misaligned cores already reported
                }
                let start = if round == 0 { 0 } else { sh.marks[round - 1] };
                let end = sh.marks[round];
                for instr in &sh.program.body[start.min(end)..end] {
                    if let Semantics::UpdateRvs(rvs) = &instr.sem {
                        for &rv in rvs {
                            updated_by[rv as usize] = sh.core;
                            members.push(rv);
                        }
                    }
                }
            }
            for &rv in &members {
                for &nb in g.neighbors(rv as usize) {
                    if nb > rv && updated_by[nb as usize] != usize::MAX {
                        races += 1;
                        if races <= MAX_INSTANCES {
                            report.push(Diagnostic::new(
                                DiagCode::CrossCoreRace,
                                format!(
                                    "round {round}: RVs {rv} (core {}) and {nb} (core {}) \
                                     are blanket neighbors updated in the same round",
                                    updated_by[rv as usize], updated_by[nb as usize]
                                ),
                            ));
                        }
                    }
                }
            }
            for &rv in &members {
                updated_by[rv as usize] = usize::MAX;
            }
        }
        if races > MAX_INSTANCES {
            report.push(Diagnostic::new(
                DiagCode::CrossCoreRace,
                format!("... and {} more same-round dependent pairs", races - MAX_INSTANCES),
            ));
        }
    }

    // --- Crossbar-bandwidth consistency: per round, every core
    // broadcasts the boundary RVs it updated; the round cannot retire
    // faster than (words / crossbar bandwidth) + the barrier latency.
    // Compare against the longest per-core instruction stream to flag
    // interconnect-bound schedules.
    if mhw.cores > 1 {
        let g = model.interaction();
        let boundary = {
            // A RV is boundary iff any neighbor lives on another core.
            let mut owner_of = vec![usize::MAX; n];
            for sh in shards {
                for &rv in &sh.owned {
                    owner_of[rv as usize] = sh.core;
                }
            }
            (0..n)
                .map(|v| g.neighbors(v).iter().any(|&u| owner_of[u as usize] != owner_of[v]))
                .collect::<Vec<bool>>()
        };
        let mut total_words = 0u64;
        let mut xbar_cycles = 0u64;
        let mut compute_cycles = 0u64;
        for round in 0..rounds {
            let mut round_words = 0u64;
            let mut longest = 0u64;
            for sh in shards {
                if round >= sh.marks.len() {
                    continue;
                }
                let start = if round == 0 { 0 } else { sh.marks[round - 1] };
                let end = sh.marks[round];
                longest = longest.max((end - start.min(end)) as u64);
                for instr in &sh.program.body[start.min(end)..end] {
                    if let Semantics::UpdateRvs(rvs) = &instr.sem {
                        round_words +=
                            rvs.iter().filter(|&&rv| boundary[rv as usize]).count() as u64;
                    }
                }
            }
            total_words += round_words;
            xbar_cycles +=
                round_words.div_ceil(mhw.xbar_words_per_cycle as u64) + mhw.sync_latency as u64;
            compute_cycles += longest;
        }
        let cut = shards
            .iter()
            .flat_map(|sh| sh.owned.iter())
            .filter(|&&rv| boundary[rv as usize])
            .count();
        report.push(Diagnostic::new(
            DiagCode::EnsembleTraffic,
            format!(
                "{} cores, {rounds} rounds/iteration: {total_words} boundary words/iteration \
                 over a {}-word/cycle crossbar ({cut}/{n} boundary RVs), \
                 ~{xbar_cycles} interconnect vs ~{compute_cycles} compute cycles",
                mhw.cores, mhw.xbar_words_per_cycle
            ),
        ));
        if xbar_cycles > compute_cycles {
            report.push(Diagnostic::new(
                DiagCode::XbarSyncBound,
                format!(
                    "estimated interconnect time ({xbar_cycles} cycles/iteration) exceeds \
                     compute time ({compute_cycles}); the ensemble is crossbar/barrier-bound \
                     — widen xbar_words_per_cycle or cut the boundary"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;
    use crate::isa::HwConfig;

    fn mhw(cores: usize) -> MultiHwConfig {
        MultiHwConfig::new(HwConfig::paper_default(), cores)
    }

    #[test]
    fn clean_ensembles_for_bg_and_ag() {
        let m = PottsGrid::new(8, 8, 3, 1.0);
        for algo in [AlgoKind::BlockGibbs, AlgoKind::AsyncGibbs] {
            for cores in [1, 2, 4] {
                let r = analyze_ensemble(&m, algo, &mhw(cores), 1).unwrap();
                assert!(!r.has_errors(), "{algo:?} x{cores}: {}", r.render_human());
            }
        }
    }

    #[test]
    fn unshardable_configs_are_typed_errors() {
        let m = PottsGrid::new(4, 4, 2, 1.0);
        assert!(matches!(
            analyze_ensemble(&m, AlgoKind::Pas, &mhw(2), 4),
            Err(Mc2aError::InvalidConfig(_))
        ));
        let mut bad = mhw(2);
        bad.core.s = 48; // not 2^M
        assert!(matches!(
            analyze_ensemble(&m, AlgoKind::BlockGibbs, &bad, 1),
            Err(Mc2aError::InvalidHardware(_))
        ));
    }

    #[test]
    fn foreign_write_and_race_detected() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        // Corrupt every shard: commit an update to RVs 0 and 1 (grid
        // neighbors, and RV 0/1 cannot be owned by every core).
        let r = analyze_ensemble_mutated(
            &m,
            AlgoKind::BlockGibbs,
            &mhw(2),
            1,
            Some(|p: &mut Program| {
                let mut i = crate::isa::Instr::nop();
                i.sem = Semantics::UpdateRvs(vec![0, 1]);
                p.body.push(i);
            }),
        )
        .unwrap();
        assert!(r.diagnostics.iter().any(|d| d.code == DiagCode::OwnershipViolation));
        assert!(r.diagnostics.iter().any(|d| d.code == DiagCode::BadUpdateCoverage));
        assert!(r.has_errors());
    }

    #[test]
    fn round_misalignment_detected() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let partition = partition_balanced(m.interaction(), 2);
        let hw = HwConfig::paper_default();
        let mut shards = Vec::new();
        for (core, owned) in partition.parts().into_iter().enumerate() {
            let (program, mut marks) =
                compile_shard(&m, AlgoKind::BlockGibbs, &hw, 1, &owned, true).unwrap();
            if core == 1 {
                marks.pop(); // drop a round on one core only
            }
            shards.push(ShardProgram { core, owned, program, marks });
        }
        let mut report = Report::new();
        analyze_shards(&shards, &m, &mhw(2), AlgoKind::BlockGibbs, &mut report);
        assert!(report.diagnostics.iter().any(|d| d.code == DiagCode::RoundMisalignment));
    }

    #[test]
    fn tiny_crossbar_flags_sync_bound() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let mut cfg = mhw(4);
        cfg.xbar_words_per_cycle = 1;
        cfg.sync_latency = 64;
        let r = analyze_ensemble(&m, AlgoKind::BlockGibbs, &cfg, 1).unwrap();
        assert!(
            r.diagnostics.iter().any(|d| d.code == DiagCode::XbarSyncBound),
            "{}",
            r.render_human()
        );
        assert!(!r.has_errors());
    }
}
