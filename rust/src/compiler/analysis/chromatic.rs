//! Chromatic-parallelism race checks: the compiler's entire parallel
//! schedule rests on color classes being independent sets with respect
//! to the model's *Markov blanket*. The structural half re-verifies the
//! greedy coloring against the interaction graph; the functional half
//! probes `local_energies` directly — if a variable's conditional
//! depends on a same-color variable the interaction graph doesn't
//! declare, the declared graph under-approximates the true blanket and
//! every "independent" parallel update is a silent race.

use super::{DiagCode, Diagnostic, Report};
use crate::energy::EnergyModel;
use crate::graph::color_greedy;
use crate::isa::{Program, Semantics};
use crate::rng::Rng;

/// Cap on per-instance error diagnostics of one kind.
const MAX_INSTANCES: usize = 8;

/// Maximum same-color pairs exercised by the functional probe.
const PROBE_PAIRS: usize = 64;

/// Tolerance on normalized local-energy differences: conditional
/// distributions are invariant under a constant energy shift, so only
/// `e[s] - e[0]` changes are evidence of dependence.
const PROBE_TOL: f32 = 1e-4;

/// Analyze the model's chromatic schedule: structural independence of
/// every greedy color class, a functional hidden-dependence probe on
/// `local_energies`, and a coloring-quality summary.
pub fn analyze_chromatic(model: &dyn EnergyModel) -> Report {
    let mut report = Report::new();
    let g = model.interaction();
    let coloring = color_greedy(g);

    // --- Structural: each color class must be an independent set.
    let mut improper = 0usize;
    for v in 0..g.num_nodes() {
        for &u in g.neighbors(v) {
            if (u as usize) > v && coloring.color[v] == coloring.color[u as usize] {
                improper += 1;
                if improper <= MAX_INSTANCES {
                    report.push(Diagnostic::new(
                        DiagCode::ImproperColoring,
                        format!(
                            "interaction-graph neighbors {v} and {u} share color {} — their \
                             parallel updates race",
                            coloring.color[v]
                        ),
                    ));
                }
            }
        }
    }
    if improper > MAX_INSTANCES {
        report.push(Diagnostic::new(
            DiagCode::ImproperColoring,
            format!("... and {} more same-color edges", improper - MAX_INSTANCES),
        ));
    }

    // --- Functional: perturb a same-color, non-adjacent variable b and
    // require variable a's normalized conditional energies to be
    // unchanged. Deterministic: the probe seed derives from the model
    // shape only.
    let n = model.num_vars();
    if n >= 2 {
        let mut rng = Rng::new(0x5EED_C0DE ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut x: Vec<u32> = (0..n)
            .map(|i| rng.below(model.num_states(i).max(1)) as u32)
            .collect();
        let blocks = coloring.blocks();
        let mut base = Vec::new();
        let mut perturbed = Vec::new();
        let mut hidden = 0usize;
        let mut probes = 0usize;
        'outer: for block in &blocks {
            if block.len() < 2 {
                continue;
            }
            for w in 0..block.len().min(9) - 1 {
                let a = block[w] as usize;
                let b = block[w + 1] as usize;
                debug_assert_ne!(a, b);
                if g.has_edge(a, b) || model.num_states(b) < 2 {
                    continue; // adjacency already reported structurally
                }
                probes += 1;
                model.local_energies(&x, a, &mut base);
                let old = x[b];
                x[b] = (old + 1) % model.num_states(b) as u32;
                model.local_energies(&x, a, &mut perturbed);
                x[b] = old;
                let drifted = base.len() != perturbed.len()
                    || base.iter().zip(&perturbed).any(|(&e0, &e1)| {
                        ((e0 - base[0]) - (e1 - perturbed[0])).abs() > PROBE_TOL
                    });
                if drifted {
                    hidden += 1;
                    if hidden <= MAX_INSTANCES {
                        report.push(Diagnostic::new(
                            DiagCode::HiddenDependence,
                            format!(
                                "local_energies({a}) changed when same-color non-neighbor {b} \
                                 was perturbed — the interaction graph under-approximates the \
                                 Markov blanket, so the chromatic schedule races"
                            ),
                        ));
                    }
                }
                if probes >= PROBE_PAIRS {
                    break 'outer;
                }
            }
        }
        if hidden > MAX_INSTANCES {
            report.push(Diagnostic::new(
                DiagCode::HiddenDependence,
                format!("... and {} more hidden dependencies", hidden - MAX_INSTANCES),
            ));
        }
    }

    report.push(Diagnostic::new(
        DiagCode::ColoringSummary,
        format!(
            "{} colors over {} RVs / {} edges (greedy bound is max-degree+1 = {}); largest \
             class {} RVs",
            coloring.num_colors,
            g.num_nodes(),
            g.num_edges(),
            g.max_degree() + 1,
            coloring.blocks().iter().map(|b| b.len()).max().unwrap_or(0),
        ),
    ));
    report
}

/// Measure the Async-Gibbs hazard window of a snapshot program: every
/// interaction edge whose *both* endpoints are updated from one
/// snapshot reads a stale neighbor value for part of the iteration.
/// That staleness is the algorithm's documented trade-off, so this is a
/// warning sized for the user, not an error.
pub fn async_hazard_window(program: &Program, model: &dyn EnergyModel, report: &mut Report) {
    let instrs = || program.prologue.iter().chain(&program.body);
    if !instrs().any(|i| matches!(i.sem, Semantics::Snapshot)) {
        return;
    }
    let mut updated = vec![false; model.num_vars()];
    for instr in instrs() {
        if let Semantics::UpdateRvs(rvs) = &instr.sem {
            for &rv in rvs {
                if let Some(slot) = updated.get_mut(rv as usize) {
                    *slot = true;
                }
            }
        }
    }
    let g = model.interaction();
    let mut stale = 0usize;
    for v in 0..g.num_nodes() {
        if !updated[v] {
            continue;
        }
        stale += g
            .neighbors(v)
            .iter()
            .filter(|&&u| (u as usize) > v && updated[u as usize])
            .count();
    }
    if stale > 0 {
        report.push(Diagnostic::new(
            DiagCode::AsyncHazardWindow,
            format!(
                "async (snapshot) program: {stale} of {} interaction edges update both \
                 endpoints from one snapshot — those reads see values up to one iteration \
                 stale (Async-Gibbs semantics, not an error)",
                g.num_edges()
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::energy::{MaxCutModel, PottsGrid};
    use crate::graph::Graph;
    use crate::isa::HwConfig;
    use crate::mcmc::AlgoKind;

    #[test]
    fn registry_style_models_are_chromatically_clean() {
        let potts = PottsGrid::new(8, 8, 3, 1.0);
        let r = analyze_chromatic(&potts);
        assert!(!r.has_errors(), "{}", r.render_human());
        assert!(r.diagnostics.iter().any(|d| d.code == DiagCode::ColoringSummary));
    }

    /// A model whose `local_energies` secretly reads a variable the
    /// interaction graph does not declare.
    struct LyingModel {
        g: Graph,
    }

    impl EnergyModel for LyingModel {
        fn num_vars(&self) -> usize {
            4
        }
        fn num_states(&self, _i: usize) -> usize {
            2
        }
        fn interaction(&self) -> &Graph {
            &self.g
        }
        fn neighbor_words(&self, _i: usize) -> usize {
            1
        }
        fn param_words_per_state(&self, _i: usize) -> usize {
            0
        }
        fn local_energies(&self, x: &[u32], i: usize, out: &mut Vec<f32>) {
            out.clear();
            for s in 0..2u32 {
                // Undeclared coupling: everything interacts with x[3].
                let hidden = if i != 3 { (s ^ x[3]) as f32 } else { 0.0 };
                out.push(s as f32 * 0.25 + hidden);
            }
        }
        fn energy(&self, _x: &[u32]) -> f64 {
            0.0
        }
    }

    #[test]
    fn hidden_dependence_is_detected() {
        // Declared graph: a path 0-1-2, node 3 isolated (a lie).
        let m = LyingModel { g: Graph::from_edges(4, &[(0, 1), (1, 2)], None) };
        let r = analyze_chromatic(&m);
        assert!(
            r.diagnostics.iter().any(|d| d.code == DiagCode::HiddenDependence),
            "{}",
            r.render_human()
        );
        assert!(r.has_errors());
    }

    #[test]
    fn honest_cop_model_passes_probe() {
        // Ring of 16 nodes plus a few chords: 2 colors won't suffice,
        // so same-color non-neighbor probe pairs exist.
        let mut edges: Vec<(u32, u32)> = (0..16u32).map(|v| (v, (v + 1) % 16)).collect();
        edges.push((0, 5));
        edges.push((3, 11));
        let m = MaxCutModel::new(Graph::from_edges(16, &edges, None), None);
        let r = analyze_chromatic(&m);
        assert!(!r.has_errors(), "{}", r.render_human());
    }

    #[test]
    fn async_program_warns_with_hazard_size() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let hw = HwConfig::paper_default();
        let p = compile(&m, AlgoKind::AsyncGibbs, &hw, 1).unwrap();
        let mut r = Report::new();
        async_hazard_window(&p, &m, &mut r);
        assert!(
            r.diagnostics.iter().any(|d| d.code == DiagCode::AsyncHazardWindow),
            "{}",
            r.render_human()
        );
        assert!(!r.has_errors());

        // Synchronous programs carry no snapshot and no warning.
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let mut r = Report::new();
        async_hazard_window(&p, &m, &mut r);
        assert!(r.diagnostics.is_empty());
    }
}
