//! Static program validation: the invariants the compiler must uphold
//! so the hardware executes hazard- and conflict-free. Exercised
//! directly by the property-based test-suite (`prop_invariants`).

use crate::energy::EnergyModel;
use crate::isa::{HwConfig, Program, Semantics};

/// A violated program invariant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Two RVs in one `UpdateRvs` commit are Markov-blanket neighbors.
    DependentParallelUpdate {
        /// First RV.
        a: u32,
        /// Second RV.
        b: u32,
    },
    /// A Load instruction exceeds the memory-bandwidth budget.
    BandwidthExceeded {
        /// Instruction index within the body.
        at: usize,
        /// Words requested.
        words: usize,
    },
    /// Two load slots write the same RF bank in one instruction.
    WritePortConflict {
        /// Instruction index.
        at: usize,
        /// Conflicting bank.
        bank: u16,
    },
    /// A crossbar route references an out-of-range resource.
    RouteOutOfRange {
        /// Instruction index.
        at: usize,
    },
    /// An RV is updated more than once (or never) in one iteration of a
    /// Gibbs-family program.
    BadUpdateCoverage {
        /// RV id.
        rv: u32,
        /// Times updated.
        count: u32,
    },
    /// An SU control names more lanes than exist.
    SuLanesOutOfRange {
        /// Instruction index.
        at: usize,
    },
    /// A CU control names more lanes than exist.
    CuLanesOutOfRange {
        /// Instruction index.
        at: usize,
    },
}

/// Validate a compiled program against the hardware config and, when
/// `expect_full_coverage`, against the model's update-coverage
/// requirement (every free RV exactly once per iteration).
pub fn validate_program(
    program: &Program,
    model: &dyn EnergyModel,
    hw: &HwConfig,
    expect_full_coverage: bool,
) -> Vec<Violation> {
    let mut violations = Vec::new();
    let g = model.interaction();
    let mut update_counts = vec![0u32; model.num_vars()];
    // Async (hogwild) programs snapshot the state first; their commits
    // read stale values, so dependent parallel updates are the
    // *algorithm's* semantics, not a compiler hazard.
    let is_async = program
        .prologue
        .iter()
        .chain(&program.body)
        .any(|i| matches!(i.sem, Semantics::Snapshot));

    for (at, instr) in program.prologue.iter().chain(&program.body).enumerate() {
        // Bandwidth budget.
        if instr.loads.len() > hw.bw_words {
            violations.push(Violation::BandwidthExceeded {
                at,
                words: instr.loads.len(),
            });
        }
        // One row-wide write per bank per instruction (RF banks have
        // 2^K-word row write ports).
        let row_w = 1u16 << hw.k;
        let mut bank_rows: std::collections::HashMap<u16, u16> = std::collections::HashMap::new();
        for l in &instr.loads {
            let row = l.rf_reg / row_w;
            match bank_rows.get(&l.rf_bank) {
                Some(&r) if r != row => {
                    violations.push(Violation::WritePortConflict { at, bank: l.rf_bank });
                }
                _ => {
                    bank_rows.insert(l.rf_bank, row);
                }
            }
        }
        // Route ranges.
        for r in &instr.routes {
            if r.rf_bank as usize >= hw.rf_banks
                || r.rf_reg as usize >= hw.rf_regs_per_bank
                || r.cu as usize >= hw.t
                || r.port as usize >= (1 << hw.k)
            {
                violations.push(Violation::RouteOutOfRange { at });
            }
        }
        // Lane ranges.
        if let Some(cu) = &instr.cu {
            if cu.lanes as usize > hw.t {
                violations.push(Violation::CuLanesOutOfRange { at });
            }
        }
        if let Some(su) = &instr.su {
            if su.lanes as usize > hw.s {
                violations.push(Violation::SuLanesOutOfRange { at });
            }
        }
        // Parallel-update independence (skipped for async programs).
        if let Semantics::UpdateRvs(rvs) = &instr.sem {
            for (i, &a) in rvs.iter().enumerate() {
                update_counts[a as usize] += 1;
                if is_async {
                    continue;
                }
                for &b in &rvs[i + 1..] {
                    if g.has_edge(a as usize, b as usize) {
                        violations.push(Violation::DependentParallelUpdate { a, b });
                    }
                }
            }
        }
    }

    if expect_full_coverage {
        for (rv, &count) in update_counts.iter().enumerate() {
            if count != 1 {
                violations.push(Violation::BadUpdateCoverage {
                    rv: rv as u32,
                    count,
                });
            }
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::energy::PottsGrid;
    use crate::isa::Instr;
    use crate::mcmc::AlgoKind;

    #[test]
    fn compiled_programs_are_clean() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        for hw in [HwConfig::fig10_toy(), HwConfig::paper_default()] {
            for algo in [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::AsyncGibbs] {
                let p = compile(&m, algo, &hw, 1).unwrap();
                let v = validate_program(&p, &m, &hw, true);
                assert!(v.is_empty(), "{algo:?} on {hw:?}: {v:?}");
            }
        }
    }

    #[test]
    fn detects_dependent_update() {
        let m = PottsGrid::new(3, 3, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let mut p = Program::default();
        let mut i = Instr::nop();
        // RVs 0 and 1 are grid neighbors — illegal parallel update.
        i.sem = Semantics::UpdateRvs(vec![0, 1]);
        p.body.push(i);
        let v = validate_program(&p, &m, &hw, false);
        assert!(matches!(
            v[0],
            Violation::DependentParallelUpdate { a: 0, b: 1 }
        ));
    }

    #[test]
    fn detects_missing_coverage() {
        let m = PottsGrid::new(2, 2, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let p = Program::default(); // updates nothing
        let v = validate_program(&p, &m, &hw, true);
        assert_eq!(v.len(), 4);
        assert!(matches!(v[0], Violation::BadUpdateCoverage { count: 0, .. }));
    }
}
