//! The MC²A scheduling compiler.
//!
//! Lowers a *(workload, algorithm, hardware config)* triple to a VLIW
//! [`Program`]: it extracts RV-level parallelism (graph coloring for
//! Block Gibbs, chessboard on grids), maps update groups onto the
//! `T`-lane CU / `S`-lane SU arrays, allocates operands across the
//! multi-bank register file to avoid read/write conflicts, batches
//! memory traffic under the `B` words/cycle budget, schedules
//! multi-cycle `Compute`/`Sample` phases for distributions that exceed
//! the PE tree or SU width, and inserts the NOPs that resolve the
//! store→load hazard between dependent blocks (§V-B, §V-E).

pub mod analysis;
mod validate;

pub use validate::{validate_program, Violation};

use crate::energy::EnergyModel;
use crate::engine::error::Mc2aError;
use crate::graph::color_greedy;
use crate::isa::{
    CtrlType, CuCtrl, CuMode, HwConfig, Instr, LoadSlot, MemSpace, Program, Semantics, StoreSlot,
    SuCtrl, SuMode, XbarRoute,
};
use crate::mcmc::AlgoKind;

/// Compile `algo` over `model` for `hw`, with the VLIW load/compute
/// fusion optimization enabled (the production path).
///
/// `pas_flips` is the PAS path length L (ignored for other algorithms).
///
/// Fails with [`Mc2aError::InvalidHardware`] when `hw` is inconsistent,
/// so bad CLI hardware flags surface as typed errors, not panics.
pub fn compile(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    hw: &HwConfig,
    pas_flips: usize,
) -> Result<Program, Mc2aError> {
    compile_opt(model, algo, hw, pas_flips, true)
}

/// [`compile`] with the optimizer switchable — `optimize = false` keeps
/// the naive one-phase-per-instruction schedule (the EXPERIMENTS.md
/// §Perf "before" baseline and the ablation bench).
pub fn compile_opt(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    hw: &HwConfig,
    pas_flips: usize,
    optimize: bool,
) -> Result<Program, Mc2aError> {
    hw.validate().map_err(Mc2aError::InvalidHardware)?;
    let c = Compiler::new(model, hw);
    let (mut program, _marks) = dispatch(c, algo, pas_flips);
    if optimize {
        program.body = fuse_loads(program.body, hw);
    }
    Ok(program)
}

/// Compile the schedule for one *shard* of a multi-core system: only
/// the RVs in `owned` are scheduled, but the group structure (the
/// full-graph color classes for Block Gibbs) is preserved, so every
/// core's program has the same synchronization rounds. Returns the
/// program plus the per-round segment boundaries — `marks[s]` is the
/// body index just past round `s`'s instructions (ascending, last
/// equals `body.len()`); the multi-core simulator barriers cores at
/// these points.
///
/// With `owned` covering every RV the emitted program is identical to
/// [`compile_opt`]: load fusion never crosses the drain NOPs that end
/// each round, so per-segment fusion equals whole-body fusion.
///
/// PAS schedules a *global* move table and therefore cannot be
/// sharded; for `AlgoKind::Pas` the mask is ignored and the full
/// single-core program is returned as one segment (the multi-core
/// backend only accepts PAS at C = 1).
pub fn compile_shard(
    model: &dyn EnergyModel,
    algo: AlgoKind,
    hw: &HwConfig,
    pas_flips: usize,
    owned: &[u32],
    optimize: bool,
) -> Result<(Program, Vec<usize>), Mc2aError> {
    hw.validate().map_err(Mc2aError::InvalidHardware)?;
    let mut c = Compiler::new(model, hw);
    if !matches!(algo, AlgoKind::Pas) {
        let mut mask = vec![false; model.num_vars()];
        for &rv in owned {
            mask[rv as usize] = true;
        }
        c.owned = Some(mask);
    }
    let (mut program, mut marks) = dispatch(c, algo, pas_flips);
    if optimize {
        let (body, fused_marks) = fuse_segments(program.body, &marks, hw);
        program.body = body;
        marks = fused_marks;
    }
    Ok((program, marks))
}

fn dispatch(c: Compiler<'_>, algo: AlgoKind, pas_flips: usize) -> (Program, Vec<usize>) {
    match algo {
        AlgoKind::Gibbs | AlgoKind::Mh => c.compile_gibbs_family(false, true),
        AlgoKind::BlockGibbs => c.compile_gibbs_family(true, false),
        AlgoKind::AsyncGibbs => c.compile_async_gibbs(),
        AlgoKind::Pas => c.compile_pas(pas_flips.max(1)),
    }
}

/// [`fuse_loads`] applied independently within each segment, keeping
/// the segment boundaries valid after fusion shrinks the body.
fn fuse_segments(body: Vec<Instr>, marks: &[usize], hw: &HwConfig) -> (Vec<Instr>, Vec<usize>) {
    let mut out: Vec<Instr> = Vec::with_capacity(body.len());
    let mut new_marks = Vec::with_capacity(marks.len());
    let mut start = 0usize;
    for &end in marks {
        out.extend(fuse_loads(body[start..end].to_vec(), hw));
        new_marks.push(out.len());
        start = end;
    }
    (out, new_marks)
}

/// VLIW software pipelining: fold Load-only instructions into the
/// nearest preceding Compute/Sample bundle (Fig. 7/10 issue Load fields
/// and CU/SU fields in the *same* VLIW word — the naive schedule
/// serializes them).
///
/// Safety argument: a folded load belongs to the *next* RV group; its
/// destination rows come from the rotating row allocator (≥ 2 rows per
/// bank required), so it never clobbers operands the host bundle still
/// reads, and groups inside one color block are mutually non-adjacent,
/// so it never reads sample-memory words the host bundle's commit
/// writes. Fusion never crosses a NOP (pipeline drain = dependence
/// boundary).
fn fuse_loads(body: Vec<Instr>, hw: &HwConfig) -> Vec<Instr> {
    let rows_per_bank = hw.rf_regs_per_bank / (1 << hw.k);
    if rows_per_bank < 2 {
        return body; // single-buffered RF: fusion would clobber operands
    }
    let mut out: Vec<Instr> = Vec::with_capacity(body.len());
    for instr in body {
        let is_load_only = matches!(instr.ctrl, CtrlType::Load)
            && instr.cu.is_none()
            && instr.su.is_none()
            && instr.stores.is_empty();
        if is_load_only {
            if let Some(host) = out.last_mut() {
                let host_ok = !matches!(host.ctrl, CtrlType::Nop | CtrlType::Load)
                    && (host.cu.is_some() || host.su.is_some());
                if host_ok && host.loads.len() + instr.loads.len() <= hw.bw_words {
                    // one row-wide write port per bank per cycle
                    let row_w = (1u16) << hw.k;
                    let mut bank_row: std::collections::HashMap<u16, u16> = host
                        .loads
                        .iter()
                        .map(|l| (l.rf_bank, l.rf_reg / row_w))
                        .collect();
                    let compatible = instr.loads.iter().all(|l| {
                        let row = l.rf_reg / row_w;
                        match bank_row.get(&l.rf_bank) {
                            Some(&r) => r == row,
                            None => {
                                bank_row.insert(l.rf_bank, row);
                                true
                            }
                        }
                    });
                    if compatible {
                        host.loads.extend(instr.loads);
                        continue;
                    }
                }
            }
        }
        out.push(instr);
    }
    out
}

struct Compiler<'m> {
    model: &'m dyn EnergyModel,
    hw: HwConfig,
    body: Vec<Instr>,
    /// rotating register row cursor per bank
    reg_cursor: Vec<usize>,
    /// Shard mask for multi-core compilation: when set, only RVs with
    /// `owned[rv]` are scheduled (the group/round structure of the
    /// full model is kept so cores stay barrier-aligned).
    owned: Option<Vec<bool>>,
}

impl<'m> Compiler<'m> {
    fn new(model: &'m dyn EnergyModel, hw: &HwConfig) -> Compiler<'m> {
        Compiler {
            model,
            hw: *hw,
            body: Vec::new(),
            reg_cursor: vec![0; hw.rf_banks],
            owned: None,
        }
    }

    /// Apply the shard mask to one group/block of RVs.
    fn filter_owned(&self, rvs: &[u32]) -> Vec<u32> {
        match &self.owned {
            None => rvs.to_vec(),
            Some(mask) => rvs.iter().copied().filter(|&rv| mask[rv as usize]).collect(),
        }
    }

    /// Max RVs updated concurrently: bounded by the CU lanes, the SU
    /// lanes (temporal mode: one SE per RV) and the RF banks (each lane
    /// gets a home bank so its operand rows never conflict).
    fn group_width(&self) -> usize {
        self.hw.t.min(self.hw.s).min(self.hw.rf_banks)
    }

    /// Allocate the next register row in `bank` (wraps around; the
    /// streaming schedule never keeps more rows live than the RF holds).
    fn alloc_row(&mut self, bank: usize) -> u16 {
        let row_w = 1 << self.hw.k;
        let rows = (self.hw.rf_regs_per_bank / row_w).max(1);
        let r = self.reg_cursor[bank] % rows;
        self.reg_cursor[bank] += 1;
        (r * row_w) as u16
    }

    /// Emit Load instructions moving `words_per_lane` words for each
    /// lane of a group, spreading destination banks one-per-lane and
    /// batching at the memory-bandwidth budget. Returns each lane's
    /// (bank, reg-row) home.
    fn emit_group_loads(
        &mut self,
        lanes: &[u32],
        words_per_lane: usize,
        space: MemSpace,
        addr_of_lane: impl Fn(usize) -> u32,
    ) -> Vec<(u16, u16)> {
        let row_w = 1 << self.hw.k;
        let mut homes = Vec::with_capacity(lanes.len());
        let mut slots = Vec::new();
        for (lane_idx, _rv) in lanes.iter().enumerate() {
            let bank = lane_idx % self.hw.rf_banks;
            // A lane may need several rows when its operands exceed 2^K.
            let rows_needed = words_per_lane.div_ceil(row_w).max(1);
            let first_row = self.alloc_row(bank);
            for _ in 1..rows_needed {
                self.alloc_row(bank);
            }
            homes.push((bank as u16, first_row));
            for w in 0..words_per_lane {
                slots.push(LoadSlot {
                    mem: space,
                    addr: addr_of_lane(lane_idx).wrapping_add(w as u32),
                    rf_bank: bank as u16,
                    rf_reg: (first_row as usize + w) as u16
                        % self.hw.rf_regs_per_bank as u16,
                });
            }
        }
        // Greedy cycle packing: ≤ B words per Load instruction and at
        // most one *row* write per bank per instruction ("suppresses
        // register/memory conflicts"). RF banks have row-wide write
        // ports (2^K words), so a lane's whole operand tuple lands in
        // one write as long as it stays within one row.
        let row_of = |s: &LoadSlot| (s.rf_bank, s.rf_reg as usize / row_w);
        let mut by_cycle: Vec<Vec<LoadSlot>> = Vec::new();
        let mut rows_used: Vec<std::collections::HashMap<u16, (u16, usize)>> = Vec::new();
        for slot in slots {
            let (bank, row) = row_of(&slot);
            let mut placed = false;
            for (cyc, used) in rows_used.iter_mut().enumerate() {
                if by_cycle[cyc].len() >= self.hw.bw_words {
                    continue;
                }
                match used.get_mut(&bank) {
                    // same bank allowed only within the already-open row,
                    // up to the row width
                    Some((open_row, count)) if *open_row as usize == row && *count < row_w => {
                        *count += 1;
                        by_cycle[cyc].push(slot);
                        placed = true;
                        break;
                    }
                    Some(_) => continue,
                    None => {
                        used.insert(bank, (row as u16, 1));
                        by_cycle[cyc].push(slot);
                        placed = true;
                        break;
                    }
                }
            }
            if !placed {
                let mut map = std::collections::HashMap::new();
                map.insert(bank, (row as u16, 1));
                rows_used.push(map);
                by_cycle.push(vec![slot]);
            }
        }
        for loads in by_cycle {
            self.body.push(Instr {
                ctrl: CtrlType::Load,
                loads,
                routes: Vec::new(),
                cu: None,
                su: None,
                stores: Vec::new(),
                sem: Semantics::None,
            });
        }
        homes
    }

    /// Crossbar routes feeding each lane's PE from its home row.
    fn group_routes(&self, homes: &[(u16, u16)], words_per_lane: usize) -> Vec<XbarRoute> {
        let ports = 1 << self.hw.k;
        let mut routes = Vec::new();
        for (lane, &(bank, row)) in homes.iter().enumerate() {
            for p in 0..words_per_lane.min(ports) {
                routes.push(XbarRoute {
                    rf_bank: bank,
                    rf_reg: row,
                    cu: lane as u16,
                    port: p as u16,
                });
            }
        }
        routes
    }

    /// Pipeline-drain NOPs for the store→load dependency between
    /// successive dependent blocks.
    fn emit_drain(&mut self) {
        for _ in 0..self.hw.cu_latency() {
            self.body.push(Instr::nop());
        }
    }

    /// Schedule one conditionally-independent group of RVs: loads, the
    /// per-state Compute(-Sample) ladder, and the final store+commit.
    fn emit_group_update(&mut self, group: &[u32]) {
        let ports = 1 << self.hw.k;
        let max_card = group
            .iter()
            .map(|&rv| self.model.num_states(rv as usize))
            .max()
            .unwrap_or(2);
        let max_nbr_words = group
            .iter()
            .map(|&rv| self.model.neighbor_words(rv as usize))
            .max()
            .unwrap_or(0);
        let max_param_words = group
            .iter()
            .map(|&rv| self.model.param_words_per_state(rv as usize))
            .max()
            .unwrap_or(0);

        // Phase 1: neighbor/weight loads (state-independent operands).
        let homes = self.emit_group_loads(
            group,
            max_nbr_words.max(1),
            MemSpace::Sample,
            |lane| group[lane] * 4,
        );

        // Phase 2: per candidate state, optional per-state parameter
        // load (CPT/unary), partial-compute cycles when the operand
        // row exceeds the PE tree, then the pipelined Compute-Sample.
        for s in 0..max_card {
            if max_param_words > 0 {
                self.emit_group_loads(group, max_param_words, MemSpace::Input, |lane| {
                    group[lane] * 16 + s as u32
                });
            }
            let words = max_nbr_words + max_param_words;
            let partial_cycles = words.div_ceil(ports).max(1);
            for pc in 0..partial_cycles {
                let last_partial = pc + 1 == partial_cycles;
                let last_state = s + 1 == max_card;
                let ctrl = if !last_partial {
                    CtrlType::Compute
                } else if last_state {
                    CtrlType::ComputeSampleStore
                } else {
                    CtrlType::ComputeSample
                };
                let routes = self.group_routes(&homes, words.min(ports));
                let cu = Some(CuCtrl {
                    mode: if last_partial {
                        CuMode::ReducedSum
                    } else {
                        CuMode::Partial
                    },
                    lanes: group.len() as u16,
                    scale_beta: last_partial,
                    accumulate: pc > 0,
                });
                let su = last_partial.then_some(SuCtrl {
                    mode: SuMode::Temporal,
                    lanes: group.len() as u16,
                    dist_size: max_card as u16,
                    first: s == 0,
                    last: last_state,
                });
                let stores = if last_partial && last_state {
                    group
                        .iter()
                        .enumerate()
                        .map(|(lane, &rv)| StoreSlot {
                            mem: MemSpace::Sample,
                            addr: rv,
                            su_lane: lane as u16,
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                let sem = if last_partial && last_state {
                    Semantics::UpdateRvs(group.to_vec())
                } else {
                    Semantics::None
                };
                self.body.push(Instr {
                    ctrl,
                    loads: Vec::new(),
                    routes,
                    cu,
                    su,
                    stores,
                    sem,
                });
            }
        }
    }

    /// Gibbs-family schedule. `use_coloring` = Block Gibbs parallelism;
    /// otherwise sequential single-RV groups (Gibbs/MH). `drain_each` =
    /// drain after every group (sequential chains need it). Returns the
    /// program plus one segment mark per block — the multi-core
    /// synchronization rounds (a block owned entirely by other shards
    /// still yields a mark, with zero instructions, so every core sees
    /// the same round count).
    fn compile_gibbs_family(
        mut self,
        use_coloring: bool,
        drain_each: bool,
    ) -> (Program, Vec<usize>) {
        let n = self.model.num_vars();
        let blocks: Vec<Vec<u32>> = if use_coloring {
            color_greedy(self.model.interaction()).blocks()
        } else {
            (0..n as u32).map(|i| vec![i]).collect()
        };
        let width = self.group_width();
        let mut updates = 0u64;
        let mut marks = Vec::with_capacity(blocks.len());
        for block in &blocks {
            let mine = self.filter_owned(block);
            if !mine.is_empty() {
                for group in mine.chunks(width) {
                    self.emit_group_update(group);
                    updates += group.len() as u64;
                    if drain_each {
                        self.emit_drain();
                    }
                }
                if !drain_each {
                    self.emit_drain();
                }
            }
            marks.push(self.body.len());
        }
        let program = Program {
            prologue: Vec::new(),
            body: self.body,
            updates_per_iter: updates,
            samples_per_iter: updates,
            name: if use_coloring { "block-gibbs" } else { "gibbs" }.into(),
        };
        (program, marks)
    }

    /// Async Gibbs: snapshot, then all RVs in maximal groups with no
    /// inter-block drains (stale reads are the algorithm's semantics).
    /// One segment: cores exchange boundary state once per iteration.
    fn compile_async_gibbs(mut self) -> (Program, Vec<usize>) {
        let n = self.model.num_vars();
        let width = self.group_width();
        let mut snap = Instr::nop();
        snap.sem = Semantics::Snapshot;
        self.body.push(snap);
        let all = self.filter_owned(&(0..n as u32).collect::<Vec<u32>>());
        let mut updates = 0u64;
        for group in all.chunks(width) {
            self.emit_group_update(group);
            updates += group.len() as u64;
        }
        self.emit_drain();
        let marks = vec![self.body.len()];
        let program = Program {
            prologue: Vec::new(),
            body: self.body,
            updates_per_iter: updates,
            samples_per_iter: updates,
            name: "async-gibbs".into(),
        };
        (program, marks)
    }

    /// PAS schedule (Fig. 10c): multi-cycle ΔE Compute pass over all
    /// moves, spatial-mode Sample passes for the L indices, then L
    /// sequential conditional updates plus the MH energy check. The
    /// move table is global, so the schedule is always one segment.
    fn compile_pas(mut self, l: usize) -> (Program, Vec<usize>) {
        let n = self.model.num_vars();
        let ports = 1 << self.hw.k;
        let width = self.group_width();
        // Total move-table size (the "distribution ΔE" of Fig. 10c).
        let n_moves: usize = (0..n).map(|i| self.model.num_states(i)).sum();

        // Phase 1: ΔE over all vars, chunked across the T CU lanes.
        let all: Vec<u32> = (0..n as u32).collect();
        for chunk in all.chunks(width) {
            let max_words = chunk
                .iter()
                .map(|&rv| {
                    self.model.neighbor_words(rv as usize)
                        + self.model.param_words_per_state(rv as usize)
                })
                .max()
                .unwrap_or(1)
                .max(1);
            let homes =
                self.emit_group_loads(chunk, max_words, MemSpace::Sample, |lane| chunk[lane] * 4);
            let max_card = chunk
                .iter()
                .map(|&rv| self.model.num_states(rv as usize))
                .max()
                .unwrap_or(2);
            for s in 0..max_card {
                let partial_cycles = max_words.div_ceil(ports).max(1);
                for pc in 0..partial_cycles {
                    let last = pc + 1 == partial_cycles;
                    let routes = self.group_routes(&homes, max_words.min(ports));
                    // ΔE results stream to the distribution buffer.
                    let stores = if last {
                        chunk
                            .iter()
                            .enumerate()
                            .map(|(lane, &rv)| StoreSlot {
                                mem: MemSpace::Input,
                                addr: rv * 4 + s as u32,
                                su_lane: lane as u16,
                            })
                            .collect()
                    } else {
                        Vec::new()
                    };
                    self.body.push(Instr {
                        ctrl: CtrlType::Compute,
                        loads: Vec::new(),
                        routes,
                        cu: Some(CuCtrl {
                            mode: if last {
                                CuMode::ReducedSum
                            } else {
                                CuMode::Partial
                            },
                            lanes: chunk.len() as u16,
                            scale_beta: last,
                            accumulate: pc > 0,
                        }),
                        su: None,
                        stores,
                        sem: Semantics::None,
                    });
                }
            }
        }
        self.emit_drain();

        // Phase 2: L index samples from the size-n_moves distribution,
        // spatial mode: ceil(n_moves / S) passes of S bins each.
        let s_lanes = self.hw.s;
        let passes = n_moves.div_ceil(s_lanes);
        for sample_idx in 0..l {
            for p in 0..passes {
                let remaining = (n_moves - p * s_lanes).min(s_lanes);
                let last = p + 1 == passes;
                let stores = if last {
                    vec![StoreSlot {
                        mem: MemSpace::Sample,
                        addr: (n + sample_idx) as u32,
                        su_lane: 0,
                    }]
                } else {
                    Vec::new()
                };
                // Feed the SU from the distribution buffer. One load
                // slot per distinct RF bank per cycle (when the config
                // has fewer banks than SEs, the extra bins stream
                // through the direct memory→SU path, which has no RF
                // write-port constraint).
                let loads: Vec<LoadSlot> = (0..remaining
                    .min(self.hw.bw_words)
                    .min(self.hw.rf_banks))
                    .map(|w| LoadSlot {
                        mem: MemSpace::Input,
                        addr: (p * s_lanes + w) as u32,
                        rf_bank: w as u16,
                        rf_reg: 0,
                    })
                    .collect();
                self.body.push(Instr {
                    ctrl: CtrlType::Sample,
                    loads,
                    routes: Vec::new(),
                    cu: None,
                    su: Some(SuCtrl {
                        mode: SuMode::Spatial,
                        lanes: s_lanes as u16,
                        dist_size: remaining as u16,
                        first: p == 0,
                        last,
                    }),
                    stores,
                    sem: Semantics::None,
                });
            }
        }
        self.emit_drain();

        // Phase 3: L sequential conditional updates (each like a
        // single-RV Gibbs update) + the MH energy comparison.
        for flip in 0..l {
            let rv = (flip % n) as u32; // representative lane; timing-equivalent
            let words = self.model.neighbor_words(rv as usize).max(1)
                + self.model.param_words_per_state(rv as usize);
            let homes = self.emit_group_loads(&[rv], words, MemSpace::Sample, |_| rv * 4);
            let card = self.model.num_states(rv as usize);
            for s in 0..card {
                let last_state = s + 1 == card;
                let routes = self.group_routes(&homes, words.min(ports));
                self.body.push(Instr {
                    ctrl: if last_state {
                        CtrlType::ComputeSampleStore
                    } else {
                        CtrlType::ComputeSample
                    },
                    loads: Vec::new(),
                    routes,
                    cu: Some(CuCtrl {
                        mode: CuMode::ReducedSum,
                        lanes: 1,
                        scale_beta: true,
                        accumulate: false,
                    }),
                    su: Some(SuCtrl {
                        mode: SuMode::Temporal,
                        lanes: 1,
                        dist_size: card as u16,
                        first: s == 0,
                        last: last_state,
                    }),
                    stores: if last_state {
                        vec![StoreSlot {
                            mem: MemSpace::Sample,
                            addr: rv,
                            su_lane: 0,
                        }]
                    } else {
                        Vec::new()
                    },
                    sem: Semantics::None,
                });
            }
            self.emit_drain();
        }
        // MH acceptance: two-term energy comparison + commit; the
        // commit instruction carries the functional PasIterate.
        self.body.push(Instr {
            ctrl: CtrlType::ComputeSampleStore,
            loads: Vec::new(),
            routes: Vec::new(),
            cu: Some(CuCtrl {
                mode: CuMode::ReducedSum,
                lanes: 1,
                scale_beta: true,
                accumulate: false,
            }),
            su: Some(SuCtrl {
                mode: SuMode::Temporal,
                lanes: 1,
                dist_size: 2,
                first: true,
                last: true,
            }),
            stores: vec![StoreSlot {
                mem: MemSpace::Histogram,
                addr: 0,
                su_lane: 0,
            }],
            sem: Semantics::PasIterate,
        });
        let marks = vec![self.body.len()];
        let program = Program {
            prologue: Vec::new(),
            body: self.body,
            updates_per_iter: l as u64,
            samples_per_iter: l as u64,
            name: "pas".into(),
        };
        (program, marks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{MaxCutModel, PottsGrid};
    use crate::graph::erdos_renyi_with_edges;
    use crate::workloads;

    #[test]
    fn block_gibbs_ising_schedule_is_compact() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        assert_eq!(p.updates_per_iter, 64);
        // Chessboard: 2 blocks of 32, groups of 4 ⇒ 16 groups, ≥2
        // instructions each, plus 2 block drains.
        assert!(p.body.len() >= 32, "body={} instrs", p.body.len());
        let h = p.body_histogram();
        assert!(h.get(&CtrlType::ComputeSampleStore).copied().unwrap_or(0) >= 16);
        assert!(h.get(&CtrlType::Nop).copied().unwrap_or(0) >= 4);
    }

    #[test]
    fn sequential_gibbs_has_more_drains_than_bg() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let seq = compile(&m, AlgoKind::Gibbs, &hw, 1).unwrap();
        let bg = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let nseq = seq
            .body_histogram()
            .get(&CtrlType::Nop)
            .copied()
            .unwrap_or(0);
        let nbg = bg.body_histogram().get(&CtrlType::Nop).copied().unwrap_or(0);
        assert!(nseq > nbg, "seq NOPs {nseq} vs bg {nbg}");
    }

    #[test]
    fn pas_schedule_has_compute_and_sample_phases() {
        let g = erdos_renyi_with_edges(64, 200, 3);
        let m = MaxCutModel::new(g, None);
        let hw = HwConfig::fig10_toy();
        let p = compile(&m, AlgoKind::Pas, &hw, 4).unwrap();
        let h = p.body_histogram();
        assert!(h.get(&CtrlType::Compute).copied().unwrap_or(0) > 0);
        assert!(h.get(&CtrlType::Sample).copied().unwrap_or(0) > 0);
        assert_eq!(p.updates_per_iter, 4);
        // Spatial sampling: L × ceil(n_moves/S) Sample instrs.
        let n_moves = 128usize;
        assert_eq!(h[&CtrlType::Sample], 4 * n_moves.div_ceil(hw.s));
    }

    #[test]
    fn all_rvs_updated_once_per_iteration() {
        let m = PottsGrid::new(5, 5, 3, 0.5);
        let hw = HwConfig::paper_default();
        for algo in [AlgoKind::Gibbs, AlgoKind::BlockGibbs, AlgoKind::AsyncGibbs] {
            let p = compile(&m, algo, &hw, 1).unwrap();
            let mut seen = vec![0u32; 25];
            for i in &p.body {
                if let Semantics::UpdateRvs(rvs) = &i.sem {
                    for &rv in rvs {
                        seen[rv as usize] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{algo:?}: {seen:?}");
        }
    }

    #[test]
    fn shard_with_full_ownership_matches_single_core() {
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let hw = HwConfig::paper_default();
        let all: Vec<u32> = (0..64).collect();
        for algo in [
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            let full = compile(&m, algo, &hw, 4).unwrap();
            let (shard, marks) = compile_shard(&m, algo, &hw, 4, &all, true).unwrap();
            assert_eq!(shard.body, full.body, "{algo:?} diverged");
            assert_eq!(shard.updates_per_iter, full.updates_per_iter);
            assert_eq!(*marks.last().unwrap(), shard.body.len());
            assert!(marks.windows(2).all(|w| w[0] <= w[1]), "{algo:?}: {marks:?}");
        }
    }

    #[test]
    fn shards_jointly_cover_every_rv_once_with_aligned_rounds() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let hw = HwConfig::fig10_toy();
        let p = crate::graph::partition_balanced(m.interaction(), 3);
        let mut seen = vec![0u32; 36];
        let mut rounds: Option<usize> = None;
        for part in p.parts() {
            let (prog, marks) = compile_shard(&m, AlgoKind::BlockGibbs, &hw, 1, &part, true).unwrap();
            match rounds {
                None => rounds = Some(marks.len()),
                Some(k) => assert_eq!(k, marks.len(), "cores disagree on round count"),
            }
            for i in &prog.body {
                if let Semantics::UpdateRvs(rvs) = &i.sem {
                    for &rv in rvs {
                        seen[rv as usize] += 1;
                    }
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn loads_respect_bandwidth() {
        let wl = workloads::wl_survey();
        let hw = HwConfig::fig10_toy();
        let p = compile(wl.model.as_ref(), AlgoKind::BlockGibbs, &hw, 1).unwrap();
        for i in &p.body {
            assert!(i.loads.len() <= hw.bw_words, "{} loads", i.loads.len());
        }
    }

    #[test]
    fn loads_avoid_multi_row_bank_writes() {
        // One row-wide write per bank per instruction: several words of
        // one row are fine, two different rows of one bank are not.
        let m = PottsGrid::new(8, 8, 2, 1.0);
        let hw = HwConfig::paper_default();
        let p = compile(&m, AlgoKind::BlockGibbs, &hw, 1).unwrap();
        let row_w = 1u16 << hw.k;
        for i in &p.body {
            let mut bank_row = std::collections::HashMap::new();
            for l in &i.loads {
                let row = l.rf_reg / row_w;
                let prev = bank_row.insert(l.rf_bank, row);
                assert!(
                    prev.is_none() || prev == Some(row),
                    "bank {} writes rows {:?} and {}",
                    l.rf_bank,
                    prev,
                    row
                );
            }
        }
    }
}
