//! Categorical samplers: the baseline CDF (inverse-transform) sampler
//! and the paper's Gumbel-max sampler (§V-D, Fig. 9).
//!
//! All samplers draw from the conditional distribution implied by a
//! vector of **unnormalized energies** `e` at inverse temperature `β`:
//! `P(s) ∝ exp(-β e_s)`. The CDF sampler must exponentiate and
//! normalize first; the Gumbel sampler works directly in the energy
//! (log) domain — this is the core hardware win the paper claims
//! (2× op-count reduction, no CDT register file, no under/overflow).
//!
//! [`GumbelLutSampler`] additionally models the hardware LUT that maps
//! uniform noise to Gumbel noise with finite size and precision; the
//! Fig. 12 ablation sweeps those two parameters.

use crate::rng::Rng;

/// A sampler for discrete distributions given as unnormalized energies.
pub trait CategoricalSampler: Send {
    /// Draw a state index from `P(s) ∝ exp(-β e[s])`.
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize;

    /// Draw one state per chain from a chain-major batch of `k`
    /// energy vectors: `e[c * n + s]` is chain `c`'s energy for state
    /// `s`, `betas[c]` its inverse temperature, `rngs[c]` its RNG, and
    /// `out[c]` receives its sample (`k = out.len()`).
    ///
    /// Every implementation must consume exactly the same draws from
    /// `rngs[c]` as `k` scalar [`CategoricalSampler::sample`] calls
    /// would, so batched and scalar chains stay bit-identical. The
    /// default simply loops the scalar kernel; vectorized overrides
    /// (Gumbel) iterate state-outer / chain-inner, which preserves
    /// each chain's per-state draw order.
    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.sample(&e[c * n..(c + 1) * n], betas[c], &mut rngs[c]) as u32;
        }
    }

    /// Human-readable name (used by the benches).
    fn name(&self) -> &'static str;

    /// Abstract op count to draw one sample from a size-`n`
    /// distribution — the Fig. 9(d)/Fig. 13 accounting.
    fn ops_per_sample(&self, n: usize) -> u64;
}

/// Shared batched Gumbel-argmax loop: state-outer / chain-inner so
/// each chain draws its noise in state order (bit-identical to the
/// scalar kernel), with `noise(c)` supplying chain `c`'s next variate.
fn gumbel_argmax_batch(
    e: &[f32],
    n: usize,
    betas: &[f32],
    out: &mut [u32],
    best_v: &mut Vec<f32>,
    mut noise: impl FnMut(usize) -> f32,
) {
    let k = out.len();
    debug_assert_eq!(e.len(), k * n);
    best_v.clear();
    best_v.resize(k, f32::NEG_INFINITY);
    out.fill(0);
    for s in 0..n {
        for c in 0..k {
            let v = -betas[c] * e[c * n + s] + noise(c);
            if v > best_v[c] {
                best_v[c] = v;
                out[c] = s as u32;
            }
        }
    }
}

/// Baseline inverse-transform (CDF) sampler, as used by SPU / PGMA.
///
/// Converts energies to probabilities (`exp`), accumulates the CDT,
/// scales a uniform by the total sum and searches the table:
/// `O(2N + 1)` sequential operations (Fig. 9d).
#[derive(Clone, Debug, Default)]
pub struct CdfSampler;

impl CategoricalSampler for CdfSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        debug_assert!(!e.is_empty());
        // Shift by the min energy for numerical stability (the hardware
        // baseline cannot do this — one of the weaknesses §V-D lists).
        let emin = e.iter().copied().fold(f32::INFINITY, f32::min);
        if emin.is_infinite() {
            // all-infinite guard: uniform fallback
            return rng.below(e.len());
        }
        let mut total = 0.0f64;
        let mut cdf = Vec::with_capacity(e.len());
        for &ei in e {
            total += ((-beta * (ei - emin)) as f64).exp();
            cdf.push(total);
        }
        let u = rng.uniform_f64() * total;
        match cdf.iter().position(|&c| u < c) {
            Some(i) => i,
            None => e.len() - 1,
        }
    }

    fn name(&self) -> &'static str {
        "cdf"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        // N exp + N accumulate + 1 scale, then sequential search
        // (counted in the N accumulate pass by the paper): 2N + 1.
        2 * n as u64 + 1
    }
}

/// Exact (float-precision) Gumbel-max sampler:
/// `argmax_s (-β e_s + g_s)`, `g_s ~ Gumbel(0,1)`.
#[derive(Clone, Debug, Default)]
pub struct GumbelSampler {
    /// Per-chain running argmax values for the batched kernel.
    best_v: Vec<f32>,
}

impl CategoricalSampler for GumbelSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (s, &ei) in e.iter().enumerate() {
            let v = -beta * ei + rng.gumbel_f32();
            if v > best_v {
                best_v = v;
                best = s;
            }
        }
        best
    }

    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        gumbel_argmax_batch(e, n, betas, out, &mut self.best_v, |c| rngs[c].gumbel_f32());
    }

    fn name(&self) -> &'static str {
        "gumbel"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        // One LUT lookup + add + compare per element, fully pipelined:
        // O(N) (Fig. 9d).
        n as u64
    }
}

/// Hardware-model Gumbel sampler: the uniform→Gumbel conversion goes
/// through a LUT of `size` entries quantized to `bits` of fixed-point
/// precision (Fig. 9c / Fig. 12 ablation).
#[derive(Clone, Debug)]
pub struct GumbelLutSampler {
    lut: Vec<f32>,
    size: usize,
    bits: u32,
    /// Per-chain running argmax values for the batched kernel.
    best_v: Vec<f32>,
}

impl GumbelLutSampler {
    /// Build the LUT: entry `k` holds the Gumbel quantile at the bin
    /// midpoint `(k + 0.5) / size`, then values are quantized to
    /// `bits`-bit fixed point across the table's dynamic range.
    pub fn new(size: usize, bits: u32) -> GumbelLutSampler {
        assert!(size >= 2 && bits >= 2 && bits <= 24);
        let raw: Vec<f32> = (0..size)
            .map(|k| {
                let u = (k as f32 + 0.5) / size as f32;
                -(-(u.ln())).ln()
            })
            .collect();
        let lo = raw.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = raw.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let levels = ((1u64 << bits) - 1) as f32;
        let lut = raw
            .iter()
            .map(|&v| {
                let q = ((v - lo) / (hi - lo) * levels).round() / levels;
                lo + q * (hi - lo)
            })
            .collect();
        GumbelLutSampler {
            lut,
            size,
            bits,
            best_v: Vec::new(),
        }
    }

    /// LUT size (number of entries).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fixed-point precision in bits.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// One LUT-noise draw (hardware URNG index → table value).
    #[inline]
    pub fn noise(&self, rng: &mut Rng) -> f32 {
        self.lut[rng.below(self.size)]
    }
}

impl CategoricalSampler for GumbelLutSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (s, &ei) in e.iter().enumerate() {
            let v = -beta * ei + self.noise(rng);
            if v > best_v {
                best_v = v;
                best = s;
            }
        }
        best
    }

    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        let (lut, size) = (&self.lut, self.size);
        gumbel_argmax_batch(e, n, betas, out, &mut self.best_v, |c| {
            lut[rngs[c].below(size)]
        });
    }

    fn name(&self) -> &'static str {
        "gumbel-lut"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        n as u64
    }
}

/// Empirical total-variation distance between a sampler's output
/// histogram and the exact softmax over `e` — the Fig. 12 metric.
pub fn sampler_tv_distance(
    sampler: &mut dyn CategoricalSampler,
    e: &[f32],
    beta: f32,
    draws: usize,
    rng: &mut Rng,
) -> f64 {
    let mut counts = vec![0u64; e.len()];
    for _ in 0..draws {
        counts[sampler.sample(e, beta, rng)] += 1;
    }
    let emin = e.iter().copied().fold(f32::INFINITY, f32::min);
    let probs: Vec<f64> = e
        .iter()
        .map(|&ei| ((-beta * (ei - emin)) as f64).exp())
        .collect();
    let z: f64 = probs.iter().sum();
    0.5 * counts
        .iter()
        .zip(&probs)
        .map(|(&c, &p)| (c as f64 / draws as f64 - p / z).abs())
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_distribution(sampler: &mut dyn CategoricalSampler, tol: f64) {
        let e = [0.0f32, 1.0, 2.0];
        let beta = 1.0;
        let mut rng = Rng::new(77);
        let tv = sampler_tv_distance(sampler, &e, beta, 200_000, &mut rng);
        assert!(tv < tol, "{}: tv={tv}", sampler.name());
    }

    #[test]
    fn cdf_matches_softmax() {
        check_distribution(&mut CdfSampler, 0.01);
    }

    #[test]
    fn gumbel_matches_softmax() {
        check_distribution(&mut GumbelSampler::default(), 0.01);
    }

    #[test]
    fn gumbel_lut16x8_close() {
        // Paper's chosen config: size 16, 8-bit — "good enough".
        check_distribution(&mut GumbelLutSampler::new(16, 8), 0.06);
    }

    #[test]
    fn lut_accuracy_improves_with_size() {
        let e = [0.0f32, 0.5, 1.0, 1.5];
        let mut rng = Rng::new(5);
        let tv4 = sampler_tv_distance(&mut GumbelLutSampler::new(4, 8), &e, 1.0, 100_000, &mut rng);
        let tv64 =
            sampler_tv_distance(&mut GumbelLutSampler::new(64, 8), &e, 1.0, 100_000, &mut rng);
        assert!(tv64 < tv4, "tv64={tv64} tv4={tv4}");
    }

    #[test]
    fn deterministic_energy_dominates() {
        // With beta huge, the min-energy state must always win.
        let e = [5.0f32, 0.0, 5.0];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(GumbelSampler::default().sample(&e, 50.0, &mut rng), 1);
            assert_eq!(CdfSampler.sample(&e, 50.0, &mut rng), 1);
        }
    }

    #[test]
    fn infinite_energies_never_selected() {
        let e = [f32::INFINITY, 0.0, f32::INFINITY];
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            assert_eq!(CdfSampler.sample(&e, 1.0, &mut rng), 1);
            assert_eq!(GumbelSampler::default().sample(&e, 1.0, &mut rng), 1);
        }
    }

    #[test]
    fn batched_sampling_is_bit_identical_to_scalar() {
        let (n, k) = (5usize, 4usize);
        let mut rng = Rng::new(99);
        let e: Vec<f32> = (0..k * n).map(|_| rng.uniform_f32() * 3.0).collect();
        let betas: Vec<f32> = (0..k).map(|c| 0.5 + c as f32 * 0.3).collect();
        let samplers: Vec<Box<dyn CategoricalSampler>> = vec![
            Box::new(CdfSampler),
            Box::new(GumbelSampler::default()),
            Box::new(GumbelLutSampler::new(16, 8)),
        ];
        for mut s in samplers {
            let mut rngs_a: Vec<Rng> = (0..k as u64).map(|c| Rng::fork(7, c)).collect();
            let mut rngs_b = rngs_a.clone();
            let scalar: Vec<u32> = (0..k)
                .map(|c| s.sample(&e[c * n..(c + 1) * n], betas[c], &mut rngs_a[c]) as u32)
                .collect();
            let mut batched = vec![0u32; k];
            s.sample_batch(&e, n, &betas, &mut rngs_b, &mut batched);
            assert_eq!(scalar, batched, "{}: samples diverge", s.name());
            // Identical RNG consumption: the streams must stay in sync.
            for (a, b) in rngs_a.iter_mut().zip(&mut rngs_b) {
                assert_eq!(a.next_u64(), b.next_u64(), "{}: rng streams diverged", s.name());
            }
        }
    }

    #[test]
    fn op_counts_match_paper() {
        // Fig. 9(d): CDF O(2N+1) vs Gumbel O(N).
        assert_eq!(CdfSampler.ops_per_sample(64), 129);
        assert_eq!(GumbelSampler::default().ops_per_sample(64), 64);
    }

    #[test]
    fn lut_is_quantized() {
        let s = GumbelLutSampler::new(16, 4);
        // 4-bit: at most 16 distinct values (trivially true for size 16),
        // and all values within the Gumbel quantile range of the table.
        let lo = s.lut.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = s.lut.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(lo < 0.0 && hi > 1.0, "lo={lo} hi={hi}");
    }
}
