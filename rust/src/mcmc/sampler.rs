//! Categorical samplers: the baseline CDF (inverse-transform) sampler
//! and the paper's Gumbel-max sampler (§V-D, Fig. 9).
//!
//! All samplers draw from the conditional distribution implied by a
//! vector of **unnormalized energies** `e` at inverse temperature `β`:
//! `P(s) ∝ exp(-β e_s)`. The CDF sampler must exponentiate and
//! normalize first; the Gumbel sampler works directly in the energy
//! (log) domain — this is the core hardware win the paper claims
//! (2× op-count reduction, no CDT register file, no under/overflow).
//!
//! [`GumbelLutSampler`] additionally models the hardware LUT that maps
//! uniform noise to Gumbel noise with finite size and precision; the
//! Fig. 12 ablation sweeps those two parameters.

use crate::rng::{LaneRng, Rng, LANES};

/// A sampler for discrete distributions given as unnormalized energies.
pub trait CategoricalSampler: Send {
    /// Draw a state index from `P(s) ∝ exp(-β e[s])`.
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize;

    /// Draw one state per chain from a **state-major** batch of `k`
    /// energy vectors: `e[s * k + c]` is chain `c`'s energy for state
    /// `s` (the layout [`crate::energy::EnergyModel::local_energies_batch`]
    /// produces), `betas[c]` its inverse temperature, `rngs[c]` its
    /// RNG, and `out[c]` receives its sample (`k = out.len()`).
    ///
    /// Every implementation must consume exactly the same draws from
    /// `rngs[c]` as `k` scalar [`CategoricalSampler::sample`] calls
    /// would, so batched and scalar chains stay bit-identical. The
    /// default gathers each chain's column and loops the scalar
    /// kernel; the Gumbel samplers override it with the lane-parallel
    /// argmax, which draws each chain's noise in per-state order —
    /// the same order the scalar kernel consumes it.
    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        let k = out.len();
        debug_assert_eq!(e.len(), k * n);
        let mut col = vec![0.0f32; n];
        for (c, o) in out.iter_mut().enumerate() {
            for (s, v) in col.iter_mut().enumerate() {
                *v = e[s * k + c];
            }
            *o = self.sample(&col, betas[c], &mut rngs[c]) as u32;
        }
    }

    /// Human-readable name (used by the benches).
    fn name(&self) -> &'static str;

    /// Abstract op count to draw one sample from a size-`n`
    /// distribution — the Fig. 9(d)/Fig. 13 accounting.
    fn ops_per_sample(&self, n: usize) -> u64;
}

/// Noise source for the lane-parallel Gumbel argmax: exact Gumbel
/// variates or the hardware LUT.
enum LaneNoise<'a> {
    Gumbel,
    Lut(&'a [f32]),
}

impl LaneNoise<'_> {
    /// `LANES` noise draws, one per lane — each lane consumes exactly
    /// one draw from its stream, like the scalar kernel.
    #[inline]
    fn lanes(&self, r: &mut LaneRng) -> [f32; LANES] {
        match self {
            LaneNoise::Gumbel => r.gumbel_f32(),
            LaneNoise::Lut(lut) => {
                let idx = r.below(lut.len());
                let mut out = [0.0f32; LANES];
                for (o, &i) in out.iter_mut().zip(idx.iter()) {
                    *o = lut[i];
                }
                out
            }
        }
    }

    /// One scalar noise draw (remainder chains).
    #[inline]
    fn scalar(&self, r: &mut Rng) -> f32 {
        match self {
            LaneNoise::Gumbel => r.gumbel_f32(),
            LaneNoise::Lut(lut) => lut[r.below(lut.len())],
        }
    }
}

/// One argmax update over a `LANES`-wide row: `v = -b·row + g`, then
/// keep the running max and its state index per lane. Strict `>` keeps
/// the first index on ties and never selects NaN — identical to the
/// scalar kernel's comparison.
///
/// Portable body; written elementwise over fixed-width arrays so it
/// autovectorizes. The `simd` feature swaps in the intrinsic versions
/// below (same semantics: separate mul + add, no FMA contraction, so
/// results stay bit-identical to this path and to the scalar kernel).
#[cfg(not(any(
    all(feature = "simd", target_arch = "x86_64", target_feature = "avx2"),
    all(feature = "simd", target_arch = "aarch64", target_feature = "neon")
)))]
#[inline]
fn argmax_step(
    row: &[f32],
    b: &[f32; LANES],
    g: &[f32; LANES],
    s: u32,
    best: &mut [f32; LANES],
    arg: &mut [u32; LANES],
) {
    for l in 0..LANES {
        let v = -b[l] * row[l] + g[l];
        if v > best[l] {
            best[l] = v;
            arg[l] = s;
        }
    }
}

/// AVX2 argmax update: one 8-wide compare + two blends per state.
/// `_CMP_GT_OQ` is strict greater-than with quiet NaN handling, so tie
/// and NaN behavior match the portable `>`; negation is a sign-bit
/// flip and mul/add stay separate (no FMA), preserving bit-identity.
#[cfg(all(feature = "simd", target_arch = "x86_64", target_feature = "avx2"))]
#[inline]
fn argmax_step(
    row: &[f32],
    b: &[f32; LANES],
    g: &[f32; LANES],
    s: u32,
    best: &mut [f32; LANES],
    arg: &mut [u32; LANES],
) {
    debug_assert!(row.len() >= LANES);
    unsafe {
        use std::arch::x86_64::*;
        let nb = _mm256_xor_ps(_mm256_loadu_ps(b.as_ptr()), _mm256_set1_ps(-0.0));
        let v = _mm256_add_ps(
            _mm256_mul_ps(nb, _mm256_loadu_ps(row.as_ptr())),
            _mm256_loadu_ps(g.as_ptr()),
        );
        let bv = _mm256_loadu_ps(best.as_ptr());
        let m = _mm256_cmp_ps(v, bv, _CMP_GT_OQ);
        _mm256_storeu_ps(best.as_mut_ptr(), _mm256_blendv_ps(bv, v, m));
        let av = _mm256_loadu_si256(arg.as_ptr() as *const __m256i);
        let sv = _mm256_set1_epi32(s as i32);
        _mm256_storeu_si256(
            arg.as_mut_ptr() as *mut __m256i,
            _mm256_blendv_epi8(av, sv, _mm256_castps_si256(m)),
        );
    }
}

/// NEON argmax update: two 4-wide halves. `vcgtq_f32` is strict
/// greater-than (false on NaN), `vbslq` selects per lane; negation and
/// separate mul/add (`vmulq` + `vaddq`, no fused `vmla`) keep results
/// bit-identical to the portable path.
#[cfg(all(feature = "simd", target_arch = "aarch64", target_feature = "neon"))]
#[inline]
fn argmax_step(
    row: &[f32],
    b: &[f32; LANES],
    g: &[f32; LANES],
    s: u32,
    best: &mut [f32; LANES],
    arg: &mut [u32; LANES],
) {
    debug_assert!(row.len() >= LANES);
    unsafe {
        use std::arch::aarch64::*;
        for half in 0..2 {
            let o = half * 4;
            let nb = vnegq_f32(vld1q_f32(b.as_ptr().add(o)));
            let v = vaddq_f32(
                vmulq_f32(nb, vld1q_f32(row.as_ptr().add(o))),
                vld1q_f32(g.as_ptr().add(o)),
            );
            let bv = vld1q_f32(best.as_ptr().add(o));
            let m = vcgtq_f32(v, bv);
            vst1q_f32(best.as_mut_ptr().add(o), vbslq_f32(m, v, bv));
            let av = vld1q_u32(arg.as_ptr().add(o));
            vst1q_u32(arg.as_mut_ptr().add(o), vbslq_u32(m, vdupq_n_u32(s), av));
        }
    }
}

/// Lane-parallel batched Gumbel argmax over state-major energies:
/// chains are processed `LANES` at a time, with each chunk's RNG
/// streams gathered into a [`LaneRng`] so noise generation and the
/// argmax update run K-wide; the `k % LANES` remainder chains run the
/// scalar kernel. Each chain draws its noise in state order from its
/// own stream, so samples and RNG consumption are bit-identical to
/// `k` scalar calls regardless of lane width or code path.
fn gumbel_argmax_lanes(
    e: &[f32],
    n: usize,
    betas: &[f32],
    rngs: &mut [Rng],
    out: &mut [u32],
    noise: LaneNoise<'_>,
) {
    let k = out.len();
    debug_assert_eq!(e.len(), k * n);
    debug_assert_eq!(rngs.len(), k);
    let chunks = k / LANES;
    for ch in 0..chunks {
        let base = ch * LANES;
        let mut lanes = LaneRng::load(&rngs[base..base + LANES]);
        let mut b = [0.0f32; LANES];
        b.copy_from_slice(&betas[base..base + LANES]);
        let mut best = [f32::NEG_INFINITY; LANES];
        let mut arg = [0u32; LANES];
        for s in 0..n {
            let g = noise.lanes(&mut lanes);
            let row = &e[s * k + base..s * k + base + LANES];
            argmax_step(row, &b, &g, s as u32, &mut best, &mut arg);
        }
        lanes.store(&mut rngs[base..base + LANES]);
        out[base..base + LANES].copy_from_slice(&arg);
    }
    for c in chunks * LANES..k {
        let mut best = f32::NEG_INFINITY;
        let mut arg = 0u32;
        for s in 0..n {
            let v = -betas[c] * e[s * k + c] + noise.scalar(&mut rngs[c]);
            if v > best {
                best = v;
                arg = s as u32;
            }
        }
        out[c] = arg;
    }
}

/// Baseline inverse-transform (CDF) sampler, as used by SPU / PGMA.
///
/// Converts energies to probabilities (`exp`), accumulates the CDT,
/// scales a uniform by the total sum and searches the table:
/// `O(2N + 1)` sequential operations (Fig. 9d).
#[derive(Clone, Debug, Default)]
pub struct CdfSampler;

impl CategoricalSampler for CdfSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        debug_assert!(!e.is_empty());
        // Shift by the min energy for numerical stability (the hardware
        // baseline cannot do this — one of the weaknesses §V-D lists).
        let emin = e.iter().copied().fold(f32::INFINITY, f32::min);
        if emin.is_infinite() {
            // all-infinite guard: uniform fallback
            return rng.below(e.len());
        }
        let mut total = 0.0f64;
        let mut cdf = Vec::with_capacity(e.len());
        for &ei in e {
            total += ((-beta * (ei - emin)) as f64).exp();
            cdf.push(total);
        }
        let u = rng.uniform_f64() * total;
        match cdf.iter().position(|&c| u < c) {
            Some(i) => i,
            None => e.len() - 1,
        }
    }

    fn name(&self) -> &'static str {
        "cdf"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        // N exp + N accumulate + 1 scale, then sequential search
        // (counted in the N accumulate pass by the paper): 2N + 1.
        2 * n as u64 + 1
    }
}

/// Exact (float-precision) Gumbel-max sampler:
/// `argmax_s (-β e_s + g_s)`, `g_s ~ Gumbel(0,1)`.
#[derive(Clone, Debug, Default)]
pub struct GumbelSampler;

impl CategoricalSampler for GumbelSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (s, &ei) in e.iter().enumerate() {
            let v = -beta * ei + rng.gumbel_f32();
            if v > best_v {
                best_v = v;
                best = s;
            }
        }
        best
    }

    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        gumbel_argmax_lanes(e, n, betas, rngs, out, LaneNoise::Gumbel);
    }

    fn name(&self) -> &'static str {
        "gumbel"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        // One LUT lookup + add + compare per element, fully pipelined:
        // O(N) (Fig. 9d).
        n as u64
    }
}

/// Hardware-model Gumbel sampler: the uniform→Gumbel conversion goes
/// through a LUT of `size` entries quantized to `bits` of fixed-point
/// precision (Fig. 9c / Fig. 12 ablation).
#[derive(Clone, Debug)]
pub struct GumbelLutSampler {
    lut: Vec<f32>,
    size: usize,
    bits: u32,
}

impl GumbelLutSampler {
    /// Build the LUT: entry `k` holds the Gumbel quantile at the bin
    /// midpoint `(k + 0.5) / size`, then values are quantized to
    /// `bits`-bit fixed point across the table's dynamic range.
    pub fn new(size: usize, bits: u32) -> GumbelLutSampler {
        assert!(size >= 2 && bits >= 2 && bits <= 24);
        let raw: Vec<f32> = (0..size)
            .map(|k| {
                let u = (k as f32 + 0.5) / size as f32;
                -(-(u.ln())).ln()
            })
            .collect();
        let lo = raw.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = raw.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let levels = ((1u64 << bits) - 1) as f32;
        let lut = raw
            .iter()
            .map(|&v| {
                let q = ((v - lo) / (hi - lo) * levels).round() / levels;
                lo + q * (hi - lo)
            })
            .collect();
        GumbelLutSampler { lut, size, bits }
    }

    /// LUT size (number of entries).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fixed-point precision in bits.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// One LUT-noise draw (hardware URNG index → table value).
    #[inline]
    pub fn noise(&self, rng: &mut Rng) -> f32 {
        self.lut[rng.below(self.size)]
    }
}

impl CategoricalSampler for GumbelLutSampler {
    fn sample(&mut self, e: &[f32], beta: f32, rng: &mut Rng) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (s, &ei) in e.iter().enumerate() {
            let v = -beta * ei + self.noise(rng);
            if v > best_v {
                best_v = v;
                best = s;
            }
        }
        best
    }

    fn sample_batch(&mut self, e: &[f32], n: usize, betas: &[f32], rngs: &mut [Rng], out: &mut [u32]) {
        gumbel_argmax_lanes(e, n, betas, rngs, out, LaneNoise::Lut(&self.lut));
    }

    fn name(&self) -> &'static str {
        "gumbel-lut"
    }

    fn ops_per_sample(&self, n: usize) -> u64 {
        n as u64
    }
}

/// Empirical total-variation distance between a sampler's output
/// histogram and the exact softmax over `e` — the Fig. 12 metric.
pub fn sampler_tv_distance(
    sampler: &mut dyn CategoricalSampler,
    e: &[f32],
    beta: f32,
    draws: usize,
    rng: &mut Rng,
) -> f64 {
    let mut counts = vec![0u64; e.len()];
    for _ in 0..draws {
        counts[sampler.sample(e, beta, rng)] += 1;
    }
    let emin = e.iter().copied().fold(f32::INFINITY, f32::min);
    let probs: Vec<f64> = e
        .iter()
        .map(|&ei| ((-beta * (ei - emin)) as f64).exp())
        .collect();
    let z: f64 = probs.iter().sum();
    0.5 * counts
        .iter()
        .zip(&probs)
        .map(|(&c, &p)| (c as f64 / draws as f64 - p / z).abs())
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_distribution(sampler: &mut dyn CategoricalSampler, tol: f64) {
        let e = [0.0f32, 1.0, 2.0];
        let beta = 1.0;
        let mut rng = Rng::new(77);
        let tv = sampler_tv_distance(sampler, &e, beta, 200_000, &mut rng);
        assert!(tv < tol, "{}: tv={tv}", sampler.name());
    }

    #[test]
    fn cdf_matches_softmax() {
        check_distribution(&mut CdfSampler, 0.01);
    }

    #[test]
    fn gumbel_matches_softmax() {
        check_distribution(&mut GumbelSampler::default(), 0.01);
    }

    #[test]
    fn gumbel_lut16x8_close() {
        // Paper's chosen config: size 16, 8-bit — "good enough".
        check_distribution(&mut GumbelLutSampler::new(16, 8), 0.06);
    }

    #[test]
    fn lut_accuracy_improves_with_size() {
        let e = [0.0f32, 0.5, 1.0, 1.5];
        let mut rng = Rng::new(5);
        let tv4 = sampler_tv_distance(&mut GumbelLutSampler::new(4, 8), &e, 1.0, 100_000, &mut rng);
        let tv64 =
            sampler_tv_distance(&mut GumbelLutSampler::new(64, 8), &e, 1.0, 100_000, &mut rng);
        assert!(tv64 < tv4, "tv64={tv64} tv4={tv4}");
    }

    #[test]
    fn deterministic_energy_dominates() {
        // With beta huge, the min-energy state must always win.
        let e = [5.0f32, 0.0, 5.0];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(GumbelSampler::default().sample(&e, 50.0, &mut rng), 1);
            assert_eq!(CdfSampler.sample(&e, 50.0, &mut rng), 1);
        }
    }

    #[test]
    fn infinite_energies_never_selected() {
        let e = [f32::INFINITY, 0.0, f32::INFINITY];
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            assert_eq!(CdfSampler.sample(&e, 1.0, &mut rng), 1);
            assert_eq!(GumbelSampler::default().sample(&e, 1.0, &mut rng), 1);
        }
    }

    #[test]
    fn batched_sampling_is_bit_identical_to_scalar() {
        use crate::rng::LANES;
        let n = 5usize;
        // Widths straddling the lane boundary: lone chain, partial
        // chunk, exact chunk, chunk + remainder, several chunks.
        for k in [1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3] {
            let mut rng = Rng::new(99);
            // State-major energies: e[s * k + c].
            let e: Vec<f32> = (0..k * n).map(|_| rng.uniform_f32() * 3.0).collect();
            let betas: Vec<f32> = (0..k).map(|c| 0.5 + c as f32 * 0.3).collect();
            let samplers: Vec<Box<dyn CategoricalSampler>> = vec![
                Box::new(CdfSampler),
                Box::new(GumbelSampler),
                Box::new(GumbelLutSampler::new(16, 8)),
                Box::new(GumbelLutSampler::new(64, 6)),
            ];
            for mut s in samplers {
                let mut rngs_a: Vec<Rng> = (0..k as u64).map(|c| Rng::fork(7, c)).collect();
                let mut rngs_b = rngs_a.clone();
                let scalar: Vec<u32> = (0..k)
                    .map(|c| {
                        let col: Vec<f32> = (0..n).map(|st| e[st * k + c]).collect();
                        s.sample(&col, betas[c], &mut rngs_a[c]) as u32
                    })
                    .collect();
                let mut batched = vec![0u32; k];
                s.sample_batch(&e, n, &betas, &mut rngs_b, &mut batched);
                assert_eq!(scalar, batched, "{} k={k}: samples diverge", s.name());
                // Identical RNG consumption: the streams must stay in sync.
                for (a, b) in rngs_a.iter_mut().zip(&mut rngs_b) {
                    assert_eq!(
                        a.next_u64(),
                        b.next_u64(),
                        "{} k={k}: rng streams diverged",
                        s.name()
                    );
                }
            }
        }
    }

    #[test]
    fn op_counts_match_paper() {
        // Fig. 9(d): CDF O(2N+1) vs Gumbel O(N).
        assert_eq!(CdfSampler.ops_per_sample(64), 129);
        assert_eq!(GumbelSampler::default().ops_per_sample(64), 64);
    }

    #[test]
    fn lut_is_quantized() {
        let s = GumbelLutSampler::new(16, 4);
        // 4-bit: at most 16 distinct values (trivially true for size 16),
        // and all values within the Gumbel quantile range of the table.
        let lo = s.lut.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = s.lut.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(lo < 0.0 && hi > 1.0, "lo={lo} hi={hi}");
    }
}
