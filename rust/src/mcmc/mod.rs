//! MCMC algorithms over [`crate::energy::EnergyModel`].
//!
//! Implements the algorithm zoo of §II-A: Metropolis-Hastings, Gibbs,
//! Block Gibbs, Asynchronous Gibbs and the gradient-based Path Auxiliary
//! Sampler (PAS), all parameterized by a pluggable categorical sampler
//! (CDF baseline vs Gumbel-max, §V-D) and an annealing schedule.

pub mod anneal;
pub mod batch;
mod gibbs;
mod metrics;
mod mh;
mod pas;
pub mod sampler;
pub mod tempering;

pub use anneal::{
    AdaptiveSchedule, AnnealConfig, AnnealPolicy, BetaController, FixedController,
    RoundDiagnostics,
};
pub use batch::{batch_supported, build_batch_algo, BatchMcmc, ChainBatch};
pub use tempering::{
    AdaptSpacing, Ladder, ReplicaExchange, TemperConfig, TemperingReport, SWAP_STREAM,
};
pub use gibbs::{AsyncGibbs, BlockGibbs, Gibbs};
pub use metrics::{
    effective_sample_size, run_to_accuracy, split_r_hat, AccuracyTrace, TracePoint,
};
pub use mh::MetropolisHastings;
pub use pas::PathAuxiliarySampler;

use crate::energy::{EnergyModel, OpCost};
use crate::rng::Rng;
use sampler::{CategoricalSampler, CdfSampler, GumbelLutSampler, GumbelSampler};

/// Which MCMC algorithm to run (CLI / workload selection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    /// Sequential single-site Metropolis-Hastings.
    Mh,
    /// Sequential single-site Gibbs.
    Gibbs,
    /// Block Gibbs over a greedy coloring of the interaction graph.
    BlockGibbs,
    /// Asynchronous (hogwild) Gibbs: all RVs updated from stale state.
    AsyncGibbs,
    /// Path Auxiliary Sampler with `L` flips per step.
    Pas,
}

impl AlgoKind {
    /// Short name used in benches/CSV output.
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Mh => "MH",
            AlgoKind::Gibbs => "Gibbs",
            AlgoKind::BlockGibbs => "BG",
            AlgoKind::AsyncGibbs => "AG",
            AlgoKind::Pas => "PAS",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<AlgoKind> {
        match s.to_ascii_lowercase().as_str() {
            "mh" => Some(AlgoKind::Mh),
            "gibbs" => Some(AlgoKind::Gibbs),
            "bg" | "blockgibbs" | "block-gibbs" => Some(AlgoKind::BlockGibbs),
            "ag" | "asyncgibbs" | "async-gibbs" => Some(AlgoKind::AsyncGibbs),
            "pas" => Some(AlgoKind::Pas),
            _ => None,
        }
    }
}

/// Which categorical sampler backs the algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Exact inverse-transform (software baseline).
    Cdf,
    /// Exact Gumbel-max.
    Gumbel,
    /// Hardware-model Gumbel with LUT `{ size, bits }`.
    GumbelLut {
        /// LUT entries.
        size: usize,
        /// Fixed-point bits.
        bits: u32,
    },
}

/// Why a sampler spec string failed to parse. The error names the
/// accepted forms, so CLI and server messages are self-describing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SamplerParseError {
    /// The string matches no known sampler name or spec form.
    Unknown(String),
    /// A `lut:SIZE:BITS` spec with a missing or non-numeric field.
    BadLutField {
        /// The offending spec string.
        spec: String,
        /// Which field failed (`SIZE` or `BITS`).
        field: &'static str,
    },
    /// `lut:SIZE:BITS` parsed but the values fall outside the
    /// supported hardware range.
    LutOutOfRange {
        /// Requested LUT entries.
        size: usize,
        /// Requested fixed-point bits.
        bits: u32,
    },
}

impl std::fmt::Display for SamplerParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerParseError::Unknown(s) => write!(
                f,
                "unknown sampler `{s}` (accepted: cdf | gumbel | lut | lut:SIZE:BITS, \
                 e.g. lut:64:6)"
            ),
            SamplerParseError::BadLutField { spec, field } => write!(
                f,
                "bad {field} in sampler `{spec}` (accepted form: lut:SIZE:BITS, e.g. lut:16:8)"
            ),
            SamplerParseError::LutOutOfRange { size, bits } => write!(
                f,
                "lut:{size}:{bits} out of range (need SIZE in 2..=1048576, BITS in 2..=24)"
            ),
        }
    }
}

impl std::error::Error for SamplerParseError {}

impl SamplerKind {
    /// Short name used in CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Cdf => "cdf",
            SamplerKind::Gumbel => "gumbel",
            SamplerKind::GumbelLut { .. } => "lut",
        }
    }

    /// Canonical spec string that [`SamplerKind::parse`] round-trips
    /// exactly: `cdf`, `gumbel`, or `lut:SIZE:BITS`. Serialization
    /// (checkpoints, job envelopes) uses this instead of
    /// [`SamplerKind::name`] so a non-default LUT shape survives a
    /// save/restore cycle.
    pub fn spec(&self) -> String {
        match self {
            SamplerKind::Cdf => "cdf".to_string(),
            SamplerKind::Gumbel => "gumbel".to_string(),
            SamplerKind::GumbelLut { size, bits } => format!("lut:{size}:{bits}"),
        }
    }

    /// Parse from a CLI/spec string: `cdf`, `gumbel`, bare `lut` /
    /// `gumbel-lut` (the paper's 16-entry / 8-bit configuration), or
    /// an explicit `lut:SIZE:BITS` shape.
    pub fn parse(s: &str) -> Result<SamplerKind, SamplerParseError> {
        let low = s.to_ascii_lowercase();
        match low.as_str() {
            "cdf" => return Ok(SamplerKind::Cdf),
            "gumbel" => return Ok(SamplerKind::Gumbel),
            "lut" | "gumbel-lut" => return Ok(SamplerKind::GumbelLut { size: 16, bits: 8 }),
            _ => {}
        }
        if let Some(rest) = low
            .strip_prefix("lut:")
            .or_else(|| low.strip_prefix("gumbel-lut:"))
        {
            let (size_s, bits_s) =
                rest.split_once(':')
                    .ok_or_else(|| SamplerParseError::BadLutField {
                        spec: s.to_string(),
                        field: "BITS",
                    })?;
            let size: usize = size_s.parse().map_err(|_| SamplerParseError::BadLutField {
                spec: s.to_string(),
                field: "SIZE",
            })?;
            let bits: u32 = bits_s.parse().map_err(|_| SamplerParseError::BadLutField {
                spec: s.to_string(),
                field: "BITS",
            })?;
            // Match `GumbelLutSampler::new`'s assertions (plus a sane
            // allocation cap) so a parsed spec can never panic later.
            if size < 2 || size > 1 << 20 || !(2..=24).contains(&bits) {
                return Err(SamplerParseError::LutOutOfRange { size, bits });
            }
            return Ok(SamplerKind::GumbelLut { size, bits });
        }
        Err(SamplerParseError::Unknown(s.to_string()))
    }

    /// Instantiate the sampler.
    pub fn build(&self) -> Box<dyn CategoricalSampler> {
        match *self {
            SamplerKind::Cdf => Box::new(CdfSampler),
            SamplerKind::Gumbel => Box::new(GumbelSampler),
            SamplerKind::GumbelLut { size, bits } => Box::new(GumbelLutSampler::new(size, bits)),
        }
    }
}

/// Statistics from one MCMC step (one outer-loop iteration of Alg. 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// RV updates performed.
    pub updates: u64,
    /// Proposals accepted (MH-style algorithms; Gibbs counts all).
    pub accepted: u64,
    /// Hardware-cost accounting (ops / bytes / samples).
    pub cost: OpCost,
}

impl StepStats {
    /// Accumulate another step's stats.
    pub fn add(&mut self, o: &StepStats) {
        self.updates += o.updates;
        self.accepted += o.accepted;
        self.cost.add(o.cost);
    }
}

/// An MCMC transition kernel.
pub trait Mcmc: Send {
    /// Perform one step (one iteration of the outer `t` loop in Alg. 1),
    /// mutating `x` in place.
    fn step(&mut self, model: &dyn EnergyModel, x: &mut [u32], beta: f32, rng: &mut Rng)
        -> StepStats;

    /// Algorithm name.
    fn name(&self) -> &'static str;
}

/// Build an algorithm instance with sensible defaults for `model`.
pub fn build_algo(
    kind: AlgoKind,
    sampler: SamplerKind,
    model: &dyn EnergyModel,
    pas_flips: usize,
) -> Box<dyn Mcmc> {
    match kind {
        AlgoKind::Mh => Box::new(MetropolisHastings::new()),
        AlgoKind::Gibbs => Box::new(Gibbs::new(sampler.build())),
        AlgoKind::BlockGibbs => Box::new(BlockGibbs::new(sampler.build(), model)),
        AlgoKind::AsyncGibbs => Box::new(AsyncGibbs::new(sampler.build())),
        AlgoKind::Pas => Box::new(PathAuxiliarySampler::new(pas_flips.max(1))),
    }
}

/// Inverse-temperature (β) annealing schedule for optimization
/// workloads (§II-A's simulated-annealing factor).
#[derive(Clone, Copy, Debug)]
pub enum BetaSchedule {
    /// Constant β (posterior sampling).
    Constant(f32),
    /// Linear ramp from `from` to `to` over `steps`.
    Linear {
        /// Initial β.
        from: f32,
        /// Final β.
        to: f32,
        /// Ramp length in steps.
        steps: usize,
    },
    /// Geometric ramp: β(t) = from · r^t, clamped at `to` from
    /// whichever side the ramp approaches it — heating (`rate > 1`)
    /// caps from below, cooling (`rate < 1`) terminates exactly at
    /// `to` from above.
    Geometric {
        /// Initial β.
        from: f32,
        /// Final β (clamp target).
        to: f32,
        /// Per-step growth factor (> 1 heats, < 1 cools).
        rate: f32,
    },
}

impl BetaSchedule {
    /// β at step `t`.
    pub fn beta(&self, t: usize) -> f32 {
        match *self {
            BetaSchedule::Constant(b) => b,
            BetaSchedule::Linear { from, to, steps } => {
                if steps == 0 || t >= steps {
                    // Past the ramp the schedule holds *exactly* `to`
                    // (`from + (to - from) · 1` can miss it by an ulp).
                    to
                } else {
                    let f = t as f32 / steps as f32;
                    let b = from + (to - from) * f;
                    // Float guard: interpolation never leaves [from, to].
                    if from <= to {
                        b.clamp(from, to)
                    } else {
                        b.clamp(to, from)
                    }
                }
            }
            BetaSchedule::Geometric { from, to, rate } => {
                // Clamp toward `to` regardless of ramp direction: a
                // one-sided `.min(to)` would let a cooling schedule
                // (`rate < 1`) sail straight past its target.
                let b = from * rate.powi(t as i32);
                if from <= to {
                    b.min(to)
                } else {
                    b.max(to)
                }
            }
        }
    }

    /// Reject degenerate configurations up front (the engine builder
    /// calls this; a bad schedule is a typed error, not a silent NaN
    /// or runaway ramp at step time).
    pub fn validate(&self) -> Result<(), String> {
        let finite_beta = |name: &str, b: f32| -> Result<(), String> {
            if !b.is_finite() || b < 0.0 {
                Err(format!("schedule {name} β must be finite and ≥ 0 (got {b})"))
            } else {
                Ok(())
            }
        };
        match *self {
            BetaSchedule::Constant(b) => finite_beta("constant", b),
            BetaSchedule::Linear { from, to, .. } => {
                finite_beta("linear `from`", from)?;
                finite_beta("linear `to`", to)
            }
            BetaSchedule::Geometric { from, to, rate } => {
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(format!(
                        "geometric schedule rate must be finite and > 0 (got {rate})"
                    ));
                }
                if !from.is_finite() || from <= 0.0 {
                    return Err(format!(
                        "geometric schedule `from` must be finite and > 0 (got {from}); \
                         a ramp starting at 0 never moves"
                    ));
                }
                finite_beta("geometric `to`", to)?;
                // A rate pointed away from (or exactly at) the target
                // never reaches it: β drifts out of [from, to] with the
                // clamp never firing.
                let mismatched = (rate > 1.0 && to < from)
                    || (rate < 1.0 && to > from)
                    || (rate == 1.0 && to != from);
                if mismatched {
                    return Err(format!(
                        "geometric schedule never reaches `to`: from {from}, to {to}, \
                         rate {rate} (use rate > 1 to heat toward to > from, \
                         rate < 1 to cool toward to < from)"
                    ));
                }
                Ok(())
            }
        }
    }
}

/// A single MCMC chain: state + histograms + cumulative statistics.
///
/// This is the software twin of the accelerator's sample/histogram
/// memories (Fig. 7a): `histogram[i][s]` counts how often RV `i` held
/// state `s` at step boundaries — posterior marginals for Bayes nets.
pub struct Chain<'m> {
    model: &'m dyn EnergyModel,
    algo: Box<dyn Mcmc>,
    /// Current assignment.
    pub x: Vec<u32>,
    /// β schedule.
    pub schedule: BetaSchedule,
    /// Global-step offset added to the schedule clock: a resumed chain
    /// evaluates β at `step_offset + step_count` so the ramp continues
    /// where the previous run stopped instead of restarting at t = 0.
    step_offset: usize,
    /// Steps taken.
    pub step_count: usize,
    /// Cumulative statistics.
    pub stats: StepStats,
    /// Per-RV state histograms (flattened, offsets in `hist_offsets`).
    hist: Vec<u64>,
    hist_offsets: Vec<usize>,
    rng: Rng,
    /// Best objective seen and the assignment that achieved it.
    pub best_objective: f64,
    best_x: Vec<u32>,
}

impl<'m> Chain<'m> {
    /// Create a chain with a random initial state.
    pub fn new(
        model: &'m dyn EnergyModel,
        algo: Box<dyn Mcmc>,
        schedule: BetaSchedule,
        seed: u64,
    ) -> Chain<'m> {
        Chain::with_rng(model, algo, schedule, Rng::new(seed))
    }

    /// Create a chain driving a caller-supplied RNG stream — the
    /// engine's per-chain seeding path (`Rng::fork(seed, chain_id)`).
    pub fn with_rng(
        model: &'m dyn EnergyModel,
        algo: Box<dyn Mcmc>,
        schedule: BetaSchedule,
        mut rng: Rng,
    ) -> Chain<'m> {
        let x = crate::energy::random_state(model, &mut rng);
        let mut hist_offsets = Vec::with_capacity(model.num_vars() + 1);
        let mut acc = 0usize;
        for i in 0..model.num_vars() {
            hist_offsets.push(acc);
            acc += model.num_states(i);
        }
        hist_offsets.push(acc);
        let best_objective = model.objective(&x);
        let best_x = x.clone();
        Chain {
            model,
            algo,
            x,
            schedule,
            step_offset: 0,
            step_count: 0,
            stats: StepStats::default(),
            hist: vec![0; acc],
            hist_offsets,
            rng,
            best_objective,
            best_x,
        }
    }

    /// Replace the RNG stream — the engine's cold-chain restart hook:
    /// a stagnating chain is handed a freshly-forked stream so its
    /// continuation explores a different trajectory.
    pub fn reseed(&mut self, rng: Rng) {
        self.rng = rng;
    }

    /// Overwrite the current assignment and re-seed the best-so-far
    /// tracking from it (the random state chosen at construction is
    /// discarded entirely).
    pub fn set_state(&mut self, x0: &[u32]) {
        self.x.copy_from_slice(x0);
        self.best_objective = self.model.objective(&self.x);
        self.best_x.clone_from(&self.x);
    }

    /// Set the global-step offset of the schedule clock (checkpoint
    /// resume: β continues at `offset + t` instead of restarting).
    pub fn set_step_offset(&mut self, offset: usize) {
        self.step_offset = offset;
    }

    /// The global-step offset of the schedule clock.
    pub fn step_offset(&self) -> usize {
        self.step_offset
    }

    /// Run `n` steps, updating histograms and best-so-far.
    pub fn run(&mut self, n: usize) {
        for _ in 0..n {
            let beta = self.schedule.beta(self.step_offset + self.step_count);
            self.step_once(beta);
        }
    }

    /// Run one step per entry of `betas`, using the supplied β values
    /// instead of the fixed schedule — the adaptive annealing
    /// controller's entry point ([`crate::mcmc::anneal`]).
    pub fn run_betas(&mut self, betas: &[f32]) {
        for &beta in betas {
            self.step_once(beta);
        }
    }

    fn step_once(&mut self, beta: f32) {
        let s = self
            .algo
            .step(self.model, &mut self.x, beta, &mut self.rng);
        self.stats.add(&s);
        self.step_count += 1;
        for i in 0..self.model.num_vars() {
            self.hist[self.hist_offsets[i] + self.x[i] as usize] += 1;
        }
        let obj = self.model.objective(&self.x);
        if obj > self.best_objective {
            self.best_objective = obj;
            self.best_x.clone_from(&self.x);
        }
    }

    /// Empirical marginal distribution of RV `i`.
    pub fn marginal(&self, i: usize) -> Vec<f64> {
        let span = &self.hist[self.hist_offsets[i]..self.hist_offsets[i + 1]];
        let total: u64 = span.iter().sum();
        span.iter()
            .map(|&c| c as f64 / total.max(1) as f64)
            .collect()
    }

    /// Best assignment found so far.
    pub fn best_assignment(&self) -> &[u32] {
        &self.best_x
    }

    /// The algorithm's name.
    pub fn algo_name(&self) -> &'static str {
        self.algo.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;

    #[test]
    fn algo_kind_roundtrip() {
        for k in [
            AlgoKind::Mh,
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            assert_eq!(AlgoKind::parse(&k.name().to_ascii_lowercase()), Some(k));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn sampler_kind_spec_roundtrip() {
        for k in [
            SamplerKind::Cdf,
            SamplerKind::Gumbel,
            SamplerKind::GumbelLut { size: 16, bits: 8 },
            SamplerKind::GumbelLut { size: 64, bits: 6 },
            SamplerKind::GumbelLut { size: 1024, bits: 24 },
        ] {
            assert_eq!(SamplerKind::parse(&k.spec()), Ok(k));
        }
        // Legacy shorthand stays accepted, defaults to the paper shape.
        assert_eq!(
            SamplerKind::parse("lut"),
            Ok(SamplerKind::GumbelLut { size: 16, bits: 8 })
        );
        assert_eq!(
            SamplerKind::parse("GUMBEL-LUT:32:6"),
            Ok(SamplerKind::GumbelLut { size: 32, bits: 6 })
        );
    }

    #[test]
    fn sampler_kind_parse_errors_name_accepted_forms() {
        let err = SamplerKind::parse("nope").unwrap_err();
        assert_eq!(err, SamplerParseError::Unknown("nope".to_string()));
        assert!(err.to_string().contains("lut:SIZE:BITS"), "{err}");

        // Missing BITS field.
        let err = SamplerKind::parse("lut:16").unwrap_err();
        assert!(matches!(
            err,
            SamplerParseError::BadLutField { field: "BITS", .. }
        ));
        assert!(err.to_string().contains("lut:SIZE:BITS"), "{err}");

        // Non-numeric SIZE.
        let err = SamplerKind::parse("lut:big:8").unwrap_err();
        assert!(matches!(
            err,
            SamplerParseError::BadLutField { field: "SIZE", .. }
        ));

        // Values the sampler constructor would reject.
        for bad in ["lut:1:8", "lut:16:1", "lut:16:25", "lut:2097152:8"] {
            assert!(matches!(
                SamplerKind::parse(bad),
                Err(SamplerParseError::LutOutOfRange { .. })
            ));
        }
    }

    #[test]
    fn beta_schedules() {
        let c = BetaSchedule::Constant(2.0);
        assert_eq!(c.beta(0), 2.0);
        assert_eq!(c.beta(100), 2.0);
        let l = BetaSchedule::Linear {
            from: 0.0,
            to: 1.0,
            steps: 10,
        };
        assert_eq!(l.beta(0), 0.0);
        assert_eq!(l.beta(5), 0.5);
        assert_eq!(l.beta(20), 1.0);
        let g = BetaSchedule::Geometric {
            from: 0.1,
            to: 2.0,
            rate: 2.0,
        };
        assert_eq!(g.beta(0), 0.1);
        assert!(g.beta(10) <= 2.0);
        // Cooling schedule: clamps from above and terminates *exactly*
        // at `to` (the wrong-sided `.min(to)` regression).
        let cool = BetaSchedule::Geometric {
            from: 2.0,
            to: 0.5,
            rate: 0.5,
        };
        assert_eq!(cool.beta(0), 2.0);
        assert_eq!(cool.beta(1), 1.0);
        assert_eq!(cool.beta(2), 0.5);
        assert_eq!(cool.beta(100), 0.5);
    }

    #[test]
    fn schedule_validation_rejects_degenerate_ramps() {
        for bad in [
            BetaSchedule::Geometric { from: 1.0, to: 2.0, rate: 0.0 },
            BetaSchedule::Geometric { from: 1.0, to: 2.0, rate: -1.0 },
            BetaSchedule::Geometric { from: 0.0, to: 2.0, rate: 1.5 },
            BetaSchedule::Geometric { from: 1.0, to: f32::NAN, rate: 1.5 },
            // Rate pointed away from (or exactly at) the target.
            BetaSchedule::Geometric { from: 0.5, to: 2.0, rate: 0.9 },
            BetaSchedule::Geometric { from: 2.0, to: 0.5, rate: 1.1 },
            BetaSchedule::Geometric { from: 0.5, to: 2.0, rate: 1.0 },
            BetaSchedule::Constant(-1.0),
            BetaSchedule::Linear { from: -0.5, to: 1.0, steps: 10 },
        ] {
            assert!(bad.validate().is_err(), "accepted {bad:?}");
        }
        for ok in [
            BetaSchedule::Constant(1.0),
            BetaSchedule::Linear { from: 0.0, to: 1.0, steps: 10 },
            BetaSchedule::Geometric { from: 0.1, to: 2.0, rate: 2.0 },
            BetaSchedule::Geometric { from: 2.0, to: 0.5, rate: 0.5 },
        ] {
            assert!(ok.validate().is_ok(), "rejected {ok:?}");
        }
    }

    #[test]
    fn chain_step_offset_shifts_the_schedule_clock() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        let schedule = BetaSchedule::Linear { from: 0.0, to: 1.0, steps: 100 };
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        let mut chain = Chain::new(&m, algo, schedule, 7);
        chain.set_step_offset(50);
        assert_eq!(chain.step_offset(), 50);
        chain.run(10);
        // β consumed at the last step was schedule.beta(50 + 9); the
        // next one would be beta(60) — pinned via the public clock.
        assert_eq!(chain.step_count, 10);
    }

    #[test]
    fn chain_histogram_totals() {
        let m = PottsGrid::new(3, 3, 2, 0.5);
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        let mut chain = Chain::new(&m, algo, BetaSchedule::Constant(1.0), 7);
        chain.run(50);
        assert_eq!(chain.step_count, 50);
        for i in 0..m.num_vars() {
            let marg = chain.marginal(i);
            assert!((marg.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn chain_tracks_best_objective() {
        let m = PottsGrid::new(4, 4, 2, 1.0);
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        let mut chain = Chain::new(
            &m,
            algo,
            BetaSchedule::Linear {
                from: 0.2,
                to: 3.0,
                steps: 100,
            },
            3,
        );
        chain.run(200);
        // Ferromagnetic 4x4 grid: ground state = all-equal, E = -24.
        assert!(chain.best_objective >= 20.0, "best={}", chain.best_objective);
        assert_eq!(
            chain.best_objective,
            m.objective(chain.best_assignment())
        );
    }
}
