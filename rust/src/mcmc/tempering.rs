//! Replica exchange (parallel tempering): per-chain β ladders with
//! even/odd neighbor swaps.
//!
//! Adaptive annealing ([`crate::mcmc::anneal`]) moves *every* chain
//! along one shared β trajectory. Replica exchange instead pins K
//! chains ("replicas") to K fixed inverse temperatures — a [`Ladder`]
//! — and periodically proposes to exchange the temperatures of
//! neighboring replicas with the standard Metropolis swap rule
//! `min(1, exp((β_i − β_j)(E_i − E_j)))`. Hot replicas (low β) cross
//! energy barriers freely; accepted swaps carry their discoveries down
//! to the cold end of the ladder. This is exactly the many-chain
//! scheme Sountsov et al. recommend for modern hardware, and the
//! tempered-ensemble mode the MRF accelerator of Bashizade et al. runs
//! for multimodal COP workloads — it is what makes the batched
//! backend's per-chain β storage ([`crate::mcmc::ChainBatch`]) real.
//!
//! The moving parts:
//!
//! * [`Ladder`] — the β rungs (geometric or explicit spacing) with
//!   up-front validation (K ≥ 2, strictly increasing, finite),
//! * [`ReplicaExchange`] — the controller for one ensemble of K
//!   replicas: swap proposals, per-pair acceptance accounting,
//!   round-trip tracking, optional adaptive re-spacing
//!   ([`AdaptSpacing`]) toward a target swap rate, and flat-state
//!   serialization for checkpoint/resume,
//! * [`TemperingReport`] — the per-pair swap-rate / per-replica
//!   round-trip diagnostics attached to every tempered
//!   [`crate::coordinator::ChainResult`].
//!
//! **Determinism:** swap decisions consume a dedicated RNG stream,
//! [`crate::rng::Rng::fork`]`(seed, `[`SWAP_STREAM`]` ^ ensemble)`,
//! disjoint from every chain's stream — and exactly one uniform draw
//! is consumed per proposed pair whether or not the acceptance test
//! needs it, so the stream position is a pure function of `(K, rounds)`
//! and a restored controller can replay it. Tempered trajectories are
//! therefore bit-identical across the software and batched backends,
//! pinned by `tests/integration_temper.rs`.

use crate::rng::Rng;

/// Dedicated RNG stream tag for swap decisions: ensemble `e` draws
/// from `Rng::fork(seed, SWAP_STREAM ^ e)`. The constant is far above
/// any chain id (chains use streams `0..chains`, restarts
/// `chain_id + epoch << 32`), so swap randomness never aliases a
/// chain's stream.
pub const SWAP_STREAM: u64 = 0x7E3A_9B1C_5D2F_8A47;

/// A β (inverse-temperature) ladder: one rung per replica, strictly
/// increasing from the hottest (rung 0, lowest β) to the coldest
/// (rung K−1, highest β — the sampling/optimization target).
#[derive(Clone, Debug, PartialEq)]
pub struct Ladder {
    betas: Vec<f32>,
}

impl Ladder {
    /// A K-rung ladder spaced geometrically (uniform in log β) from
    /// `from` to `to`, endpoints exact.
    pub fn geometric(from: f32, to: f32, k: usize) -> Ladder {
        let mut betas = Vec::with_capacity(k);
        if k == 1 {
            betas.push(to);
        } else if k >= 2 {
            let lf = (from.max(f32::MIN_POSITIVE) as f64).ln();
            let lt = (to.max(f32::MIN_POSITIVE) as f64).ln();
            for r in 0..k {
                let f = r as f64 / (k - 1) as f64;
                betas.push((lf + (lt - lf) * f).exp() as f32);
            }
            betas[0] = from;
            betas[k - 1] = to;
        }
        Ladder { betas }
    }

    /// A ladder from explicit rungs (validated by [`Ladder::validate`]).
    pub fn explicit(betas: Vec<f32>) -> Ladder {
        Ladder { betas }
    }

    /// The rungs, hottest first.
    pub fn betas(&self) -> &[f32] {
        &self.betas
    }

    /// Number of rungs (replicas per ensemble).
    pub fn k(&self) -> usize {
        self.betas.len()
    }

    /// Reject degenerate ladders up front: fewer than 2 rungs, a
    /// non-finite or non-positive β, or rungs that are not strictly
    /// increasing.
    pub fn validate(&self) -> Result<(), String> {
        if self.betas.len() < 2 {
            return Err(format!(
                "tempering ladder needs at least 2 rungs (got {})",
                self.betas.len()
            ));
        }
        for (r, &b) in self.betas.iter().enumerate() {
            if !b.is_finite() || b <= 0.0 {
                return Err(format!(
                    "tempering ladder rung {r} must be finite and > 0 (got {b})"
                ));
            }
        }
        for (r, w) in self.betas.windows(2).enumerate() {
            if w[1] <= w[0] {
                return Err(format!(
                    "tempering ladder must be strictly increasing: rung {} (β = {}) \
                     does not exceed rung {r} (β = {})",
                    r + 1,
                    w[1],
                    w[0]
                ));
            }
        }
        Ok(())
    }

    /// Parse a CLI `--ladder` spec for a K-replica ensemble:
    /// `geom:FROM:TO` (K rungs, geometric spacing) or
    /// `explicit:B1,B2,…` (exactly K comma-separated rungs).
    pub fn parse(spec: &str, k: usize) -> Result<Ladder, String> {
        let bad = || format!("bad ladder {spec:?} (geom:FROM:TO | explicit:B1,B2,…)");
        let parts: Vec<&str> = spec.split(':').collect();
        let ladder = match parts.as_slice() {
            ["geom", f, t] | ["geometric", f, t] => {
                let from = f.parse::<f32>().map_err(|_| bad())?;
                let to = t.parse::<f32>().map_err(|_| bad())?;
                Ladder::geometric(from, to, k)
            }
            ["explicit", list] => {
                let mut betas = Vec::new();
                for tok in list.split(',') {
                    betas.push(tok.trim().parse::<f32>().map_err(|_| bad())?);
                }
                if betas.len() != k {
                    return Err(format!(
                        "explicit ladder lists {} rungs but --temper asks for {k} replicas",
                        betas.len()
                    ));
                }
                Ladder::explicit(betas)
            }
            _ => return Err(bad()),
        };
        ladder.validate()?;
        Ok(ladder)
    }
}

/// Adaptive ladder re-spacing: every `every_rounds` swap rounds the
/// log-β gaps are rescaled toward `target_rate` per-pair acceptance
/// (a pair swapping too often sits too close — widen its gap; one
/// swapping too rarely sits too far — shrink it), then renormalized so
/// the endpoint rungs stay fixed. Monotonicity is preserved because
/// gaps stay positive.
#[derive(Clone, Copy, Debug)]
pub struct AdaptSpacing {
    /// Per-pair swap acceptance rate to steer toward (must lie in
    /// (0, 1); the engine builder enforces this).
    pub target_rate: f64,
    /// Swap rounds per adaptation window.
    pub every_rounds: usize,
    /// Per-window clamp on any gap's rescale factor (and its inverse).
    pub max_factor: f64,
}

impl AdaptSpacing {
    /// The CLI default: 30% target rate, retune every 10 swap rounds,
    /// gaps move at most 2× per window.
    pub fn new(target_rate: f64) -> AdaptSpacing {
        AdaptSpacing {
            target_rate,
            every_rounds: 10,
            max_factor: 2.0,
        }
    }
}

impl Default for AdaptSpacing {
    fn default() -> Self {
        AdaptSpacing::new(0.3)
    }
}

/// Tuning knobs for a [`ReplicaExchange`] controller.
#[derive(Clone, Copy, Debug)]
pub struct TemperConfig {
    /// Steps between swap rounds (the CLI's `--swap-every`).
    pub swap_every: usize,
    /// Adaptive ladder re-spacing (None = keep the ladder fixed).
    pub adapt: Option<AdaptSpacing>,
}

impl Default for TemperConfig {
    fn default() -> Self {
        TemperConfig {
            swap_every: 10,
            adapt: None,
        }
    }
}

/// Per-ensemble tempering diagnostics, attached to every tempered
/// chain's [`crate::coordinator::ChainResult`]. Pair `r` is the swap
/// channel between rungs `r` and `r + 1`; replica slot `s` is the
/// chain `first_chain + s`.
#[derive(Clone, Debug)]
pub struct TemperingReport {
    /// First chain id of the ensemble.
    pub first_chain: usize,
    /// Final ladder rungs (differs from the initial ladder only under
    /// [`AdaptSpacing`]).
    pub betas: Vec<f32>,
    /// Swap proposals per adjacent rung pair (length K−1).
    pub pair_attempts: Vec<u64>,
    /// Accepted swaps per adjacent rung pair (length K−1).
    pub pair_accepts: Vec<u64>,
    /// Completed ladder round trips (rung 0 → K−1 → 0) per replica
    /// slot.
    pub round_trips: Vec<u64>,
    /// Final rung of each replica slot.
    pub rungs: Vec<usize>,
    /// Swap rounds executed.
    pub rounds: u64,
    /// Ladder re-spacing windows applied.
    pub adapts: u64,
}

impl TemperingReport {
    /// Acceptance rate per adjacent rung pair (0 when never proposed).
    pub fn swap_rates(&self) -> Vec<f64> {
        self.pair_attempts
            .iter()
            .zip(&self.pair_accepts)
            .map(|(&att, &acc)| if att == 0 { 0.0 } else { acc as f64 / att as f64 })
            .collect()
    }

    /// Mean per-pair acceptance rate.
    pub fn mean_swap_rate(&self) -> f64 {
        let rates = self.swap_rates();
        if rates.is_empty() {
            0.0
        } else {
            rates.iter().sum::<f64>() / rates.len() as f64
        }
    }

    /// Total round trips across the ensemble.
    pub fn total_round_trips(&self) -> u64 {
        self.round_trips.iter().sum()
    }
}

/// Round-trip phase per replica slot: the slot has not yet touched the
/// bottom rung, is heading up from the bottom, or is heading back down
/// from the top.
const PHASE_NONE: u8 = 0;
const PHASE_UP: u8 = 1;
const PHASE_DOWN: u8 = 2;

/// The replica-exchange controller for one ensemble of K replicas
/// (chains `first_chain .. first_chain + K`).
///
/// The controller swaps *temperatures*, not states: replica slot `s`
/// is a chain whose RNG stream and state evolve untouched, while
/// `rung_of[s]` — the ladder rung it currently runs at — migrates via
/// accepted swaps. This keeps every backend's chains bit-identical
/// (no cross-chain state copies) and makes a swap O(1).
pub struct ReplicaExchange {
    ladder: Ladder,
    cfg: TemperConfig,
    first_chain: usize,
    /// Seed of the dedicated swap stream (replayable on restore).
    rng_seed: u64,
    rng: Rng,
    /// Swap rounds completed (round parity selects even/odd pairs).
    rounds: u64,
    /// Replica slot → current rung.
    rung_of: Vec<usize>,
    /// Current rung → replica slot (inverse of `rung_of`).
    slot_of: Vec<usize>,
    pair_attempts: Vec<u64>,
    pair_accepts: Vec<u64>,
    /// Adaptation-window counters (reset every retune).
    win_attempts: Vec<u64>,
    win_accepts: Vec<u64>,
    trip_phase: Vec<u8>,
    round_trips: Vec<u64>,
    adapts: u64,
}

impl ReplicaExchange {
    /// Controller for ensemble `ensemble` (chains `first_chain ..
    /// first_chain + ladder.k()`), with slot `s` starting on rung `s`.
    pub fn new(
        ladder: Ladder,
        cfg: TemperConfig,
        seed: u64,
        first_chain: usize,
        ensemble: u64,
    ) -> ReplicaExchange {
        let k = ladder.k();
        let rng_seed = Rng::fork_seed(seed, SWAP_STREAM ^ ensemble);
        let mut trip_phase = vec![PHASE_NONE; k];
        if k > 0 {
            // Slot 0 starts on the bottom rung: its round trip is armed.
            trip_phase[0] = PHASE_UP;
        }
        ReplicaExchange {
            ladder,
            cfg,
            first_chain,
            rng_seed,
            rng: Rng::new(rng_seed),
            rounds: 0,
            rung_of: (0..k).collect(),
            slot_of: (0..k).collect(),
            pair_attempts: vec![0; k.saturating_sub(1)],
            pair_accepts: vec![0; k.saturating_sub(1)],
            win_attempts: vec![0; k.saturating_sub(1)],
            win_accepts: vec![0; k.saturating_sub(1)],
            trip_phase,
            round_trips: vec![0; k],
            adapts: 0,
        }
    }

    /// Replicas per ensemble.
    pub fn k(&self) -> usize {
        self.ladder.k()
    }

    /// First chain id of the ensemble.
    pub fn first_chain(&self) -> usize {
        self.first_chain
    }

    /// Global chain id of replica slot `slot`.
    pub fn chain_id(&self, slot: usize) -> usize {
        self.first_chain + slot
    }

    /// Steps between swap rounds.
    pub fn swap_every(&self) -> usize {
        self.cfg.swap_every.max(1)
    }

    /// β replica slot `slot` currently runs at.
    pub fn beta_of_slot(&self, slot: usize) -> f32 {
        self.ladder.betas()[self.rung_of[slot]]
    }

    /// The current ladder (re-spaced under [`AdaptSpacing`]).
    pub fn ladder(&self) -> &Ladder {
        &self.ladder
    }

    /// Swap rounds completed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// One even/odd swap round. `energies[slot]` is the *energy*
    /// (−objective) of replica slot `slot`'s current state; a pair of
    /// neighboring rungs `(r, r+1)` swaps with probability
    /// `min(1, exp((β_r − β_{r+1})(E_r − E_{r+1})))`. Even rounds
    /// propose pairs starting at rung 0, odd rounds at rung 1, so
    /// every channel is exercised every two rounds. Returns the number
    /// of accepted swaps.
    pub fn swap_round(&mut self, energies: &[f64]) -> usize {
        let k = self.k();
        assert_eq!(energies.len(), k, "one energy per replica slot");
        let betas = self.ladder.betas().to_vec();
        let mut accepted = 0usize;
        let mut attempts = 0u64;
        let mut r = (self.rounds % 2) as usize;
        while r + 1 < k {
            let (si, sj) = (self.slot_of[r], self.slot_of[r + 1]);
            // One draw per proposed pair, *always*: the stream position
            // stays a pure function of (K, rounds) so checkpoint
            // restore can replay it.
            let u = self.rng.uniform_f64();
            let log_a = (betas[r] as f64 - betas[r + 1] as f64) * (energies[si] - energies[sj]);
            self.pair_attempts[r] += 1;
            self.win_attempts[r] += 1;
            attempts += 1;
            if log_a >= 0.0 || u < log_a.exp() {
                self.rung_of[si] = r + 1;
                self.rung_of[sj] = r;
                self.slot_of[r] = sj;
                self.slot_of[r + 1] = si;
                self.pair_accepts[r] += 1;
                self.win_accepts[r] += 1;
                accepted += 1;
            }
            r += 2;
        }
        self.rounds += 1;
        if crate::engine::telemetry::enabled() {
            let m = crate::engine::telemetry::metrics();
            m.counter_add("swap_attempts_total", &[], attempts);
            m.counter_add("swap_accepts_total", &[], accepted as u64);
        }
        // Round-trip bookkeeping: a slot completes a trip when it
        // returns to the bottom rung after touching the top.
        for slot in 0..k {
            let rung = self.rung_of[slot];
            if rung == 0 {
                if self.trip_phase[slot] == PHASE_DOWN {
                    self.round_trips[slot] += 1;
                }
                self.trip_phase[slot] = PHASE_UP;
            } else if rung == k - 1 && self.trip_phase[slot] == PHASE_UP {
                self.trip_phase[slot] = PHASE_DOWN;
            }
        }
        if let Some(adapt) = self.cfg.adapt {
            if adapt.every_rounds > 0 && self.rounds % adapt.every_rounds as u64 == 0 {
                self.retune(adapt);
            }
        }
        accepted
    }

    /// Rescale the log-β gaps toward the target per-pair swap rate and
    /// renormalize so the endpoint rungs stay fixed.
    fn retune(&mut self, adapt: AdaptSpacing) {
        let k = self.k();
        if k < 2 {
            return;
        }
        let betas = self.ladder.betas();
        let lo = (betas[0] as f64).ln();
        let hi = (betas[k - 1] as f64).ln();
        // Damping keeps a zero-acceptance window from collapsing a gap
        // to the clamp floor in one jump.
        const DAMP: f64 = 0.05;
        let max_f = adapt.max_factor.max(1.0);
        let mut gaps: Vec<f64> = betas
            .windows(2)
            .map(|w| (w[1] as f64).ln() - (w[0] as f64).ln())
            .collect();
        for (r, gap) in gaps.iter_mut().enumerate() {
            let rate = if self.win_attempts[r] == 0 {
                adapt.target_rate
            } else {
                self.win_accepts[r] as f64 / self.win_attempts[r] as f64
            };
            let factor = ((rate + DAMP) / (adapt.target_rate + DAMP)).clamp(1.0 / max_f, max_f);
            *gap *= factor;
        }
        let total: f64 = gaps.iter().sum();
        if total > 0.0 && total.is_finite() {
            let span = hi - lo;
            let mut new_betas = Vec::with_capacity(k);
            new_betas.push(betas[0]);
            let mut acc = lo;
            for gap in &gaps[..k - 1] {
                acc += gap / total * span;
                new_betas.push(acc.exp() as f32);
            }
            new_betas[k - 1] = betas[k - 1];
            self.ladder = Ladder::explicit(new_betas);
        }
        self.win_attempts.fill(0);
        self.win_accepts.fill(0);
        self.adapts += 1;
    }

    /// The ensemble's diagnostics snapshot.
    pub fn report(&self) -> TemperingReport {
        TemperingReport {
            first_chain: self.first_chain,
            betas: self.ladder.betas().to_vec(),
            pair_attempts: self.pair_attempts.clone(),
            pair_accepts: self.pair_accepts.clone(),
            round_trips: self.round_trips.clone(),
            rungs: self.rung_of.clone(),
            rounds: self.rounds,
            adapts: self.adapts,
        }
    }

    /// Serialized-state length for a K-rung ensemble (see
    /// [`ReplicaExchange::state`]).
    pub fn state_len(k: usize) -> usize {
        3 + 4 * k + 4 * k.saturating_sub(1)
    }

    /// Serialize the controller's memory as a flat vector (stored in
    /// [`crate::engine::Checkpoint`]'s `temper` field). The swap RNG
    /// is *not* serialized: its position is `rounds`-determined and
    /// [`ReplicaExchange::restore`] replays it.
    pub fn state(&self) -> Vec<f64> {
        let k = self.k();
        let mut s = Vec::with_capacity(Self::state_len(k));
        s.push(k as f64);
        s.push(self.rounds as f64);
        s.push(self.adapts as f64);
        s.extend(self.ladder.betas().iter().map(|&b| b as f64));
        s.extend(self.rung_of.iter().map(|&r| r as f64));
        s.extend(self.pair_attempts.iter().map(|&v| v as f64));
        s.extend(self.pair_accepts.iter().map(|&v| v as f64));
        s.extend(self.win_attempts.iter().map(|&v| v as f64));
        s.extend(self.win_accepts.iter().map(|&v| v as f64));
        s.extend(self.trip_phase.iter().map(|&p| p as f64));
        s.extend(self.round_trips.iter().map(|&v| v as f64));
        s
    }

    /// Restore memory serialized by [`ReplicaExchange::state`],
    /// replaying the swap RNG to its recorded position: one draw per
    /// proposed pair, `⌊K/2⌋` pairs on even rounds and `⌊(K−1)/2⌋` on
    /// odd rounds.
    pub fn restore(&mut self, state: &[f64]) -> Result<(), String> {
        let k = self.k();
        if state.len() != Self::state_len(k) {
            return Err(format!(
                "tempering state has {} entries, expected {} for a {k}-rung ladder",
                state.len(),
                Self::state_len(k)
            ));
        }
        if state[0] as usize != k {
            return Err(format!(
                "tempering state was saved for a {}-rung ladder, this run uses {k}",
                state[0] as usize
            ));
        }
        self.rounds = state[1] as u64;
        self.adapts = state[2] as u64;
        let mut at = 3usize;
        let mut next = |n: usize| {
            let range = at..at + n;
            at += n;
            range
        };
        let betas: Vec<f32> = state[next(k)].iter().map(|&b| b as f32).collect();
        let ladder = Ladder::explicit(betas);
        ladder.validate()?;
        self.ladder = ladder;
        let rung_of: Vec<usize> = state[next(k)].iter().map(|&r| r as usize).collect();
        let mut slot_of = vec![usize::MAX; k];
        for (slot, &rung) in rung_of.iter().enumerate() {
            if rung >= k || slot_of[rung] != usize::MAX {
                return Err("tempering state rung assignment is not a permutation".into());
            }
            slot_of[rung] = slot;
        }
        self.rung_of = rung_of;
        self.slot_of = slot_of;
        self.pair_attempts = state[next(k - 1)].iter().map(|&v| v as u64).collect();
        self.pair_accepts = state[next(k - 1)].iter().map(|&v| v as u64).collect();
        self.win_attempts = state[next(k - 1)].iter().map(|&v| v as u64).collect();
        self.win_accepts = state[next(k - 1)].iter().map(|&v| v as u64).collect();
        self.trip_phase = state[next(k)].iter().map(|&p| p as u8).collect();
        self.round_trips = state[next(k)].iter().map(|&v| v as u64).collect();
        // Replay the swap stream to its recorded position.
        self.rng = Rng::new(self.rng_seed);
        let ku = k as u64;
        let draws = (self.rounds / 2) * ku.saturating_sub(1) + (self.rounds % 2) * (ku / 2);
        for _ in 0..draws {
            let _ = self.rng.uniform_f64();
        }
        Ok(())
    }

    /// One-line human-readable summary.
    pub fn describe(&self) -> String {
        format!(
            "temper(K={}, chains {}..{}): {} swap rounds, mean swap rate {:.2}, \
             {} round trips, {} retunes",
            self.k(),
            self.first_chain,
            self.first_chain + self.k(),
            self.rounds,
            self.report().mean_swap_rate(),
            self.round_trips.iter().sum::<u64>(),
            self.adapts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder4() -> Ladder {
        Ladder::explicit(vec![0.25, 0.5, 1.0, 2.0])
    }

    #[test]
    fn geometric_ladder_hits_endpoints_and_is_monotone() {
        let l = Ladder::geometric(0.2, 3.2, 5);
        assert_eq!(l.k(), 5);
        assert_eq!(l.betas()[0], 0.2);
        assert_eq!(l.betas()[4], 3.2);
        l.validate().unwrap();
        // Uniform log spacing: ratios between neighbors are equal.
        let r0 = l.betas()[1] / l.betas()[0];
        let r2 = l.betas()[3] / l.betas()[2];
        assert!((r0 - r2).abs() < 1e-3, "{r0} vs {r2}");
    }

    #[test]
    fn ladder_validation_rejects_degenerate_rungs() {
        for bad in [
            Ladder::explicit(vec![1.0]),
            Ladder::explicit(vec![]),
            Ladder::explicit(vec![1.0, 1.0]),
            Ladder::explicit(vec![2.0, 1.0]),
            Ladder::explicit(vec![0.0, 1.0]),
            Ladder::explicit(vec![-1.0, 1.0]),
            Ladder::explicit(vec![1.0, f32::NAN]),
        ] {
            assert!(bad.validate().is_err(), "accepted {:?}", bad.betas());
        }
        ladder4().validate().unwrap();
    }

    #[test]
    fn ladder_parse_roundtrip_and_errors() {
        let l = Ladder::parse("geom:0.2:3.2", 5).unwrap();
        assert_eq!(l.betas(), Ladder::geometric(0.2, 3.2, 5).betas());
        let e = Ladder::parse("explicit:0.25,0.5,1,2", 4).unwrap();
        assert_eq!(e, ladder4());
        assert!(Ladder::parse("geom:0.2", 4).is_err());
        assert!(Ladder::parse("explicit:1,2", 4).is_err());
        assert!(Ladder::parse("explicit:2,1,3,4", 4).is_err());
        assert!(Ladder::parse("nope:1:2", 4).is_err());
        assert!(Ladder::parse("geom:0.5:2.0", 1).is_err());
    }

    #[test]
    fn certain_swaps_are_accepted_and_rungs_migrate() {
        // Hot replica holds a *lower* energy than its colder neighbor:
        // log_a = (β_r − β_{r+1})(E_r − E_{r+1}) > 0 ⇒ certain accept.
        let mut ex = ReplicaExchange::new(ladder4(), TemperConfig::default(), 1, 0, 0);
        // Slot s starts on rung s. Energies increasing in slot make
        // every even pair a certain swap.
        let accepted = ex.swap_round(&[-30.0, -20.0, -10.0, 0.0]);
        assert_eq!(accepted, 2, "pairs (0,1) and (2,3) must both swap");
        // Slots 0↔1 and 2↔3 exchanged rungs.
        assert_eq!(ex.beta_of_slot(0), 0.5);
        assert_eq!(ex.beta_of_slot(1), 0.25);
        assert_eq!(ex.beta_of_slot(2), 2.0);
        assert_eq!(ex.beta_of_slot(3), 1.0);
        let rep = ex.report();
        assert_eq!(rep.pair_attempts, vec![1, 0, 1]);
        assert_eq!(rep.pair_accepts, vec![1, 0, 1]);
    }

    #[test]
    fn hopeless_swaps_are_rejected() {
        // Huge energy penalty the wrong way: exp(log_a) underflows to 0.
        let mut ex = ReplicaExchange::new(ladder4(), TemperConfig::default(), 1, 0, 0);
        let accepted = ex.swap_round(&[0.0, -1e6, 0.0, -1e6]);
        assert_eq!(accepted, 0);
        assert_eq!(ex.beta_of_slot(0), 0.25);
        let rep = ex.report();
        assert_eq!(rep.pair_attempts, vec![1, 0, 1]);
        assert_eq!(rep.pair_accepts, vec![0, 0, 0]);
    }

    #[test]
    fn even_odd_rounds_alternate_pairs() {
        let mut ex = ReplicaExchange::new(ladder4(), TemperConfig::default(), 1, 0, 0);
        ex.swap_round(&[0.0; 4]);
        ex.swap_round(&[0.0; 4]);
        let rep = ex.report();
        // Round 0 proposes (0,1),(2,3); round 1 proposes (1,2).
        assert_eq!(rep.pair_attempts, vec![1, 1, 1]);
        assert_eq!(rep.rounds, 2);
    }

    #[test]
    fn round_trips_count_bottom_top_bottom() {
        let mut ex = ReplicaExchange::new(
            Ladder::explicit(vec![0.5, 1.0]),
            TemperConfig::default(),
            1,
            0,
            0,
        );
        // K = 2: every even round proposes the single pair. Equal
        // energies ⇒ log_a = 0 ⇒ certain accept. Slot 0 bounces
        // 0 → 1 → 0 → 1 …, completing a trip every second accepted
        // swap. Odd rounds propose nothing.
        for _ in 0..8 {
            ex.swap_round(&[0.0, 0.0]);
        }
        let rep = ex.report();
        // 4 even rounds ⇒ 4 swaps: slot 0 path 1,1?,… rungs after each
        // even round alternate; two full trips.
        assert_eq!(rep.pair_attempts, vec![4]);
        assert_eq!(rep.pair_accepts, vec![4]);
        assert!(rep.round_trips[0] >= 1, "{:?}", rep.round_trips);
        assert_eq!(rep.total_round_trips(), rep.round_trips.iter().sum::<u64>());
    }

    #[test]
    fn adaptive_respacing_keeps_endpoints_and_monotonicity() {
        let cfg = TemperConfig {
            swap_every: 5,
            adapt: Some(AdaptSpacing {
                target_rate: 0.3,
                every_rounds: 2,
                max_factor: 2.0,
            }),
        };
        let mut ex = ReplicaExchange::new(ladder4(), cfg, 1, 0, 0);
        // All swaps certain ⇒ rates 1.0 ≫ target ⇒ gaps widen, then
        // renormalize; endpoints must stay put and order must hold.
        for _ in 0..6 {
            ex.swap_round(&[0.0; 4]);
        }
        let rep = ex.report();
        assert!(rep.adapts >= 1);
        assert_eq!(rep.betas[0], 0.25);
        assert_eq!(rep.betas[3], 2.0);
        Ladder::explicit(rep.betas.clone()).validate().unwrap();
    }

    #[test]
    fn state_roundtrip_replays_the_swap_stream() {
        let cfg = TemperConfig {
            swap_every: 5,
            adapt: Some(AdaptSpacing::new(0.3)),
        };
        // Borderline energies so acceptance genuinely consumes the
        // uniform draw (neither certain accept nor certain reject).
        let energy = |round: u64, slot: usize| -> f64 {
            ((round as f64 * 0.7 + slot as f64 * 1.3).sin()) * 2.0
        };
        let mut a = ReplicaExchange::new(ladder4(), cfg, 99, 4, 1);
        for round in 0..5 {
            let e: Vec<f64> = (0..4).map(|s| energy(round, s)).collect();
            a.swap_round(&e);
        }
        let saved = a.state();
        assert_eq!(saved.len(), ReplicaExchange::state_len(4));
        // Continue the original.
        for round in 5..12 {
            let e: Vec<f64> = (0..4).map(|s| energy(round, s)).collect();
            a.swap_round(&e);
        }
        // Restore a fresh controller mid-sequence and replay the tail.
        let mut b = ReplicaExchange::new(ladder4(), cfg, 99, 4, 1);
        b.restore(&saved).unwrap();
        for round in 5..12 {
            let e: Vec<f64> = (0..4).map(|s| energy(round, s)).collect();
            b.swap_round(&e);
        }
        assert_eq!(a.state(), b.state(), "resumed swap schedule diverged");
        assert_eq!(a.report().pair_accepts, b.report().pair_accepts);
        // Wrong-length and wrong-K states are typed errors.
        assert!(b.restore(&[1.0, 2.0]).is_err());
        let mut wrong_k = saved.clone();
        wrong_k[0] = 3.0;
        assert!(b.restore(&wrong_k).is_err());
    }
}
