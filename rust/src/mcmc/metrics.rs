//! Convergence metrics: accuracy-vs-steps/ops traces (Fig. 5a/5b).
//!
//! "Accuracy" follows the paper's COP convention: the best objective
//! seen so far divided by the instance's best-known objective, traced
//! against both algorithmic steps and consumed operations so that the
//! step-efficient-but-op-hungry behavior of gradient-based samplers
//! (observation 1 in §III) is visible.

use super::{BetaSchedule, Chain, Mcmc};
use crate::energy::EnergyModel;

/// One point on a convergence trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    /// Algorithmic steps so far.
    pub steps: u64,
    /// Consumed arithmetic ops so far (paper's Fig. 5a x-axis).
    pub ops: u64,
    /// Bytes moved so far.
    pub bytes: u64,
    /// Samples drawn so far.
    pub samples: u64,
    /// Best objective so far.
    pub best_objective: f64,
    /// best_objective / best_known (clamped to [0, 1] when known).
    pub accuracy: f64,
}

/// A full convergence trace plus summary.
#[derive(Clone, Debug)]
pub struct AccuracyTrace {
    /// Algorithm name.
    pub algo: &'static str,
    /// Sampled trace points.
    pub points: Vec<TracePoint>,
    /// First step index reaching the target accuracy, if ever.
    pub steps_to_target: Option<u64>,
    /// Ops consumed when the target accuracy was first reached.
    pub ops_to_target: Option<u64>,
    /// Target accuracy used.
    pub target: f64,
}

/// Run `algo` on `model` until `target` accuracy or `max_steps`,
/// recording a trace every `trace_every` steps.
pub fn run_to_accuracy(
    model: &dyn EnergyModel,
    algo: Box<dyn Mcmc>,
    schedule: BetaSchedule,
    target: f64,
    max_steps: usize,
    trace_every: usize,
    seed: u64,
) -> AccuracyTrace {
    let best_known = model.best_known();
    let name = algo.name();
    let mut chain = Chain::new(model, algo, schedule, seed);
    let mut points = Vec::new();
    let mut steps_to_target = None;
    let mut ops_to_target = None;

    let accuracy_of = |best: f64| -> f64 {
        match best_known {
            Some(bk) if bk != 0.0 => (best / bk).clamp(0.0, 1.0),
            _ => best,
        }
    };

    let chunk = trace_every.max(1);
    let mut step = 0usize;
    // initial point
    points.push(TracePoint {
        steps: 0,
        ops: 0,
        bytes: 0,
        samples: 0,
        best_objective: chain.best_objective,
        accuracy: accuracy_of(chain.best_objective),
    });
    while step < max_steps {
        let n = chunk.min(max_steps - step);
        chain.run(n);
        step += n;
        let acc = accuracy_of(chain.best_objective);
        points.push(TracePoint {
            steps: step as u64,
            ops: chain.stats.cost.ops,
            bytes: chain.stats.cost.bytes,
            samples: chain.stats.cost.samples,
            best_objective: chain.best_objective,
            accuracy: acc,
        });
        if acc >= target && steps_to_target.is_none() {
            steps_to_target = Some(step as u64);
            ops_to_target = Some(chain.stats.cost.ops);
            break;
        }
    }
    AccuracyTrace {
        algo: name,
        points,
        steps_to_target,
        ops_to_target,
        target,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::MaxCutModel;
    use crate::graph::Graph;
    use crate::mcmc::{build_algo, AlgoKind, SamplerKind};

    fn small_cut() -> MaxCutModel {
        // 4-cycle: optimal cut = 4.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)], None);
        MaxCutModel::new(g, Some(4.0))
    }

    #[test]
    fn trace_reaches_target_on_trivial_instance() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        let tr = run_to_accuracy(
            &m,
            algo,
            BetaSchedule::Linear {
                from: 0.5,
                to: 4.0,
                steps: 50,
            },
            0.99,
            500,
            5,
            3,
        );
        assert!(tr.steps_to_target.is_some(), "never hit target: {tr:?}");
        assert!(tr.ops_to_target.unwrap() > 0);
    }

    #[test]
    fn trace_is_monotone_in_ops_and_accuracy() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Mh, SamplerKind::Gumbel, &m, 1);
        let tr = run_to_accuracy(&m, algo, BetaSchedule::Constant(1.0), 1.1, 100, 10, 5);
        for w in tr.points.windows(2) {
            assert!(w[1].ops >= w[0].ops);
            assert!(w[1].accuracy >= w[0].accuracy);
        }
    }

    #[test]
    fn unreachable_target_returns_none() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        // Target accuracy 2.0 can never be reached (clamped at 1.0).
        let tr = run_to_accuracy(&m, algo, BetaSchedule::Constant(1.0), 2.0, 20, 5, 7);
        assert!(tr.steps_to_target.is_none());
        assert_eq!(tr.points.last().unwrap().steps, 20);
    }
}
