//! Convergence metrics: accuracy-vs-steps/ops traces (Fig. 5a/5b) and
//! the cross-chain diagnostics the engine's observer loop streams
//! (split potential-scale-reduction R-hat, effective sample size).
//!
//! "Accuracy" follows the paper's COP convention: the best objective
//! seen so far divided by the instance's best-known objective, traced
//! against both algorithmic steps and consumed operations so that the
//! step-efficient-but-op-hungry behavior of gradient-based samplers
//! (observation 1 in §III) is visible.

use super::{BetaSchedule, Chain, Mcmc};
use crate::energy::EnergyModel;

/// One point on a convergence trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    /// Algorithmic steps so far.
    pub steps: u64,
    /// Consumed arithmetic ops so far (paper's Fig. 5a x-axis).
    pub ops: u64,
    /// Bytes moved so far.
    pub bytes: u64,
    /// Samples drawn so far.
    pub samples: u64,
    /// Best objective so far.
    pub best_objective: f64,
    /// best_objective / best_known (clamped to [0, 1] when known).
    pub accuracy: f64,
}

/// A full convergence trace plus summary.
#[derive(Clone, Debug)]
pub struct AccuracyTrace {
    /// Algorithm name.
    pub algo: &'static str,
    /// Sampled trace points.
    pub points: Vec<TracePoint>,
    /// First step index reaching the target accuracy, if ever.
    pub steps_to_target: Option<u64>,
    /// Ops consumed when the target accuracy was first reached.
    pub ops_to_target: Option<u64>,
    /// Target accuracy used.
    pub target: f64,
}

/// Run `algo` on `model` until `target` accuracy or `max_steps`,
/// recording a trace every `trace_every` steps.
pub fn run_to_accuracy(
    model: &dyn EnergyModel,
    algo: Box<dyn Mcmc>,
    schedule: BetaSchedule,
    target: f64,
    max_steps: usize,
    trace_every: usize,
    seed: u64,
) -> AccuracyTrace {
    let best_known = model.best_known();
    let name = algo.name();
    let mut chain = Chain::new(model, algo, schedule, seed);
    let mut points = Vec::new();
    let mut steps_to_target = None;
    let mut ops_to_target = None;

    let accuracy_of = |best: f64| -> f64 {
        match best_known {
            Some(bk) if bk != 0.0 => (best / bk).clamp(0.0, 1.0),
            _ => best,
        }
    };

    let chunk = trace_every.max(1);
    let mut step = 0usize;
    // initial point
    points.push(TracePoint {
        steps: 0,
        ops: 0,
        bytes: 0,
        samples: 0,
        best_objective: chain.best_objective,
        accuracy: accuracy_of(chain.best_objective),
    });
    while step < max_steps {
        let n = chunk.min(max_steps - step);
        chain.run(n);
        step += n;
        let acc = accuracy_of(chain.best_objective);
        points.push(TracePoint {
            steps: step as u64,
            ops: chain.stats.cost.ops,
            bytes: chain.stats.cost.bytes,
            samples: chain.stats.cost.samples,
            best_objective: chain.best_objective,
            accuracy: acc,
        });
        if acc >= target && steps_to_target.is_none() {
            steps_to_target = Some(step as u64);
            ops_to_target = Some(chain.stats.cost.ops);
            break;
        }
    }
    AccuracyTrace {
        algo: name,
        points,
        steps_to_target,
        ops_to_target,
        target,
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n−1 denominator); 0 for n < 2.
fn sample_variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    xs.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Split potential-scale-reduction (R-hat) over per-chain scalar
/// traces (Gelman et al.; each chain is split in half, so a single
/// long chain still yields a diagnostic). Values near 1 indicate the
/// chains have mixed; > ~1.05 means keep sampling.
///
/// Returns `None` until every chain has at least 4 observations (two
/// per split half). Traces of unequal length are truncated to the
/// shortest.
pub fn split_r_hat(traces: &[Vec<f64>]) -> Option<f64> {
    let n = traces.iter().map(Vec::len).min()?;
    let half = n / 2;
    if half < 2 {
        return None;
    }
    let mut subs: Vec<&[f64]> = Vec::with_capacity(2 * traces.len());
    for t in traces {
        subs.push(&t[..half]);
        subs.push(&t[n - half..n]);
    }
    let m = subs.len() as f64;
    let len = half as f64;
    let means: Vec<f64> = subs.iter().map(|s| mean(s)).collect();
    let grand = mean(&means);
    let between = len / (m - 1.0) * means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>();
    let within = subs.iter().map(|s| sample_variance(s)).sum::<f64>() / m;
    if within <= 0.0 {
        // Zero within-chain variance: either perfectly stuck chains
        // that agree (R-hat 1) or disagree (diverged → infinity).
        return Some(if between <= 0.0 { 1.0 } else { f64::INFINITY });
    }
    let var_plus = (len - 1.0) / len * within + between / len;
    Some((var_plus / within).sqrt())
}

/// Effective sample size of one scalar trace via Geyer's initial
/// positive sequence: autocorrelations are summed in pairs until a
/// pair goes negative. Clamped to `[1, n]`; short traces (< 4) return
/// their own length.
pub fn effective_sample_size(trace: &[f64]) -> f64 {
    let n = trace.len();
    if n < 4 {
        return n as f64;
    }
    let mu = mean(trace);
    let var = trace.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / n as f64;
    if var <= 0.0 {
        return n as f64;
    }
    let rho = |lag: usize| -> f64 {
        let mut acc = 0.0;
        for t in 0..n - lag {
            acc += (trace[t] - mu) * (trace[t + lag] - mu);
        }
        acc / n as f64 / var
    };
    let mut sum = 0.0;
    let mut lag = 1;
    while lag + 1 < n {
        let pair = rho(lag) + rho(lag + 1);
        if pair < 0.0 {
            break;
        }
        sum += pair;
        lag += 2;
    }
    (n as f64 / (1.0 + 2.0 * sum)).clamp(1.0, n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::MaxCutModel;
    use crate::graph::Graph;
    use crate::mcmc::{build_algo, AlgoKind, SamplerKind};
    use crate::rng::Rng;

    fn small_cut() -> MaxCutModel {
        // 4-cycle: optimal cut = 4.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)], None);
        MaxCutModel::new(g, Some(4.0))
    }

    #[test]
    fn trace_reaches_target_on_trivial_instance() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        let tr = run_to_accuracy(
            &m,
            algo,
            BetaSchedule::Linear {
                from: 0.5,
                to: 4.0,
                steps: 50,
            },
            0.99,
            500,
            5,
            3,
        );
        assert!(tr.steps_to_target.is_some(), "never hit target: {tr:?}");
        assert!(tr.ops_to_target.unwrap() > 0);
    }

    #[test]
    fn trace_is_monotone_in_ops_and_accuracy() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Mh, SamplerKind::Gumbel, &m, 1);
        let tr = run_to_accuracy(&m, algo, BetaSchedule::Constant(1.0), 1.1, 100, 10, 5);
        for w in tr.points.windows(2) {
            assert!(w[1].ops >= w[0].ops);
            assert!(w[1].accuracy >= w[0].accuracy);
        }
    }

    fn noise(seed: u64, n: usize, offset: f64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| offset + rng.uniform_f64()).collect()
    }

    #[test]
    fn r_hat_near_one_for_matching_chains() {
        let chains = vec![noise(1, 200, 0.0), noise(2, 200, 0.0), noise(3, 200, 0.0)];
        let r = split_r_hat(&chains).unwrap();
        assert!((r - 1.0).abs() < 0.1, "r_hat={r}");
    }

    #[test]
    fn r_hat_large_for_disjoint_chains() {
        let chains = vec![noise(1, 200, 0.0), noise(2, 200, 10.0)];
        let r = split_r_hat(&chains).unwrap();
        assert!(r > 2.0, "r_hat={r}");
    }

    #[test]
    fn r_hat_needs_four_observations() {
        assert!(split_r_hat(&[vec![1.0, 2.0, 3.0], vec![1.0, 2.0, 3.0]]).is_none());
        assert!(split_r_hat(&[]).is_none());
        assert!(split_r_hat(&[vec![0.0; 8], vec![0.0; 8]]).is_some());
    }

    #[test]
    fn ess_high_for_iid_low_for_trending() {
        let iid = noise(7, 400, 0.0);
        let ess_iid = effective_sample_size(&iid);
        assert!(ess_iid > 100.0, "iid ESS={ess_iid}");
        // A monotone ramp is maximally autocorrelated.
        let ramp: Vec<f64> = (0..400).map(|i| i as f64).collect();
        let ess_ramp = effective_sample_size(&ramp);
        assert!(ess_ramp < ess_iid / 5.0, "ramp ESS={ess_ramp} vs {ess_iid}");
        // Bounds respected.
        assert!(effective_sample_size(&[1.0, 2.0]) == 2.0);
        assert!(effective_sample_size(&vec![3.0; 50]) == 50.0);
    }

    #[test]
    fn unreachable_target_returns_none() {
        let m = small_cut();
        let algo = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
        // Target accuracy 2.0 can never be reached (clamped at 1.0).
        let tr = run_to_accuracy(&m, algo, BetaSchedule::Constant(1.0), 2.0, 20, 5, 7);
        assert!(tr.steps_to_target.is_none());
        assert_eq!(tr.points.last().unwrap().steps, 20);
    }
}
