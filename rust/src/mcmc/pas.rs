//! Path Auxiliary Sampler (PAS) — the gradient-based discrete sampler
//! of Sun et al. (ICLR'22) the paper benchmarks for COP/EBM workloads.
//!
//! One step builds a length-`L` path of single-site moves. At substep
//! `l` a move `(j, s)` (set RV `j` to state `s ≠ x_j`) is drawn from the
//! locally-balanced proposal
//! `q((j,s) | x) ∝ exp(-β/2 · [E(x with x_j = s) − E(x)])`,
//! i.e. the "most dynamic" variables (largest energy drop) are flipped
//! preferentially — eq. (2) of the paper. The composite proposal is
//! corrected with an exact MH step using the reversed path, so the
//! chain targets `P(x) ∝ exp(-β E(x))` exactly.
//!
//! Move weights are maintained *incrementally*: flipping `j` only
//! perturbs the weights of `j` and its Markov blanket, so a substep is
//! `O(deg · card)` instead of `O(N · card)`.

use super::{Mcmc, StepStats};
use crate::energy::{EnergyModel, OpCost};
use crate::rng::Rng;

/// Exponent clamp for proposal weights (numerical guard; ±80 keeps
/// `exp` finite in f64 while leaving the dynamics untouched for any
/// realistic β·ΔE).
const EXP_CLAMP: f64 = 80.0;

/// Path Auxiliary Sampler with `path_len` single-site moves per step.
pub struct PathAuxiliarySampler {
    path_len: usize,
    /// Flattened move weights `w[off[j] + s]`, `s ∈ [0, card_j)`;
    /// entry for the *current* state is 0 (no-op moves excluded).
    weights: Vec<f64>,
    offsets: Vec<usize>,
    scratch: Vec<f32>,
}

impl PathAuxiliarySampler {
    /// New PAS kernel flipping `path_len` sites per step.
    pub fn new(path_len: usize) -> PathAuxiliarySampler {
        assert!(path_len >= 1);
        PathAuxiliarySampler {
            path_len,
            weights: Vec::new(),
            offsets: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Number of moves per step (the paper's `L`).
    pub fn path_len(&self) -> usize {
        self.path_len
    }

    pub(crate) fn ensure_layout(&mut self, model: &dyn EnergyModel) {
        if !self.offsets.is_empty() {
            return;
        }
        let mut acc = 0usize;
        self.offsets.reserve(model.num_vars() + 1);
        for i in 0..model.num_vars() {
            self.offsets.push(acc);
            acc += model.num_states(i);
        }
        self.offsets.push(acc);
        self.weights = vec![0.0; acc];
    }

    /// Fill RV `j`'s move weights from a **state-major batched** energy
    /// block (`e[s * k + c]`, chain `c` of `k`) instead of a scalar
    /// `local_energies` call — the batched PAS kernel's path-head
    /// build. The arithmetic replicates [`Self::refresh_var`] exactly
    /// (f32 `es - cur`, then the clamped f64 exponent), and the batched
    /// energies themselves are pinned bit-identical to the scalar
    /// kernel, so the resulting weight table matches bitwise.
    pub(crate) fn load_weights_for_var(
        &mut self,
        j: usize,
        e: &[f32],
        k: usize,
        c: usize,
        cur_state: u32,
        beta: f32,
    ) {
        let off = self.offsets[j];
        let card = self.offsets[j + 1] - off;
        let cur = e[cur_state as usize * k + c];
        for s in 0..card {
            let es = e[s * k + c];
            self.weights[off + s] = if s as u32 == cur_state {
                0.0
            } else {
                let expo = (-0.5 * beta as f64 * (es - cur) as f64).clamp(-EXP_CLAMP, EXP_CLAMP);
                expo.exp()
            };
        }
    }

    /// Recompute move weights for RV `j` from the current state.
    fn refresh_var(&mut self, model: &dyn EnergyModel, x: &[u32], j: usize, beta: f32) {
        model.local_energies(x, j, &mut self.scratch);
        let cur = self.scratch[x[j] as usize];
        let off = self.offsets[j];
        for (s, &es) in self.scratch.iter().enumerate() {
            self.weights[off + s] = if s as u32 == x[j] {
                0.0
            } else {
                let expo = (-0.5 * beta as f64 * (es - cur) as f64).clamp(-EXP_CLAMP, EXP_CLAMP);
                expo.exp()
            };
        }
    }

    /// Draw a move index from the weight table; returns the flat index.
    fn sample_move(&self, total: f64, rng: &mut Rng) -> usize {
        let mut u = rng.uniform_f64() * total;
        for (k, &w) in self.weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 && w > 0.0 {
                return k;
            }
        }
        // Numerical tail: last positive-weight move.
        self.weights
            .iter()
            .rposition(|&w| w > 0.0)
            .expect("no admissible move")
    }

    /// Decode a flat move index into (var, state).
    fn decode(&self, k: usize) -> (usize, u32) {
        let j = match self.offsets.binary_search(&k) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (j, (k - self.offsets[j]) as u32)
    }
}

impl PathAuxiliarySampler {
    /// One PAS step given an already-built weight table for the path
    /// head (via [`Self::refresh_var`] over every var, or the batched
    /// [`Self::load_weights_for_var`]). Everything from the first RNG
    /// draw onward lives here, so the scalar and batched paths consume
    /// identical draw sequences.
    pub(crate) fn step_prepared(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        let n = model.num_vars();
        let x0 = x.to_vec();
        let e0 = model.energy(x);
        let mut total: f64 = self.weights.iter().sum();

        // Randomize the path length between L and L+1: a fixed L
        // preserves the parity of the number of net flips, making the
        // kernel periodic (reducible on small binary spaces). A fair
        // L/L+1 coin keeps the expected work at ~L while restoring
        // irreducibility. The random draw is independent of the state,
        // so the MH correction below is unaffected.
        let len_t = self.path_len + (rng.next_u64() & 1) as usize;

        // Forward path.
        let mut log_q_fwd = 0.0f64;
        let mut path: Vec<(usize, u32, u32)> = Vec::with_capacity(len_t); // (j, old, new)
        for _ in 0..len_t {
            if total <= 0.0 {
                break; // fully constrained state: no admissible move
            }
            let k = self.sample_move(total, rng);
            let (j, s) = self.decode(k);
            log_q_fwd += (self.weights[k] / total).ln();
            path.push((j, x[j], s));
            x[j] = s;
            // Incremental refresh: j and its Markov blanket.
            self.refresh_var(model, x, j, beta);
            let blanket: Vec<u32> = model.interaction().neighbors(j).to_vec();
            for &nb in &blanket {
                self.refresh_var(model, x, nb as usize, beta);
            }
            total = self.weights.iter().sum();
        }

        // Reverse-path probability: replay backwards, reading the weight
        // of the inverse move at each intermediate state.
        let mut log_q_rev = 0.0f64;
        {
            // x currently = x^L; walk back to x^0 accumulating q_rev.
            for &(j, old, _new) in path.iter().rev() {
                // weight of the inverse move (j -> old) at the current state
                let w_inv = self.weights[self.offsets[j] + old as usize];
                let t: f64 = self.weights.iter().sum();
                log_q_rev += (w_inv / t).ln();
                x[j] = old;
                self.refresh_var(model, x, j, beta);
                let blanket: Vec<u32> = model.interaction().neighbors(j).to_vec();
                for &nb in &blanket {
                    self.refresh_var(model, x, nb as usize, beta);
                }
            }
        }
        // x is back to x^0 now; decide acceptance.
        let mut xl = x0.clone();
        for &(j, _old, new) in &path {
            xl[j] = new;
        }
        let el = model.energy(&xl);
        let log_alpha = -(beta as f64) * (el - e0) + log_q_rev - log_q_fwd;
        let accept = log_alpha >= 0.0 || rng.uniform_f64().ln() < log_alpha;

        let mut stats = StepStats::default();
        stats.updates = path.len() as u64;
        if accept {
            x.copy_from_slice(&xl);
            stats.accepted = path.len() as u64;
        }

        // Hardware-cost accounting per the paper's PAS schedule
        // (Fig. 10c): one full ΔE distribution build + L categorical
        // samples over the size-N move table + the MH energy evals.
        let mut cost = OpCost::default();
        for j in 0..n {
            cost.add(model.update_cost(j));
        }
        cost.samples = path.len() as u64;
        cost.ops += (path.len() * self.weights.len()) as u64; // L × size-N sampling scans
        stats.cost = cost;
        stats
    }
}

impl Mcmc for PathAuxiliarySampler {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        self.ensure_layout(model);
        // Full weight build at the path head (the paper's ΔE pass).
        for j in 0..model.num_vars() {
            self.refresh_var(model, x, j, beta);
        }
        self.step_prepared(model, x, beta, rng)
    }

    fn name(&self) -> &'static str {
        "PAS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{BayesNet, Cpt, MaxCutModel, PottsGrid};
    use crate::graph::Graph;
    use crate::mcmc::{BetaSchedule, Chain};

    #[test]
    fn pas_marginals_match_exact_on_bayes_net() {
        // Statistical exactness of the path-MH correction.
        let a = Cpt {
            parents: vec![],
            card: 2,
            table: vec![0.6, 0.4],
        };
        let b = Cpt {
            parents: vec![0],
            card: 2,
            table: vec![0.8, 0.2, 0.3, 0.7],
        };
        let net = BayesNet::new("ab", vec![a, b]);
        let exact = net.exact_marginal(1);
        let algo = Box::new(PathAuxiliarySampler::new(2));
        let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 13);
        chain.run(80_000);
        let emp = chain.marginal(1);
        assert!(
            (emp[1] - exact[1]).abs() < 0.015,
            "empirical={emp:?} exact={exact:?}"
        );
    }

    #[test]
    fn pas_matches_exact_on_small_ising() {
        let m = PottsGrid::new(2, 2, 2, 0.8);
        // Exact marginal of var 0 by enumeration.
        let mut num = 0.0f64;
        let mut z = 0.0f64;
        for bits in 0..16u32 {
            let x: Vec<u32> = (0..4).map(|i| (bits >> i) & 1).collect();
            let p = (-m.energy(&x)).exp();
            z += p;
            if x[0] == 1 {
                num += p;
            }
        }
        let exact = num / z;
        let algo = Box::new(PathAuxiliarySampler::new(3));
        let mut chain = Chain::new(&m, algo, BetaSchedule::Constant(1.0), 19);
        chain.run(80_000);
        let emp = chain.marginal(0)[1];
        assert!((emp - exact).abs() < 0.02, "emp={emp} exact={exact}");
    }

    #[test]
    fn pas_solves_small_maxcut() {
        // Complete bipartite K_{3,3} minus nothing: optimal cut = 9 with
        // the bipartition split.
        let mut edges = Vec::new();
        for a in 0..3u32 {
            for b in 3..6u32 {
                edges.push((a, b));
            }
        }
        let g = Graph::from_edges(6, &edges, None);
        let m = MaxCutModel::new(g, Some(9.0));
        let algo = Box::new(PathAuxiliarySampler::new(4));
        let mut chain = Chain::new(
            &m,
            algo,
            BetaSchedule::Linear {
                from: 0.3,
                to: 4.0,
                steps: 300,
            },
            29,
        );
        chain.run(500);
        assert_eq!(chain.best_objective, 9.0);
    }

    #[test]
    fn pas_prefers_dynamic_variables() {
        // In a strongly frustrated single spin, PAS must flip it first.
        let m = PottsGrid::new(3, 3, 2, 1.0);
        let mut x = vec![0u32; 9];
        x[4] = 1; // center spin disagrees with all 4 neighbors
        let mut pas = PathAuxiliarySampler::new(1);
        let mut rng = Rng::new(41);
        let mut flipped_center = 0;
        for _ in 0..100 {
            let mut y = x.clone();
            pas.step(&m, &mut y, 3.0, &mut rng);
            if y[4] == 0 {
                flipped_center += 1;
            }
        }
        // The center flip drops energy by 8 coupling units; it should
        // dominate the proposal.
        assert!(flipped_center > 80, "flipped={flipped_center}");
    }

    #[test]
    fn pas_step_cost_includes_full_delta_pass() {
        let m = PottsGrid::new(4, 4, 2, 1.0);
        let mut x = vec![0u32; 16];
        let mut pas = PathAuxiliarySampler::new(2);
        let mut rng = Rng::new(7);
        let s = pas.step(&m, &mut x, 1.0, &mut rng);
        assert!(s.cost.ops > 16); // ≥ one op per RV for the ΔE pass
        assert_eq!(s.cost.samples, s.updates);
    }
}
