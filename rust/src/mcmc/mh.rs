//! Sequential single-site Metropolis-Hastings (Alg. 1).

use super::{Mcmc, StepStats};
use crate::energy::EnergyModel;
use crate::rng::Rng;

/// Single-site MH: one step = one sweep of `num_vars` proposals in
/// random order, each proposing a uniform new state for one RV and
/// accepting with `min(1, exp(-β ΔE))` (symmetric proposal, so the
/// Hastings correction cancels).
#[derive(Debug, Default)]
pub struct MetropolisHastings {
    order: Vec<u32>,
    scratch: Vec<f32>,
}

impl MetropolisHastings {
    /// New MH kernel.
    pub fn new() -> MetropolisHastings {
        MetropolisHastings::default()
    }
}

impl Mcmc for MetropolisHastings {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        let n = model.num_vars();
        if self.order.len() != n {
            self.order = (0..n as u32).collect();
        }
        rng.shuffle(&mut self.order);
        let mut stats = StepStats::default();
        for idx in 0..n {
            let i = self.order[idx] as usize;
            let card = model.num_states(i);
            if card < 2 {
                continue;
            }
            // Propose uniformly among the *other* states.
            let mut s = rng.below(card - 1) as u32;
            if s >= x[i] {
                s += 1;
            }
            let de = model.delta_energy(x, i, s, &mut self.scratch);
            let accept = de <= 0.0 || rng.uniform_f32() < (-beta * de).exp();
            if accept {
                x[i] = s;
                stats.accepted += 1;
            }
            stats.updates += 1;
            let mut c = model.update_cost(i);
            // MH samples a uniform proposal + one accept/reject draw
            // instead of a categorical over all states.
            c.samples = 1;
            stats.cost.add(c);
        }
        stats
    }

    fn name(&self) -> &'static str {
        "MH"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{EnergyModel, PottsGrid};

    #[test]
    fn mh_reaches_ground_state_when_cold() {
        let m = PottsGrid::new(4, 4, 2, 1.0);
        let mut x = vec![0u32; 16];
        x[5] = 1;
        x[10] = 1;
        let mut rng = Rng::new(3);
        let mut mh = MetropolisHastings::new();
        for _ in 0..50 {
            mh.step(&m, &mut x, 10.0, &mut rng);
        }
        // Cold chain must heal the two flipped spins.
        let e = m.energy(&x);
        assert_eq!(e, -(m.interaction().num_edges() as f64));
    }

    #[test]
    fn acceptance_rate_reasonable_at_high_temp() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let mut x = vec![0u32; 36];
        let mut rng = Rng::new(4);
        let mut mh = MetropolisHastings::new();
        // At β = 0 every proposal is accepted.
        let s = mh.step(&m, &mut x, 0.0, &mut rng);
        assert_eq!(s.accepted, s.updates);
    }

    #[test]
    fn step_stats_count_all_vars() {
        let m = PottsGrid::new(3, 5, 3, 0.5);
        let mut x = vec![0u32; 15];
        let mut rng = Rng::new(5);
        let s = MetropolisHastings::new().step(&m, &mut x, 1.0, &mut rng);
        assert_eq!(s.updates, 15);
        assert_eq!(s.cost.samples, 15);
        assert!(s.cost.ops > 0 && s.cost.bytes > 0);
    }
}
