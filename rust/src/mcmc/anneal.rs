//! Observer-driven adaptive annealing: stateful β controllers layered
//! over the fixed [`BetaSchedule`] ramps.
//!
//! The fixed schedules of §II-A are open-loop: β(t) is a pure function
//! of the step index, blind to whether the chains are mixing, stuck,
//! or already converged. Sountsov et al. ("Running MCMC on Modern
//! Hardware and Software") make the case that cheap streaming
//! diagnostics — exactly the split R-hat / ESS the engine's
//! [`crate::engine::ChainObserver`] already computes — should close
//! that loop. This module provides the controller layer:
//!
//! * [`BetaController`] — the trait the engine drives: β for any
//!   global step, one diagnostics callback per observation round, and
//!   flat-state serialization for checkpoint/resume,
//! * [`AdaptiveSchedule`] — wraps a fixed [`BetaSchedule`] in a
//!   *virtual clock* that the controller warps between observation
//!   rounds: **reheat** (rewind the ramp) on best-objective
//!   stagnation, **accelerate** cooling while the chains mix (low
//!   R-hat), **hold** the temperature on plateau,
//! * [`FixedController`] — the trivial open-loop controller (β(t) =
//!   schedule.beta(t)), useful for testing the engine's lockstep
//!   driver against the plain fixed-ramp path.
//!
//! Every decision is a deterministic function of the diagnostics
//! sequence, so two backends that produce bit-identical chains (the
//! scalar and batched software backends) produce bit-identical β
//! trajectories — pinned by `tests/integration_anneal.rs`.

use crate::mcmc::BetaSchedule;

/// Stagnation response policy (the CLI's `--adaptive reheat|plateau`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnnealPolicy {
    /// Rewind the ramp on stagnation: β drops back along the schedule
    /// (a fraction of the elapsed virtual time), giving trapped chains
    /// another escape window.
    Reheat,
    /// Freeze the ramp on stagnation: β holds its current value until
    /// the best objective improves again.
    Plateau,
}

impl AnnealPolicy {
    /// Short name used in CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            AnnealPolicy::Reheat => "reheat",
            AnnealPolicy::Plateau => "plateau",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<AnnealPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "reheat" => Some(AnnealPolicy::Reheat),
            "plateau" | "hold" => Some(AnnealPolicy::Plateau),
            _ => None,
        }
    }
}

/// Tuning knobs for [`AdaptiveSchedule`]. [`AnnealConfig::new`] gives
/// the defaults the CLI uses; every field is public for library
/// callers.
#[derive(Clone, Copy, Debug)]
pub struct AnnealConfig {
    /// Stagnation response.
    pub policy: AnnealPolicy,
    /// Consecutive observation rounds without best-objective
    /// improvement that count as stagnation.
    pub patience: usize,
    /// Minimum absolute best-objective gain that resets the plateau
    /// counter.
    pub min_improve: f64,
    /// Split R-hat at or below which the chains count as mixed (the
    /// acceleration trigger). Needs ≥ 2 chains; with one chain R-hat
    /// is undefined and cooling never accelerates.
    pub mixed_r_hat: f64,
    /// Virtual-clock rate while the chains are mixed (> 1 cools
    /// faster than the fixed ramp).
    pub accel: f64,
    /// Fraction of the elapsed virtual ramp rewound per reheat
    /// (policy [`AnnealPolicy::Reheat`] only), in [0, 1].
    pub reheat_fraction: f64,
}

impl AnnealConfig {
    /// Default configuration for `policy`: patience 3, R-hat 1.05,
    /// 2× acceleration, 50% reheat rewind.
    pub fn new(policy: AnnealPolicy) -> AnnealConfig {
        AnnealConfig {
            policy,
            patience: 3,
            min_improve: 1e-9,
            mixed_r_hat: 1.05,
            accel: 2.0,
            reheat_fraction: 0.5,
        }
    }
}

/// One observation round's cross-chain diagnostics, as consumed by a
/// [`BetaController`]. The engine's lockstep driver computes these
/// with the same `split_r_hat` / `effective_sample_size` functions the
/// streaming [`crate::engine::ChainObserver`] reports use.
#[derive(Clone, Copy, Debug)]
pub struct RoundDiagnostics {
    /// Observation round index (1-based within this run).
    pub round: usize,
    /// Global step at the round boundary (resume offset included).
    pub step: usize,
    /// Split potential-scale-reduction over the per-chain objective
    /// traces; `None` until ≥ 2 chains have ≥ 4 observations.
    pub r_hat: Option<f64>,
    /// Smallest per-chain effective sample size of the objective
    /// trace.
    pub min_ess: f64,
    /// Best objective across all chains so far.
    pub best_objective: f64,
}

/// A stateful β controller. `t` is always the *global* step index —
/// cumulative across checkpoint resumes — so a restored controller
/// continues both the ramp and its own memory.
pub trait BetaController: Send {
    /// β at global step `t`.
    fn beta_at(&self, t: usize) -> f32;

    /// Consume one completed observation round's diagnostics; the
    /// controller may adjust its state for the next segment.
    fn observe_round(&mut self, d: &RoundDiagnostics);

    /// Serialize the controller's memory as a flat vector (stored in
    /// [`crate::engine::Checkpoint`]'s `anneal` field).
    fn state(&self) -> Vec<f64>;

    /// Restore memory serialized by [`BetaController::state`].
    fn restore(&mut self, state: &[f64]) -> Result<(), String>;

    /// One-line human-readable summary (decisions taken so far).
    fn describe(&self) -> String;

    /// Short controller name ("fixed", "adaptive").
    fn name(&self) -> &'static str;
}

/// The open-loop controller: β(t) = `schedule.beta(t)`, no memory.
#[derive(Clone, Copy, Debug)]
pub struct FixedController {
    schedule: BetaSchedule,
}

impl FixedController {
    /// Controller replaying `schedule` verbatim.
    pub fn new(schedule: BetaSchedule) -> FixedController {
        FixedController { schedule }
    }
}

impl BetaController for FixedController {
    fn beta_at(&self, t: usize) -> f32 {
        self.schedule.beta(t)
    }

    fn observe_round(&mut self, _d: &RoundDiagnostics) {}

    fn state(&self) -> Vec<f64> {
        Vec::new()
    }

    fn restore(&mut self, _state: &[f64]) -> Result<(), String> {
        Ok(())
    }

    fn describe(&self) -> String {
        format!("fixed({:?})", self.schedule)
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

/// Number of entries in [`AdaptiveSchedule`]'s serialized state.
const ADAPTIVE_STATE_LEN: usize = 8;

/// A fixed [`BetaSchedule`] driven through a warped *virtual clock*.
///
/// The schedule is evaluated at a virtual time `v` instead of the real
/// step index. Between observation rounds `v` advances at `rate`
/// virtual steps per real step; the diagnostics of each completed
/// round pick the rate for the next segment:
///
/// * best objective stagnant for `patience` rounds → **reheat**
///   (rewind `v` by `reheat_fraction`, policy `Reheat`) or **hold**
///   (`rate = 0`, policy `Plateau`),
/// * chains mixed (split R-hat ≤ `mixed_r_hat`) → **accelerate**
///   (`rate = accel`),
/// * otherwise → follow the fixed ramp (`rate = 1`).
pub struct AdaptiveSchedule {
    base: BetaSchedule,
    cfg: AnnealConfig,
    /// Virtual schedule time at `anchor`.
    virtual_t: f64,
    /// Global step where the current segment began.
    anchor: usize,
    /// Virtual steps per real step for the current segment.
    rate: f64,
    /// Consecutive stagnant observation rounds.
    plateau: usize,
    /// Best objective the controller has seen.
    best_seen: f64,
    reheats: u64,
    accels: u64,
    holds: u64,
}

impl AdaptiveSchedule {
    /// Adaptive controller over `base`, starting at virtual time 0.
    pub fn new(base: BetaSchedule, cfg: AnnealConfig) -> AdaptiveSchedule {
        AdaptiveSchedule {
            base,
            cfg,
            virtual_t: 0.0,
            anchor: 0,
            rate: 1.0,
            plateau: 0,
            best_seen: f64::NEG_INFINITY,
            reheats: 0,
            accels: 0,
            holds: 0,
        }
    }

    /// Start the virtual clock at global step `offset` (checkpoint
    /// resume: the ramp continues where the previous run stopped).
    /// Restoring a serialized state afterwards overrides this.
    pub fn with_offset(mut self, offset: usize) -> AdaptiveSchedule {
        self.virtual_t = offset as f64;
        self.anchor = offset;
        self
    }

    /// The wrapped fixed schedule.
    pub fn base(&self) -> BetaSchedule {
        self.base
    }

    /// Reheats issued so far.
    pub fn reheats(&self) -> u64 {
        self.reheats
    }

    /// Accelerated segments issued so far.
    pub fn accels(&self) -> u64 {
        self.accels
    }

    /// Hold segments issued so far.
    pub fn holds(&self) -> u64 {
        self.holds
    }

    fn virtual_at(&self, t: usize) -> f64 {
        let dt = t.saturating_sub(self.anchor) as f64;
        (self.virtual_t + self.rate * dt).max(0.0)
    }
}

impl BetaController for AdaptiveSchedule {
    fn beta_at(&self, t: usize) -> f32 {
        self.base.beta(self.virtual_at(t) as usize)
    }

    fn observe_round(&mut self, d: &RoundDiagnostics) {
        // Close the finished segment: advance the virtual clock to the
        // round boundary, then decide the next segment's rate.
        self.virtual_t = self.virtual_at(d.step);
        self.anchor = d.step;
        let improved = d.best_objective > self.best_seen + self.cfg.min_improve;
        if d.best_objective > self.best_seen {
            self.best_seen = d.best_objective;
        }
        self.plateau = if improved { 0 } else { self.plateau + 1 };
        let mixed = d.r_hat.is_some_and(|r| r <= self.cfg.mixed_r_hat);
        if self.plateau >= self.cfg.patience {
            match self.cfg.policy {
                AnnealPolicy::Reheat => {
                    self.virtual_t *= 1.0 - self.cfg.reheat_fraction.clamp(0.0, 1.0);
                    self.rate = 1.0;
                    self.plateau = 0;
                    self.reheats += 1;
                }
                AnnealPolicy::Plateau => {
                    self.rate = 0.0;
                    self.holds += 1;
                }
            }
        } else if mixed {
            self.rate = self.cfg.accel;
            self.accels += 1;
        } else {
            self.rate = 1.0;
        }
    }

    fn state(&self) -> Vec<f64> {
        vec![
            self.virtual_t,
            self.anchor as f64,
            self.rate,
            self.plateau as f64,
            self.best_seen,
            self.reheats as f64,
            self.accels as f64,
            self.holds as f64,
        ]
    }

    fn restore(&mut self, state: &[f64]) -> Result<(), String> {
        if state.len() != ADAPTIVE_STATE_LEN {
            return Err(format!(
                "adaptive annealing state has {} entries, expected {ADAPTIVE_STATE_LEN}",
                state.len()
            ));
        }
        self.virtual_t = state[0];
        self.anchor = state[1] as usize;
        self.rate = state[2];
        self.plateau = state[3] as usize;
        self.best_seen = state[4];
        self.reheats = state[5] as u64;
        self.accels = state[6] as u64;
        self.holds = state[7] as u64;
        Ok(())
    }

    fn describe(&self) -> String {
        format!(
            "adaptive({}): {} reheats, {} accels, {} holds, virtual t {:.0}",
            self.cfg.policy.name(),
            self.reheats,
            self.accels,
            self.holds,
            self.virtual_t
        )
    }

    fn name(&self) -> &'static str {
        "adaptive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> BetaSchedule {
        BetaSchedule::Linear {
            from: 0.0,
            to: 1.0,
            steps: 100,
        }
    }

    fn diag(round: usize, step: usize, r_hat: Option<f64>, best: f64) -> RoundDiagnostics {
        RoundDiagnostics {
            round,
            step,
            r_hat,
            min_ess: 10.0,
            best_objective: best,
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [AnnealPolicy::Reheat, AnnealPolicy::Plateau] {
            assert_eq!(AnnealPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AnnealPolicy::parse("nope"), None);
    }

    #[test]
    fn follows_the_fixed_ramp_until_a_decision_fires() {
        let mut c = AdaptiveSchedule::new(ramp(), AnnealConfig::new(AnnealPolicy::Reheat));
        for t in 0..10 {
            assert_eq!(c.beta_at(t), ramp().beta(t), "t={t}");
        }
        // Improving rounds with unmixed chains keep rate 1.
        c.observe_round(&diag(1, 10, Some(2.0), 1.0));
        c.observe_round(&diag(2, 20, Some(2.0), 2.0));
        for t in 20..30 {
            assert_eq!(c.beta_at(t), ramp().beta(t), "t={t}");
        }
    }

    #[test]
    fn mixed_chains_accelerate_cooling() {
        let mut c = AdaptiveSchedule::new(ramp(), AnnealConfig::new(AnnealPolicy::Reheat));
        c.observe_round(&diag(1, 10, Some(1.0), 1.0));
        assert_eq!(c.accels(), 1);
        // rate 2: at real step 20 the virtual clock reads 10 + 2·10 = 30.
        assert_eq!(c.beta_at(20), ramp().beta(30));
    }

    #[test]
    fn stagnation_reheats_under_reheat_policy() {
        let mut cfg = AnnealConfig::new(AnnealPolicy::Reheat);
        cfg.patience = 2;
        let mut c = AdaptiveSchedule::new(ramp(), cfg);
        c.observe_round(&diag(1, 40, Some(2.0), 5.0));
        // Two stagnant rounds at the patience threshold trigger the
        // rewind: virtual time halves (reheat_fraction 0.5).
        c.observe_round(&diag(2, 50, Some(2.0), 5.0));
        c.observe_round(&diag(3, 60, Some(2.0), 5.0));
        assert_eq!(c.reheats(), 1);
        assert_eq!(c.beta_at(60), ramp().beta(30));
    }

    #[test]
    fn stagnation_holds_under_plateau_policy() {
        let mut cfg = AnnealConfig::new(AnnealPolicy::Plateau);
        cfg.patience = 1;
        let mut c = AdaptiveSchedule::new(ramp(), cfg);
        c.observe_round(&diag(1, 30, Some(2.0), 5.0));
        c.observe_round(&diag(2, 40, Some(2.0), 5.0));
        assert!(c.holds() >= 1);
        // Frozen clock: β stays at the round-boundary value.
        assert_eq!(c.beta_at(80), c.beta_at(40));
    }

    #[test]
    fn state_roundtrip_continues_the_trajectory() {
        let mut cfg = AnnealConfig::new(AnnealPolicy::Reheat);
        cfg.patience = 2;
        let rounds = [
            diag(1, 10, Some(1.0), 1.0),
            diag(2, 20, Some(2.0), 1.0),
            diag(3, 30, Some(2.0), 1.0),
            diag(4, 40, None, 3.0),
        ];
        // Uninterrupted controller.
        let mut a = AdaptiveSchedule::new(ramp(), cfg);
        for d in &rounds[..2] {
            a.observe_round(d);
        }
        let saved = a.state();
        for d in &rounds[2..] {
            a.observe_round(d);
        }
        // Resumed controller: restore mid-sequence state, replay the tail.
        let mut b = AdaptiveSchedule::new(ramp(), cfg).with_offset(20);
        b.restore(&saved).unwrap();
        for d in &rounds[2..] {
            b.observe_round(d);
        }
        assert_eq!(a.state(), b.state());
        for t in 40..60 {
            assert_eq!(a.beta_at(t), b.beta_at(t), "t={t}");
        }
        assert!(b.restore(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn fixed_controller_replays_the_schedule() {
        let mut c = FixedController::new(ramp());
        c.observe_round(&diag(1, 10, Some(1.0), 1.0));
        for t in [0, 5, 50, 150] {
            assert_eq!(c.beta_at(t), ramp().beta(t));
        }
        assert!(c.state().is_empty());
        assert_eq!(c.name(), "fixed");
    }
}
