//! Batched many-chain execution: a structure-of-arrays [`ChainBatch`]
//! holding K chains' states column-major per variable, plus batched
//! step loops ([`BatchMcmc`]) for the Gibbs-family algorithms and MH.
//!
//! The MC²A roofline (§II) and Sountsov & Carroll's many-chain study
//! both make the same point: MCMC throughput on modern hardware is won
//! by keeping many independent chains resident and amortizing every
//! per-variable cost (neighbor-index walks, parameter fetches, virtual
//! dispatch) across the whole batch. The SoA layout puts chain `c`'s
//! value of RV `i` at `states[i * K + c]`, so one neighbor lookup
//! serves K chains and the inner loops stream contiguous columns.
//!
//! **Bit-identity invariant:** every chain owns its RNG
//! ([`crate::rng::Rng::fork`]`(seed, chain_id)`), and the batched
//! kernels consume each chain's stream in exactly the order the scalar
//! kernels do. A chain's trajectory is therefore identical whether it
//! runs on the scalar thread-per-chain backend, in a batch of 1, or in
//! a batch of 1024 — the equivalence tests in
//! `tests/integration_batched.rs` pin this down per workload.

use crate::energy::{BatchScratch, EnergyModel};
use crate::graph::color_greedy;
use crate::mcmc::pas::PathAuxiliarySampler;
use crate::mcmc::sampler::CategoricalSampler;
use crate::mcmc::{AlgoKind, BetaSchedule, SamplerKind, StepStats};
use crate::rng::Rng;

/// A batched MCMC transition kernel: one call advances all `k` chains
/// of an SoA state block by one step (one sweep).
pub trait BatchMcmc: Send {
    /// Perform one step for every chain. `states[i * k + c]` is chain
    /// `c`'s value of RV `i`; `betas[c]`, `rngs[c]` and `stats[c]` are
    /// chain `c`'s inverse temperature, RNG stream and statistics.
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    );

    /// Algorithm name.
    fn name(&self) -> &'static str;
}

/// True when [`build_batch_algo`] has a batched kernel for `kind`.
/// Every algorithm now has one (PAS and Async Gibbs landed last); the
/// predicate stays as the engine's guard so a future kernel without a
/// batched twin degrades gracefully to scalar chains.
pub fn batch_supported(kind: AlgoKind) -> bool {
    matches!(
        kind,
        AlgoKind::Gibbs
            | AlgoKind::BlockGibbs
            | AlgoKind::Mh
            | AlgoKind::AsyncGibbs
            | AlgoKind::Pas
    )
}

/// Build the batched kernel for `kind`, or `None` when only the scalar
/// path exists. `pas_flips` is PAS's path length `L` (ignored by the
/// other algorithms), mirroring [`crate::mcmc::build_algo`].
pub fn build_batch_algo(
    kind: AlgoKind,
    sampler: SamplerKind,
    model: &dyn EnergyModel,
    pas_flips: usize,
) -> Option<Box<dyn BatchMcmc>> {
    match kind {
        AlgoKind::Gibbs => Some(Box::new(BatchGibbs::new(sampler.build()))),
        AlgoKind::BlockGibbs => Some(Box::new(BatchBlockGibbs::new(sampler.build(), model))),
        AlgoKind::Mh => Some(Box::new(BatchMh::new())),
        AlgoKind::AsyncGibbs => Some(Box::new(BatchAsyncGibbs::new(sampler.build()))),
        AlgoKind::Pas => Some(Box::new(BatchPas::new(pas_flips.max(1)))),
    }
}

/// K chains' worth of MCMC state in structure-of-arrays form: the
/// software twin of K parallel MC²A cores sharing one compiled model.
///
/// Layout: `states[i * k + c]` (column-major per variable), so a
/// variable's K values are contiguous. Per-chain scalars (β, current
/// and best objective, RNG, statistics, RV-0 histogram) live in dense
/// K-length vectors.
pub struct ChainBatch<'m> {
    model: &'m dyn EnergyModel,
    k: usize,
    first_chain: usize,
    /// SoA states: `states[i * k + c]`.
    states: Vec<u32>,
    /// Per-chain inverse temperature at the current step. All chains
    /// follow `schedule` today; the per-chain storage is the hook for
    /// parallel tempering.
    betas: Vec<f32>,
    schedule: BetaSchedule,
    /// Global-step offset added to the schedule clock (checkpoint
    /// resume; mirrors `Chain::step_offset`).
    step_offset: usize,
    /// Steps taken (uniform across the batch).
    pub step_count: usize,
    rngs: Vec<Rng>,
    /// Per-chain cumulative statistics.
    pub stats: Vec<StepStats>,
    /// Per-chain objective of the current state.
    pub objectives: Vec<f64>,
    /// Per-chain best objective seen so far.
    pub best_objectives: Vec<f64>,
    /// Best assignments, same SoA layout as `states`.
    best_states: Vec<u32>,
    /// RV-0 state histogram per chain: `hist0[c * S0 + s]`.
    hist0: Vec<u64>,
    s0: usize,
    gather: Vec<u32>,
}

impl<'m> ChainBatch<'m> {
    /// Create a batch of `k` chains with ids `first_chain ..
    /// first_chain + k`. Each chain draws its random initial state from
    /// `Rng::fork(seed, chain_id)` exactly as the scalar path does;
    /// `init` (when given) then overwrites every chain's state, again
    /// mirroring the scalar `Chain::new` + `set_state` sequence so RNG
    /// streams stay aligned.
    pub fn new(
        model: &'m dyn EnergyModel,
        schedule: BetaSchedule,
        seed: u64,
        first_chain: usize,
        k: usize,
        init: Option<&[u32]>,
    ) -> ChainBatch<'m> {
        assert!(k >= 1);
        let n = model.num_vars();
        let s0 = model.num_states(0);
        let mut states = vec![0u32; n * k];
        let mut rngs = Vec::with_capacity(k);
        let mut objectives = Vec::with_capacity(k);
        for c in 0..k {
            let mut rng = Rng::fork(seed, (first_chain + c) as u64);
            let mut x = crate::energy::random_state(model, &mut rng);
            if let Some(x0) = init {
                x.copy_from_slice(x0);
            }
            for (i, &v) in x.iter().enumerate() {
                states[i * k + c] = v;
            }
            objectives.push(model.objective(&x));
            rngs.push(rng);
        }
        let best_states = states.clone();
        let best_objectives = objectives.clone();
        ChainBatch {
            model,
            k,
            first_chain,
            states,
            betas: vec![schedule.beta(0); k],
            schedule,
            step_offset: 0,
            step_count: 0,
            rngs,
            stats: vec![StepStats::default(); k],
            objectives,
            best_objectives,
            best_states,
            hist0: vec![0; s0 * k],
            s0,
            gather: vec![0; n],
        }
    }

    /// Number of chains in the batch.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Global chain id of batch slot `c`.
    pub fn chain_id(&self, c: usize) -> usize {
        self.first_chain + c
    }

    /// Set the global-step offset of the schedule clock (checkpoint
    /// resume: β continues at `offset + t` instead of restarting).
    pub fn set_step_offset(&mut self, offset: usize) {
        self.step_offset = offset;
    }

    /// β at the last completed step (what a progress event reports).
    pub fn last_beta(&self) -> f32 {
        self.schedule
            .beta((self.step_offset + self.step_count).saturating_sub(1))
    }

    /// Gather chain `c`'s current assignment out of the SoA block.
    pub fn chain_state(&self, c: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend(self.states[c..].iter().step_by(self.k).copied());
    }

    /// Chain `c`'s best assignment so far.
    pub fn best_state(&self, c: usize) -> Vec<u32> {
        self.best_states[c..].iter().step_by(self.k).copied().collect()
    }

    /// Empirical marginal of RV 0 for chain `c` (the convergence smoke
    /// signal every `ChainResult` carries).
    pub fn marginal0(&self, c: usize) -> Vec<f64> {
        let span = &self.hist0[c * self.s0..(c + 1) * self.s0];
        let total: u64 = span.iter().sum();
        span.iter().map(|&v| v as f64 / total.max(1) as f64).collect()
    }

    /// Run `n` steps of `algo`, updating histograms, objectives and
    /// best-so-far per chain — the batched twin of `Chain::run`.
    pub fn run(&mut self, algo: &mut dyn BatchMcmc, n: usize) {
        for _ in 0..n {
            let beta = self.schedule.beta(self.step_offset + self.step_count);
            self.step_with(algo, beta);
        }
    }

    /// Run one step per entry of `betas`, using the supplied β values
    /// instead of the fixed schedule — the adaptive annealing
    /// controller's entry point (the batched twin of
    /// `Chain::run_betas`).
    pub fn run_betas(&mut self, algo: &mut dyn BatchMcmc, betas: &[f32]) {
        for &beta in betas {
            self.step_with(algo, beta);
        }
    }

    /// Run `n` steps with chain `c` held at `per_chain[c]` — true
    /// per-chain β, the replica-exchange entry point
    /// ([`crate::mcmc::tempering`]). Each chain's trajectory is
    /// bit-identical to a scalar chain running the same constant β,
    /// because the batched kernels already consume `betas[c]` per
    /// chain; only the uniform [`ChainBatch::run`]/[`ChainBatch::run_betas`]
    /// paths flatten the vector.
    pub fn run_betas_per_chain(&mut self, algo: &mut dyn BatchMcmc, per_chain: &[f32], n: usize) {
        assert_eq!(per_chain.len(), self.k, "one β per chain in the batch");
        self.betas.copy_from_slice(per_chain);
        for _ in 0..n {
            self.step_current(algo);
        }
    }

    fn step_with(&mut self, algo: &mut dyn BatchMcmc, beta: f32) {
        self.betas.fill(beta);
        self.step_current(algo);
    }

    /// One step at whatever `self.betas` currently holds (the shared
    /// tail of the uniform and per-chain paths).
    fn step_current(&mut self, algo: &mut dyn BatchMcmc) {
        let nv = self.model.num_vars();
        algo.step_batch(
            self.model,
            &mut self.states,
            self.k,
            &self.betas,
            &mut self.rngs,
            &mut self.stats,
        );
        self.step_count += 1;
        for c in 0..self.k {
            self.hist0[c * self.s0 + self.states[c] as usize] += 1;
            self.gather.clear();
            self.gather
                .extend(self.states[c..].iter().step_by(self.k).copied());
            let obj = self.model.objective(&self.gather);
            self.objectives[c] = obj;
            if obj > self.best_objectives[c] {
                self.best_objectives[c] = obj;
                for i in 0..nv {
                    self.best_states[i * self.k + c] = self.states[i * self.k + c];
                }
            }
        }
    }
}

/// Batched sequential Gibbs: one step = one systematic sweep; every
/// variable's conditional is built for all K chains at once
/// ([`EnergyModel::local_energies_batch`]) and sampled K-wide
/// ([`CategoricalSampler::sample_batch`]).
pub struct BatchGibbs {
    sampler: Box<dyn CategoricalSampler>,
    e: Vec<f32>,
    scratch: BatchScratch,
    out: Vec<u32>,
}

impl BatchGibbs {
    /// Batched Gibbs kernel backed by `sampler`.
    pub fn new(sampler: Box<dyn CategoricalSampler>) -> BatchGibbs {
        BatchGibbs {
            sampler,
            e: Vec::new(),
            scratch: BatchScratch::default(),
            out: Vec::new(),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn update_var(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        i: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        let s = model.num_states(i);
        model.local_energies_batch(states, k, i, &mut self.e, &mut self.scratch);
        self.out.resize(k, 0);
        self.sampler.sample_batch(&self.e, s, betas, rngs, &mut self.out);
        states[i * k..(i + 1) * k].copy_from_slice(&self.out);
        let mut cost = model.update_cost(i);
        cost.ops += self.sampler.ops_per_sample(s);
        for st in stats.iter_mut() {
            st.updates += 1;
            st.accepted += 1;
            st.cost.add(cost);
        }
    }
}

impl BatchMcmc for BatchGibbs {
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        for i in 0..model.num_vars() {
            self.update_var(model, states, k, i, betas, rngs, stats);
        }
    }

    fn name(&self) -> &'static str {
        "Gibbs"
    }
}

/// Batched Block Gibbs: the same greedy coloring as the scalar kernel,
/// swept color class by color class with K-wide conditional builds.
pub struct BatchBlockGibbs {
    inner: BatchGibbs,
    blocks: Vec<Vec<u32>>,
}

impl BatchBlockGibbs {
    /// Build by coloring `model`'s interaction graph greedily.
    pub fn new(sampler: Box<dyn CategoricalSampler>, model: &dyn EnergyModel) -> BatchBlockGibbs {
        BatchBlockGibbs {
            inner: BatchGibbs::new(sampler),
            blocks: color_greedy(model.interaction()).blocks(),
        }
    }
}

impl BatchMcmc for BatchBlockGibbs {
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        for block in &self.blocks {
            for &iu in block {
                self.inner
                    .update_var(model, states, k, iu as usize, betas, rngs, stats);
            }
        }
    }

    fn name(&self) -> &'static str {
        "BG"
    }
}

/// Batched single-site Metropolis-Hastings. Each chain keeps its own
/// shuffled visit order (exactly as the scalar kernel evolves it), so
/// the sweep iterates position-outer / chain-inner: neighbor gathers
/// are per-chain, but proposal evaluation and acceptance still run
/// K-wide per position.
pub struct BatchMh {
    /// Chain-major visit orders: `orders[c * n + idx]`.
    orders: Vec<u32>,
    scratch: BatchScratch,
}

impl BatchMh {
    /// New batched MH kernel.
    pub fn new() -> BatchMh {
        BatchMh {
            orders: Vec::new(),
            scratch: BatchScratch::default(),
        }
    }
}

impl Default for BatchMh {
    fn default() -> Self {
        BatchMh::new()
    }
}

impl BatchMcmc for BatchMh {
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        let n = model.num_vars();
        if self.orders.len() != k * n {
            self.orders.clear();
            for _ in 0..k {
                self.orders.extend(0..n as u32);
            }
        }
        for (c, rng) in rngs.iter_mut().enumerate() {
            rng.shuffle(&mut self.orders[c * n..(c + 1) * n]);
        }
        self.scratch.x.resize(n, 0);
        for idx in 0..n {
            for c in 0..k {
                let i = self.orders[c * n + idx] as usize;
                let card = model.num_states(i);
                if card < 2 {
                    continue;
                }
                let cur = states[i * k + c];
                let mut s = rngs[c].below(card - 1) as u32;
                if s >= cur {
                    s += 1;
                }
                // Gather chain c's Markov blanket for the scalar ΔE.
                self.scratch.x[i] = cur;
                for &nb in model.interaction().neighbors(i) {
                    self.scratch.x[nb as usize] = states[nb as usize * k + c];
                }
                let de = model.delta_energy(&self.scratch.x, i, s, &mut self.scratch.e);
                let accept = de <= 0.0 || rngs[c].uniform_f32() < (-betas[c] * de).exp();
                if accept {
                    states[i * k + c] = s;
                    stats[c].accepted += 1;
                }
                stats[c].updates += 1;
                let mut cost = model.update_cost(i);
                cost.samples = 1;
                stats[c].cost.add(cost);
            }
        }
    }

    fn name(&self) -> &'static str {
        "MH"
    }
}

/// Batched asynchronous (hogwild) Gibbs: one step snapshots the whole
/// SoA block, then resamples every variable for all K chains against
/// the snapshot — the batched twin of the scalar `AsyncGibbs` kernel,
/// with the conditional build and the categorical draw both K-wide.
pub struct BatchAsyncGibbs {
    sampler: Box<dyn CategoricalSampler>,
    e: Vec<f32>,
    scratch: BatchScratch,
    out: Vec<u32>,
    snapshot: Vec<u32>,
}

impl BatchAsyncGibbs {
    /// Batched Async-Gibbs kernel backed by `sampler`.
    pub fn new(sampler: Box<dyn CategoricalSampler>) -> BatchAsyncGibbs {
        BatchAsyncGibbs {
            sampler,
            e: Vec::new(),
            scratch: BatchScratch::default(),
            out: Vec::new(),
            snapshot: Vec::new(),
        }
    }
}

impl BatchMcmc for BatchAsyncGibbs {
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        self.snapshot.clear();
        self.snapshot.extend_from_slice(states);
        // Vars ascending, one draw per chain per var — exactly the
        // order each scalar chain consumes its stream.
        for i in 0..model.num_vars() {
            let s = model.num_states(i);
            model.local_energies_batch(&self.snapshot, k, i, &mut self.e, &mut self.scratch);
            self.out.resize(k, 0);
            self.sampler.sample_batch(&self.e, s, betas, rngs, &mut self.out);
            states[i * k..(i + 1) * k].copy_from_slice(&self.out);
            let mut cost = model.update_cost(i);
            cost.ops += self.sampler.ops_per_sample(s);
            for st in stats.iter_mut() {
                st.updates += 1;
                st.accepted += 1;
                st.cost.add(cost);
            }
        }
    }

    fn name(&self) -> &'static str {
        "AG"
    }
}

/// Batched Path Auxiliary Sampler. The expensive part of a PAS step —
/// the full `O(N · card)` move-weight build at the path head — runs
/// batched: one K-wide conditional-energy build per variable fills all
/// K chains' weight tables, amortizing the neighbor-index walk exactly
/// like the Gibbs kernels. The path construction and MH correction
/// that follow are inherently per chain (data-dependent path lengths
/// and move sequences), so each chain then runs
/// `PathAuxiliarySampler::step_prepared` on its gathered state.
///
/// The head build draws no randomness, so chain `c`'s RNG stream is
/// consumed in exactly the scalar order — trajectories stay
/// bit-identical to scalar PAS chains.
pub struct BatchPas {
    path_len: usize,
    /// One weight table per chain (weights are state-dependent, so
    /// they cannot be shared).
    per_chain: Vec<PathAuxiliarySampler>,
    e: Vec<f32>,
    scratch: BatchScratch,
    /// Gather buffer for one chain's assignment.
    x: Vec<u32>,
}

impl BatchPas {
    /// Batched PAS kernel flipping `path_len` sites per step.
    pub fn new(path_len: usize) -> BatchPas {
        assert!(path_len >= 1);
        BatchPas {
            path_len,
            per_chain: Vec::new(),
            e: Vec::new(),
            scratch: BatchScratch::default(),
            x: Vec::new(),
        }
    }
}

impl BatchMcmc for BatchPas {
    fn step_batch(
        &mut self,
        model: &dyn EnergyModel,
        states: &mut [u32],
        k: usize,
        betas: &[f32],
        rngs: &mut [Rng],
        stats: &mut [StepStats],
    ) {
        let n = model.num_vars();
        if self.per_chain.len() != k {
            self.per_chain = (0..k)
                .map(|_| PathAuxiliarySampler::new(self.path_len))
                .collect();
        }
        for p in self.per_chain.iter_mut() {
            p.ensure_layout(model);
        }
        // Batched path-head build: one K-wide energy build per var
        // serves every chain's weight table.
        for j in 0..n {
            model.local_energies_batch(states, k, j, &mut self.e, &mut self.scratch);
            for (c, p) in self.per_chain.iter_mut().enumerate() {
                p.load_weights_for_var(j, &self.e, k, c, states[j * k + c], betas[c]);
            }
        }
        // Per-chain path + MH correction on gathered state.
        for c in 0..k {
            self.x.clear();
            self.x.extend(states[c..].iter().step_by(k).copied());
            let st = self.per_chain[c].step_prepared(model, &mut self.x, betas[c], &mut rngs[c]);
            for (i, &v) in self.x.iter().enumerate() {
                states[i * k + c] = v;
            }
            stats[c].add(&st);
        }
    }

    fn name(&self) -> &'static str {
        "PAS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PottsGrid;
    use crate::mcmc::{build_algo, Chain};

    /// Batched kernels must reproduce the scalar chains bit-for-bit:
    /// same states, same best-so-far, same RV-0 marginals.
    fn assert_matches_scalar(algo_kind: AlgoKind, sampler: SamplerKind, steps: usize) {
        assert_matches_scalar_flips(algo_kind, sampler, steps, 1);
    }

    fn assert_matches_scalar_flips(
        algo_kind: AlgoKind,
        sampler: SamplerKind,
        steps: usize,
        flips: usize,
    ) {
        let m = PottsGrid::new(6, 5, 3, 0.8);
        let (seed, k) = (0xBA7C4u64, 5usize);

        let mut batch = ChainBatch::new(&m, BetaSchedule::Constant(0.9), seed, 0, k, None);
        let mut batch_algo =
            build_batch_algo(algo_kind, sampler, &m, flips).expect("batched kernel");
        batch.run(&mut *batch_algo, steps);

        let mut gathered = Vec::new();
        for c in 0..k {
            let algo = build_algo(algo_kind, sampler, &m, flips);
            let mut chain =
                Chain::with_rng(&m, algo, BetaSchedule::Constant(0.9), Rng::fork(seed, c as u64));
            chain.run(steps);
            batch.chain_state(c, &mut gathered);
            assert_eq!(gathered, chain.x, "{algo_kind:?} chain {c}: states diverge");
            assert_eq!(
                batch.best_objectives[c], chain.best_objective,
                "{algo_kind:?} chain {c}: best objective diverges"
            );
            assert_eq!(
                batch.best_state(c),
                chain.best_assignment(),
                "{algo_kind:?} chain {c}: best assignment diverges"
            );
            assert_eq!(
                batch.marginal0(c),
                chain.marginal(0),
                "{algo_kind:?} chain {c}: marginal diverges"
            );
            assert_eq!(batch.stats[c].updates, chain.stats.updates);
            assert_eq!(batch.stats[c].accepted, chain.stats.accepted);
        }
    }

    #[test]
    fn batched_gibbs_is_bit_identical_to_scalar() {
        assert_matches_scalar(AlgoKind::Gibbs, SamplerKind::Gumbel, 25);
        assert_matches_scalar(AlgoKind::Gibbs, SamplerKind::Cdf, 25);
    }

    #[test]
    fn batched_block_gibbs_is_bit_identical_to_scalar() {
        assert_matches_scalar(AlgoKind::BlockGibbs, SamplerKind::Gumbel, 25);
        assert_matches_scalar(
            AlgoKind::BlockGibbs,
            SamplerKind::GumbelLut { size: 16, bits: 8 },
            25,
        );
    }

    #[test]
    fn batched_mh_is_bit_identical_to_scalar() {
        assert_matches_scalar(AlgoKind::Mh, SamplerKind::Gumbel, 25);
    }

    #[test]
    fn init_state_keeps_streams_aligned() {
        let m = PottsGrid::new(4, 4, 2, 0.5);
        let x0 = vec![1u32; 16];
        let mut batch = ChainBatch::new(&m, BetaSchedule::Constant(1.0), 3, 0, 3, Some(&x0));
        let mut algo = build_batch_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1).unwrap();
        batch.run(&mut *algo, 10);
        let mut gathered = Vec::new();
        for c in 0..3 {
            let scalar = build_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1);
            let mut chain =
                Chain::with_rng(&m, scalar, BetaSchedule::Constant(1.0), Rng::fork(3, c as u64));
            chain.set_state(&x0);
            chain.run(10);
            batch.chain_state(c, &mut gathered);
            assert_eq!(gathered, chain.x, "chain {c}");
        }
    }

    #[test]
    fn uniform_beta_path_is_identical_via_per_chain_entry_point() {
        // Regression pin for the `step_with` refactor: feeding the
        // per-chain entry point a uniform β vector must reproduce the
        // uniform `run` path bit-for-bit.
        let m = PottsGrid::new(5, 4, 3, 0.7);
        let (seed, k, steps) = (0x5EEDu64, 4usize, 20usize);
        let mut uniform = ChainBatch::new(&m, BetaSchedule::Constant(0.8), seed, 0, k, None);
        let mut a1 = build_batch_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1).unwrap();
        uniform.run(&mut *a1, steps);
        let mut per_chain = ChainBatch::new(&m, BetaSchedule::Constant(0.8), seed, 0, k, None);
        let mut a2 = build_batch_algo(AlgoKind::Gibbs, SamplerKind::Gumbel, &m, 1).unwrap();
        per_chain.run_betas_per_chain(&mut *a2, &[0.8; 4], steps);
        let (mut ga, mut gb) = (Vec::new(), Vec::new());
        for c in 0..k {
            uniform.chain_state(c, &mut ga);
            per_chain.chain_state(c, &mut gb);
            assert_eq!(ga, gb, "chain {c}: states diverge");
            assert_eq!(uniform.best_objectives[c], per_chain.best_objectives[c]);
            assert_eq!(uniform.marginal0(c), per_chain.marginal0(c));
        }
    }

    #[test]
    fn per_chain_betas_match_scalar_chains_at_their_own_beta() {
        // True per-chain β: chain c of the batch held at betas[c] must
        // be bit-identical to a scalar chain running Constant(betas[c]).
        let m = PottsGrid::new(5, 5, 2, 0.6);
        let (seed, steps) = (0xB17Au64, 25usize);
        let betas = [0.25f32, 0.5, 1.0, 2.0];
        for (algo_kind, sampler) in [
            (AlgoKind::Gibbs, SamplerKind::Gumbel),
            (AlgoKind::BlockGibbs, SamplerKind::Cdf),
            (AlgoKind::Mh, SamplerKind::Gumbel),
            (AlgoKind::AsyncGibbs, SamplerKind::Gumbel),
            (AlgoKind::Pas, SamplerKind::Gumbel),
        ] {
            let mut batch =
                ChainBatch::new(&m, BetaSchedule::Constant(1.0), seed, 0, betas.len(), None);
            let mut algo = build_batch_algo(algo_kind, sampler, &m, 2).unwrap();
            batch.run_betas_per_chain(&mut *algo, &betas, steps);
            let mut gathered = Vec::new();
            for (c, &beta) in betas.iter().enumerate() {
                let scalar = build_algo(algo_kind, sampler, &m, 2);
                let mut chain = Chain::with_rng(
                    &m,
                    scalar,
                    BetaSchedule::Constant(beta),
                    Rng::fork(seed, c as u64),
                );
                chain.run(steps);
                batch.chain_state(c, &mut gathered);
                assert_eq!(gathered, chain.x, "{algo_kind:?} chain {c} at β={beta}");
                assert_eq!(batch.best_objectives[c], chain.best_objective);
                assert_eq!(batch.marginal0(c), chain.marginal(0));
            }
        }
    }

    #[test]
    fn batched_async_gibbs_is_bit_identical_to_scalar() {
        assert_matches_scalar(AlgoKind::AsyncGibbs, SamplerKind::Gumbel, 25);
        assert_matches_scalar(AlgoKind::AsyncGibbs, SamplerKind::Cdf, 25);
    }

    #[test]
    fn batched_pas_is_bit_identical_to_scalar() {
        assert_matches_scalar_flips(AlgoKind::Pas, SamplerKind::Gumbel, 15, 1);
        assert_matches_scalar_flips(AlgoKind::Pas, SamplerKind::Gumbel, 15, 3);
    }

    #[test]
    fn every_algorithm_has_a_batched_kernel() {
        // PR 2 shipped without batched PAS / Async Gibbs; this pin
        // replaced its negative twin when those kernels landed.
        let m = PottsGrid::new(3, 3, 2, 0.5);
        for kind in [
            AlgoKind::Gibbs,
            AlgoKind::BlockGibbs,
            AlgoKind::Mh,
            AlgoKind::AsyncGibbs,
            AlgoKind::Pas,
        ] {
            assert!(batch_supported(kind), "{kind:?}");
            assert!(
                build_batch_algo(kind, SamplerKind::Gumbel, &m, 2).is_some(),
                "{kind:?}"
            );
        }
    }
}
