//! Gibbs-family kernels: sequential Gibbs, Block Gibbs over a graph
//! coloring, and asynchronous (hogwild) Gibbs (§II-A, Fig. 4).

use super::sampler::CategoricalSampler;
use super::{Mcmc, StepStats};
use crate::energy::EnergyModel;
use crate::graph::{color_greedy, Coloring};
use crate::rng::Rng;

/// Sequential single-site Gibbs: one step = one systematic sweep; each
/// RV is resampled from its full conditional (accept ratio ≡ 1).
pub struct Gibbs {
    sampler: Box<dyn CategoricalSampler>,
    scratch: Vec<f32>,
}

impl Gibbs {
    /// Gibbs kernel backed by `sampler`.
    pub fn new(sampler: Box<dyn CategoricalSampler>) -> Gibbs {
        Gibbs {
            sampler,
            scratch: Vec::new(),
        }
    }
}

impl Mcmc for Gibbs {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        let mut stats = StepStats::default();
        for i in 0..model.num_vars() {
            model.local_energies(x, i, &mut self.scratch);
            x[i] = self.sampler.sample(&self.scratch, beta, rng) as u32;
            stats.updates += 1;
            stats.accepted += 1;
            let mut c = model.update_cost(i);
            c.ops += self.sampler.ops_per_sample(self.scratch.len());
            stats.cost.add(c);
        }
        stats
    }

    fn name(&self) -> &'static str {
        "Gibbs"
    }
}

/// Block Gibbs: RVs grouped by a proper coloring of the interaction
/// graph; one step sweeps the color classes, resampling every RV of a
/// class against the frozen state of the others. Within a class the
/// updates are conditionally independent — exactly the RV-level
/// parallelism the accelerator exploits (Fig. 4, Fig. 10a/b).
pub struct BlockGibbs {
    sampler: Box<dyn CategoricalSampler>,
    blocks: Vec<Vec<u32>>,
    scratch: Vec<f32>,
}

impl BlockGibbs {
    /// Build by coloring `model`'s interaction graph greedily.
    pub fn new(sampler: Box<dyn CategoricalSampler>, model: &dyn EnergyModel) -> BlockGibbs {
        let coloring = color_greedy(model.interaction());
        BlockGibbs {
            sampler,
            blocks: coloring.blocks(),
            scratch: Vec::new(),
        }
    }

    /// Build from an explicit coloring (tests / compiler reuse).
    pub fn with_coloring(sampler: Box<dyn CategoricalSampler>, coloring: &Coloring) -> BlockGibbs {
        BlockGibbs {
            sampler,
            blocks: coloring.blocks(),
            scratch: Vec::new(),
        }
    }

    /// The conditional-independence blocks (color classes).
    pub fn blocks(&self) -> &[Vec<u32>] {
        &self.blocks
    }

    /// Maximum RV-level parallelism this model admits (largest block).
    pub fn max_parallelism(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).max().unwrap_or(0)
    }
}

impl Mcmc for BlockGibbs {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        let mut stats = StepStats::default();
        for block in &self.blocks {
            // All RVs in a block share no edges, so resampling them
            // sequentially here is semantically identical to a parallel
            // hardware update: none of them reads another's fresh value.
            for &iu in block {
                let i = iu as usize;
                model.local_energies(x, i, &mut self.scratch);
                x[i] = self.sampler.sample(&self.scratch, beta, rng) as u32;
                stats.updates += 1;
                stats.accepted += 1;
                let mut c = model.update_cost(i);
                c.ops += self.sampler.ops_per_sample(self.scratch.len());
                stats.cost.add(c);
            }
        }
        stats
    }

    fn name(&self) -> &'static str {
        "BG"
    }
}

/// Asynchronous Gibbs: every RV resampled in the same step against a
/// *snapshot* of the previous state (hogwild). Fastest per-step wall
/// clock, but the non-Markovian update can hurt convergence (§II-A).
pub struct AsyncGibbs {
    sampler: Box<dyn CategoricalSampler>,
    scratch: Vec<f32>,
    snapshot: Vec<u32>,
}

impl AsyncGibbs {
    /// Async-Gibbs kernel backed by `sampler`.
    pub fn new(sampler: Box<dyn CategoricalSampler>) -> AsyncGibbs {
        AsyncGibbs {
            sampler,
            scratch: Vec::new(),
            snapshot: Vec::new(),
        }
    }
}

impl Mcmc for AsyncGibbs {
    fn step(
        &mut self,
        model: &dyn EnergyModel,
        x: &mut [u32],
        beta: f32,
        rng: &mut Rng,
    ) -> StepStats {
        let mut stats = StepStats::default();
        self.snapshot.clear();
        self.snapshot.extend_from_slice(x);
        for i in 0..model.num_vars() {
            model.local_energies(&self.snapshot, i, &mut self.scratch);
            x[i] = self.sampler.sample(&self.scratch, beta, rng) as u32;
            stats.updates += 1;
            stats.accepted += 1;
            let mut c = model.update_cost(i);
            c.ops += self.sampler.ops_per_sample(self.scratch.len());
            stats.cost.add(c);
        }
        stats
    }

    fn name(&self) -> &'static str {
        "AG"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::{BayesNet, Cpt, EnergyModel, PottsGrid};
    use crate::mcmc::sampler::{CdfSampler, GumbelSampler};
    use crate::mcmc::{BetaSchedule, Chain};

    fn two_node_net() -> BayesNet {
        // A -> B with strong correlation.
        let a = Cpt {
            parents: vec![],
            card: 2,
            table: vec![0.7, 0.3],
        };
        let b = Cpt {
            parents: vec![0],
            card: 2,
            table: vec![0.9, 0.1, 0.2, 0.8],
        };
        BayesNet::new("ab", vec![a, b])
    }

    /// Gibbs histograms must converge to the exact marginals — the core
    /// statistical correctness test for the whole sampling stack.
    #[test]
    fn gibbs_marginals_match_exact() {
        let net = two_node_net();
        let exact = net.exact_marginal(1);
        let algo = Box::new(Gibbs::new(Box::new(GumbelSampler::default())));
        let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 11);
        chain.run(60_000);
        let emp = chain.marginal(1);
        assert!(
            (emp[1] - exact[1]).abs() < 0.01,
            "empirical={emp:?} exact={exact:?}"
        );
    }

    #[test]
    fn cdf_and_gumbel_agree_statistically() {
        let net = two_node_net();
        let run = |sampler: Box<dyn CategoricalSampler>, seed| {
            let algo = Box::new(Gibbs::new(sampler));
            let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), seed);
            chain.run(40_000);
            chain.marginal(0)[1]
        };
        let a = run(Box::new(CdfSampler), 1);
        let b = run(Box::new(GumbelSampler::default()), 2);
        assert!((a - b).abs() < 0.015, "cdf={a} gumbel={b}");
    }

    #[test]
    fn block_gibbs_blocks_are_independent_sets() {
        let m = PottsGrid::new(6, 6, 2, 1.0);
        let bg = BlockGibbs::new(Box::new(GumbelSampler::default()), &m);
        let g = m.interaction();
        for block in bg.blocks() {
            for (a, &i) in block.iter().enumerate() {
                for &j in &block[a + 1..] {
                    assert!(!g.has_edge(i as usize, j as usize));
                }
            }
        }
        // Chessboard: exactly 2 blocks of 18.
        assert_eq!(bg.blocks().len(), 2);
        assert_eq!(bg.max_parallelism(), 18);
    }

    #[test]
    fn block_gibbs_marginals_match_exact() {
        let net = two_node_net();
        let algo = Box::new(BlockGibbs::new(Box::new(GumbelSampler::default()), &net));
        let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 17);
        chain.run(60_000);
        let exact = net.exact_marginal(0);
        let emp = chain.marginal(0);
        assert!((emp[1] - exact[1]).abs() < 0.01);
    }

    #[test]
    fn async_gibbs_runs_and_mixes_roughly() {
        let net = two_node_net();
        let algo = Box::new(AsyncGibbs::new(Box::new(GumbelSampler::default())));
        let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 23);
        chain.run(60_000);
        // AG is biased on strongly-coupled pairs but must stay in the
        // right ballpark on this mild net.
        let exact = net.exact_marginal(0);
        let emp = chain.marginal(0);
        assert!((emp[1] - exact[1]).abs() < 0.05, "emp={emp:?} exact={exact:?}");
    }

    #[test]
    fn gibbs_never_moves_clamped_evidence() {
        let mut net = two_node_net();
        net.set_evidence(0, 1);
        let algo = Box::new(Gibbs::new(Box::new(GumbelSampler::default())));
        let mut chain = Chain::new(&net, algo, BetaSchedule::Constant(1.0), 31);
        // Force evidence into the initial state, then check it never moves.
        chain.x[0] = 1;
        chain.run(2_000);
        assert_eq!(chain.marginal(0)[1], 1.0);
    }
}
